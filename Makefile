# Sparse Upcycling reproduction — build/verify entry points.
#
# `make verify` mirrors .github/workflows/ci.yml exactly: if it is green
# here, CI is green.

.PHONY: verify build test test-release docs bench-compile bench-json bench-gate bench-baseline \
        check-features kernel-props fmt fmt-check clippy quickstart mesh-smoke serve-smoke \
        chaos-smoke strategy-smoke serving-load-smoke sweep-smoke artifacts clean

verify: build test test-release fmt-check clippy docs bench-compile bench-json bench-gate \
        check-features kernel-props quickstart mesh-smoke serve-smoke chaos-smoke \
        strategy-smoke serving-load-smoke sweep-smoke

build:
	cargo build --release

test:
	cargo test -q

# Release-profile tests: the chaos fault sweep (tests/chaos.rs) is
# debug-ignored and runs here, under the same profile as the bench gate.
test-release:
	cargo test --release -q

bench-compile:
	cargo bench --no-run

# The runtime baseline CI uploads as a build artifact (docs/BENCHMARKS.md).
bench-json:
	cargo bench --bench runtime_step -- --quick

# Fail on tokens/s or p50 regression vs the committed baseline (same
# tolerance CI uses; see docs/BENCHMARKS.md for the refresh procedure).
# Depends on bench-json so `make -j verify` can never gate a stale report.
bench-gate: bench-json
	cargo run --release -- bench-gate --baseline BENCH_baseline.json \
	  --current rust/BENCH_runtime.json --tolerance-pct 50

# Refresh the committed baseline from a fresh --quick run on this machine.
bench-baseline: bench-json
	cargo run --release -- bench-gate --baseline BENCH_baseline.json \
	  --current rust/BENCH_runtime.json --update-baseline

# Feature matrix: the off-by-default PJRT stub, the no-default build and
# the AVX2+FMA simd feature must keep compiling even though none of them
# is exercised by default tests.
check-features:
	cargo check -p sparse-upcycle --all-targets --features pjrt
	cargo check -p sparse-upcycle --all-targets --no-default-features
	cargo check -p sparse-upcycle --all-targets --features simd

# Kernel oracle suite (tests/kernel_props.rs): every fast GEMM tier —
# blocked, SIMD, and the fused bf16/int8 kernels — held to
# gemm::reference over the randomized shape grid, plus the e2e
# quantized-inference agreement floors. Release profile (the grid is
# heavy in debug), run with the simd feature off *and* on so both
# resolved implementations of the SIMD tier gate (they differ in FMA
# rounding; each must hold the oracle bound and its own bitwise
# determinism contracts).
kernel-props:
	cargo test -p sparse-upcycle --release -q --test kernel_props
	cargo test -p sparse-upcycle --release -q --test kernel_props --features simd

fmt:
	cargo fmt --all

fmt-check:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

quickstart:
	cargo run --release -- quickstart --pretrain-steps 30 --extra-steps 5

# Blocking docs gate (mirrors the CI docs job): rustdoc must be
# warning-clean, every relative markdown link in README + docs/*.md must
# resolve, and no fenced example may use a deprecated CLI flag.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p sparse-upcycle --lib
	cargo run --release -- check-docs

# End-to-end expert parallelism: 2x2 mesh, experts sharded across EP
# ranks, all-to-all overlapped with expert compute (2 microbatches).
mesh-smoke:
	cargo run --release -- train --model lm_tiny_moe_e8_c2 \
	  --topology dp=2,ep=2 --microbatches 2 --steps 10

# Fault tolerance: the elastic CLI path end-to-end — snapshot rotation,
# injected mid-step rank kill, rollback + replay (docs/RESILIENCE.md; exits
# nonzero if no recovery happened). The bitwise-recovery *assertion*
# (tests/chaos.rs) already runs under `make test-release`, so this target
# does not repeat it.
# The fault lands in the `exchange` phase — inside the split-phase
# all-to-all window — with the overlapped (2-microbatch) pipeline active.
chaos-smoke:
	cargo run --release -- train --model lm_tiny_moe_e8_c2 \
	  --topology dp=1,ep=2 --microbatches 2 --steps 6 \
	  --snapshot-every 2 --inject-fault 1:4:exchange

# Strategy-matrix smoke: two differently-seeded dense parents → one
# surgery per upcycle strategy (replicate / drop-upcycle / split /
# multi-checkpoint) → 2 continued-training steps each under expert
# parallelism. `train` exits nonzero on a non-finite final loss, so every
# leg is a real assertion (docs/UPCYCLING.md).
strategy-smoke:
	cargo run --release -- train --model lm_tiny_dense --steps 10 \
	  --save results/checkpoints/smoke_parent_a.supc
	cargo run --release -- train --model lm_tiny_dense --steps 10 --seed 21 \
	  --save results/checkpoints/smoke_parent_b.supc
	cargo run --release -- upcycle --dense results/checkpoints/smoke_parent_a.supc \
	  --model lm_tiny_moe_e8_c2 --out-ck results/checkpoints/smoke_replicate.supc
	cargo run --release -- upcycle --dense results/checkpoints/smoke_parent_a.supc \
	  --model lm_tiny_moe_e8_c2 --strategy drop-upcycle --reinit-fraction 0.25 \
	  --diversity --out-ck results/checkpoints/smoke_drop.supc
	cargo run --release -- upcycle --dense results/checkpoints/smoke_parent_a.supc \
	  --model lm_tiny_moe_split_g2e8 --strategy split --granularity 2 --expansion 4 \
	  --out-ck results/checkpoints/smoke_split.supc
	cargo run --release -- upcycle --dense results/checkpoints/smoke_parent_a.supc \
	  --model lm_tiny_moe_e8_c2 --strategy multi-checkpoint \
	  --checkpoints results/checkpoints/smoke_parent_b.supc --shared average \
	  --diversity --out-ck results/checkpoints/smoke_multi.supc
	cargo run --release -- train --model lm_tiny_moe_e8_c2 --steps 2 \
	  --topology dp=1,ep=2 --load results/checkpoints/smoke_replicate.supc
	cargo run --release -- train --model lm_tiny_moe_e8_c2 --steps 2 \
	  --topology dp=1,ep=2 --load results/checkpoints/smoke_drop.supc
	cargo run --release -- train --model lm_tiny_moe_split_g2e8 --steps 2 \
	  --topology dp=1,ep=2 --load results/checkpoints/smoke_split.supc
	cargo run --release -- train --model lm_tiny_moe_e8_c2 --steps 2 \
	  --topology dp=1,ep=2 --load results/checkpoints/smoke_multi.supc

# End-to-end serving: train → one-file checkpoint bundle → continuous-
# batching inference engine (docs/SERVING.md).
serve-smoke:
	cargo run --release -- train --model lm_tiny_moe_e8_c2 --steps 10 \
	  --save results/checkpoints/serve_smoke.supc
	cargo run --release -- serve --load results/checkpoints/serve_smoke.supc --requests 16

# Serving-load smoke: one bursty multi-tenant trace through every
# scheduler policy under a bounded queue (docs/SERVING.md). `serve` exits
# nonzero if any request is silently lost — completions + named sheds must
# cover the whole trace — so every leg asserts the no-silent-drop
# contract, not just liveness.
serving-load-smoke:
	cargo run --release -- train --model lm_tiny_moe_e8_c2 --steps 10 \
	  --save results/checkpoints/serving_load_smoke.supc
	cargo run --release -- serve --load results/checkpoints/serving_load_smoke.supc \
	  --requests 32 --traffic bursty --tenants 4 --serve policy=fifo,queue=8
	cargo run --release -- serve --load results/checkpoints/serving_load_smoke.supc \
	  --requests 32 --traffic bursty --tenants 4 --serve policy=priority,queue=8,floor=10000
	cargo run --release -- serve --load results/checkpoints/serving_load_smoke.supc \
	  --requests 32 --traffic bursty --tenants 4 --serve policy=fair,queue=8,shed=evict
	cargo run --release -- serve --load results/checkpoints/serving_load_smoke.supc \
	  --requests 32 --traffic bursty --tenants 4 --serve policy=slo,queue=8,slo=20000

# Scaling-law sweep smoke: a tiny 2x2 grid (experts x budget) through the
# concurrent scheduler on 2 cores, then `sweep fit` over the results store
# (docs/SWEEPS.md). `sweep` exits nonzero on any missing/failed leg and
# `sweep fit` re-checks completeness and refuses non-finite fits, so both
# legs are real assertions, not liveness checks.
sweep-smoke:
	cargo run --release -- sweep \
	  --sweep sunk=10,experts=2+8,budget=4+8,eval=4 --cores 2 \
	  --results results/SWEEP_smoke.json
	cargo run --release -- sweep fit --results results/SWEEP_smoke.json

# AOT artifacts for the PJRT backend (requires the Python toolchain; not
# needed for the default native build). Written under rust/ because cargo
# runs test binaries with the package dir as cwd.
artifacts:
	python3 -m python.compile.aot --out rust/artifacts

clean:
	cargo clean
	rm -rf results rust/BENCH_runtime.json
