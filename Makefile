# Sparse Upcycling reproduction — build/verify entry points.
#
# `make verify` mirrors .github/workflows/ci.yml exactly: if it is green
# here, CI is green.

.PHONY: verify build test bench-compile fmt fmt-check clippy quickstart artifacts clean

verify: build test fmt-check clippy bench-compile quickstart

build:
	cargo build --release

test:
	cargo test -q

bench-compile:
	cargo bench --no-run

fmt:
	cargo fmt --all

# Advisory (matching the CI rustfmt step): the tree was authored offline
# without rustfmt; drop the leading `-` together with CI's
# continue-on-error once a `cargo fmt` pass is committed.
fmt-check:
	-cargo fmt --all -- --check

# Advisory, mirroring CI's continue-on-error on the clippy step; drop the
# `-` together with CI's once the lint run is clean.
clippy:
	-cargo clippy --all-targets -- -D warnings

quickstart:
	cargo run --release -- quickstart --pretrain-steps 30 --extra-steps 5

# AOT artifacts for the PJRT backend (requires the Python toolchain; not
# needed for the default native build). Written under rust/ because cargo
# runs test binaries with the package dir as cwd.
artifacts:
	python3 -m python.compile.aot --out rust/artifacts

clean:
	cargo clean
	rm -rf results
