# Sparse Upcycling reproduction — build/verify entry points.
#
# `make verify` mirrors .github/workflows/ci.yml exactly: if it is green
# here, CI is green.

.PHONY: verify build test bench-compile bench-json fmt fmt-check clippy quickstart artifacts clean

verify: build test fmt-check clippy bench-compile bench-json quickstart

build:
	cargo build --release

test:
	cargo test -q

bench-compile:
	cargo bench --no-run

# The runtime baseline CI uploads as a build artifact (docs/BENCHMARKS.md).
bench-json:
	cargo bench --bench runtime_step -- --quick

fmt:
	cargo fmt --all

fmt-check:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

quickstart:
	cargo run --release -- quickstart --pretrain-steps 30 --extra-steps 5

# AOT artifacts for the PJRT backend (requires the Python toolchain; not
# needed for the default native build). Written under rust/ because cargo
# runs test binaries with the package dir as cwd.
artifacts:
	python3 -m python.compile.aot --out rust/artifacts

clean:
	cargo clean
	rm -rf results rust/BENCH_runtime.json
