"""Make `compile.*` importable whether pytest runs from python/ or the repo
root (the Makefile uses python/, the top-level capture command uses the
root)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
