"""L2 model tests: shapes, routing invariants, MoE mechanics, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS, MoeSpec, ModelConfig


def lm_batch(cfg, rng, uniform_mask=True):
    b = dict(
        enc_tokens=jnp.asarray(
            rng.integers(1, cfg.vocab_size - 16, (cfg.batch_size, cfg.enc_len)), jnp.int32),
        dec_tokens=jnp.asarray(
            rng.integers(1, cfg.vocab_size - 16, (cfg.batch_size, cfg.dec_len)), jnp.int32),
        targets=jnp.asarray(
            rng.integers(1, cfg.vocab_size - 16, (cfg.batch_size, cfg.dec_len)), jnp.int32),
        loss_mask=jnp.ones((cfg.batch_size, cfg.dec_len), jnp.float32),
    )
    if not uniform_mask:
        m = np.ones((cfg.batch_size, cfg.dec_len), np.float32)
        m[:, cfg.dec_len // 2:] = 0.0
        b["loss_mask"] = jnp.asarray(m)
    return b


def vit_batch(cfg, rng):
    return dict(
        images=jnp.asarray(
            rng.random((cfg.batch_size, cfg.image_size, cfg.image_size, 3)), jnp.float32),
        labels=jnp.asarray(rng.integers(0, cfg.num_classes, (cfg.batch_size,)), jnp.int32),
    )


@pytest.mark.parametrize("name", [
    "lm_tiny_dense", "lm_tiny_moe_e8_c2", "lm_tiny_moe_e8_c2_top1",
    "lm_tiny_moe_e8_c2_top2bpr", "vit_tiny_dense", "vit_tiny_moe_e8_c2",
])
def test_forward_shapes_and_finiteness(name):
    cfg = CONFIGS[name]
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    if cfg.family == "lm":
        logits, aux = model.lm_forward(cfg, params, lm_batch(cfg, rng)["enc_tokens"],
                                       lm_batch(cfg, rng)["dec_tokens"])
        assert logits.shape == (cfg.batch_size, cfg.dec_len, cfg.vocab_size)
    else:
        logits, aux = model.vit_forward(cfg, params, vit_batch(cfg, rng)["images"])
        assert logits.shape == (cfg.batch_size, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert 0.0 <= float(aux["coverage"]) <= 1.0


def test_param_specs_sorted_unique_and_complete():
    for name in ["lm_tiny_dense", "lm_tiny_moe_e8_c2", "vit_tiny_moe_e8_c2"]:
        cfg = CONFIGS[name]
        specs = model.param_specs(cfg)
        names = [s["name"] for s in specs]
        assert names == sorted(names)
        assert len(names) == len(set(names))
        params = model.init_params(cfg, 0)
        assert set(params.keys()) == set(names)
        for s in specs:
            assert params[s["name"]].shape == tuple(s["shape"])


def test_moe_layer_count_matches_config():
    cfg = CONFIGS["lm_tiny_moe_e8_c2"]
    specs = model.param_specs(cfg)
    routers = [s for s in specs if "moe/router" in s["name"]]
    # every-other on 4 enc + 4 dec layers = 2 + 2 MoE layers.
    assert len(routers) == 4
    enc_routers = [s for s in routers if s["name"].startswith("enc/")]
    assert {s["name"].split("/")[1] for s in enc_routers} == {"block_01", "block_03"}


def test_expert_choice_is_perfectly_load_balanced():
    """EC dispatches exactly c = g*C/E tokens to every expert."""
    cfg = CONFIGS["lm_tiny_moe_e8_c2"]
    spec = cfg.enc_moe
    rng = np.random.default_rng(1)
    g, d = 64, cfg.d_model
    xg = jnp.asarray(rng.standard_normal((1, g, d)), jnp.float32)
    probs = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((1, g, spec.num_experts)), jnp.float32), -1)
    wi = jnp.asarray(rng.standard_normal(
        (spec.num_experts, d, cfg.d_ff)) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.standard_normal(
        (spec.num_experts, cfg.d_ff, d)) * 0.05, jnp.float32)
    out, aux = model._expert_choice(cfg, spec, xg, probs, wi, wo)
    assert out.shape == (1, g, d)
    # c = g*C/E = 64*2/8 = 16 per expert ⇒ 8*16 = 128 dispatches over 64
    # tokens ⇒ mean 2 experts per token; coverage < 1 possible but high.
    assert float(aux["coverage"]) > 0.7


def test_top_k_capacity_is_never_exceeded():
    """Token-choice dispatch: each expert's buffer ≤ cap, weights in [0,1]."""
    cfg = CONFIGS["lm_tiny_moe_e8_c2_top1"]
    spec = cfg.enc_moe
    rng = np.random.default_rng(2)
    g, d, e = 32, cfg.d_model, spec.num_experts
    xg = jnp.asarray(rng.standard_normal((2, g, d)), jnp.float32)
    # Adversarially skewed router: everyone wants expert 0.
    logits = np.zeros((2, g, e), np.float32)
    logits[..., 0] = 10.0
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    wi = jnp.asarray(rng.standard_normal((e, d, cfg.d_ff)) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((e, cfg.d_ff, d)) * 0.05, jnp.float32)
    out, aux = model._top_k(cfg, spec, xg, probs, wi, wo)
    assert out.shape == (2, g, d)
    # cap = g*C*K/E = 32*2*1/8 = 8 ⇒ at most 8 of 32 tokens reach expert 0;
    # the rest are dropped ⇒ coverage ≈ 8/32.
    cov = float(aux["coverage"])
    assert cov <= 0.27, f"capacity must drop overflow tokens, coverage={cov}"
    assert float(aux["aux_loss"]) > 0.0, "skew must produce load-balance loss"


def test_bpr_keeps_high_confidence_tokens():
    """With BPR, kept tokens are the highest-probability ones."""
    cfg = CONFIGS["lm_tiny_moe_e8_c2_top2bpr"]
    spec = cfg.enc_moe
    assert spec.bpr
    rng = np.random.default_rng(3)
    g, d, e = 32, cfg.d_model, spec.num_experts
    xg = jnp.asarray(rng.standard_normal((1, g, d)), jnp.float32)
    logits = np.zeros((1, g, e), np.float32)
    logits[..., 0] = np.linspace(1.0, 5.0, g)  # later tokens more confident
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    wi = jnp.ones((e, d, cfg.d_ff), jnp.float32) * 0.01
    wo = jnp.ones((e, cfg.d_ff, d), jnp.float32) * 0.01
    out_bpr, _ = model._top_k(cfg, spec, xg, probs, wi, wo)
    # Without BPR (position order) the *early* tokens survive instead.
    spec_nobpr = MoeSpec(**{**spec.__dict__, "bpr": False})
    out_pos, _ = model._top_k(cfg, spec_nobpr, xg, probs, wi, wo)
    # Expert-0 buffer differs between the two fill orders.
    assert not np.allclose(np.asarray(out_bpr), np.asarray(out_pos))
    # BPR favors the high-confidence tail: the last tokens must be routed.
    tail = np.abs(np.asarray(out_bpr)[0, -4:]).sum()
    assert tail > 0.0


def test_renormalization_weights_sum_to_one():
    """With renormalize=True, the combine weights of every routed token sum
    to 1 — checked indirectly via an experts-as-identity trick."""
    cfg = CONFIGS["lm_tiny_moe_e8_c2_renorm"]
    spec = cfg.enc_moe
    assert spec.renormalize
    rng = np.random.default_rng(4)
    g, d, e = 32, cfg.d_model, spec.num_experts
    xg = jnp.asarray(rng.standard_normal((1, g, d)), jnp.float32)
    probs = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((1, g, e)), jnp.float32), -1)
    # Identity-ish experts: wi/wo chosen so expert(x) == const vector 1.
    wi = jnp.zeros((e, d, cfg.d_ff), jnp.float32)
    wo = jnp.zeros((e, cfg.d_ff, d), jnp.float32)
    out, aux = model._expert_choice(cfg, spec, xg, probs, wi, wo)
    # gelu(0) = 0, so expert output is 0 — switch to checking the
    # renormalized scatter weights via ones-experts instead:
    ones_out = jnp.ones((1, g, d), jnp.float32)

    def combine_only(vals):
        return vals

    # Direct check: run EC with experts replaced by identity via monkeypatch.
    orig = model._run_experts
    try:
        model._run_experts = lambda _cfg, x_e, _wi, _wo: jnp.ones_like(x_e)
        out, aux = model._expert_choice(cfg, spec, xg, probs, wi, wo)
    finally:
        model._run_experts = orig
    routed = np.asarray(out[0])
    sums = routed[:, 0]  # each routed token: sum of weights * 1
    for s in sums:
        assert abs(s - 1.0) < 1e-4 or abs(s) < 1e-6, f"weight sum {s}"
    del ones_out, combine_only


def test_loss_mask_is_respected():
    cfg = CONFIGS["lm_tiny_dense"]
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(5)
    b_full = lm_batch(cfg, rng, uniform_mask=True)
    b_half = dict(b_full)
    m = np.ones((cfg.batch_size, cfg.dec_len), np.float32)
    m[:, cfg.dec_len // 2:] = 0.0
    b_half["loss_mask"] = jnp.asarray(m)
    l_full, _ = model.lm_loss(cfg, params, b_full)
    l_half, _ = model.lm_loss(cfg, params, b_half)
    assert not np.isclose(float(l_full), float(l_half))
    # Changing targets in masked positions must not change the loss.
    b_half2 = dict(b_half)
    t = np.asarray(b_half["targets"]).copy()
    t[:, cfg.dec_len // 2:] = 1
    b_half2["targets"] = jnp.asarray(t)
    l_half2, _ = model.lm_loss(cfg, params, b_half2)
    np.testing.assert_allclose(float(l_half), float(l_half2), rtol=1e-6)


def test_padding_tokens_do_not_affect_encoding():
    """Changing content *behind* padding leaves decoder logits unchanged."""
    cfg = CONFIGS["lm_tiny_dense"]
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(6)
    enc = np.asarray(rng.integers(2, 200, (cfg.batch_size, cfg.enc_len)), np.int32)
    enc[:, cfg.enc_len // 2:] = 0  # PAD the second half
    dec = jnp.asarray(rng.integers(2, 200, (cfg.batch_size, cfg.dec_len)), jnp.int32)
    l1, _ = model.lm_forward(cfg, params, jnp.asarray(enc), dec)
    enc2 = enc.copy()
    enc2[:, cfg.enc_len // 2:] = 0  # still pad — but embed of pad is used...
    l2, _ = model.lm_forward(cfg, params, jnp.asarray(enc2), dec)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)


def test_causality_of_decoder():
    """Future decoder tokens must not influence earlier positions."""
    cfg = CONFIGS["lm_tiny_dense"]
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(7)
    enc = jnp.asarray(rng.integers(2, 200, (cfg.batch_size, cfg.enc_len)), jnp.int32)
    dec1 = np.asarray(rng.integers(2, 200, (cfg.batch_size, cfg.dec_len)), np.int32)
    dec2 = dec1.copy()
    dec2[:, -1] = (dec2[:, -1] % 100) + 2  # change only the last token
    l1, _ = model.lm_forward(cfg, params, enc, jnp.asarray(dec1))
    l2, _ = model.lm_forward(cfg, params, enc, jnp.asarray(dec2))
    np.testing.assert_allclose(
        np.asarray(l1)[:, :-1], np.asarray(l2)[:, :-1], rtol=1e-5, atol=1e-6)


def test_vit_patchify_roundtrip_structure():
    cfg = CONFIGS["vit_tiny_dense"]
    img = jnp.arange(cfg.image_size * cfg.image_size * 3, dtype=jnp.float32)
    img = img.reshape(1, cfg.image_size, cfg.image_size, 3)
    patches = model.vit_patchify(cfg, img)
    assert patches.shape == (1, cfg.num_patches, cfg.patch_size**2 * 3)
    # First patch must be exactly the top-left block.
    top_left = np.asarray(img[0, :cfg.patch_size, :cfg.patch_size, :]).reshape(-1)
    np.testing.assert_allclose(np.asarray(patches[0, 0]), top_left)


def test_pallas_and_ref_model_paths_agree():
    """use_pallas=False (pure jnp) and True (Pallas kernels) are numerically
    interchangeable — the whole-model integration of the L1 kernels."""
    import dataclasses
    cfg = CONFIGS["lm_tiny_moe_e8_c2"]
    cfg_ref = dataclasses.replace(cfg, use_pallas=False)
    params = model.init_params(cfg, 0)
    rng = np.random.default_rng(8)
    b = lm_batch(cfg, rng)
    l1, m1 = model.lm_loss(cfg, params, b)
    l2, m2 = model.lm_loss(cfg_ref, params, b)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(float(m1["accuracy"]), float(m2["accuracy"]))
