"""AOT/manifest contract tests: FLOPs model sanity, manifest completeness,
and — when artifacts exist — HLO text parseability constraints."""

import json
import os

import pytest

from compile import aot, flops, model, train_step
from compile.configs import CONFIGS, build_artifact_set

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_flops_monotone_in_capacity():
    d = flops.train_flops_per_step(CONFIGS["lm_tiny_dense"])
    c1 = flops.train_flops_per_step(CONFIGS["lm_tiny_moe_e8_c1"])
    c2 = flops.train_flops_per_step(CONFIGS["lm_tiny_moe_e8_c2"])
    c3 = flops.train_flops_per_step(CONFIGS["lm_tiny_moe_e8_c3"])
    assert d < c1 < c2 < c3
    # C=1 ≈ dense + router only (paper §2.1 footnote 2).
    assert c1 / d < 1.3


def test_flops_expert_count_is_nearly_neutral():
    e2 = flops.train_flops_per_step(CONFIGS["lm_tiny_moe_e2_c2"])
    e16 = flops.train_flops_per_step(CONFIGS["lm_tiny_moe_e16_c2"])
    assert abs(e16 / e2 - 1.0) < 0.1


def test_flops_train_is_3x_eval():
    for name in ["lm_tiny_dense", "vit_tiny_moe_e8_c2"]:
        cfg = CONFIGS[name]
        assert flops.train_flops_per_step(cfg) == pytest.approx(
            3 * flops.eval_flops_per_step(cfg))


def test_artifact_set_is_consistent():
    cfgs = build_artifact_set()
    assert len(cfgs) >= 24, "full experiment coverage requires the whole set"
    for cfg in cfgs:
        entry = aot.model_entry(cfg, ".")
        n_params = len(entry["params"])
        assert entry["param_count"] > 0
        assert n_params == len(model.param_specs(cfg))
        assert len(entry["opt_state"]) == len(train_step.opt_specs(cfg))
        # Sparse configs expose experts in the signature.
        if cfg.is_sparse:
            assert any("/moe/wi" in s["name"] for s in entry["params"])
        # Every family ships train + eval; vit also features.
        assert set(entry["artifacts"]) >= {"train", "eval"}
        if cfg.family == "vit":
            assert "features" in entry["artifacts"]


def test_sparse_param_count_exceeds_dense():
    dense = aot.model_entry(CONFIGS["lm_tiny_dense"], ".")["param_count"]
    sparse = aot.model_entry(CONFIGS["lm_tiny_moe_e8_c2"], ".")["param_count"]
    assert sparse > 2 * dense, "8 experts on half the layers ⇒ ≫2× params"


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_configs():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    names = {m["name"] for m in manifest["models"]}
    assert names == set(CONFIGS.keys())
    for m in manifest["models"]:
        for kind, fname in m["artifacts"].items():
            path = os.path.join(ARTIFACTS, fname)
            assert os.path.exists(path), f"missing artifact {fname}"


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_hlo_text_avoids_unparseable_ops():
    """xla_extension 0.5.1's HLO text parser rejects the dedicated `topk`
    instruction newer jax emits — model.top_k must keep it out (the Rust
    integration test compiles these files for real; this is the fast guard)."""
    for fname in os.listdir(ARTIFACTS):
        if not fname.endswith(".hlo.txt"):
            continue
        with open(os.path.join(ARTIFACTS, fname)) as f:
            text = f.read()
        assert " topk(" not in text, f"{fname} contains an unparseable topk op"
        assert "ENTRY" in text and "HloModule" in text
