"""Upcycling surgery tests — including the paper's function-preservation
property (Appendix B.8 / Fig. 15): with renormalized combine weights, every
token selected by ≥1 expert computes exactly the dense model's function at
initialization."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train_step, upcycle
from compile.configs import CONFIGS


def test_surgery_copies_and_replicates():
    dense_cfg = CONFIGS["lm_tiny_dense"]
    sparse_cfg = CONFIGS["lm_tiny_moe_e8_c2"]
    dense = model.init_params(dense_cfg, 0)
    sparse = upcycle.upcycle_params(dense, sparse_cfg, seed=1)
    for name, v in sparse.items():
        if "/moe/wi" in name or "/moe/wo" in name:
            src = dense[name.replace("/moe/", "/mlp/")]
            for e in range(v.shape[0]):
                np.testing.assert_array_equal(np.asarray(v[e]), np.asarray(src))
        elif "/moe/router" in name:
            std = float(jnp.std(v))
            assert 0.005 < std < 0.05
        else:
            np.testing.assert_array_equal(np.asarray(v), np.asarray(dense[name]))


def test_surgery_random_experts_differ():
    dense = model.init_params(CONFIGS["lm_tiny_dense"], 0)
    sparse = upcycle.upcycle_params(dense, CONFIGS["lm_tiny_moe_e8_c2"],
                                    seed=1, load_experts=False)
    for name, v in sparse.items():
        if "/moe/wi" in name:
            src = dense[name.replace("/moe/", "/mlp/")]
            assert not np.allclose(np.asarray(v[0]), np.asarray(src))
            assert not np.allclose(np.asarray(v[0]), np.asarray(v[1]))


def test_surgery_noise_magnitude():
    dense = model.init_params(CONFIGS["lm_tiny_dense"], 0)
    sparse = upcycle.upcycle_params(dense, CONFIGS["lm_tiny_moe_e8_c2"],
                                    seed=1, expert_noise=0.01)
    for name, v in sparse.items():
        if "/moe/wi" in name:
            src = np.asarray(dense[name.replace("/moe/", "/mlp/")])
            dev = np.abs(np.asarray(v[0]) - src)
            assert 0 < dev.max() < 0.06
            assert not np.allclose(np.asarray(v[0]), np.asarray(v[1]))


def test_opt_state_surgery():
    dense_cfg = CONFIGS["lm_tiny_dense"]
    sparse_cfg = CONFIGS["lm_tiny_moe_e8_c2"]
    rng = np.random.default_rng(0)
    dense_opt = {
        s["name"]: jnp.asarray(rng.standard_normal(s["shape"]), jnp.float32)
        for s in train_step.opt_specs(dense_cfg)
    }
    loaded = upcycle.upcycle_opt_state(dense_opt, sparse_cfg, load_optimizer=True)
    zeroed = upcycle.upcycle_opt_state(dense_opt, sparse_cfg, load_optimizer=False)
    for s in train_step.opt_specs(sparse_cfg):
        name = s["name"]
        assert loaded[name].shape == tuple(s["shape"])
        assert float(jnp.sum(jnp.abs(zeroed[name]))) == 0.0
        if "/moe/router/" in name:
            assert float(jnp.sum(jnp.abs(loaded[name]))) == 0.0
        elif "/moe/wi/" in name or "/moe/wo/" in name:
            src = dense_opt[name.replace("/moe/", "/mlp/")]
            for e in range(s["shape"][0]):
                np.testing.assert_array_equal(
                    np.asarray(loaded[name][e]), np.asarray(src))


def test_depth_tiling_maps_blocks():
    dense_cfg = CONFIGS["lm_tiny_dense"]
    tiled_cfg = CONFIGS["lm_tiny_dense_tiled"]
    dense = model.init_params(dense_cfg, 0)
    tiled = upcycle.depth_tile_params(dense, dense_cfg, tiled_cfg)
    assert set(tiled.keys()) == {s["name"] for s in model.param_specs(tiled_cfg)}
    # 6-block tower from 4 blocks: block 5 ← source 5*4//6 = 3.
    np.testing.assert_array_equal(
        np.asarray(tiled["enc/block_05/attn/wq"]),
        np.asarray(dense["enc/block_03/attn/wq"]))
    np.testing.assert_array_equal(
        np.asarray(tiled["token_embed"]), np.asarray(dense["token_embed"]))


# ---------------------------------------------------------------------------
# Function preservation (Appendix B.8 / Fig. 15)
# ---------------------------------------------------------------------------

def _renorm_full_capacity_cfg():
    """LM config whose MoE layers renormalize and have capacity ≥ group size
    so *every* token is selected by at least one expert."""
    base = CONFIGS["lm_tiny_moe_e8_c2_renorm"]
    # capacity factor = E ⇒ c = g·E/E = g: every expert can take every token.
    enc = dataclasses.replace(base.enc_moe, capacity_factor=8.0)
    dec = dataclasses.replace(base.dec_moe, capacity_factor=8.0,
                              router_type="ec")
    return dataclasses.replace(base, enc_moe=enc, dec_moe=dec)


def test_function_preservation_with_renorm_and_full_capacity():
    """At C=E with renormalization, the upcycled model's logits equal the
    dense parent's logits exactly (up to float tolerance) — paper Fig. 15."""
    dense_cfg = CONFIGS["lm_tiny_dense"]
    sparse_cfg = _renorm_full_capacity_cfg()
    dense = model.init_params(dense_cfg, 3)
    sparse = upcycle.upcycle_params(dense, sparse_cfg, seed=9)

    rng = np.random.default_rng(10)
    enc = jnp.asarray(rng.integers(1, 200, (4, dense_cfg.enc_len)), jnp.int32)
    dec = jnp.asarray(rng.integers(1, 200, (4, dense_cfg.dec_len)), jnp.int32)
    logits_dense, _ = model.lm_forward(dense_cfg, dense, enc, dec)
    logits_sparse, aux = model.lm_forward(sparse_cfg, sparse, enc, dec)
    assert float(aux["coverage"]) == 1.0, "full capacity must cover all tokens"
    np.testing.assert_allclose(
        np.asarray(logits_sparse), np.asarray(logits_dense),
        rtol=2e-3, atol=2e-3)


def test_partial_capacity_only_approximates_dense():
    """At C=1 without renorm the initial model deviates from the parent —
    the 'initial performance drop' the paper's recipe fights."""
    dense_cfg = CONFIGS["lm_tiny_dense"]
    sparse_cfg = CONFIGS["lm_tiny_moe_e8_c1"]
    dense = model.init_params(dense_cfg, 3)
    sparse = upcycle.upcycle_params(dense, sparse_cfg, seed=9)
    rng = np.random.default_rng(10)
    enc = jnp.asarray(rng.integers(1, 200, (4, dense_cfg.enc_len)), jnp.int32)
    dec = jnp.asarray(rng.integers(1, 200, (4, dense_cfg.dec_len)), jnp.int32)
    ld, _ = model.lm_forward(dense_cfg, dense, enc, dec)
    ls, _ = model.lm_forward(sparse_cfg, sparse, enc, dec)
    assert not np.allclose(np.asarray(ls), np.asarray(ld), rtol=1e-3, atol=1e-3)


def test_vision_function_preservation():
    dense_cfg = CONFIGS["vit_tiny_dense"]
    base = CONFIGS["vit_tiny_moe_e8_c2"]
    enc = dataclasses.replace(base.enc_moe, capacity_factor=8.0)
    sparse_cfg = dataclasses.replace(base, enc_moe=enc)
    assert sparse_cfg.enc_moe.renormalize
    dense = model.init_params(dense_cfg, 3)
    sparse = upcycle.upcycle_params(dense, sparse_cfg, seed=9)
    rng = np.random.default_rng(11)
    img = jnp.asarray(rng.random((4, 32, 32, 3)), jnp.float32)
    ld, _ = model.vit_forward(dense_cfg, dense, img)
    ls, aux = model.vit_forward(sparse_cfg, sparse, img)
    assert float(aux["coverage"]) == 1.0
    np.testing.assert_allclose(np.asarray(ls), np.asarray(ld), rtol=2e-3, atol=2e-3)
