"""Train-step / Adafactor tests: optimizer math against a NumPy reference,
loss decrease on a learnable batch, and the flat-signature contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train_step
from compile.configs import CONFIGS


def np_adafactor_reference(param, grad, vr, vc, lr, step):
    """Hand-rolled NumPy Adafactor (factored, beta1=0) for 2-D params."""
    decay = 1.0 - (step + 1.0) ** (-0.8)
    g2 = grad**2 + 1e-30
    vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
    vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
    row_mean = np.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
    v = (vr / row_mean)[..., None] * vc[..., None, :]
    u = grad / np.sqrt(v + 1e-30)
    rms = np.sqrt((u**2).mean() + 1e-30)
    u = u / max(1.0, rms / 1.0)
    return param - lr * u, vr, vc


def test_adafactor_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((6, 10)).astype(np.float32)
    g = (rng.standard_normal((6, 10)) * 0.1).astype(np.float32)
    vr = np.abs(rng.standard_normal(6)).astype(np.float32) * 0.01
    vc = np.abs(rng.standard_normal(10)).astype(np.float32) * 0.01
    opt = {"opt/w/vr": jnp.asarray(vr), "opt/w/vc": jnp.asarray(vc)}
    new_p, new_state = train_step.adafactor_update(
        "w", jnp.asarray(p), jnp.asarray(g), opt,
        jnp.float32(0.01), jnp.float32(0.0), jnp.float32(7.0))
    ref_p, ref_vr, ref_vc = np_adafactor_reference(p, g, vr, vc, 0.01, 7.0)
    np.testing.assert_allclose(np.asarray(new_p), ref_p, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_state["opt/w/vr"]), ref_vr, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_state["opt/w/vc"]), ref_vc, rtol=1e-5)


def test_adafactor_converges_on_quadratic():
    """Adafactor minimizes a toy factored quadratic."""
    target = jnp.asarray(np.random.default_rng(1).standard_normal((4, 6)), jnp.float32)
    p = jnp.zeros((4, 6), jnp.float32)
    opt = {"opt/w/vr": jnp.zeros((4,)), "opt/w/vc": jnp.zeros((6,))}
    for step in range(200):
        g = 2.0 * (p - target)
        p, new = train_step.adafactor_update(
            "w", p, g, opt, jnp.float32(0.05), jnp.float32(0.0),
            jnp.float32(step))
        opt = new
    assert float(jnp.mean((p - target) ** 2)) < 1e-2


def test_adafactor_weight_decay_shrinks_params():
    p = jnp.ones((4, 4), jnp.float32)
    g = jnp.zeros((4, 4), jnp.float32)
    opt = {"opt/w/vr": jnp.ones((4,)), "opt/w/vc": jnp.ones((4,))}
    new_p, _ = train_step.adafactor_update(
        "w", p, g, opt, jnp.float32(0.0), jnp.float32(0.01), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(new_p), 0.99 * np.ones((4, 4)), rtol=1e-6)


def test_opt_specs_cover_all_params():
    for name in ["lm_tiny_dense", "lm_tiny_moe_e8_c2", "vit_tiny_moe_e8_c2"]:
        cfg = CONFIGS[name]
        p_specs = model.param_specs(cfg)
        o_specs = train_step.opt_specs(cfg)
        o_names = {s["name"] for s in o_specs}
        for p in p_specs:
            if train_step.factored(p["shape"]):
                assert f"opt/{p['name']}/vr" in o_names
                assert f"opt/{p['name']}/vc" in o_names
            else:
                assert f"opt/{p['name']}/v" in o_names
        # Factored state is strictly smaller than the parameters.
        p_count = sum(int(np.prod(s["shape"])) for s in p_specs)
        o_count = sum(int(np.prod(s["shape"])) for s in o_specs)
        assert o_count < p_count, "factored Adafactor must be sublinear"


def _toy_lm_batch(cfg, seed=0):
    """A batch with learnable structure: targets = enc tokens' first slice."""
    rng = np.random.default_rng(seed)
    enc = rng.integers(2, 100, (cfg.batch_size, cfg.enc_len)).astype(np.int32)
    tgt = enc[:, : cfg.dec_len].copy()
    dec = np.zeros_like(tgt)
    dec[:, 1:] = tgt[:, :-1]
    return dict(
        enc_tokens=jnp.asarray(enc),
        dec_tokens=jnp.asarray(dec),
        targets=jnp.asarray(tgt),
        loss_mask=jnp.ones((cfg.batch_size, cfg.dec_len), jnp.float32),
    )


@pytest.mark.parametrize("name", ["lm_tiny_dense", "lm_tiny_moe_e8_c2"])
def test_train_step_reduces_loss(name):
    cfg = CONFIGS[name]
    fn, in_names, out_names = train_step.build_train_step(cfg)
    jfn = jax.jit(fn)
    p_specs = model.param_specs(cfg)
    o_specs = train_step.opt_specs(cfg)
    params = model.init_params(cfg, 0)
    flat_p = [params[s["name"]] for s in p_specs]
    flat_o = [jnp.zeros(tuple(s["shape"]), jnp.float32) for s in o_specs]
    batch = _toy_lm_batch(cfg)
    flat_b = [batch[s["name"]] for s in model.batch_specs(cfg)]

    losses = []
    for step in range(12):
        outs = jfn(*flat_p, *flat_o, *flat_b,
                   jnp.float32(0.01), jnp.float32(0.0), jnp.float32(step + 1))
        flat_p = list(outs[: len(flat_p)])
        flat_o = list(outs[len(flat_p): len(flat_p) + len(flat_o)])
        losses.append(float(outs[len(flat_p) + len(flat_o)]))
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]} -> {losses[-1]}"
    # Signature arity matches the manifest contract.
    assert len(in_names) == len(flat_p) + len(flat_o) + len(flat_b) + 3
    assert len(outs) == len(out_names)
    assert out_names[-5:] == train_step.METRIC_NAMES


def test_eval_step_is_pure():
    cfg = CONFIGS["lm_tiny_dense"]
    fn, _, _ = train_step.build_eval_step(cfg)
    jfn = jax.jit(fn)
    params = model.init_params(cfg, 0)
    flat_p = [params[s["name"]] for s in model.param_specs(cfg)]
    batch = _toy_lm_batch(cfg)
    flat_b = [batch[s["name"]] for s in model.batch_specs(cfg)]
    a = jfn(*flat_p, *flat_b)
    b = jfn(*flat_p, *flat_b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_features_shape():
    cfg = CONFIGS["vit_tiny_dense"]
    fn, _, _ = train_step.build_features(cfg)
    params = model.init_params(cfg, 0)
    flat_p = [params[s["name"]] for s in model.param_specs(cfg)]
    img = jnp.ones((cfg.batch_size, cfg.image_size, cfg.image_size, 3), jnp.float32)
    (feats,) = jax.jit(fn)(*flat_p, img)
    assert feats.shape == (cfg.batch_size, cfg.d_model)
