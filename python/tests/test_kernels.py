"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (`ref.py`).

Hypothesis sweeps shapes and dtypes; gradients of the custom_vjp wrappers are
checked against jax.grad of the references — this is the core correctness
signal for everything the AOT artifacts compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_mlp, ref, router_probs

settings.register_profile("kernels", deadline=None, max_examples=10)
settings.load_profile("kernels")


def rand(rng, shape, dtype, scale=1.0):
    x = rng.standard_normal(shape).astype(np.float32) * scale
    return jnp.asarray(x, dtype)


shapes = st.tuples(
    st.integers(1, 6),    # experts
    st.integers(1, 24),   # capacity (tokens per expert)
    st.integers(1, 24),   # d_model
    st.integers(1, 32),   # d_ff
)


@given(shapes=shapes, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([jnp.float32]))
def test_expert_mlp_matches_ref(shapes, seed, dtype):
    e, c, d, f = shapes
    rng = np.random.default_rng(seed)
    x = rand(rng, (e, c, d), dtype)
    w1 = rand(rng, (e, d, f), dtype, 0.3)
    w2 = rand(rng, (e, f, d), dtype, 0.3)
    got = expert_mlp(x, w1, w2)
    want = ref.expert_mlp(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(shapes=shapes, seed=st.integers(0, 2**31 - 1))
def test_expert_mlp_grads_match_ref(shapes, seed):
    e, c, d, f = shapes
    rng = np.random.default_rng(seed)
    x = rand(rng, (e, c, d), jnp.float32)
    w1 = rand(rng, (e, d, f), jnp.float32, 0.3)
    w2 = rand(rng, (e, f, d), jnp.float32, 0.3)
    # Scalar loss with a non-trivial cotangent.
    cot = rand(rng, (e, c, d), jnp.float32)

    def loss_k(a, b, w):
        return jnp.sum(expert_mlp(a, b, w) * cot)

    def loss_r(a, b, w):
        return jnp.sum(ref.expert_mlp(a, b, w) * cot)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w1, w2)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w1, w2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_expert_mlp_bwd_kernel_matches_manual_ref():
    # The Pallas backward kernel against the hand-derived ref.expert_mlp_bwd.
    rng = np.random.default_rng(0)
    x = rand(rng, (3, 8, 16), jnp.float32)
    w1 = rand(rng, (3, 16, 32), jnp.float32, 0.2)
    w2 = rand(rng, (3, 32, 16), jnp.float32, 0.2)
    g = rand(rng, (3, 8, 16), jnp.float32)
    _, vjp = jax.vjp(expert_mlp, x, w1, w2)
    dx, dw1, dw2 = vjp(g)
    rdx, rdw1, rdw2 = ref.expert_mlp_bwd(x, w1, w2, g)
    np.testing.assert_allclose(dx, rdx, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw1, rdw1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw2, rdw2, rtol=1e-4, atol=1e-5)


router_shapes = st.tuples(
    st.integers(1, 4),    # groups
    st.integers(1, 32),   # group size
    st.integers(1, 24),   # d_model
    st.integers(2, 16),   # experts
)


@given(shapes=router_shapes, seed=st.integers(0, 2**31 - 1))
def test_router_matches_ref(shapes, seed):
    n, g, d, e = shapes
    rng = np.random.default_rng(seed)
    x = rand(rng, (n, g, d), jnp.float32)
    w = rand(rng, (d, e), jnp.float32, 0.5)
    got = router_probs(x, w)
    want = jnp.stack([ref.router_probs(x[i], w) for i in range(n)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Rows are distributions.
    np.testing.assert_allclose(jnp.sum(got, -1), np.ones((n, g)), rtol=1e-5)
    assert bool(jnp.all(got >= 0))


@given(shapes=router_shapes, seed=st.integers(0, 2**31 - 1))
def test_router_grads_match_ref(shapes, seed):
    n, g, d, e = shapes
    rng = np.random.default_rng(seed)
    x = rand(rng, (n, g, d), jnp.float32)
    w = rand(rng, (d, e), jnp.float32, 0.5)
    cot = rand(rng, (n, g, e), jnp.float32)

    def loss_k(a, b):
        return jnp.sum(router_probs(a, b) * cot)

    def loss_r(a, b):
        p = jnp.stack([ref.router_probs(a[i], b) for i in range(n)])
        return jnp.sum(p * cot)

    gk = jax.grad(loss_k, argnums=(0, 1))(x, w)
    gr = jax.grad(loss_r, argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_router_is_stable_for_large_logits():
    # Softmax stability: huge logits must not produce NaN/Inf.
    x = jnp.full((1, 4, 8), 100.0, jnp.float32)
    w = jnp.full((8, 4), 50.0, jnp.float32)
    p = router_probs(x, w)
    assert bool(jnp.all(jnp.isfinite(p)))
    np.testing.assert_allclose(jnp.sum(p, -1), np.ones((1, 4)), rtol=1e-5)


def test_gelu_grad_matches_autodiff():
    x = jnp.linspace(-4, 4, 101, dtype=jnp.float32)
    auto = jax.vmap(jax.grad(lambda v: ref.gelu(v)))(x)
    np.testing.assert_allclose(ref.gelu_grad(x), auto, rtol=1e-5, atol=1e-6)
