"""Layer-2 JAX models: T5-style encoder–decoder LM and ViT-style classifier
with sparse Mixture-of-Experts layers.

This is the substrate the paper's upcycling recipe operates on (paper §2.2):

* `lm`  — encoder–decoder span-corruption language model (≈ T5 1.1 geometry,
  simplified: learned absolute positions instead of relative bias, plain GELU
  MLP instead of GEGLU — both documented in DESIGN.md §2).
* `vit` — encoder-only classifier with global average pooling (≈ ViT / V-MoE
  with the paper's two modifications: GAP head + Expert Choice routing).

MoE blocks support the paper's full design space (§3.1 + Appendix B):
Expert Choice routing with capacity factor `C`, token-choice Top-K routing
(K ∈ {1,2}) with capacity buffers, token dropping, auxiliary load-balancing
loss and optional Batch Prioritized Routing, combine-weight renormalization,
configurable routing group size, and arbitrary MoE layer placement.

Everything is functional: `init_params(cfg, seed) -> {name: array}` and
`forward(cfg, params, batch) -> (logits, aux)`. Parameter names are the
interface contract with the Rust coordinator (the manifest lists them in
sorted order); the upcycling surgery in `rust/src/upcycle/` rewrites
`.../mlp/wi → .../moe/wi` etc. by name.
"""

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, MoeSpec
from .kernels import expert_mlp as pallas_expert_mlp
from .kernels import router_probs as pallas_router_probs
from .kernels import ref as kref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[dict]:
    """Full parameter inventory: name, shape, dtype and init spec.

    The init spec is consumed by the Rust coordinator (`rust/src/init.rs`) so
    that from-scratch initialization never needs Python at runtime.
    Kinds: "normal" (stddev), "fan_in" (truncated-normal-ish, stddev =
    1/sqrt(fan_in)), "zeros", "ones".
    """
    d, ff = cfg.d_model, cfg.d_ff
    specs: List[dict] = []

    def add(name, shape, kind, stddev=0.0):
        specs.append(dict(name=name, shape=list(shape), dtype="f32",
                          init=dict(kind=kind, stddev=stddev)))

    def attn(prefix):
        add(f"{prefix}_norm/scale", (d,), "ones")
        for w in ("wq", "wk", "wv", "wo"):
            add(f"{prefix}/{w}", (d, d), "fan_in", 1.0 / math.sqrt(d))

    def mlp_or_moe(prefix, spec: Optional[MoeSpec], layer: int):
        add(f"{prefix}/mlp_norm/scale", (d,), "ones")
        if spec is not None and layer in spec.moe_layers:
            e = spec.num_experts
            # Paper §3: router weights random N(0, 0.02); experts are
            # per-expert copies of the MLP geometry.
            add(f"{prefix}/moe/router", (d, e), "normal", 0.02)
            add(f"{prefix}/moe/wi", (e, d, ff), "fan_in", 1.0 / math.sqrt(d))
            add(f"{prefix}/moe/wo", (e, ff, d), "fan_in", 1.0 / math.sqrt(ff))
        else:
            add(f"{prefix}/mlp/wi", (d, ff), "fan_in", 1.0 / math.sqrt(d))
            add(f"{prefix}/mlp/wo", (ff, d), "fan_in", 1.0 / math.sqrt(ff))

    if cfg.family == "lm":
        add("token_embed", (cfg.vocab_size, d), "normal", 1.0 / math.sqrt(d))
        add("enc/pos_embed", (cfg.enc_len, d), "normal", 0.02)
        add("dec/pos_embed", (cfg.dec_len, d), "normal", 0.02)
        for b in range(cfg.num_layers):
            p = f"enc/block_{b:02d}"
            attn(f"{p}/attn")
            mlp_or_moe(p, cfg.enc_moe, b)
        for b in range(cfg.num_decoder_layers):
            p = f"dec/block_{b:02d}"
            attn(f"{p}/attn")
            attn(f"{p}/cross")
            mlp_or_moe(p, cfg.dec_moe, b)
        add("enc/final_norm/scale", (d,), "ones")
        add("dec/final_norm/scale", (d,), "ones")
    elif cfg.family == "vit":
        patch_dim = cfg.patch_size * cfg.patch_size * cfg.channels
        add("patch_embed/kernel", (patch_dim, d), "fan_in",
            1.0 / math.sqrt(patch_dim))
        add("patch_embed/bias", (d,), "zeros")
        add("pos_embed", (cfg.num_patches, d), "normal", 0.02)
        for b in range(cfg.num_layers):
            p = f"enc/block_{b:02d}"
            attn(f"{p}/attn")
            mlp_or_moe(p, cfg.enc_moe, b)
        add("final_norm/scale", (d,), "ones")
        add("head/kernel", (d, cfg.num_classes), "fan_in", 1.0 / math.sqrt(d))
        add("head/bias", (cfg.num_classes,), "zeros")
    else:
        raise ValueError(f"unknown family {cfg.family}")

    specs.sort(key=lambda s: s["name"])
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Reference initializer (tests + aot example args); Rust mirrors it."""
    params: Params = {}
    key = jax.random.PRNGKey(seed)
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        shape = tuple(spec["shape"])
        kind = spec["init"]["kind"]
        if kind == "zeros":
            v = jnp.zeros(shape, jnp.float32)
        elif kind == "ones":
            v = jnp.ones(shape, jnp.float32)
        else:
            v = jax.random.normal(sub, shape, jnp.float32) * spec["init"]["stddev"]
        params[spec["name"]] = v
    return params


# ---------------------------------------------------------------------------
# Basic blocks
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def attention(params, prefix, q_in, kv_in, cfg: ModelConfig, mask=None):
    """Multi-head attention. mask: [B, 1, Tq, Tk] additive (0 / -inf)."""
    b, tq, d = q_in.shape
    tk = kv_in.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    q = (q_in @ params[f"{prefix}/wq"]).reshape(b, tq, h, hd)
    k = (kv_in @ params[f"{prefix}/wk"]).reshape(b, tk, h, hd)
    v = (kv_in @ params[f"{prefix}/wv"]).reshape(b, tk, h, hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    if mask is not None:
        logits = logits + mask
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, tq, d)
    return o @ params[f"{prefix}/wo"]


def dense_mlp(params, prefix, x):
    h = kref.gelu(x @ params[f"{prefix}/wi"])
    return h @ params[f"{prefix}/wo"]


# ---------------------------------------------------------------------------
# Mixture-of-Experts layer
# ---------------------------------------------------------------------------

def top_k(x, k: int):
    """`lax.top_k` replacement that lowers to a plain HLO `sort`.

    jax ≥ 0.4.30 lowers `lax.top_k` to a dedicated `topk(..., largest=true)`
    HLO instruction that the xla_extension 0.5.1 text parser (the version the
    Rust `xla` crate links) rejects. A descending argsort + slice produces
    identical values/indices through parseable `sort`/`gather` ops.
    """
    # lax.sort_key_val directly (jnp.argsort on ≥3-D inputs builds a batched
    # gather this jaxlib cannot lower); indices are integer plumbing, so
    # stop_gradient keeps the sort out of the autodiff graph — gradients flow
    # through take_along_axis exactly as through lax.top_k's value output.
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    neg = jax.lax.stop_gradient(-x)  # keys constant: sort's JVP would
    # otherwise emit the unsupported batched gather during linearization
    _, sorted_idx = jax.lax.sort_key_val(neg, iota, dimension=-1)
    idx = sorted_idx[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def _run_experts(cfg: ModelConfig, x_e, wi, wo):
    """x_e: [E, c, d] → [E, c, d] through the Pallas kernel (or jnp ref)."""
    if cfg.use_pallas:
        return pallas_expert_mlp(x_e, wi, wo)
    return kref.expert_mlp(x_e, wi, wo)


def _router(cfg: ModelConfig, xg, w):
    """xg: [n_groups, g, d] → probs [n_groups, g, E]."""
    if cfg.use_pallas:
        return pallas_router_probs(xg, w)
    return jax.vmap(lambda t: kref.router_probs(t, w))(xg)


def _expert_choice(cfg, spec: MoeSpec, xg, probs, wi, wo):
    """Expert Choice routing (paper §2.1, Zhou et al. 2022).

    Every expert independently picks its top `c = g*C/E` tokens (top-c per
    probability column). Experts are perfectly load balanced by construction;
    tokens may be used by several experts or dropped entirely.

    xg: [n, g, d]; probs: [n, g, E]. Returns ([n, g, d], aux_metrics).
    """
    n, g, d = xg.shape
    e = spec.num_experts
    c = max(1, int(g * spec.capacity_factor / e))

    # Per-group top-c per expert column.
    vals, idx = top_k(jnp.swapaxes(probs, 1, 2), c)  # [n, E, c]
    # Gather dispatched tokens: [n, E, c, d].
    x_disp = jnp.take_along_axis(xg[:, None, :, :], idx[..., None], axis=2)
    # Merge groups into the expert-capacity axis for one kernel invocation:
    # [E, n*c, d] — the Pallas grid stays (E,) regardless of group count.
    x_e = jnp.swapaxes(x_disp, 0, 1).reshape(e, n * c, d)
    y_e = _run_experts(cfg, x_e, wi, wo)
    y_disp = jnp.swapaxes(y_e.reshape(e, n, c, d), 0, 1)  # [n, E, c, d]

    # Combine: scatter-add weighted expert outputs back to token slots.
    weighted = y_disp * vals[..., None]
    flat_idx = idx + (jnp.arange(n)[:, None, None] * g)
    out = jnp.zeros((n * g, d), xg.dtype).at[flat_idx.reshape(-1)].add(
        weighted.reshape(-1, d))
    if spec.renormalize:
        # Appendix B.7: combine weights of each token renormalized to sum 1
        # (tokens chosen by no expert keep weight 0).
        denom = jnp.zeros((n * g,), xg.dtype).at[flat_idx.reshape(-1)].add(
            vals.reshape(-1))
        out = out / jnp.maximum(denom, 1e-9)[:, None]
    out = out.reshape(n, g, d)

    # Fraction of tokens processed by ≥1 expert (Fig. 15 diagnostics).
    hit = jnp.zeros((n * g,), xg.dtype).at[flat_idx.reshape(-1)].add(1.0)
    coverage = jnp.mean((hit > 0).astype(jnp.float32))
    return out, dict(aux_loss=jnp.float32(0.0), coverage=coverage)


def _top_k(cfg, spec: MoeSpec, xg, probs, wi, wo):
    """Token-choice Top-K routing (Shazeer et al. 2017 / Switch) with capacity
    buffers, token dropping, the 0.01-scaled auxiliary load-balancing loss
    (paper §A.1.1) and optional Batch Prioritized Routing (Appendix B.1).

    xg: [n, g, d]; probs: [n, g, E]. Returns ([n, g, d], aux_metrics).
    """
    n, g, d = xg.shape
    e = spec.num_experts
    k = 1 if spec.router_type == "top1" else 2
    cap = max(1, int(g * spec.capacity_factor * k / e))

    top_vals, top_idx = top_k(probs, k)  # [n, g, k]

    if spec.bpr:
        # Batch Prioritized Routing: fill expert buffers in order of router
        # confidence instead of position order.
        # stop_gradient: the priority permutation is integer-valued plumbing;
        # keeping it out of the autodiff graph also avoids this jaxlib's
        # missing batched-gather transpose rule.
        order = jnp.argsort(
            jax.lax.stop_gradient(-top_vals[..., 0]), axis=-1)  # [n, g]
        inv_order = jnp.argsort(order, axis=-1)
        top_vals = jnp.take_along_axis(top_vals, order[..., None], axis=1)
        top_idx = jnp.take_along_axis(top_idx, order[..., None], axis=1)
    else:
        inv_order = None

    # Buffer positions via cumulative counts in (priority) token order,
    # vectorized over groups — no vmap: this jaxlib rejects batched gathers.
    combine = jnp.zeros((n, g, e, cap), xg.dtype)
    prev_counts = jnp.zeros((n, 1, e), jnp.int32)
    for slot in range(k):
        exp_idx = top_idx[..., slot]  # [n, g]
        onehot_i = jax.nn.one_hot(exp_idx, e, dtype=jnp.int32)  # [n, g, e]
        pos = jnp.cumsum(onehot_i, axis=1) - 1 + prev_counts
        prev_counts = prev_counts + jnp.sum(onehot_i, axis=1, keepdims=True)
        my_pos = jnp.sum(pos * onehot_i, axis=-1)  # [n, g]
        kept = (my_pos < cap).astype(xg.dtype)
        w = top_vals[..., slot] * kept
        disp = (jax.nn.one_hot(exp_idx, e, dtype=xg.dtype)[..., None]
                * jax.nn.one_hot(jnp.clip(my_pos, 0, cap - 1), cap,
                                 dtype=xg.dtype)[..., None, :]
                * kept[..., None, None])
        combine = combine + disp * w[..., None, None]
    if inv_order is not None:
        combine = jnp.take_along_axis(
            combine, inv_order[..., None, None], axis=1)
    dispatch = (combine > 0).astype(xg.dtype)  # [n, g, e, cap]

    x_e = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    x_e = jnp.swapaxes(x_e, 0, 1).reshape(e, n * cap, d)
    y_e = _run_experts(cfg, x_e, wi, wo)
    y_e = jnp.swapaxes(y_e.reshape(e, n, cap, d), 0, 1)  # [n, e, cap, d]

    if spec.renormalize:
        denom = jnp.sum(combine, axis=(2, 3), keepdims=True)
        combine = combine / jnp.maximum(denom, 1e-9)
    out = jnp.einsum("ngec,necd->ngd", combine, y_e)

    # Switch-style load balancing loss: E * sum_e f_e * p_e.
    assign_frac = jnp.mean(jnp.sum(dispatch, axis=3), axis=1)  # [n, e]
    mean_prob = jnp.mean(probs, axis=1)  # [n, e]
    aux = jnp.mean(jnp.sum(assign_frac * mean_prob, axis=-1)) * e / k
    coverage = jnp.mean((jnp.sum(combine, axis=(2, 3)) > 0).astype(jnp.float32))
    return out, dict(aux_loss=aux * spec.aux_loss_scale, coverage=coverage)


def moe_layer(cfg: ModelConfig, spec: MoeSpec, params, prefix, x):
    """Sparse MoE layer over tokens x: [B, S, d] → [B, S, d]."""
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    g = spec.group_size if spec.group_size > 0 else b * s
    assert (b * s) % g == 0, f"group size {g} must divide token count {b*s}"
    n = (b * s) // g
    xg = tokens.reshape(n, g, d)

    probs = _router(cfg, xg, params[f"{prefix}/moe/router"])
    wi = params[f"{prefix}/moe/wi"]
    wo = params[f"{prefix}/moe/wo"]
    if spec.router_type == "ec":
        out, aux = _expert_choice(cfg, spec, xg, probs, wi, wo)
    elif spec.router_type in ("top1", "top2"):
        out, aux = _top_k(cfg, spec, xg, probs, wi, wo)
    else:
        raise ValueError(f"unknown router type {spec.router_type}")
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Towers
# ---------------------------------------------------------------------------

def _block_ffn(cfg, spec, params, prefix, x, aux_acc):
    y = rms_norm(x, params[f"{prefix}/mlp_norm/scale"])
    layer = int(prefix.split("_")[-1])
    if spec is not None and layer in spec.moe_layers:
        y, aux = moe_layer(cfg, spec, params, prefix, y)
        aux_acc["aux_loss"] = aux_acc["aux_loss"] + aux["aux_loss"]
        aux_acc["coverage"].append(aux["coverage"])
    else:
        y = dense_mlp(params, f"{prefix}/mlp", y)
    return x + y


def encoder(cfg: ModelConfig, params, x, enc_mask, aux_acc):
    for b in range(cfg.num_layers):
        p = f"enc/block_{b:02d}"
        y = rms_norm(x, params[f"{p}/attn_norm/scale"])
        x = x + attention(params, f"{p}/attn", y, y, cfg, enc_mask)
        x = _block_ffn(cfg, cfg.enc_moe, params, p, x, aux_acc)
    return rms_norm(x, params["enc/final_norm/scale"])


def decoder(cfg: ModelConfig, params, x, enc_out, causal_mask, cross_mask,
            aux_acc):
    for b in range(cfg.num_decoder_layers):
        p = f"dec/block_{b:02d}"
        y = rms_norm(x, params[f"{p}/attn_norm/scale"])
        x = x + attention(params, f"{p}/attn", y, y, cfg, causal_mask)
        y = rms_norm(x, params[f"{p}/cross_norm/scale"])
        x = x + attention(params, f"{p}/cross", y, enc_out, cfg, cross_mask)
        x = _block_ffn(cfg, cfg.dec_moe, params, p, x, aux_acc)
    return rms_norm(x, params["dec/final_norm/scale"])


def _pad_mask(tokens):
    """[B, T] int32 → additive mask [B, 1, 1, T]; 0 is the pad id."""
    m = (tokens != 0).astype(jnp.float32)
    return (m - 1.0)[:, None, None, :] * 1e9


def lm_forward(cfg: ModelConfig, params: Params, enc_tokens, dec_tokens):
    """Span-corruption LM forward. Returns (logits [B,Sd,V], aux dict)."""
    aux_acc = {"aux_loss": jnp.float32(0.0), "coverage": []}
    emb = params["token_embed"]
    enc_x = emb[enc_tokens] + params["enc/pos_embed"][None, :, :]
    dec_x = emb[dec_tokens] + params["dec/pos_embed"][None, :, :]

    enc_mask = _pad_mask(enc_tokens)
    sd = dec_tokens.shape[1]
    causal = jnp.tril(jnp.ones((sd, sd), jnp.float32))
    causal_mask = (causal - 1.0)[None, None, :, :] * 1e9

    enc_out = encoder(cfg, params, enc_x, enc_mask, aux_acc)
    dec_out = decoder(cfg, params, dec_x, enc_out, causal_mask, enc_mask,
                      aux_acc)
    # Tied softmax, T5-style 1/sqrt(d) logits scaling.
    logits = (dec_out / math.sqrt(cfg.d_model)) @ emb.T
    return logits, _finalize_aux(aux_acc)


def vit_patchify(cfg: ModelConfig, images):
    """[B, H, W, C] → [B, N, P*P*C] patches (row-major patch order)."""
    b = images.shape[0]
    p = cfg.patch_size
    hp = cfg.image_size // p
    x = images.reshape(b, hp, p, hp, p, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, hp * hp, p * p * cfg.channels)


def vit_features(cfg: ModelConfig, params: Params, images):
    """ViT trunk → pooled features [B, d] (global average pooling, §2.2)."""
    aux_acc = {"aux_loss": jnp.float32(0.0), "coverage": []}
    patches = vit_patchify(cfg, images)
    x = patches @ params["patch_embed/kernel"] + params["patch_embed/bias"]
    x = x + params["pos_embed"][None, :, :]
    for b in range(cfg.num_layers):
        p = f"enc/block_{b:02d}"
        y = rms_norm(x, params[f"{p}/attn_norm/scale"])
        x = x + attention(params, f"{p}/attn", y, y, cfg, None)
        x = _block_ffn(cfg, cfg.enc_moe, params, p, x, aux_acc)
    x = rms_norm(x, params["final_norm/scale"])
    return jnp.mean(x, axis=1), _finalize_aux(aux_acc)


def vit_forward(cfg: ModelConfig, params: Params, images):
    feats, aux = vit_features(cfg, params, images)
    logits = feats @ params["head/kernel"] + params["head/bias"]
    return logits, aux


def _finalize_aux(aux_acc):
    cov = aux_acc["coverage"]
    coverage = (jnp.mean(jnp.stack(cov)) if cov else jnp.float32(1.0))
    return {"aux_loss": aux_acc["aux_loss"], "coverage": coverage}


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params: Params, batch):
    """batch: enc_tokens [B,Se] i32, dec_tokens [B,Sd] i32 (shifted inputs),
    targets [B,Sd] i32, loss_mask [B,Sd] f32."""
    logits, aux = lm_forward(cfg, params, batch["enc_tokens"],
                             batch["dec_tokens"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["targets"][..., None],
                             axis=-1)[..., 0]
    mask = batch["loss_mask"]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    xent = -jnp.sum(ll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == batch["targets"]) * mask) / denom
    loss = xent + aux["aux_loss"]
    return loss, dict(xent=xent, accuracy=acc, aux_loss=aux["aux_loss"],
                      coverage=aux["coverage"])


def vit_loss(cfg: ModelConfig, params: Params, batch):
    """batch: images [B,H,W,C] f32, labels [B] i32."""
    logits, aux = vit_forward(cfg, params, batch["images"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    xent = -jnp.mean(jnp.take_along_axis(logp, batch["labels"][:, None],
                                         axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(
        jnp.float32))
    loss = xent + aux["aux_loss"]
    return loss, dict(xent=xent, accuracy=acc, aux_loss=aux["aux_loss"],
                      coverage=aux["coverage"])


def loss_fn(cfg: ModelConfig, params: Params, batch):
    return lm_loss(cfg, params, batch) if cfg.family == "lm" else vit_loss(
        cfg, params, batch)


def batch_specs(cfg: ModelConfig) -> List[dict]:
    """Ordered batch-tensor signature (name, shape, dtype) for the manifest."""
    b = cfg.batch_size
    if cfg.family == "lm":
        return [
            dict(name="enc_tokens", shape=[b, cfg.enc_len], dtype="i32"),
            dict(name="dec_tokens", shape=[b, cfg.dec_len], dtype="i32"),
            dict(name="targets", shape=[b, cfg.dec_len], dtype="i32"),
            dict(name="loss_mask", shape=[b, cfg.dec_len], dtype="f32"),
        ]
    return [
        dict(name="images",
             shape=[b, cfg.image_size, cfg.image_size, cfg.channels],
             dtype="f32"),
        dict(name="labels", shape=[b], dtype="i32"),
    ]
