"""Layer-1 Pallas kernel: fused router (logits + numerically stable softmax).

The router is the only extra FLOPs a sparse layer adds over its dense parent
(paper §2.1 footnote 2): `R = softmax(x @ W_r)` with `W_r ∈ R^{d×E}`. Fusing
the matmul with the softmax keeps the `[g, E]` logits tile in VMEM instead of
round-tripping through HBM; the grid iterates over token groups (paper
Appendix B.8 / Fig. 16 routing groups).

Runs with `interpret=True` on this CPU image; validated against
`ref.router_probs` by pytest/hypothesis.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _router_kernel(x_ref, w_ref, o_ref):
    x = x_ref[0]  # [g, d]
    w = w_ref[...]  # [d, E]
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    o_ref[0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def _router_bwd_kernel(x_ref, w_ref, p_ref, g_ref, dx_ref, dw_ref):
    """Softmax-matmul backward for one token group.

    dlogits = p * (g - sum(g * p, axis=-1)); dx = dlogits @ Wᵀ; dW = xᵀ @ dlogits.
    The per-group dW partial is written to a [n_groups, d, E] scratch output and
    reduced outside the kernel (the grid axis is parallel, not sequential, so
    accumulating in-place across grid steps is not portable to TPU).
    """
    x = x_ref[0]  # [g, d]
    w = w_ref[...]  # [d, E]
    p = p_ref[0]  # [g, E]
    g = g_ref[0]  # [g, E]
    inner = jnp.sum(g * p, axis=-1, keepdims=True)
    dlogits = (p * (g - inner)).astype(x.dtype)
    dx_ref[0] = jnp.dot(dlogits, w.T, preferred_element_type=jnp.float32).astype(
        x.dtype
    )
    dw_ref[0] = jnp.dot(x.T, dlogits, preferred_element_type=jnp.float32).astype(
        x.dtype
    )


def _fwd_call(x, w):
    n, g, d = x.shape
    e = w.shape[-1]
    return pl.pallas_call(
        _router_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, e), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, e), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, g, e), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def _bwd_call(x, w, p, g):
    n, gsz, d = x.shape
    e = w.shape[-1]
    dx, dw_partials = pl.pallas_call(
        _router_bwd_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, gsz, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((d, e), lambda i: (0, 0)),
            pl.BlockSpec((1, gsz, e), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, gsz, e), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, gsz, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, e), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, gsz, d), x.dtype),
            jax.ShapeDtypeStruct((n, d, e), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, w, p, g)
    return dx, jnp.sum(dw_partials, axis=0)


@jax.custom_vjp
def router_probs(x, w):
    """Routing probabilities for grouped tokens.

    Args:
      x: [n_groups, g, d] token groups.
      w: [d, E] router weights.
    Returns: [n_groups, g, E], rows softmax-normalized over E.
    """
    return _fwd_call(x, w)


def _vjp_fwd(x, w):
    p = _fwd_call(x, w)
    return p, (x, w, p)


def _vjp_bwd(res, g):
    x, w, p = res
    return _bwd_call(x, w, p, g)


router_probs.defvjp(_vjp_fwd, _vjp_bwd)
