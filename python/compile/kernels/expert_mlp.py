"""Layer-1 Pallas kernel: grouped per-expert MLP (the MoE FLOPs hot-spot).

The forward pass computes, for every expert `e`,

    y[e] = gelu(x[e] @ w1[e]) @ w2[e]

over the tokens the router dispatched to that expert. This is the dominant
compute of a sparse MoE layer (the paper's Section 2.1: each expert processes
`c = n*C/E` tokens); everything else in the MoE block (router, dispatch
gather, combine scatter) is bandwidth-shaped and stays in XLA.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over the
expert axis, so each program instance holds one expert's `[d, f]` weight
tiles in VMEM and streams `[block_c, d]` token tiles through the MXU. On this
CPU image the kernels run with `interpret=True` (real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute), so block shapes are
chosen for VMEM budget, not measured wall-clock.

Autodiff: `pallas_call` has no AD rule, so the public entry point
`expert_mlp` is a `jax.custom_vjp` whose backward is a second Pallas kernel
(`_expert_mlp_bwd_kernel`) computing `dx, dw1, dw2` — both directions stay in
Pallas and both are validated against `ref.py` by pytest/hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU image: Mosaic lowering unavailable; see module docstring.


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _gelu_grad(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    u = c * (x + 0.044715 * x**3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * x**2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * du


def _fwd_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One grid step == one expert: y = gelu(x @ w1) @ w2, f32 accumulation."""
    x = x_ref[0]  # [c, d]; leading block axis of size 1 is the expert slot
    w1 = w1_ref[0]  # [d, f]
    w2 = w2_ref[0]  # [f, d]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    a = _gelu(h).astype(x.dtype)
    o_ref[0] = jnp.dot(a, w2, preferred_element_type=jnp.float32).astype(x.dtype)


def _bwd_kernel(x_ref, w1_ref, w2_ref, g_ref, dx_ref, dw1_ref, dw2_ref):
    """Backward for one expert; recomputes h (rematerialization keeps VMEM flat)."""
    x = x_ref[0]  # [c, d]
    w1 = w1_ref[0]  # [d, f]
    w2 = w2_ref[0]  # [f, d]
    g = g_ref[0]  # [c, d]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    a = _gelu(h).astype(x.dtype)
    dw2_ref[0] = jnp.dot(a.T, g, preferred_element_type=jnp.float32).astype(x.dtype)
    da = jnp.dot(g, w2.T, preferred_element_type=jnp.float32)
    dh = (da * _gelu_grad(h)).astype(x.dtype)
    dw1_ref[0] = jnp.dot(x.T, dh, preferred_element_type=jnp.float32).astype(x.dtype)
    dx_ref[0] = jnp.dot(dh, w1.T, preferred_element_type=jnp.float32).astype(x.dtype)


def _fwd_call(x, w1, w2):
    e, c, d = x.shape
    f = w1.shape[-1]
    return pl.pallas_call(
        _fwd_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=INTERPRET,
    )(x, w1, w2)


def _bwd_call(x, w1, w2, g):
    e, c, d = x.shape
    f = w1.shape[-1]
    return pl.pallas_call(
        _bwd_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, c, d), x.dtype),
            jax.ShapeDtypeStruct((e, d, f), x.dtype),
            jax.ShapeDtypeStruct((e, f, d), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, w1, w2, g)


@jax.custom_vjp
def expert_mlp(x, w1, w2):
    """Grouped per-expert MLP. Shapes: x [E,c,d], w1 [E,d,f], w2 [E,f,d] → [E,c,d]."""
    return _fwd_call(x, w1, w2)


def _vjp_fwd(x, w1, w2):
    return _fwd_call(x, w1, w2), (x, w1, w2)


def _vjp_bwd(res, g):
    x, w1, w2 = res
    return _bwd_call(x, w1, w2, g)


expert_mlp.defvjp(_vjp_fwd, _vjp_bwd)
