"""Layer-1 Pallas kernels for sparse-upcycled MoE models.

`expert_mlp` and `router_probs` are the compute hot-spots of a MoE layer;
`ref` holds the pure-jnp oracles used by the test suite.
"""

from . import ref  # noqa: F401
from .expert_mlp import expert_mlp  # noqa: F401
from .router import router_probs  # noqa: F401
