"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every kernel in this package has an exact counterpart here. The pytest suite
(`python/tests/test_kernels.py`) sweeps shapes and dtypes with hypothesis and
asserts `assert_allclose(kernel(...), ref(...))`, including gradients of the
`custom_vjp`-wrapped kernels against `jax.grad` of these references.
"""

import jax.numpy as jnp


def gelu(x):
    """tanh-approximated GELU (identical formula to the kernel's)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def gelu_grad(x):
    """d/dx of tanh-approximated GELU."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    u = c * (x + 0.044715 * x**3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * x**2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * du


def expert_mlp(x, w1, w2):
    """Grouped per-expert MLP: for each expert e, gelu(x[e] @ w1[e]) @ w2[e].

    Args:
      x:  [E, c, d]  tokens dispatched to each expert (c = expert capacity).
      w1: [E, d, f]
      w2: [E, f, d]
    Returns: [E, c, d]
    """
    h = jnp.einsum("ecd,edf->ecf", x, w1)
    return jnp.einsum("ecf,efd->ecd", gelu(h), w2)


def expert_mlp_bwd(x, w1, w2, g):
    """Backward of `expert_mlp` w.r.t. (x, w1, w2) given upstream grad g."""
    h = jnp.einsum("ecd,edf->ecf", x, w1)
    a = gelu(h)
    dw2 = jnp.einsum("ecf,ecd->efd", a, g)
    da = jnp.einsum("ecd,efd->ecf", g, w2)
    dh = da * gelu_grad(h)
    dw1 = jnp.einsum("ecd,ecf->edf", x, dh)
    dx = jnp.einsum("ecf,edf->ecd", dh, w1)
    return dx, dw1, dw2


def router_probs(x, w):
    """Router: token→expert probabilities, softmax over the expert axis.

    Args:
      x: [g, d]  token group.
      w: [d, E]  router weights.
    Returns: [g, E] rows summing to 1.
    """
    logits = x @ w
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits)
    return e / jnp.sum(e, axis=-1, keepdims=True)
