"""AOT bridge: lower every configuration to HLO text + write the manifest.

HLO **text** is the interchange format (not `lowered.compile()` /
`.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids
that xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate links)
rejects with `proto.id() <= INT_MAX`; the HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage (normally via `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts [--only lm_tiny] [--force]

Lowering is cached: an artifact is re-lowered only when missing or when the
source hash stamp changed. The manifest is always rewritten (cheap, and it is
the single source of truth for the Rust side).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax

from . import flops, model, train_step
from .configs import CONFIGS, config_to_json


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def source_hash() -> str:
    """Hash of every python source that affects lowering."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    files = []
    for root, _, names in os.walk(here):
        for n in sorted(names):
            if n.endswith(".py"):
                files.append(os.path.join(root, n))
    for f in sorted(files):
        with open(f, "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]


def lower_one(cfg, which: str, out_path: str):
    if which == "train":
        fn, _, _ = train_step.build_train_step(cfg)
    elif which == "eval":
        fn, _, _ = train_step.build_eval_step(cfg)
    elif which == "features":
        fn, _, _ = train_step.build_features(cfg)
    else:
        raise ValueError(which)
    args = train_step.example_args(cfg, which)
    t0 = time.time()
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"  {os.path.basename(out_path)}: {len(text)/1e6:.2f} MB "
          f"({time.time()-t0:.1f}s)", flush=True)


def model_entry(cfg, out_dir: str) -> dict:
    arts = {"train": f"{cfg.name}_train.hlo.txt",
            "eval": f"{cfg.name}_eval.hlo.txt"}
    if cfg.family == "vit":
        arts["features"] = f"{cfg.name}_features.hlo.txt"
    p_specs = model.param_specs(cfg)
    return dict(
        name=cfg.name,
        family=cfg.family,
        config=config_to_json(cfg),
        params=p_specs,
        opt_state=train_step.opt_specs(cfg),
        batch=model.batch_specs(cfg),
        scalars=["lr", "wd", "step"],
        metrics=train_step.METRIC_NAMES,
        param_count=int(sum(
            int(np_prod(s["shape"])) for s in p_specs)),
        flops=dict(
            train_step=flops.train_flops_per_step(cfg),
            eval_step=flops.eval_flops_per_step(cfg),
            fwd_per_example=flops.fwd_flops_per_example(cfg),
        ),
        artifacts=arts,
    )


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="substring filter on config names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    stamp_path = os.path.join(args.out_dir, ".stamp")
    cur_hash = source_hash()
    old_hash = None
    if os.path.exists(stamp_path):
        with open(stamp_path) as f:
            old_hash = f.read().strip()
    stale = args.force or (old_hash != cur_hash)

    entries = []
    for name, cfg in sorted(CONFIGS.items()):
        entry = model_entry(cfg, args.out_dir)
        entries.append(entry)
        if args.only and args.only not in name:
            continue
        print(f"{name} (params={entry['param_count']:,})", flush=True)
        for which, fname in entry["artifacts"].items():
            path = os.path.join(args.out_dir, fname)
            if os.path.exists(path) and not stale:
                print(f"  {fname}: cached", flush=True)
                continue
            lower_one(cfg, which, path)

    manifest = dict(version=1, source_hash=cur_hash, models=entries)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not args.only:
        with open(stamp_path, "w") as f:
            f.write(cur_hash)
    print(f"manifest: {len(entries)} models", flush=True)


if __name__ == "__main__":
    sys.exit(main())
