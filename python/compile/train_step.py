"""Training / evaluation step functions lowered to HLO for the Rust runtime.

The paper trains every model — dense baseline, dense continuation, upcycled
MoE, MoE-from-scratch — with **Adafactor** (Shazeer & Stern 2018; paper
§A.1), continuing the inverse-square-root schedule of the dense checkpoint
without discontinuity. We implement Adafactor from scratch here (factored
second moments for ≥2-D tensors, update RMS clipping, no first moment) and
expose the learning rate / weight decay / step index as *scalar inputs*, so
the Rust coordinator owns the schedule (`rust/src/coordinator/schedule.rs`)
and one compiled artifact serves every point of every cost sweep.

Flat signature contract (what the manifest records, in this order):

    train_step(params..., opt..., batch..., lr, wd, step)
      -> (new_params..., new_opt..., loss, xent, accuracy, aux_loss, coverage)

    eval_step(params..., batch...) -> (loss, xent, accuracy, aux_loss, coverage)

    features(params..., images) -> pooled [B, d]           (vit only)

`params...` and `opt...` are sorted by tensor name; `batch...` follows
`model.batch_specs`. All floats f32.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelConfig

# Adafactor hyperparameters (Shazeer & Stern 2018 defaults).
_EPS1 = 1e-30  # regularizer inside the second-moment accumulators
_EPS2 = 1e-3   # lower bound on the RMS-scaled update (unused with fixed lr)
_CLIP = 1.0    # update RMS clipping threshold d
_DECAY_EXP = 0.8  # beta2_t = 1 - t^-0.8

METRIC_NAMES = ["loss", "xent", "accuracy", "aux_loss", "coverage"]


def factored(shape) -> bool:
    """Factor the second moment over the last two axes for ≥2-D tensors."""
    return len(shape) >= 2


def opt_specs(cfg: ModelConfig) -> List[dict]:
    """Optimizer-state inventory, sorted by name; mirrors `param_specs`."""
    specs = []
    for p in model.param_specs(cfg):
        shape = p["shape"]
        if factored(shape):
            specs.append(dict(name=f"opt/{p['name']}/vr",
                              shape=shape[:-1], dtype="f32",
                              init=dict(kind="zeros", stddev=0.0)))
            specs.append(dict(name=f"opt/{p['name']}/vc",
                              shape=shape[:-2] + shape[-1:], dtype="f32",
                              init=dict(kind="zeros", stddev=0.0)))
        else:
            specs.append(dict(name=f"opt/{p['name']}/v",
                              shape=shape, dtype="f32",
                              init=dict(kind="zeros", stddev=0.0)))
    specs.sort(key=lambda s: s["name"])
    return specs


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def adafactor_update(name: str, param, grad, opt: Dict[str, jnp.ndarray],
                     lr, wd, step):
    """One Adafactor update. Returns (new_param, {opt_name: new_value})."""
    decay = 1.0 - (step + 1.0) ** (-_DECAY_EXP)
    g2 = jnp.square(grad) + _EPS1
    if factored(param.shape):
        vr = opt[f"opt/{name}/vr"]
        vc = opt[f"opt/{name}/vc"]
        new_vr = decay * vr + (1.0 - decay) * jnp.mean(g2, axis=-1)
        new_vc = decay * vc + (1.0 - decay) * jnp.mean(g2, axis=-2)
        # Rank-1 reconstruction of the second moment (Shazeer & Stern eq. 4).
        row_mean = jnp.mean(new_vr, axis=-1, keepdims=True)
        v = (new_vr / jnp.maximum(row_mean, _EPS1))[..., None] * new_vc[
            ..., None, :]
        new_state = {f"opt/{name}/vr": new_vr, f"opt/{name}/vc": new_vc}
    else:
        v0 = opt[f"opt/{name}/v"]
        v = decay * v0 + (1.0 - decay) * g2
        new_state = {f"opt/{name}/v": v}
    u = grad * jax.lax.rsqrt(v + _EPS1)
    # Update clipping: divide by max(1, RMS(u)/d).
    u = u / jnp.maximum(1.0, _rms(u) / _CLIP)
    new_param = param - lr * u - wd * param
    return new_param, new_state


def build_train_step(cfg: ModelConfig):
    """Returns (fn, in_names, out_names): the flat, lowering-ready step."""
    p_specs = model.param_specs(cfg)
    o_specs = opt_specs(cfg)
    b_specs = model.batch_specs(cfg)
    p_names = [s["name"] for s in p_specs]
    o_names = [s["name"] for s in o_specs]
    b_names = [s["name"] for s in b_specs]

    def step_fn(*flat):
        i = 0
        params = {n: flat[i + j] for j, n in enumerate(p_names)}
        i += len(p_names)
        opt = {n: flat[i + j] for j, n in enumerate(o_names)}
        i += len(o_names)
        batch = {n: flat[i + j] for j, n in enumerate(b_names)}
        i += len(b_names)
        lr, wd, step = flat[i], flat[i + 1], flat[i + 2]

        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch), has_aux=True)(params)

        new_params = {}
        new_opt = {}
        for name in p_names:
            np_, ns = adafactor_update(name, params[name], grads[name], opt,
                                       lr, wd, step)
            new_params[name] = np_
            new_opt.update(ns)
        outs = ([new_params[n] for n in p_names]
                + [new_opt[n] for n in o_names]
                + [loss, metrics["xent"], metrics["accuracy"],
                   metrics["aux_loss"], metrics["coverage"]])
        return tuple(outs)

    in_names = p_names + o_names + b_names + ["lr", "wd", "step"]
    out_names = p_names + o_names + METRIC_NAMES
    return step_fn, in_names, out_names


def build_eval_step(cfg: ModelConfig):
    p_names = [s["name"] for s in model.param_specs(cfg)]
    b_names = [s["name"] for s in model.batch_specs(cfg)]

    def eval_fn(*flat):
        params = {n: flat[j] for j, n in enumerate(p_names)}
        batch = {n: flat[len(p_names) + j] for j, n in enumerate(b_names)}
        loss, metrics = model.loss_fn(cfg, params, batch)
        return (loss, metrics["xent"], metrics["accuracy"],
                metrics["aux_loss"], metrics["coverage"])

    return eval_fn, p_names + b_names, METRIC_NAMES


def build_features(cfg: ModelConfig):
    """ViT frozen-representation extractor for few-shot linear eval (§A.2.2)."""
    assert cfg.family == "vit"
    p_names = [s["name"] for s in model.param_specs(cfg)]

    def feat_fn(*flat):
        params = {n: flat[j] for j, n in enumerate(p_names)}
        images = flat[len(p_names)]
        feats, _ = model.vit_features(cfg, params, images)
        return (feats,)

    return feat_fn, p_names + ["images"], ["features"]


def example_args(cfg: ModelConfig, which: str) -> Tuple:
    """ShapeDtypeStructs for lowering (`which` ∈ train/eval/features)."""
    def sds(spec):
        dt = {"f32": jnp.float32, "i32": jnp.int32}[spec["dtype"]]
        return jax.ShapeDtypeStruct(tuple(spec["shape"]), dt)

    p = [sds(s) for s in model.param_specs(cfg)]
    if which == "train":
        o = [sds(s) for s in opt_specs(cfg)]
        b = [sds(s) for s in model.batch_specs(cfg)]
        scalars = [jax.ShapeDtypeStruct((), jnp.float32)] * 3
        return tuple(p + o + b + scalars)
    if which == "eval":
        b = [sds(s) for s in model.batch_specs(cfg)]
        return tuple(p + b)
    if which == "features":
        img = jax.ShapeDtypeStruct(
            (cfg.batch_size, cfg.image_size, cfg.image_size, cfg.channels),
            jnp.float32)
        return tuple(p + [img])
    raise ValueError(which)
