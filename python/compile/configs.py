"""Model configurations and the named artifact set.

Every entry in `ARTIFACT_SET` is lowered by `aot.py` to
`artifacts/<name>_{train,eval[,features]}.hlo.txt` and described in
`artifacts/manifest.json`. The set covers every experiment in DESIGN.md §4:
the core comparisons (Fig. 2–5), the ablation sweeps (capacity factor, number
of experts, number/placement of MoE layers, router type, group size,
renormalization), and the e2e `small` scale used by `examples/e2e_language`.

Scale philosophy (repro band 0 → simulate): geometry mirrors the paper —
half of the MLP layers become MoE layers, interleaved every-other for the LM
(paper §A.1.1: "every other layer was upcycled ... starting with the second
layer") and last-k for ViT (paper §B.4) — while widths shrink so the whole
figure suite trains on a CPU PJRT client in minutes.
"""

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    """Sparse-layer configuration for one tower (encoder or decoder)."""

    num_experts: int = 8
    capacity_factor: float = 2.0
    # "ec" = Expert Choice (paper default for encoders),
    # "top1"/"top2" = token-choice Top-K (paper default for LM decoder).
    router_type: str = "ec"
    # Indices of transformer blocks whose MLP is replaced by a MoE layer.
    moe_layers: Tuple[int, ...] = ()
    # Routing group size in tokens (Fig. 16). 0 → one group per batch row set.
    group_size: int = 0
    # Renormalize combine weights to sum to 1 (Appendix B.7).
    renormalize: bool = False
    # Batch Prioritized Routing for Top-K (Appendix B.1).
    bpr: bool = False
    # Auxiliary load-balance loss scale for Top-K (paper §A.1.1: 0.01).
    aux_loss_scale: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "lm" | "vit"
    d_model: int = 64
    d_ff: int = 256
    num_heads: int = 4
    num_layers: int = 4  # encoder blocks
    num_decoder_layers: int = 4  # lm only
    vocab_size: int = 256  # lm only
    enc_len: int = 32  # lm only
    dec_len: int = 16  # lm only
    image_size: int = 32  # vit only
    patch_size: int = 8  # vit only
    channels: int = 3  # vit only
    num_classes: int = 16  # vit only
    batch_size: int = 8
    enc_moe: Optional[MoeSpec] = None
    dec_moe: Optional[MoeSpec] = None
    use_pallas: bool = True

    @property
    def is_sparse(self) -> bool:
        return self.enc_moe is not None or self.dec_moe is not None

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def every_other(n_layers: int) -> Tuple[int, ...]:
    """Paper §A.1.1: upcycle every other layer, starting with the second."""
    return tuple(range(1, n_layers, 2))


def last_k(n_layers: int, k: int) -> Tuple[int, ...]:
    """Paper §B.4 vision default: MoE layers in the last k blocks."""
    return tuple(range(n_layers - k, n_layers))


def first_k(k: int) -> Tuple[int, ...]:
    return tuple(range(k))


# ---------------------------------------------------------------------------
# Named configurations
# ---------------------------------------------------------------------------

_LM_TINY = dict(
    family="lm", d_model=64, d_ff=256, num_heads=4, num_layers=4,
    num_decoder_layers=4, vocab_size=256, enc_len=32, dec_len=16, batch_size=8,
)
_VIT_TINY = dict(
    family="vit", d_model=64, d_ff=256, num_heads=4, num_layers=6,
    image_size=32, patch_size=8, num_classes=16, batch_size=16,
)
_LM_SMALL = dict(
    family="lm", d_model=256, d_ff=1024, num_heads=8, num_layers=6,
    num_decoder_layers=6, vocab_size=8192, enc_len=128, dec_len=32,
    batch_size=8,
)


def _lm_moe(name: str, *, experts=8, cap=2.0, enc_router="ec",
            dec_router="top2", enc_layers=None, dec_layers=None,
            group_size=0, renorm=False, bpr=False, base=None, **over):
    base = dict(base or _LM_TINY)
    base.update(over)
    n_enc = base["num_layers"]
    n_dec = base["num_decoder_layers"]
    enc_layers = every_other(n_enc) if enc_layers is None else tuple(enc_layers)
    dec_layers = every_other(n_dec) if dec_layers is None else tuple(dec_layers)
    return ModelConfig(
        name=name,
        enc_moe=MoeSpec(num_experts=experts, capacity_factor=cap,
                        router_type=enc_router, moe_layers=enc_layers,
                        group_size=group_size, renormalize=renorm, bpr=bpr),
        dec_moe=MoeSpec(num_experts=experts, capacity_factor=cap,
                        router_type=dec_router, moe_layers=dec_layers,
                        group_size=group_size, renormalize=renorm, bpr=bpr),
        **base,
    )


def _vit_moe(name: str, *, experts=8, cap=2.0, router="ec", layers=None,
             renorm=True, group_size=0, base=None, **over):
    base = dict(base or _VIT_TINY)
    base.update(over)
    n = base["num_layers"]
    layers = last_k(n, n // 2) if layers is None else tuple(layers)
    return ModelConfig(
        name=name,
        enc_moe=MoeSpec(num_experts=experts, capacity_factor=cap,
                        router_type=router, moe_layers=layers,
                        renormalize=renorm, group_size=group_size),
        **base,
    )


def build_artifact_set() -> List[ModelConfig]:
    cfgs: List[ModelConfig] = [
        # ---- core language family (Figs. 2–5, Table 5) ----
        ModelConfig(name="lm_tiny_dense", **_LM_TINY),
        _lm_moe("lm_tiny_moe_e8_c2"),  # default upcycle target
        # dense upcycling baseline (Fig. 5): depth-tiled 1.5x deeper dense
        ModelConfig(name="lm_tiny_dense_tiled", **{**_LM_TINY,
                    "num_layers": 6, "num_decoder_layers": 6}),
        # ---- capacity-factor ablation (Fig. 9) ----
        _lm_moe("lm_tiny_moe_e8_c1", cap=1.0),
        _lm_moe("lm_tiny_moe_e8_c3", cap=3.0),
        # ---- number of experts (Figs. 10/11/18) ----
        _lm_moe("lm_tiny_moe_e2_c2", experts=2),
        _lm_moe("lm_tiny_moe_e4_c2", experts=4),
        _lm_moe("lm_tiny_moe_e16_c2", experts=16),
        # ---- router type (Table 2 / Fig. 8) ----
        _lm_moe("lm_tiny_moe_e8_c2_top2", enc_router="top2"),
        _lm_moe("lm_tiny_moe_e8_c2_top1", enc_router="top1", dec_router="top1"),
        _lm_moe("lm_tiny_moe_e8_c2_top2bpr", enc_router="top2", bpr=True),
        # ---- combine-weight renormalization, LM side (B.7) ----
        _lm_moe("lm_tiny_moe_e8_c2_renorm", renorm=True),
        # ---- MoE layer count / placement (Figs. 12, 17) ----
        _lm_moe("lm_tiny_moe_last1", enc_layers=last_k(4, 1), dec_layers=last_k(4, 1)),
        _lm_moe("lm_tiny_moe_last2", enc_layers=last_k(4, 2), dec_layers=last_k(4, 2)),
        _lm_moe("lm_tiny_moe_last3", enc_layers=last_k(4, 3), dec_layers=last_k(4, 3)),
        _lm_moe("lm_tiny_moe_first2", enc_layers=first_k(2), dec_layers=first_k(2)),
        # ---- routing group size (Fig. 16) ----
        _lm_moe("lm_tiny_moe_e8_c2_g16", group_size=16),
        _lm_moe("lm_tiny_moe_e8_c2_g64", group_size=64),
        # ---- core vision family (Figs. 2–4, 6, Table 4) ----
        ModelConfig(name="vit_tiny_dense", **_VIT_TINY),
        _vit_moe("vit_tiny_moe_e8_c2"),
        _vit_moe("vit_tiny_moe_e8_c1", cap=1.0),  # Fig. 6 ablation uses C=1
        # ---- renormalization from scratch (Table 3) ----
        _vit_moe("vit_tiny_moe_e8_c2_norenorm", renorm=False),
        _vit_moe("vit_tiny_moe_e8_c1_norenorm", cap=1.0, renorm=False),
        # ---- vision router type (Table 2) ----
        _vit_moe("vit_tiny_moe_e8_c2_top2", router="top2", renorm=False),
        # ---- e2e `small` scale (examples/e2e_language) ----
        ModelConfig(name="lm_small_dense", **_LM_SMALL),
        _lm_moe("lm_small_moe_e8_c2", base=_LM_SMALL),
    ]
    names = [c.name for c in cfgs]
    assert len(names) == len(set(names)), "duplicate config names"
    return cfgs


CONFIGS: Dict[str, ModelConfig] = {c.name: c for c in build_artifact_set()}


def moe_spec_to_json(spec: Optional[MoeSpec]) -> Optional[dict]:
    return None if spec is None else dataclasses.asdict(spec)


def config_to_json(cfg: ModelConfig) -> dict:
    d = dataclasses.asdict(cfg)
    return d
