"""Reference implementation of the upcycling model surgery (paper Figure 1).

The *production* surgery lives in the Rust coordinator
(`rust/src/upcycle/`) and operates on checkpoints by tensor name; this module
is its executable specification, used by the pytest suite for the
function-preservation property (Appendix B.8 / Fig. 15): with combine-weight
renormalization, every token selected by at least one expert gets exactly the
dense model's output at initialization.

Recipe (paper §3): the new model has the same blocks as the dense model; a
subset of MLP layers become MoE layers whose E experts are *identical copies*
of the original MLP; the router is freshly initialized (N(0, 0.02)); every
other tensor is copied across.
"""

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import model, train_step
from .configs import ModelConfig

Params = Dict[str, jnp.ndarray]


def upcycle_params(dense: Params, sparse_cfg: ModelConfig, seed: int = 0,
                   expert_noise: float = 0.0,
                   load_experts: bool = True) -> Params:
    """Dense parameters → sparse (MoE) parameters.

    Args:
      dense: parameter dict of the dense parent (same block geometry).
      sparse_cfg: target MoE configuration.
      seed: RNG seed for router init (and expert noise / random experts).
      expert_noise: stddev of independent Gaussian noise added to each expert
        copy (Appendix B.9; 0.0 = the paper's standard recipe).
      load_experts: False = randomly initialize experts instead of copying
        the dense MLP (Appendix B.5 ablation).
    """
    rng = np.random.default_rng(seed)
    out: Params = {}
    for spec in model.param_specs(sparse_cfg):
        name, shape = spec["name"], tuple(spec["shape"])
        if "/moe/router" in name:
            out[name] = jnp.asarray(
                rng.normal(0.0, 0.02, size=shape), jnp.float32)
        elif "/moe/wi" in name or "/moe/wo" in name:
            e = shape[0]
            dense_name = name.replace("/moe/", "/mlp/")
            if load_experts:
                w = jnp.broadcast_to(dense[dense_name][None], shape)
                if expert_noise > 0.0:
                    w = w + jnp.asarray(
                        rng.normal(0.0, expert_noise, size=shape), jnp.float32)
                out[name] = jnp.array(w)
            else:
                std = spec["init"]["stddev"]
                out[name] = jnp.asarray(
                    rng.normal(0.0, std, size=shape), jnp.float32)
        else:
            out[name] = dense[name]
    return out


def upcycle_opt_state(dense_opt: Dict[str, jnp.ndarray],
                      sparse_cfg: ModelConfig,
                      load_optimizer: bool = True) -> Dict[str, jnp.ndarray]:
    """Optimizer-state surgery (Appendix B.6, vision models only).

    Factored Adafactor accumulators of each dense MLP are broadcast to every
    expert; router state starts at zero (there is nothing to resume, paper
    footnote 6). With load_optimizer=False all state is zeroed (the paper's
    language setting).
    """
    out = {}
    for spec in train_step.opt_specs(sparse_cfg):
        name, shape = spec["name"], tuple(spec["shape"])
        base = name[len("opt/"):].rsplit("/", 1)[0]  # the parameter name
        slot = name.rsplit("/", 1)[1]
        if not load_optimizer:
            out[name] = jnp.zeros(shape, jnp.float32)
        elif "/moe/router" in base:
            out[name] = jnp.zeros(shape, jnp.float32)
        elif "/moe/wi" in base or "/moe/wo" in base:
            dense_name = f"opt/{base.replace('/moe/', '/mlp/')}/{slot}"
            out[name] = jnp.broadcast_to(dense_opt[dense_name][None], shape)
        else:
            out[name] = dense_opt[name]
    return out


def depth_tile_params(dense: Params, dense_cfg: ModelConfig,
                      tiled_cfg: ModelConfig) -> Params:
    """Dense upcycling baseline (Fig. 5): warm-start a *deeper* dense model
    by tiling blocks of the shallow parent (Rae et al. 2021 "depth tiling").

    New block i takes the weights of parent block `i * n_old // n_new`
    (order-preserving contiguous tiling); non-block tensors are copied.
    """
    def src_block(i: int, n_new: int, n_old: int) -> int:
        return i * n_old // n_new

    out: Params = {}
    for spec in model.param_specs(tiled_cfg):
        name = spec["name"]
        if "/block_" in name:
            tower = name.split("/")[0]
            b = int(name.split("/block_")[1][:2])
            n_new = (tiled_cfg.num_layers if tower == "enc"
                     else tiled_cfg.num_decoder_layers)
            n_old = (dense_cfg.num_layers if tower == "enc"
                     else dense_cfg.num_decoder_layers)
            src = src_block(b, n_new, n_old)
            src_name = name.replace(f"block_{b:02d}", f"block_{src:02d}")
            out[name] = dense[src_name]
        else:
            out[name] = dense[name]
    return out
