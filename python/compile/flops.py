"""Analytic FLOPs accounting for every model configuration.

Used twice: (i) recorded in the manifest so the Rust cost model
(`rust/src/costmodel/`) can translate training steps into the paper's
x-axis units (TPU-core-days / ExaFLOPs, Figs. 2–5 and Tables 4–5), and
(ii) as the L2 performance audit baseline (EXPERIMENTS.md §Perf).

Counting conventions: one multiply-add = 2 FLOPs; backward pass = 2× forward
(so train = 3× forward); router/softmax/norm costs included, elementwise
negligibles ignored — the same conventions used for the paper's headline
"MoE adds ~C× MLP FLOPs + router" arithmetic (§2.1 footnote 2).
"""

from .configs import ModelConfig, MoeSpec
from typing import Optional


def _attn_flops(n_q: int, n_kv: int, d: int) -> float:
    """Per-example attention FLOPs: projections + logits + weighted values."""
    proj = 2.0 * (n_q * d * d * 2 + n_kv * d * d * 2)  # q,o over n_q; k,v over n_kv
    scores = 2.0 * n_q * n_kv * d * 2  # QK^T and PV
    return proj + scores


def _ffn_flops(n_tok: int, d: int, ff: int, spec: Optional[MoeSpec],
               layer: int) -> float:
    dense = 2.0 * n_tok * d * ff * 2
    if spec is None or layer not in spec.moe_layers:
        return dense
    # MoE: every token is processed by C experts on average (Expert Choice)
    # or K·(C-limited) experts (Top-K); both scale the MLP cost by ~C.
    mult = spec.capacity_factor
    if spec.router_type in ("top1", "top2"):
        mult = min(1.0 if spec.router_type == "top1" else 2.0,
                   spec.capacity_factor)
    router = 2.0 * n_tok * d * spec.num_experts
    return dense * mult + router


def fwd_flops_per_example(cfg: ModelConfig) -> float:
    d, ff = cfg.d_model, cfg.d_ff
    total = 0.0
    if cfg.family == "lm":
        se, sd = cfg.enc_len, cfg.dec_len
        for b in range(cfg.num_layers):
            total += _attn_flops(se, se, d)
            total += _ffn_flops(se, d, ff, cfg.enc_moe, b)
        for b in range(cfg.num_decoder_layers):
            total += _attn_flops(sd, sd, d)  # causal self-attention
            total += _attn_flops(sd, se, d)  # cross-attention
            total += _ffn_flops(sd, d, ff, cfg.dec_moe, b)
        total += 2.0 * sd * d * cfg.vocab_size  # tied softmax logits
    else:
        n = cfg.num_patches
        patch_dim = cfg.patch_size ** 2 * cfg.channels
        total += 2.0 * n * patch_dim * d
        for b in range(cfg.num_layers):
            total += _attn_flops(n, n, d)
            total += _ffn_flops(n, d, ff, cfg.enc_moe, b)
        total += 2.0 * d * cfg.num_classes
    return total


def train_flops_per_step(cfg: ModelConfig) -> float:
    return 3.0 * fwd_flops_per_example(cfg) * cfg.batch_size


def eval_flops_per_step(cfg: ModelConfig) -> float:
    return fwd_flops_per_example(cfg) * cfg.batch_size
