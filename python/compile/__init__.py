"""Build-time compile path: JAX models + Pallas kernels -> HLO artifacts."""
