//! Property tests for the policy-driven serving subsystem (`serve::`):
//!
//! * the FIFO default under the [`SchedulerPolicy`] seam is **bitwise
//!   identical** to the pre-refactor engine, checked against a golden
//!   re-implementation of the old scheduling loop on random traces;
//! * every policy's saturation behavior is a pure function of
//!   (trace, [`ServeSpec`]) — bitwise-reproducible across reruns and
//!   across compute thread counts (`util::serial_compute`);
//! * conservation: every request in a multi-tenant trace ends in exactly
//!   one completion or one named shed record, and the token budget holds;
//! * `Priority` with an aging floor never starves the low class;
//! * `FairShare` keeps per-tenant served tokens within one request of each
//!   other while every tenant still has pending work;
//! * `SloDeadline` only sheds genuinely lapsed deadlines and never serves
//!   a request after its deadline has passed.
//!
//! [`SchedulerPolicy`]: sparse_upcycle::serve::SchedulerPolicy
//! [`ServeSpec`]: sparse_upcycle::serve::ServeSpec

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use sparse_upcycle::init::init_params;
use sparse_upcycle::manifest::{Manifest, ModelEntry};
use sparse_upcycle::runtime::{tensors_from_checkpoint, LoadedModel, Runtime};
use sparse_upcycle::serve::{
    generate, synthetic_trace, tokens_per_request, ArrivalProcess, Engine, PolicyKind,
    ServeReport, ServeSpec, ShedMode, ShedReason, TrafficSpec,
};
use sparse_upcycle::tensor::Tensor;
use sparse_upcycle::util::rng::Rng;
use sparse_upcycle::util::serial_compute;

fn setup(name: &str) -> (ModelEntry, LoadedModel, Vec<Tensor>) {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let entry = manifest.model(name).unwrap().clone();
    let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
    let params = tensors_from_checkpoint(&init_params(&entry, 5).unwrap(), &entry.params).unwrap();
    (entry, model, params)
}

/// The virtual timeline of one completion — everything the scheduler
/// decides (model outputs are covered by the engine's own bitwise tests).
fn timeline(r: &ServeReport) -> Vec<(u64, u64, u64, usize)> {
    r.completions.iter().map(|c| (c.id, c.start_us, c.finish_us, c.batch_index)).collect()
}

/// Golden re-implementation of the **pre-refactor** FIFO engine loop: jump
/// the virtual clock to the next arrival when idle, admit everything due,
/// compose front-of-queue micro-batches up to the token budget / request
/// cap (first pick always fits), advance the clock by the service model.
/// Returns `(id, start_us, finish_us, batch_index)` per request in service
/// order — what the old engine's completions carried.
fn golden_fifo(
    arrivals: &[u64],
    tpr: usize,
    budget: usize,
    max_requests: usize,
    base_us: u64,
    per_token_us: u64,
) -> Vec<(u64, u64, u64, usize)> {
    let n = arrivals.len();
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut admitted = 0usize;
    let mut v_now = 0u64;
    let mut out = Vec::with_capacity(n);
    let mut batch_index = 0usize;
    while out.len() < n {
        if queue.is_empty() && arrivals[admitted] > v_now {
            v_now = arrivals[admitted];
        }
        while admitted < n && arrivals[admitted] <= v_now {
            queue.push_back(admitted);
            admitted += 1;
        }
        let mut picked = Vec::new();
        let mut tokens = 0usize;
        while let Some(&i) = queue.front() {
            let full =
                tokens + tpr > budget || (max_requests > 0 && picked.len() >= max_requests);
            if !picked.is_empty() && full {
                break;
            }
            picked.push(i);
            tokens += tpr;
            queue.pop_front();
        }
        let service = base_us + per_token_us * tokens as u64;
        let (start, finish) = (v_now, v_now + service);
        v_now = finish;
        for id in picked {
            out.push((id as u64, start, finish, batch_index));
        }
        batch_index += 1;
    }
    out
}

/// The refactor's central contract: the default FIFO plan, now routed
/// through `policy_for` + `Admission`, produces the exact virtual timeline
/// of the pre-refactor engine on random traces — same clock jumps, same
/// batch composition, same service arithmetic.
#[test]
fn fifo_seam_matches_the_pre_refactor_golden_timeline() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let tpr = tokens_per_request(&entry);
    let mut rng = Rng::new(0xb00b1e5);
    for case in 0..12u64 {
        let n = 1 + rng.below(9);
        let gap = [0u64, 40, 400, 2500][rng.below(4)];
        let budget_requests = 1 + rng.below(5);
        let spec = ServeSpec {
            max_batch_tokens: budget_requests * tpr,
            max_batch_requests: if rng.below(3) == 0 { 1 + rng.below(4) } else { 0 },
            ..ServeSpec::default()
        };
        let trace = synthetic_trace(&entry, n, 1000 + case, gap);
        let arrivals: Vec<u64> = trace.iter().map(|r| r.arrival_us).collect();
        let engine = Engine::new(&model, &params, spec).unwrap();
        let report = engine.run_trace(trace).unwrap();
        let golden = golden_fifo(
            &arrivals,
            tpr,
            spec.max_batch_tokens,
            spec.max_batch_requests,
            spec.service_base_us,
            spec.service_per_token_us,
        );
        assert!(report.sheds.is_empty(), "case {case}: the unbounded default never sheds");
        assert_eq!(timeline(&report), golden, "case {case}: FIFO timeline must be bitwise");
        let batches = golden.iter().map(|t| t.3).max().map(|b| b + 1).unwrap_or(0);
        assert_eq!(report.batches.len(), batches, "case {case}");
    }
}

/// Saturation behavior of **every** policy is a pure function of
/// (trace, spec): two runs and a `serial_compute` run (different compute
/// thread count) agree bitwise on the virtual timeline, predictions, and
/// the shed log.
#[test]
fn every_policy_is_a_pure_function_of_trace_and_spec() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let tpr = tokens_per_request(&entry);
    for kind in
        [PolicyKind::Fifo, PolicyKind::Priority, PolicyKind::FairShare, PolicyKind::SloDeadline]
    {
        let spec = ServeSpec {
            policy: kind,
            max_batch_tokens: 2 * tpr,
            queue_capacity: 4,
            priority_floor_us: if kind == PolicyKind::Priority { 5_000 } else { 0 },
            slo_default_us: if kind == PolicyKind::SloDeadline { 20_000 } else { 0 },
            ..ServeSpec::default()
        };
        let process = ArrivalProcess::Bursty { mean_gap_us: 50, burst: 6 };
        let trace = generate(&entry, &TrafficSpec::standard(process, 3, 18, 7)).unwrap();
        let engine = Engine::new(&model, &params, spec).unwrap();
        let a = engine.run_trace(trace.clone()).unwrap();
        let b = engine.run_trace(trace.clone()).unwrap();
        let c = serial_compute(|| engine.run_trace(trace.clone()).unwrap());
        for (label, other) in [("rerun", &b), ("serial threads", &c)] {
            assert_eq!(
                timeline(&a),
                timeline(other),
                "{}: {label} changed the virtual timeline",
                kind.name()
            );
            assert_eq!(a.sheds, other.sheds, "{}: {label} changed the shed log", kind.name());
            for (x, y) in a.completions.iter().zip(&other.completions) {
                assert_eq!(x.predictions, y.predictions, "{}: {label}", kind.name());
                assert_eq!(x.score.to_bits(), y.score.to_bits(), "{}: {label}", kind.name());
            }
        }
    }
}

/// Conservation under load shedding, for every policy × arrival process:
/// every request id in the trace appears in exactly one completion or one
/// shed record (never both, never neither), every shed carries a named
/// reason at a plausible instant, and the token budget holds per batch.
#[test]
fn every_request_completes_or_sheds_exactly_once() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let tpr = tokens_per_request(&entry);
    let processes = [
        ArrivalProcess::Uniform { gap_us: 120 },
        ArrivalProcess::Bursty { mean_gap_us: 80, burst: 8 },
        ArrivalProcess::Diurnal { min_gap_us: 20, max_gap_us: 400, period: 10 },
        ArrivalProcess::Adversarial { gap_us: 200, flood_every: 6, flood: 3 },
    ];
    for (p, process) in processes.into_iter().enumerate() {
        for kind in [
            PolicyKind::Fifo,
            PolicyKind::Priority,
            PolicyKind::FairShare,
            PolicyKind::SloDeadline,
        ] {
            let shed =
                if kind == PolicyKind::SloDeadline { ShedMode::Evict } else { ShedMode::Reject };
            let spec = ServeSpec {
                policy: kind,
                max_batch_tokens: 2 * tpr,
                queue_capacity: 3,
                shed,
                slo_default_us: if kind == PolicyKind::SloDeadline { 5_000 } else { 0 },
                ..ServeSpec::default()
            };
            let n = 20usize;
            let traffic = TrafficSpec::standard(process, 3, n, 40 + p as u64);
            let trace = generate(&entry, &traffic).unwrap();
            let engine = Engine::new(&model, &params, spec).unwrap();
            let report = engine.run_trace(trace).unwrap();
            let label = format!("{} over {}", kind.name(), process.name());

            assert_eq!(report.completions.len() + report.sheds.len(), n, "{label}");
            let mut seen = BTreeSet::new();
            for c in &report.completions {
                assert!(seen.insert(c.id), "{label}: id {} completed twice", c.id);
            }
            for s in &report.sheds {
                assert!(seen.insert(s.id), "{label}: id {} both completed and shed", s.id);
                assert!(s.shed_us >= s.arrival_us, "{label}: shed before arrival");
                assert!(
                    ["queue_full", "evicted", "deadline_expired"].contains(&s.reason.name()),
                    "{label}: unknown shed reason"
                );
            }
            assert_eq!(seen.len(), n, "{label}: ids must partition the trace");
            for b in &report.batches {
                assert_eq!(b.tokens, b.requests * tpr, "{label}");
                assert!(
                    b.tokens <= spec.max_batch_tokens || b.requests == 1,
                    "{label}: batch {} blew the token budget",
                    b.index
                );
            }
        }
    }
}

/// Priority with an aging floor never starves the low class: in a burst
/// where one low-priority request competes with a deep high-priority
/// backlog, pure priority (floor 0) serves it dead last, while a floor of
/// 2 service times promotes it within `floor + 2·service`.
#[test]
fn priority_floor_prevents_starvation_of_the_low_class() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let service = 100u64; // base only: per-token 0 keeps arithmetic exact
    let mk_trace = || {
        let mut trace = synthetic_trace(&entry, 9, 21, 0); // all arrive at t = 0
        for r in trace.iter_mut() {
            r.priority = if r.id == 0 { 0 } else { 2 };
        }
        trace
    };
    let run = |floor_us: u64| {
        let spec = ServeSpec {
            policy: PolicyKind::Priority,
            max_batch_requests: 1,
            service_base_us: service,
            service_per_token_us: 0,
            priority_floor_us: floor_us,
            ..ServeSpec::default()
        };
        Engine::new(&model, &params, spec).unwrap().run_trace(mk_trace()).unwrap()
    };

    let starved = run(0);
    assert_eq!(starved.completions.len(), 9);
    assert_eq!(
        starved.completions.last().unwrap().id,
        0,
        "pure priority serves the low class dead last"
    );
    assert_eq!(starved.completions.last().unwrap().finish_us, 9 * service);

    let floored = run(2 * service);
    assert_eq!(floored.completions.len(), 9);
    let low = floored.completions.iter().find(|c| c.id == 0).unwrap();
    assert!(
        low.finish_us <= 2 * service + 2 * service,
        "floor must bound the low-class latency: finished at {}",
        low.finish_us
    );
    // The aging floor is itself deterministic FIFO among overdue requests:
    // once two requests are both past the floor, the earlier (arrival, id)
    // is never scheduled after the later one.
    for a in &floored.completions {
        for b in &floored.completions {
            let both_overdue = a.start_us >= a.arrival_us + 2 * service
                && b.start_us >= b.arrival_us + 2 * service;
            if both_overdue && (a.arrival_us, a.id) < (b.arrival_us, b.id) {
                assert!(
                    a.start_us <= b.start_us,
                    "overdue requests must drain FIFO: {} after {}",
                    a.id,
                    b.id
                );
            }
        }
    }
}

/// FairShare keeps served tokens balanced: replaying a 3-tenant burst one
/// request at a time, after every pick the per-tenant served-token spread
/// stays within one request's cost among tenants that still have pending
/// work — and every tenant finishes with its full share.
#[test]
fn fair_share_bounds_the_per_tenant_token_spread() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let tpr = tokens_per_request(&entry) as i64;
    let mut trace = synthetic_trace(&entry, 12, 33, 0); // all arrive at t = 0
    for r in trace.iter_mut() {
        r.tenant = r.id % 3;
    }
    let spec = ServeSpec {
        policy: PolicyKind::FairShare,
        max_batch_requests: 1,
        ..ServeSpec::default()
    };
    let report = Engine::new(&model, &params, spec).unwrap().run_trace(trace).unwrap();
    assert_eq!(report.completions.len(), 12);

    let mut served: BTreeMap<u64, i64> = BTreeMap::new();
    let mut remaining: BTreeMap<u64, i64> = BTreeMap::new();
    for t in 0..3u64 {
        served.insert(t, 0);
        remaining.insert(t, 4);
    }
    for c in &report.completions {
        *served.get_mut(&c.tenant).unwrap() += tpr;
        *remaining.get_mut(&c.tenant).unwrap() -= 1;
        let active: Vec<i64> =
            served.iter().filter(|(t, _)| remaining[t] > 0).map(|(_, s)| *s).collect();
        if active.len() > 1 {
            let spread = active.iter().max().unwrap() - active.iter().min().unwrap();
            assert!(
                spread <= tpr,
                "after serving id {} the active-tenant spread hit {spread} (> {tpr})",
                c.id
            );
        }
    }
    assert!(served.values().all(|&s| s == 4 * tpr), "every tenant gets its full share");
}

/// SloDeadline sheds exactly the lapsed deadlines: every shed record's
/// deadline had truly passed at the shed instant, every served request
/// started at or before its deadline, and the earliest-deadline-first
/// order drains a uniform burst FIFO.
#[test]
fn slo_policy_sheds_only_lapsed_deadlines() {
    let (entry, model, params) = setup("lm_tiny_dense");
    let service = 100u64;
    let slo = 350u64;
    let spec = ServeSpec {
        policy: PolicyKind::SloDeadline,
        max_batch_requests: 1,
        service_base_us: service,
        service_per_token_us: 0,
        slo_default_us: slo,
        ..ServeSpec::default()
    };
    let trace = synthetic_trace(&entry, 10, 55, 0); // burst of 10 at t = 0
    let report = Engine::new(&model, &params, spec).unwrap().run_trace(trace).unwrap();

    // One request per 100 µs against a 350 µs deadline: ids 0–3 make it
    // (the last starts at 300), the rest lapse at t = 400.
    assert_eq!(report.completions.len(), 4, "{:?}", timeline(&report));
    assert_eq!(report.sheds.len(), 6);
    for c in &report.completions {
        assert!(c.start_us <= c.arrival_us + slo, "id {} served past its deadline", c.id);
    }
    let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3], "equal deadlines tie-break FIFO");
    for s in &report.sheds {
        assert_eq!(s.reason, ShedReason::DeadlineExpired);
        assert!(s.shed_us > s.arrival_us + slo, "id {} shed before its deadline lapsed", s.id);
    }

    // An explicit per-request deadline (not the slo default) is honored
    // as-is at admission: the tighter deadline jumps the EDF order.
    let mut trace = synthetic_trace(&entry, 2, 56, 0);
    trace[1].deadline_us = 50;
    let report = Engine::new(&model, &params, spec).unwrap().run_trace(trace).unwrap();
    let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    assert_eq!(ids, vec![1, 0], "the explicit 50 µs deadline outranks the 350 µs default");
    assert!(report.sheds.is_empty(), "both still start before their deadlines lapse");
}
