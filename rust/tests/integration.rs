//! Integration tests: the full stack — manifest → backend → surgery →
//! train/eval → save → serve.
//!
//! The default build exercises the **native CPU backend** end-to-end on the
//! built-in model zoo: dense pretraining, checkpoint round-trip, upcycling
//! surgery, continued sparse training, and signature-mismatch rejection. No
//! artifacts, Python or XLA required.
//!
//! The PJRT variant of the same scenario (AOT HLO artifacts) is gated behind
//! the `pjrt` cargo feature and additionally no-ops gracefully when
//! `artifacts/` has not been built.

use sparse_upcycle::coordinator::{Evaluator, MeshConfig, Schedule, TrainConfig, TrainState};
use sparse_upcycle::data::text::{HmmCorpus, HmmSpec, TextPipeline};
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::runtime::Runtime;
use sparse_upcycle::upcycle::{upcycle_params, UpcycleOptions};

fn lm_pipeline(entry: &sparse_upcycle::manifest::ModelEntry, shard: u64) -> TextPipeline {
    TextPipeline::new(
        HmmCorpus::new(
            HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
            1,
        ),
        entry.config.batch_size,
        entry.config.enc_len,
        entry.config.dec_len,
        1,
        shard,
    )
}

/// Native end-to-end smoke: init → train → checkpoint round-trip → upcycle →
/// ≥3 further steps with the loss decreasing, all on the native backend.
#[test]
fn native_full_stack() {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    assert_eq!(runtime.platform(), "native-cpu");

    // ---------------------------------------------------------------- dense
    let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
    let dense = runtime.load_model(&manifest, "lm_tiny_dense", &["train", "eval"]).unwrap();
    assert!(dense.has("train") && dense.has("eval") && !dense.has("features"));

    let mut state = TrainState::from_checkpoints(
        &dense_entry,
        &init_params(&dense_entry, 3).unwrap(),
        &init_opt_state(&dense_entry).unwrap(),
    )
    .unwrap();
    assert_eq!(state.params.len(), dense_entry.params.len());

    let mut pipe = lm_pipeline(&dense_entry, 0);
    let mut held = lm_pipeline(&dense_entry, 99);
    let evaluator = Evaluator::from_source(&mut held, 2);

    // Scenario 1: training reduces the held-out loss from the random-init
    // plateau (≈ ln V for a 256-token vocabulary).
    let m0 = evaluator.eval(&dense, &state).unwrap();
    let loss0 = m0["loss"];
    assert!((4.5..7.0).contains(&loss0), "initial loss {loss0} implausible");

    let cfg = TrainConfig {
        steps: 60,
        schedule: Schedule::t5_pretrain(0.01, 20),
        weight_decay: 0.0,
        eval_every: 0,
        log_every: 0,
    };
    let series =
        sparse_upcycle::coordinator::train(&dense, &mut state, &mut pipe, &evaluator, &cfg, "t")
            .unwrap();
    let loss1 = series.last().unwrap().values["loss"];
    assert!(loss1 < loss0 - 0.1, "60 steps must reduce held-out loss: {loss0} -> {loss1}");
    assert_eq!(state.step, 60);

    // Scenario 2: checkpoint round-trip preserves evaluation exactly.
    let (p_ck, o_ck) = state.to_checkpoints(&dense_entry, "it").unwrap();
    let dir = std::env::temp_dir().join("supc_integration");
    let pp = dir.join("p.supc");
    let op = dir.join("o.supc");
    p_ck.save(&pp).unwrap();
    o_ck.save(&op).unwrap();
    let p_back = sparse_upcycle::checkpoint::Checkpoint::load(&pp).unwrap();
    let o_back = sparse_upcycle::checkpoint::Checkpoint::load(&op).unwrap();
    let state2 = TrainState::from_checkpoints(&dense_entry, &p_back, &o_back).unwrap();
    let m_a = evaluator.eval(&dense, &state).unwrap();
    let m_b = evaluator.eval(&dense, &state2).unwrap();
    assert_eq!(m_a["loss"], m_b["loss"], "checkpoint round-trip must be exact");

    // Scenario 3: the upcycled model evaluates close to the parent at step 0
    // (function-preservation band) and ≥3 further native train steps reduce
    // the loss (the PR's acceptance smoke).
    let sparse_entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let sparse_params = upcycle_params(&p_ck, &sparse_entry, &UpcycleOptions::default()).unwrap();
    let sparse = runtime.load_model(&manifest, "lm_tiny_moe_e8_c2", &["train", "eval"]).unwrap();
    let mut sp_state = TrainState::from_checkpoints(
        &sparse_entry,
        &sparse_params,
        &init_opt_state(&sparse_entry).unwrap(),
    )
    .unwrap();
    sp_state.step = state.step;
    let m_sp0 = evaluator.eval(&sparse, &sp_state).unwrap();
    assert!(
        (m_sp0["loss"] - m_a["loss"]).abs() < 1.5,
        "surgery must roughly preserve quality: dense {} vs upcycled {}",
        m_a["loss"],
        m_sp0["loss"]
    );
    assert!(m_sp0["coverage"] > 0.5, "EC routing must reach most tokens");

    let cfg = TrainConfig {
        steps: 30,
        schedule: Schedule::t5_pretrain(0.01, 20),
        weight_decay: 0.0,
        eval_every: 0,
        log_every: 0,
    };
    let mut pipe2 = lm_pipeline(&dense_entry, 2);
    let s2 = sparse_upcycle::coordinator::train(
        &sparse,
        &mut sp_state,
        &mut pipe2,
        &evaluator,
        &cfg,
        "up",
    )
    .unwrap();
    let loss_sp = s2.last().unwrap().values["loss"];
    assert!(
        loss_sp < m_sp0["loss"],
        "continued sparse training must improve: {} -> {loss_sp}",
        m_sp0["loss"]
    );

    // Scenario 4: signature mismatches are rejected, not silently mangled.
    let bad = TrainState::from_checkpoints(
        &sparse_entry,
        &p_ck, // dense checkpoint into sparse signature
        &init_opt_state(&sparse_entry).unwrap(),
    );
    assert!(bad.is_err(), "dense checkpoint must not bind to sparse signature");

    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end expert parallelism (the `upcycle train --mesh 2x2` path):
/// a sparse model trains on a 2x2 DP×EP mesh — 4 rank threads, expert
/// weights sharded over each group's EP pair, token buffers crossing real
/// all-to-all collectives — reduces the held-out loss, and finishes with
/// parameters bitwise-identical to the serial 1-worker run of the same
/// mesh arithmetic.
#[test]
fn native_mesh_training_stack() {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let model = runtime.load_model(&manifest, "lm_tiny_moe_e8_c2", &["train", "eval"]).unwrap();

    let cfg = TrainConfig {
        steps: 12,
        schedule: Schedule::constant(0.01),
        weight_decay: 0.0,
        eval_every: 0,
        log_every: 0,
    };
    let run = |mesh: &MeshConfig| {
        let mut state = TrainState::from_checkpoints(
            &entry,
            &init_params(&entry, 21).unwrap(),
            &init_opt_state(&entry).unwrap(),
        )
        .unwrap();
        let mut pipe = lm_pipeline(&entry, 5);
        let mut held = lm_pipeline(&entry, 99);
        let evaluator = Evaluator::from_source(&mut held, 2);
        let series = sparse_upcycle::coordinator::train_mesh(
            &model, &mut state, &mut pipe, &evaluator, &cfg, mesh, "mesh",
        )
        .unwrap();
        (state, series)
    };

    let parallel = MeshConfig::replicated(&entry, 2, 2).unwrap();
    let serial = MeshConfig::accumulated(&entry, 2, 2).unwrap();
    let (st_par, series_par) = run(&parallel);
    let (st_ser, series_ser) = run(&serial);

    // Training works: held-out loss drops from the random-init plateau.
    let first = series_par.points.first().unwrap().values["loss"];
    let last = series_par.points.last().unwrap().values["loss"];
    assert!(last < first, "mesh training must reduce held-out loss: {first} -> {last}");
    assert_eq!(st_par.step, 12);

    // Acceptance invariant: sharded-expert execution on 4 rank threads is
    // bitwise-identical to the 1-worker run.
    for ((a, b), spec) in st_par.params.iter().zip(&st_ser.params).zip(&entry.params) {
        assert_eq!(a, b, "param `{}` must match the 1-worker run bitwise", spec.name);
    }
    for (a, b) in st_par.opt_state.iter().zip(&st_ser.opt_state) {
        assert_eq!(a, b, "optimizer state must match the 1-worker run bitwise");
    }
    let l_par = series_par.points.last().unwrap().values["loss"];
    let l_ser = series_ser.points.last().unwrap().values["loss"];
    assert_eq!(l_par, l_ser, "eval curves must coincide exactly");
}

/// Native vision path: train a few steps, check accuracy metrics + frozen
/// feature extraction feed the few-shot probe machinery.
#[test]
fn native_vision_stack() {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let entry = manifest.model("vit_tiny_moe_e8_c2").unwrap().clone();
    let model = runtime
        .load_model(&manifest, "vit_tiny_moe_e8_c2", &["train", "eval", "features"])
        .unwrap();
    assert!(model.has("features"));

    let mut state = TrainState::from_checkpoints(
        &entry,
        &init_params(&entry, 5).unwrap(),
        &init_opt_state(&entry).unwrap(),
    )
    .unwrap();
    let mut pipe = sparse_upcycle::data::vision::VisionPipeline::new(
        sparse_upcycle::data::vision::VisionSpec::default(),
        entry.config.batch_size,
        7,
        0,
    );
    let (batch, _) = pipe.next_batch();
    let m0 = model.eval_step(&state.params, &batch).unwrap();
    // 16 balanced classes ⇒ random-init loss ≈ ln 16 ≈ 2.77.
    assert!((1.5..4.5).contains(&m0["loss"]), "vit init loss {} implausible", m0["loss"]);

    let mut loss_last = m0["loss"];
    for step in 1..=10u64 {
        let (b, _) = pipe.next_batch();
        let params = std::mem::take(&mut state.params);
        let opt = std::mem::take(&mut state.opt_state);
        let out = model.train_step(params, opt, &b, 3e-3, 0.0, step).unwrap();
        state.params = out.params;
        state.opt_state = out.opt_state;
        loss_last = out.metrics["loss"];
    }
    assert!(loss_last < m0["loss"] + 0.5, "vit training diverged: {loss_last}");

    let feats = model.features(&state.params, &batch[0]).unwrap();
    assert_eq!(feats.shape, vec![entry.config.batch_size, entry.config.d_model]);
    assert!(feats.f32s().unwrap().iter().all(|v| v.is_finite()));
}

/// The full train → save → serve loop (the CLI's `upcycle train --save ck
/// && upcycle serve --load ck` path): train a sparse model briefly,
/// persist the trained state as a one-file bundle, reload it, and serve —
/// with the reloaded parameters producing bitwise-identical predictions
/// to the live ones, locally and through the continuous-batching engine.
#[test]
fn native_train_save_serve_stack() {
    use sparse_upcycle::serve::{
        stack_inputs, synthetic_trace, tokens_per_request, Engine, ServeSpec,
    };
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let model = runtime.load_model(&manifest, "lm_tiny_moe_e8_c2", &["train", "eval"]).unwrap();
    let mut state = TrainState::from_checkpoints(
        &entry,
        &init_params(&entry, 21).unwrap(),
        &init_opt_state(&entry).unwrap(),
    )
    .unwrap();
    let mut pipe = lm_pipeline(&entry, 7);
    for i in 1..=3u64 {
        let b = pipe.next_batch();
        let out = model
            .train_step(
                std::mem::take(&mut state.params),
                std::mem::take(&mut state.opt_state),
                &b,
                1e-3,
                0.0,
                i,
            )
            .unwrap();
        state.params = out.params;
        state.opt_state = out.opt_state;
        state.step = i;
    }
    let path = std::env::temp_dir().join("supc_integration").join("served.supc");
    state.save(&entry, &path, "integration").unwrap();
    let loaded = TrainState::load(&entry, &path).unwrap();
    assert_eq!(loaded.step, 3, "bundle must carry the step counter");

    // Live and reloaded parameters answer identically.
    let trace = synthetic_trace(&entry, 4, 5, 0);
    let inputs = stack_inputs(&trace).unwrap();
    let live = model.infer(&state.params, &inputs).unwrap();
    let warm = model.infer(&loaded.params, &inputs).unwrap();
    assert_eq!(live, warm, "reloaded checkpoint must serve bitwise-identical outputs");

    // And the engine serves a trace off the reloaded state end to end.
    let spec = ServeSpec {
        max_batch_tokens: 2 * tokens_per_request(&entry),
        ..ServeSpec::default()
    };
    let engine = Engine::new(&model, &loaded.params, spec).unwrap();
    let report = engine.run_trace(synthetic_trace(&entry, 6, 5, 200)).unwrap();
    assert_eq!(report.completions.len(), 6);
    assert!(report.tokens_per_s() > 0.0);
    assert!(report.p99_latency_us() >= report.p50_latency_us());
    std::fs::remove_file(&path).ok();
}

/// The PJRT variant of the full stack. Requires `--features pjrt` AND real
/// xla bindings AND `make artifacts`; with the vendored stub it only checks
/// that the backend reports a clean "unavailable" error.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_gated() {
    match Runtime::pjrt() {
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("PJRT"), "unexpected error: {msg}");
        }
        Ok(runtime) => {
            // Real bindings present: run the same smoke as the native path.
            let Ok(manifest) = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
            else {
                eprintln!("skipping pjrt integration: run `make artifacts` first");
                return;
            };
            let entry = manifest.model("lm_tiny_dense").unwrap().clone();
            let model = runtime.load_model(&manifest, "lm_tiny_dense", &["eval"]).unwrap();
            let state = TrainState::from_checkpoints(
                &entry,
                &init_params(&entry, 3).unwrap(),
                &init_opt_state(&entry).unwrap(),
            )
            .unwrap();
            let mut held = lm_pipeline(&entry, 99);
            let evaluator = Evaluator::from_source(&mut held, 2);
            let m = evaluator.eval(&model, &state).unwrap();
            assert!(m["loss"].is_finite());
        }
    }
}
