//! Integration tests: the full stack — manifest → PJRT compile → surgery →
//! train/eval through real AOT artifacts. Every test no-ops gracefully when
//! `artifacts/` has not been built (CI without `make artifacts`).
//!
//! Compiling a train module costs ~30 s on this single-core CPU, so the
//! whole file shares ONE sequential test (`full_stack`) that threads through
//! the scenarios instead of paying the compile per test.

use sparse_upcycle::coordinator::{Evaluator, Schedule, TrainConfig, TrainState};
use sparse_upcycle::data::text::{HmmCorpus, HmmSpec, TextPipeline};
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::runtime::Runtime;
use sparse_upcycle::upcycle::{upcycle_params, UpcycleOptions};

fn manifest() -> Option<Manifest> {
    Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
}

#[test]
fn full_stack() {
    let Some(manifest) = manifest() else {
        eprintln!("skipping integration tests: run `make artifacts` first");
        return;
    };
    let runtime = Runtime::new().unwrap();

    // ---------------------------------------------------------------- dense
    let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
    let dense = runtime
        .load_model(&manifest, "lm_tiny_dense", &["train", "eval"])
        .unwrap();

    let mut state = TrainState::from_checkpoints(
        &dense_entry,
        &init_params(&dense_entry, 3).unwrap(),
        &init_opt_state(&dense_entry).unwrap(),
    )
    .unwrap();
    assert_eq!(state.params.len(), dense_entry.params.len());

    let corpus = HmmCorpus::new(
        HmmSpec { vocab_size: dense_entry.config.vocab_size, ..Default::default() },
        1,
    );
    let mut pipe = TextPipeline::new(
        corpus,
        dense_entry.config.batch_size,
        dense_entry.config.enc_len,
        dense_entry.config.dec_len,
        1,
        0,
    );
    let mut held = TextPipeline::new(
        HmmCorpus::new(
            HmmSpec { vocab_size: dense_entry.config.vocab_size, ..Default::default() },
            1,
        ),
        dense_entry.config.batch_size,
        dense_entry.config.enc_len,
        dense_entry.config.dec_len,
        1,
        99,
    );
    let evaluator = Evaluator::from_source(&mut held, 2);

    // Scenario 1: training reduces loss and improves on the random baseline.
    let m0 = evaluator.eval(&dense, &state).unwrap();
    let loss0 = m0["loss"];
    // Random init ⇒ loss ≈ ln(vocab) = ln 256 ≈ 5.55.
    assert!((4.5..7.0).contains(&loss0), "initial loss {loss0} implausible");

    let cfg = TrainConfig {
        steps: 60,
        schedule: Schedule::t5_pretrain(0.01, 20),
        weight_decay: 0.0,
        eval_every: 0,
        log_every: 0,
    };
    let series = sparse_upcycle::coordinator::train(
        &dense, &mut state, &mut pipe, &evaluator, &cfg, "t",
    )
    .unwrap();
    let loss1 = series.last().unwrap().values["loss"];
    assert!(
        loss1 < loss0 - 0.3,
        "60 steps must reduce held-out loss materially: {loss0} -> {loss1}"
    );
    assert_eq!(state.step, 60);

    // Scenario 2: checkpoint round-trip preserves evaluation exactly.
    let (p_ck, o_ck) = state.to_checkpoints(&dense_entry, "it").unwrap();
    let dir = std::env::temp_dir().join("supc_integration");
    let pp = dir.join("p.supc");
    let op = dir.join("o.supc");
    p_ck.save(&pp).unwrap();
    o_ck.save(&op).unwrap();
    let p_back = sparse_upcycle::checkpoint::Checkpoint::load(&pp).unwrap();
    let o_back = sparse_upcycle::checkpoint::Checkpoint::load(&op).unwrap();
    let state2 = TrainState::from_checkpoints(&dense_entry, &p_back, &o_back).unwrap();
    let m_a = evaluator.eval(&dense, &state).unwrap();
    let m_b = evaluator.eval(&dense, &state2).unwrap();
    assert_eq!(m_a["loss"], m_b["loss"], "checkpoint round-trip must be exact");

    // Scenario 3: upcycled model evaluates close to the parent at step 0
    // (within the function-preservation band) and trains further.
    let sparse_entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let sparse_params =
        upcycle_params(&p_ck, &sparse_entry, &UpcycleOptions::default()).unwrap();
    let sparse = runtime
        .load_model(&manifest, "lm_tiny_moe_e8_c2", &["train", "eval"])
        .unwrap();
    let mut sp_state = TrainState::from_checkpoints(
        &sparse_entry,
        &sparse_params,
        &init_opt_state(&sparse_entry).unwrap(),
    )
    .unwrap();
    sp_state.step = state.step;
    let m_sp0 = evaluator.eval(&sparse, &sp_state).unwrap();
    assert!(
        (m_sp0["loss"] - m_a["loss"]).abs() < 1.0,
        "surgery must roughly preserve quality: dense {} vs upcycled {}",
        m_a["loss"],
        m_sp0["loss"]
    );
    assert!(m_sp0["coverage"] > 0.5, "EC routing must reach most tokens");

    let cfg = TrainConfig {
        steps: 40,
        schedule: Schedule::t5_pretrain(0.01, 20),
        weight_decay: 0.0,
        eval_every: 0,
        log_every: 0,
    };
    let mut pipe2 = TextPipeline::new(
        HmmCorpus::new(
            HmmSpec { vocab_size: dense_entry.config.vocab_size, ..Default::default() },
            1,
        ),
        dense_entry.config.batch_size,
        dense_entry.config.enc_len,
        dense_entry.config.dec_len,
        1,
        2,
    );
    let s2 = sparse_upcycle::coordinator::train(
        &sparse, &mut sp_state, &mut pipe2, &evaluator, &cfg, "up",
    )
    .unwrap();
    let loss_sp = s2.last().unwrap().values["loss"];
    assert!(
        loss_sp < m_sp0["loss"],
        "upcycled training must improve: {} -> {loss_sp}",
        m_sp0["loss"]
    );

    // Scenario 4: signature mismatches are rejected, not silently mangled.
    let bad = TrainState::from_checkpoints(
        &sparse_entry,
        &p_ck, // dense checkpoint into sparse signature
        &init_opt_state(&sparse_entry).unwrap(),
    );
    assert!(bad.is_err(), "dense checkpoint must not bind to sparse signature");

    std::fs::remove_dir_all(&dir).ok();
}
