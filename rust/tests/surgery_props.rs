//! Property tests for the upcycling surgery (hand-rolled generator loop —
//! proptest is unavailable offline; `Rng` provides the shrink-free random
//! case generation, all seeds deterministic).
//!
//! Runs against the native model zoo, so these properties are checked on
//! every `cargo test` with no artifacts present.

use sparse_upcycle::checkpoint::Checkpoint;
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::upcycle::{
    depth_tile_params, tile_source_block, upcycle_opt_state, upcycle_params, UpcycleOptions,
    UpcycleStrategy,
};
use sparse_upcycle::util::rng::Rng;

/// Property: for every MoE weight tensor, every expert slice is bit-equal to
/// the dense parent's MLP weights; every non-MoE tensor is copied verbatim;
/// routers match the requested init scale statistically.
#[test]
fn prop_surgery_is_exact_replication() {
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let mut seeds = Rng::new(42);
    for case in 0..8 {
        let seed = seeds.next_u64();
        let dense = init_params(&dense_entry, seed).unwrap();
        for target in ["lm_tiny_moe_e8_c2", "lm_tiny_moe_e2_c2", "lm_tiny_moe_last2"] {
            let sparse_entry = m.model(target).unwrap().clone();
            let opts = UpcycleOptions { seed: seed ^ 1, ..Default::default() };
            let sparse = upcycle_params(&dense, &sparse_entry, &opts).unwrap();
            for spec in &sparse_entry.params {
                let t = sparse.get(&spec.name).unwrap();
                assert_eq!(t.shape, spec.shape, "case {case} {target} {}", spec.name);
                if spec.name.contains("/moe/wi") || spec.name.contains("/moe/wo") {
                    let src = dense.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
                    let e = spec.shape[0];
                    let n = src.numel();
                    let data = t.f32s().unwrap();
                    for x in 0..e {
                        assert_eq!(
                            &data[x * n..(x + 1) * n],
                            src.f32s().unwrap(),
                            "expert {x} of {} must be a bit-exact copy",
                            spec.name
                        );
                    }
                } else if spec.name.contains("/moe/router") {
                    let data = t.f32s().unwrap();
                    let std = (data.iter().map(|v| v * v).sum::<f32>()
                        / data.len() as f32)
                        .sqrt();
                    assert!(std < 0.1, "router init too large: {std}");
                    assert!(data.iter().any(|v| *v != 0.0), "router must not be zero");
                } else {
                    assert_eq!(t, dense.get(&spec.name).unwrap(), "{} must copy", spec.name);
                }
            }
        }
    }
}

/// Property: surgery is deterministic in its seed and differs across seeds
/// (router init only, when `expert_noise = 0`).
#[test]
fn prop_surgery_seed_determinism() {
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let sparse_entry = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let dense = init_params(&dense_entry, 7).unwrap();
    let seeded = |seed: u64| UpcycleOptions { seed, ..Default::default() };
    let a = upcycle_params(&dense, &sparse_entry, &seeded(5)).unwrap();
    let b = upcycle_params(&dense, &sparse_entry, &seeded(5)).unwrap();
    let c = upcycle_params(&dense, &sparse_entry, &seeded(6)).unwrap();
    for spec in &sparse_entry.params {
        assert_eq!(a.get(&spec.name).unwrap(), b.get(&spec.name).unwrap());
        if spec.name.contains("/moe/router") {
            assert_ne!(a.get(&spec.name).unwrap(), c.get(&spec.name).unwrap());
        } else {
            assert_eq!(a.get(&spec.name).unwrap(), c.get(&spec.name).unwrap());
        }
    }
}

/// Property: the whole sparse checkpoint is bit-identical across repeated
/// surgeries with the same seed — including when expert noise is on — and
/// different seeds diversify exactly the randomized tensors (routers, and
/// experts too once noise is non-zero).
#[test]
fn prop_surgery_bitwise_determinism() {
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let sparse_entry = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let dense = init_params(&dense_entry, 13).unwrap();

    for noise in [0.0f32, 0.05] {
        let opts = |seed| UpcycleOptions { expert_noise: noise, seed, ..Default::default() };
        let a = upcycle_params(&dense, &sparse_entry, &opts(9)).unwrap();
        let b = upcycle_params(&dense, &sparse_entry, &opts(9)).unwrap();
        // Same seed ⇒ bit-identical checkpoint (tensor set and every value).
        assert_eq!(a.tensors.len(), b.tensors.len());
        for (name, t) in &a.tensors {
            assert_eq!(t, b.get(name).unwrap(), "noise={noise}: `{name}` must be bit-identical");
        }

        let c = upcycle_params(&dense, &sparse_entry, &opts(10)).unwrap();
        for spec in &sparse_entry.params {
            let differs = a.get(&spec.name).unwrap() != c.get(&spec.name).unwrap();
            if spec.name.contains("/moe/router") {
                assert!(differs, "routers must differ across seeds");
            } else if spec.name.contains("/moe/wi") || spec.name.contains("/moe/wo") {
                // Experts depend on the seed only through the noise.
                assert_eq!(
                    differs,
                    noise > 0.0,
                    "expert tensors should {} across seeds at noise={noise}",
                    if noise > 0.0 { "differ" } else { "be identical" }
                );
            } else {
                assert!(!differs, "copied tensor `{}` must not depend on the seed", spec.name);
            }
        }
    }
}

/// Property: expert noise perturbs each expert independently with the
/// requested magnitude; load_experts=false produces experts unrelated to
/// the parent.
#[test]
fn prop_noise_and_random_experts() {
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let sparse_entry = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let dense = init_params(&dense_entry, 11).unwrap();

    let noisy = upcycle_params(
        &dense,
        &sparse_entry,
        &UpcycleOptions { expert_noise: 0.01, ..Default::default() },
    )
    .unwrap();
    for spec in &sparse_entry.params {
        if spec.name.contains("/moe/wi") {
            let src = dense.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
            let n = src.numel();
            let data = noisy.get(&spec.name).unwrap().f32s().unwrap();
            let e = spec.shape[0];
            // Distinct experts, each close to the parent.
            for x in 1..e {
                assert_ne!(&data[0..n], &data[x * n..x * n + n]);
            }
            let max_dev = data
                .iter()
                .zip(src.f32s().unwrap().iter().cycle())
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_dev < 0.1, "noise too large: {max_dev}");
        }
    }

    let random = upcycle_params(
        &dense,
        &sparse_entry,
        &UpcycleOptions { load_experts: false, ..Default::default() },
    )
    .unwrap();
    for spec in &sparse_entry.params {
        if spec.name.contains("/moe/wi") {
            let src = dense.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
            let n = src.numel();
            let data = random.get(&spec.name).unwrap().f32s().unwrap();
            assert_ne!(&data[0..n], src.f32s().unwrap(), "random experts must differ");
        }
    }
}

/// Property: optimizer-state surgery carries accumulators across and zeroes
/// exactly the router slots (or everything with load=false).
#[test]
fn prop_opt_state_surgery() {
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let sparse_entry = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let mut dense_opt = init_opt_state(&dense_entry).unwrap();
    // Make the accumulators non-trivial.
    let mut rng = Rng::new(3);
    let names: Vec<String> = dense_opt.tensors.keys().cloned().collect();
    for name in names {
        let t = dense_opt.tensors.get_mut(&name).unwrap();
        let shape = t.shape.clone();
        let n = t.numel();
        *t = sparse_upcycle::tensor::Tensor::from_f32(&shape, rng.normal_vec(n, 1.0));
    }

    let loaded =
        upcycle_opt_state(&dense_opt, &sparse_entry, true, &UpcycleStrategy::Replicate).unwrap();
    let zeroed =
        upcycle_opt_state(&dense_opt, &sparse_entry, false, &UpcycleStrategy::Replicate).unwrap();
    for spec in &sparse_entry.opt_state {
        let z = zeroed.get(&spec.name).unwrap();
        assert!(z.f32s().unwrap().iter().all(|&v| v == 0.0), "{} not zeroed", spec.name);
        let l = loaded.get(&spec.name).unwrap();
        let base = spec.name.trim_start_matches("opt/").rsplit_once('/').unwrap().0.to_string();
        if base.contains("/moe/router") {
            assert!(l.f32s().unwrap().iter().all(|&v| v == 0.0), "router state must be fresh");
        } else if base.contains("/moe/w") {
            let slot = spec.name.rsplit_once('/').unwrap().1;
            let dense_name = format!("opt/{}/{slot}", base.replace("/moe/", "/mlp/"));
            let src = dense_opt.get(&dense_name).unwrap();
            let n = src.numel();
            let data = l.f32s().unwrap();
            for x in 0..spec.shape[0] {
                assert_eq!(&data[x * n..(x + 1) * n], src.f32s().unwrap());
            }
        } else {
            assert_eq!(l, dense_opt.get(&spec.name).unwrap());
        }
    }
}

/// Property: depth tiling covers every source block, is monotone, and the
/// tiled checkpoint's block tensors equal their mapped source tensors.
#[test]
fn prop_depth_tiling() {
    // Pure mapping properties over random (n_old, n_new) pairs.
    let mut rng = Rng::new(9);
    for _ in 0..64 {
        let n_old = rng.range(1, 12);
        let n_new = rng.range(n_old, n_old * 3 + 1);
        let map: Vec<usize> = (0..n_new).map(|i| tile_source_block(i, n_new, n_old)).collect();
        assert_eq!(map[0], 0);
        assert!(map.windows(2).all(|w| w[0] <= w[1]), "monotone: {map:?}");
        assert!(map.iter().all(|&s| s < n_old));
        let covered: std::collections::BTreeSet<usize> = map.iter().copied().collect();
        assert_eq!(covered.len(), n_old, "all source blocks used: {map:?}");
    }

    // Checkpoint-level check on the zoo geometry (4 → 6 encoder blocks).
    let m = Manifest::native();
    let dense_entry = m.model("lm_tiny_dense").unwrap().clone();
    let tiled_entry = m.model("lm_tiny_dense_tiled").unwrap().clone();
    let dense = init_params(&dense_entry, 1).unwrap();
    let tiled: Checkpoint = depth_tile_params(&dense, &dense_entry, &tiled_entry).unwrap();
    assert_eq!(tiled.tensors.len(), tiled_entry.params.len());
    let t = tiled.get("enc/block_05/mlp/wi").unwrap();
    // Block 5 of 6 maps to source block 5*4/6 = 3.
    assert_eq!(t, dense.get("enc/block_03/mlp/wi").unwrap());
    assert_eq!(
        tiled.get("token_embed").unwrap(),
        dense.get("token_embed").unwrap()
    );
}
