//! Fuzz-style loader hardening: `checkpoint::load_train_state` fed
//! randomly truncated and bit-flipped SUPC bundles (seeded, reproducible)
//! must yield a **named error** for every corruption it can detect, and
//! must **never** panic, over-allocate on a corrupt length field, or hand
//! back a silently-wrong checkpoint.
//!
//! "Never silently wrong" is checkable because the format carries an
//! integrity checksum (FNV-1a over model + step + payload, in the header):
//! the only mutations allowed to load successfully are those that leave
//! the bound state — params, optimizer state, step — bitwise-identical to
//! the original (e.g. a flip inside the free-form provenance string).

use std::panic::{catch_unwind, AssertUnwindSafe};

use sparse_upcycle::checkpoint::{load_train_state, save_train_state};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::tensor::Tensor;
use sparse_upcycle::util::rng::Rng;

/// 64 truncations + 64 bit flips + 16 double-flips = 144 seeded cases.
const TRUNCATIONS: usize = 64;
const BITFLIPS: usize = 64;
const DOUBLE_FLIPS: usize = 16;

#[test]
fn corrupt_bundles_never_panic_and_never_load_wrong() {
    let manifest = Manifest::native();
    let entry = manifest.model("lm_tiny_dense").unwrap();
    // A valid reference bundle with distinctive values.
    let params: Vec<Tensor> = entry
        .params
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.shape.iter().product();
            Tensor::from_f32(&s.shape, (0..n).map(|j| (i * 37 + j) as f32 * 0.01 - 2.0).collect())
        })
        .collect();
    let opt: Vec<Tensor> = entry
        .opt_state
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.shape.iter().product();
            Tensor::from_f32(&s.shape, (0..n).map(|j| (i + j) as f32 * 1e-4).collect())
        })
        .collect();
    let dir = std::env::temp_dir().join("supc_fuzz");
    std::fs::create_dir_all(&dir).unwrap();
    let good_path = dir.join("good.supc");
    save_train_state(&good_path, entry, &params, &opt, 123, "fuzz reference").unwrap();
    let good = std::fs::read(&good_path).unwrap();
    // Sanity: the untouched bundle loads and round-trips bitwise.
    let (p0, o0, s0) = load_train_state(&good_path, entry).unwrap();
    assert_eq!((s0, &p0, &o0), (123, &params, &opt));

    let mutated_path = dir.join("mutated.supc");
    let mut rng = Rng::new(0xfa57);
    let mut named_errors = 0usize;
    let mut benign_loads = 0usize;
    let mut case = |bytes: &[u8], what: &str| {
        std::fs::write(&mutated_path, bytes).unwrap();
        let out = catch_unwind(AssertUnwindSafe(|| load_train_state(&mutated_path, entry)));
        match out {
            Err(_) => panic!("{what}: the loader PANICKED on corrupt input"),
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                assert!(
                    !msg.trim().is_empty() && msg.contains("supc"),
                    "{what}: error must name the file: {msg}"
                );
                named_errors += 1;
            }
            Ok(Ok((p, o, step))) => {
                // Loading is only acceptable if the *state* is untouched
                // (the mutation landed in cosmetic metadata).
                assert_eq!(step, 123, "{what}: loaded a silently-wrong step");
                assert_eq!(p, params, "{what}: loaded silently-wrong params");
                assert_eq!(o, opt, "{what}: loaded silently-wrong optimizer state");
                benign_loads += 1;
            }
        }
    };

    // Random truncations, including length 0 and cuts inside the preamble,
    // the header and the payload.
    for _ in 0..TRUNCATIONS {
        let cut = rng.below(good.len());
        case(&good[..cut], &format!("truncate to {cut} bytes"));
    }
    // Single bit flips anywhere in the file.
    for _ in 0..BITFLIPS {
        let mut b = good.clone();
        let at = rng.below(b.len());
        let bit = rng.below(8) as u8;
        b[at] ^= 1 << bit;
        case(&b, &format!("flip bit {bit} of byte {at}"));
    }
    // Double flips (corruption rarely comes alone).
    for _ in 0..DOUBLE_FLIPS {
        let mut b = good.clone();
        for _ in 0..2 {
            let at = rng.below(b.len());
            b[at] ^= 1 << (rng.below(8) as u8);
        }
        case(&b, "double bit flip");
    }
    assert_eq!(named_errors + benign_loads, TRUNCATIONS + BITFLIPS + DOUBLE_FLIPS);
    assert!(
        named_errors > (TRUNCATIONS + BITFLIPS + DOUBLE_FLIPS) / 2,
        "most corruptions must be detected ({named_errors} named errors, \
         {benign_loads} benign loads)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-checkpoint surgery failure paths: feeding `UpcycleStrategy::
/// MultiCheckpoint` a mismatched-architecture bundle, a corrupt bundle
/// among the paths, a source count that does not divide the expert count,
/// or an empty/duplicate path list must yield a **named error** — never a
/// panic, never a silently-wrong merged checkpoint.
#[test]
fn multi_checkpoint_surgery_failure_paths_are_named_errors() {
    use sparse_upcycle::init::init_params;
    use sparse_upcycle::upcycle::{upcycle_params, SharedInit, UpcycleOptions, UpcycleStrategy};

    let manifest = Manifest::native();
    let tiny = manifest.model("lm_tiny_dense").unwrap();
    let sparse = manifest.model("lm_tiny_moe_e8_c2").unwrap();
    let dir = std::env::temp_dir().join("supc_fuzz_multi");
    std::fs::create_dir_all(&dir).unwrap();
    let dense_ck = init_params(tiny, 1).unwrap();
    let path_of = |name: &str| dir.join(name).to_string_lossy().into_owned();
    for (name, seed) in [("tiny_b.supc", 2u64), ("tiny_c.supc", 3), ("tiny_d.supc", 4)] {
        init_params(tiny, seed).unwrap().save(dir.join(name)).unwrap();
    }

    let surgery = |paths: Vec<String>, shared: SharedInit| {
        let opts = UpcycleOptions {
            strategy: UpcycleStrategy::MultiCheckpoint { checkpoint_paths: paths, shared },
            ..Default::default()
        };
        catch_unwind(AssertUnwindSafe(|| upcycle_params(&dense_ck, sparse, &opts)))
            .expect("multi-checkpoint surgery must never panic")
    };

    // Positive control: a valid two-source merge succeeds, so the failures
    // below are failures of the *inputs*, not of the path under test.
    let merged = surgery(vec![path_of("tiny_b.supc")], SharedInit::Average)
        .expect("valid two-source merge");
    assert_eq!(merged.tensors.len(), sparse.params.len());

    // (1) Mismatched architecture: a different zoo geometry as the extra
    // source must be rejected by name under both shared-init modes.
    let small = manifest.model("lm_small_dense").unwrap();
    init_params(small, 5).unwrap().save(dir.join("small.supc")).unwrap();
    for shared in [SharedInit::Primary, SharedInit::Average] {
        let err = surgery(vec![path_of("small.supc")], shared)
            .expect_err("mismatched architecture must be rejected");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("multi-checkpoint")
                && (msg.contains("architecture mismatch") || msg.contains("lacks")),
            "mismatch error must name the problem: {msg}"
        );
    }

    // (2) A corrupt bundle among the paths: truncation and bit flips must
    // surface the hardened loader's error, wrapped with the source path.
    let good = std::fs::read(dir.join("tiny_d.supc")).unwrap();
    let mut rng = Rng::new(0xc0de);
    for i in 0..8 {
        let mut b = good.clone();
        if i % 2 == 0 {
            b.truncate(rng.below(b.len()));
        } else {
            let at = rng.below(b.len().min(64)); // header/preamble flips
            b[at] ^= 1 << (rng.below(8) as u8);
        }
        std::fs::write(dir.join("corrupt.supc"), &b).unwrap();
        // Two healthy sources + one corrupt: 4 sources, divides 8 experts.
        let out = surgery(
            vec![path_of("tiny_b.supc"), path_of("tiny_c.supc"), path_of("corrupt.supc")],
            SharedInit::Primary,
        );
        match out {
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("loading multi-checkpoint source #3") && msg.contains("supc"),
                    "corrupt-source error must name the source and file: {msg}"
                );
            }
            // A flip can land in cosmetic metadata; then the load is benign
            // and the merge must still be architecturally valid.
            Ok(ck) => assert_eq!(ck.tensors.len(), sparse.params.len()),
        }
    }

    // (3) Expert count not divisible by source count: 2 extra sources make
    // 3 round-robin sources for 8 experts → fail fast, before any load.
    let err = surgery(
        vec![path_of("tiny_b.supc"), path_of("tiny_c.supc")],
        SharedInit::Primary,
    )
    .expect_err("8 experts over 3 sources must be rejected");
    assert!(format!("{err:#}").contains("not divisible"), "{err:#}");

    // (4) Degenerate path lists: empty list, empty path, duplicates.
    let err = surgery(vec![], SharedInit::Primary).expect_err("empty source list");
    assert!(format!("{err:#}").contains("at least one"), "{err:#}");
    let err = surgery(vec!["  ".into()], SharedInit::Primary).expect_err("blank path");
    assert!(format!("{err:#}").contains("empty path"), "{err:#}");
    let err = surgery(
        vec![path_of("tiny_b.supc"), path_of("tiny_b.supc")],
        SharedInit::Primary,
    )
    .expect_err("duplicate paths");
    assert!(format!("{err:#}").contains("twice"), "{err:#}");

    // A path that simply does not exist is a named load error too.
    let err = surgery(vec![path_of("nope.supc")], SharedInit::Primary)
        .expect_err("missing file");
    assert!(
        format!("{err:#}").contains("loading multi-checkpoint source #1"),
        "{err:#}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Adversarial length fields: every u64/u32 length position rewritten to
/// extreme values must error by name — never allocate absurd buffers.
#[test]
fn hostile_length_fields_are_rejected() {
    let manifest = Manifest::native();
    let entry = manifest.model("lm_tiny_dense").unwrap();
    let params: Vec<Tensor> =
        entry.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let opt: Vec<Tensor> =
        entry.opt_state.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let dir = std::env::temp_dir().join("supc_fuzz_len");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("len.supc");
    save_train_state(&path, entry, &params, &opt, 7, "len").unwrap();
    let good = std::fs::read(&path).unwrap();
    for hostile in [u64::MAX, u64::MAX / 2, good.len() as u64 + 1, 1 << 40] {
        let mut b = good.clone();
        b[8..16].copy_from_slice(&hostile.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        let out = catch_unwind(AssertUnwindSafe(|| load_train_state(&path, entry)));
        let err = out.expect("must not panic").expect_err("hostile header length must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("header length"), "{msg}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
