//! Fuzz-style loader hardening: `checkpoint::load_train_state` fed
//! randomly truncated and bit-flipped SUPC bundles (seeded, reproducible)
//! must yield a **named error** for every corruption it can detect, and
//! must **never** panic, over-allocate on a corrupt length field, or hand
//! back a silently-wrong checkpoint.
//!
//! "Never silently wrong" is checkable because the format carries an
//! integrity checksum (FNV-1a over model + step + payload, in the header):
//! the only mutations allowed to load successfully are those that leave
//! the bound state — params, optimizer state, step — bitwise-identical to
//! the original (e.g. a flip inside the free-form provenance string).

use std::panic::{catch_unwind, AssertUnwindSafe};

use sparse_upcycle::checkpoint::{load_train_state, save_train_state};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::tensor::Tensor;
use sparse_upcycle::util::rng::Rng;

/// 64 truncations + 64 bit flips + 16 double-flips = 144 seeded cases.
const TRUNCATIONS: usize = 64;
const BITFLIPS: usize = 64;
const DOUBLE_FLIPS: usize = 16;

#[test]
fn corrupt_bundles_never_panic_and_never_load_wrong() {
    let manifest = Manifest::native();
    let entry = manifest.model("lm_tiny_dense").unwrap();
    // A valid reference bundle with distinctive values.
    let params: Vec<Tensor> = entry
        .params
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.shape.iter().product();
            Tensor::from_f32(&s.shape, (0..n).map(|j| (i * 37 + j) as f32 * 0.01 - 2.0).collect())
        })
        .collect();
    let opt: Vec<Tensor> = entry
        .opt_state
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.shape.iter().product();
            Tensor::from_f32(&s.shape, (0..n).map(|j| (i + j) as f32 * 1e-4).collect())
        })
        .collect();
    let dir = std::env::temp_dir().join("supc_fuzz");
    std::fs::create_dir_all(&dir).unwrap();
    let good_path = dir.join("good.supc");
    save_train_state(&good_path, entry, &params, &opt, 123, "fuzz reference").unwrap();
    let good = std::fs::read(&good_path).unwrap();
    // Sanity: the untouched bundle loads and round-trips bitwise.
    let (p0, o0, s0) = load_train_state(&good_path, entry).unwrap();
    assert_eq!((s0, &p0, &o0), (123, &params, &opt));

    let mutated_path = dir.join("mutated.supc");
    let mut rng = Rng::new(0xfa57);
    let mut named_errors = 0usize;
    let mut benign_loads = 0usize;
    let mut case = |bytes: &[u8], what: &str| {
        std::fs::write(&mutated_path, bytes).unwrap();
        let out = catch_unwind(AssertUnwindSafe(|| load_train_state(&mutated_path, entry)));
        match out {
            Err(_) => panic!("{what}: the loader PANICKED on corrupt input"),
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                assert!(
                    !msg.trim().is_empty() && msg.contains("supc"),
                    "{what}: error must name the file: {msg}"
                );
                named_errors += 1;
            }
            Ok(Ok((p, o, step))) => {
                // Loading is only acceptable if the *state* is untouched
                // (the mutation landed in cosmetic metadata).
                assert_eq!(step, 123, "{what}: loaded a silently-wrong step");
                assert_eq!(p, params, "{what}: loaded silently-wrong params");
                assert_eq!(o, opt, "{what}: loaded silently-wrong optimizer state");
                benign_loads += 1;
            }
        }
    };

    // Random truncations, including length 0 and cuts inside the preamble,
    // the header and the payload.
    for _ in 0..TRUNCATIONS {
        let cut = rng.below(good.len());
        case(&good[..cut], &format!("truncate to {cut} bytes"));
    }
    // Single bit flips anywhere in the file.
    for _ in 0..BITFLIPS {
        let mut b = good.clone();
        let at = rng.below(b.len());
        let bit = rng.below(8) as u8;
        b[at] ^= 1 << bit;
        case(&b, &format!("flip bit {bit} of byte {at}"));
    }
    // Double flips (corruption rarely comes alone).
    for _ in 0..DOUBLE_FLIPS {
        let mut b = good.clone();
        for _ in 0..2 {
            let at = rng.below(b.len());
            b[at] ^= 1 << (rng.below(8) as u8);
        }
        case(&b, "double bit flip");
    }
    assert_eq!(named_errors + benign_loads, TRUNCATIONS + BITFLIPS + DOUBLE_FLIPS);
    assert!(
        named_errors > (TRUNCATIONS + BITFLIPS + DOUBLE_FLIPS) / 2,
        "most corruptions must be detected ({named_errors} named errors, \
         {benign_loads} benign loads)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Adversarial length fields: every u64/u32 length position rewritten to
/// extreme values must error by name — never allocate absurd buffers.
#[test]
fn hostile_length_fields_are_rejected() {
    let manifest = Manifest::native();
    let entry = manifest.model("lm_tiny_dense").unwrap();
    let params: Vec<Tensor> =
        entry.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let opt: Vec<Tensor> =
        entry.opt_state.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    let dir = std::env::temp_dir().join("supc_fuzz_len");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("len.supc");
    save_train_state(&path, entry, &params, &opt, 7, "len").unwrap();
    let good = std::fs::read(&path).unwrap();
    for hostile in [u64::MAX, u64::MAX / 2, good.len() as u64 + 1, 1 << 40] {
        let mut b = good.clone();
        b[8..16].copy_from_slice(&hostile.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        let out = catch_unwind(AssertUnwindSafe(|| load_train_state(&path, entry)));
        let err = out.expect("must not panic").expect_err("hostile header length must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("header length"), "{msg}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
