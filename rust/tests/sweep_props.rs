//! Sweep-lab property tests (docs/SWEEPS.md §Determinism): the results
//! store is a **pure function of (SweepSpec, seed)** — the `--cores`
//! worker budget changes wall-clock only, never a byte of the store.

use sparse_upcycle::sweep::fit::power_law_fit;
use sparse_upcycle::sweep::{run_sweep, SweepConfig, SweepSpec};

/// Run the same tiny grid on 1, 2 and 4 workers into three separate store
/// files and require the files to be bitwise identical; then fit the run
/// end to end the way `sweep fit` does. One test (not three) so the dense
/// parent pretrains once and the disk cache serves the reruns.
#[test]
fn results_store_is_bitwise_identical_across_worker_counts() {
    let dir = std::env::temp_dir().join(format!("supc_sweep_props_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let out = dir.to_string_lossy().into_owned();
    // 4 legs: experts × budget. Budgets vary 2× so the fit has a real
    // continuation axis; sunk stays constant (reported as not fitted).
    let spec = SweepSpec::parse("sunk=6,experts=2+8,budget=3+6,eval=3").unwrap();

    let mut stores: Vec<Vec<u8>> = Vec::new();
    let mut last_run = None;
    for cores in [1usize, 2, 4] {
        let mut cfg = SweepConfig::new("artifacts", &out);
        cfg.cores = cores;
        cfg.seed = 11;
        cfg.eval_batches = 2;
        cfg.results_path = dir.join(format!("SWEEP_results_c{cores}.json"));
        let run = run_sweep(&spec, &cfg).unwrap();
        assert_eq!(run.legs.len(), 4, "cores={cores}");
        run.check_complete().unwrap();
        for leg in &run.legs {
            // Priced-vs-accounted audit: the continuation is priced as
            // step_flops × budget up front and metered identically by the
            // training loop — the two columns must agree exactly.
            assert_eq!(
                leg.priced.extra_flops, leg.accounted_extra_flops,
                "leg `{}`: priced vs accounted extra FLOPs",
                leg.label
            );
            assert!(leg.final_loss.is_finite() && leg.final_loss > 0.0);
        }
        stores.push(std::fs::read(&cfg.results_path).unwrap());
        last_run = Some(run);
    }
    assert_eq!(stores[0], stores[1], "store bytes differ between 1 and 2 workers");
    assert_eq!(stores[0], stores[2], "store bytes differ between 1 and 4 workers");

    // `sweep fit` end to end: experts and continuation budget vary, sunk
    // is constant — so the fit must report exponents for the former and
    // None for the latter, with finite everything.
    let fit = power_law_fit(&last_run.unwrap().fit_points()).unwrap();
    assert!(fit.exponents[0].is_none(), "constant sunk axis must not be fitted");
    assert!(fit.exponents[1].is_some() && fit.exponents[2].is_some());
    assert!(fit.coefficient.is_finite() && fit.rmse.is_finite());
    assert_eq!(fit.residuals.len(), 4);

    std::fs::remove_dir_all(&dir).ok();
}

/// A zero worker budget is a named error, not a hang or a silent serial
/// fallback.
#[test]
fn zero_cores_is_a_named_error() {
    let dir = std::env::temp_dir().join(format!("supc_sweep_props_c0_{}", std::process::id()));
    let mut cfg = SweepConfig::new("artifacts", &dir.to_string_lossy());
    cfg.cores = 0;
    let err = run_sweep(&SweepSpec::default(), &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("--cores"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}
