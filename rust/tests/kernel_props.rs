//! Property tests holding every fast GEMM tier to the `gemm::reference`
//! oracle, and the quantized inference path to the f32 serving path.
//!
//! Coverage, per ISSUE 9:
//!   * blocked and SIMD `mm_nn`/`mm_tn`/`mm_nt` (+ `_par` forms) vs the
//!     scalar reference over a randomized shape grid seeded with the nasty
//!     cases — 0/1-sized dims, remainder lanes (n, k, m not multiples of
//!     the 8-lane width or the 4-column block), and tile-boundary ±1 sizes
//!     around `ROW_TILE`=64 / `COL_TILE`=32 — under a per-element
//!     f64-computed error bound (reassociation only; no fast-math).
//!   * bitwise rerun and thread-count determinism for every fast kernel:
//!     `_par` ≡ serial, and the same bits inside `util::serial_compute`.
//!   * the fused low-precision GEMMs (`lowp::mm_nn_bf16`/`mm_nn_i8`)
//!     bitwise-equal to decode-then-blocked-GEMM (their defining contract)
//!     and within tolerance of the reference oracle on decoded weights.
//!   * end-to-end `--precision` agreement on three zoo models: the
//!     quantized path is bitwise `infer(quantize_params(..))`, reruns are
//!     bitwise, and bf16/int8 predictions hold pinned agreement/score
//!     floors against f32 (documented tolerances, not exactness — that is
//!     the accuracy the tokens/s is traded against; see docs/SERVING.md).
//!
//! With `--features simd` on x86_64 the SIMD tier resolves to AVX2+FMA
//! kernels whose fused rounding differs from the portable path, so the
//! oracle bound — not bitwise equality — is the cross-feature contract;
//! every determinism assertion is within one resolved implementation. The
//! e2e tests run on the default blocked-kernel runtime so their expected
//! values are identical with the feature on and off.

use sparse_upcycle::checkpoint::quant::{quantize_params, Precision};
use sparse_upcycle::init::init_params;
use sparse_upcycle::linalg::gemm::{self, reference, GemmKernels};
use sparse_upcycle::linalg::lowp::{mm_nn_bf16, mm_nn_i8, Bf16Mat, Int8Mat};
use sparse_upcycle::linalg::simd;
use sparse_upcycle::manifest::{Manifest, ModelEntry};
use sparse_upcycle::runtime::{tensors_from_checkpoint, LoadedModel, Runtime};
use sparse_upcycle::serve::{stack_inputs, synthetic_trace};
use sparse_upcycle::tensor::Tensor;
use sparse_upcycle::util::rng::Rng;
use sparse_upcycle::util::serial_compute;

// ---------------------------------------------------------------- grid --

/// Boundary shapes: zero/unit dims, lane remainders (8-lane × 4-column
/// micro-kernel), and ±1 around the 64-row / 32-column tile edges.
const FIXED_SHAPES: &[(usize, usize, usize)] = &[
    (0, 4, 4),
    (4, 0, 4),
    (4, 4, 0),
    (1, 1, 1),
    (1, 8, 1),
    (1, 9, 2),
    (3, 5, 2),
    (5, 9, 3),
    (7, 15, 5),
    (4, 8, 4),
    (8, 16, 8),
    (31, 33, 31),
    (32, 32, 32),
    (33, 31, 33),
    (63, 65, 31),
    (64, 64, 32),
    (65, 63, 33),
];

/// The full grid: the fixed boundary shapes plus seeded random ones
/// (`below(80)` keeps the grid fast while still crossing every remainder
/// class; 0-sized draws are valid no-op shapes).
fn shape_grid() -> Vec<(usize, usize, usize)> {
    let mut shapes = FIXED_SHAPES.to_vec();
    let mut rng = Rng::new(0x5eed_9);
    for _ in 0..12 {
        shapes.push((rng.below(80), rng.below(80), rng.below(80)));
    }
    shapes
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn transpose(b: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0f32; b.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = b[r * cols + c];
        }
    }
    t
}

/// Per-element tolerance for a length-`len` f32 dot product accumulated in
/// any association order, starting from `out0`: a standard forward bound
/// computed in f64, plus an absolute floor for near-zero sums.
fn elem_bound(x: &[f32], y: &[f32], out0: f32) -> f64 {
    let abs_sum: f64 =
        x.iter().zip(y).map(|(a, b)| (a * b).abs() as f64).sum::<f64>() + out0.abs() as f64;
    2.0 * f32::EPSILON as f64 * (x.len() + 1) as f64 * abs_sum + 1e-7
}

/// Assert `got` matches the oracle `want` under the per-element bound,
/// where element (i, j) is the dot of `xs(i)` and `ys(j)` plus `out0`.
fn assert_close(
    label: &str,
    got: &[f32],
    want: &[f32],
    rows: usize,
    cols: usize,
    out0: &[f32],
    xs: &dyn Fn(usize) -> Vec<f32>,
    ys: &dyn Fn(usize) -> Vec<f32>,
) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for i in 0..rows {
        for j in 0..cols {
            let (g, w) = (got[i * cols + j], want[i * cols + j]);
            let bound = elem_bound(&xs(i), &ys(j), out0[i * cols + j]);
            assert!(
                ((g - w) as f64).abs() <= bound,
                "{label}[{i},{j}]: got {g}, oracle {w}, bound {bound:e}"
            );
        }
    }
}

// ------------------------------------------------- fast tiers vs oracle --

/// One tier's six kernels against the scalar reference over the grid,
/// with a non-zero initial `out` so the `+=` accumulate contract is
/// exercised too.
fn tier_matches_reference(tier: GemmKernels) {
    let mut rng = Rng::new(42);
    for &(n, k, m) in &shape_grid() {
        let a_nk = randv(&mut rng, n * k);
        let b_km = randv(&mut rng, k * m);
        let b_nm = randv(&mut rng, n * m);
        let a_nm = randv(&mut rng, n * m);
        let b_km2 = randv(&mut rng, k * m);
        let label = format!("{tier:?} ({n},{k},{m})");

        // nn: out[n,m] += a[n,k] · b[k,m]
        let out0 = randv(&mut rng, n * m);
        let (mut got, mut want) = (out0.clone(), out0.clone());
        reference::mm_nn(&a_nk, &b_km, n, k, m, &mut want);
        for big in [false, true] {
            got.copy_from_slice(&out0);
            if big {
                tier.mm_nn_big(&a_nk, &b_km, n, k, m, &mut got);
            } else {
                tier.mm_nn(&a_nk, &b_km, n, k, m, &mut got);
            }
            let bt = transpose(&b_km, k, m);
            assert_close(
                &format!("{label} nn big={big}"),
                &got,
                &want,
                n,
                m,
                &out0,
                &|i| a_nk[i * k..(i + 1) * k].to_vec(),
                &|j| bt[j * k..(j + 1) * k].to_vec(),
            );
        }

        // tn: out[k,m] += aᵀ · b with a[n,k], b[n,m]
        let out0 = randv(&mut rng, k * m);
        let (mut got, mut want) = (out0.clone(), out0.clone());
        reference::mm_tn(&a_nk, &b_nm, n, k, m, &mut want);
        let at = transpose(&a_nk, n, k);
        let bt = transpose(&b_nm, n, m);
        for big in [false, true] {
            got.copy_from_slice(&out0);
            if big {
                tier.mm_tn_big(&a_nk, &b_nm, n, k, m, &mut got);
            } else {
                tier.mm_tn(&a_nk, &b_nm, n, k, m, &mut got);
            }
            assert_close(
                &format!("{label} tn big={big}"),
                &got,
                &want,
                k,
                m,
                &out0,
                &|l| at[l * n..(l + 1) * n].to_vec(),
                &|j| bt[j * n..(j + 1) * n].to_vec(),
            );
        }

        // nt: out[n,k] += a · bᵀ with a[n,m], b[k,m]
        let out0 = randv(&mut rng, n * k);
        let (mut got, mut want) = (out0.clone(), out0.clone());
        reference::mm_nt(&a_nm, &b_km2, n, m, k, &mut want);
        for big in [false, true] {
            got.copy_from_slice(&out0);
            if big {
                tier.mm_nt_big(&a_nm, &b_km2, n, m, k, &mut got);
            } else {
                tier.mm_nt(&a_nm, &b_km2, n, m, k, &mut got);
            }
            assert_close(
                &format!("{label} nt big={big}"),
                &got,
                &want,
                n,
                k,
                &out0,
                &|i| a_nm[i * m..(i + 1) * m].to_vec(),
                &|l| b_km2[l * m..(l + 1) * m].to_vec(),
            );
        }
    }
}

#[test]
fn blocked_tier_matches_reference_over_the_shape_grid() {
    tier_matches_reference(GemmKernels::Blocked);
}

#[test]
fn simd_tier_matches_reference_over_the_shape_grid() {
    tier_matches_reference(GemmKernels::Simd);
}

/// The selector is pure dispatch: `GemmKernels::Simd` produces the same
/// bits as calling the simd module directly (and `Reference` the oracle's
/// own bits) — no shape-dependent rerouting.
#[test]
fn selector_dispatch_is_bitwise_per_tier() {
    let mut rng = Rng::new(7);
    let (n, k, m) = (13, 21, 17);
    let a = randv(&mut rng, n * k);
    let b = randv(&mut rng, k * m);
    let mut via_selector = vec![0f32; n * m];
    let mut direct = vec![0f32; n * m];
    GemmKernels::Simd.mm_nn(&a, &b, n, k, m, &mut via_selector);
    simd::mm_nn(&a, &b, n, k, m, &mut direct);
    assert_eq!(via_selector, direct);
    via_selector.fill(0.0);
    direct.fill(0.0);
    GemmKernels::Reference.mm_nn(&a, &b, n, k, m, &mut via_selector);
    reference::mm_nn(&a, &b, n, k, m, &mut direct);
    assert_eq!(via_selector, direct);
}

// ------------------------------------------------------------ determinism --

/// Every fast `_par` kernel is (a) bitwise-identical to its serial form,
/// (b) bitwise-reproducible across reruns, and (c) bitwise-identical under
/// `serial_compute` — i.e. the result does not depend on thread count.
/// The shape sits above `PAR_MIN_MACS` so the parallel path really forks.
#[test]
fn par_kernels_are_bitwise_serial_rerun_and_thread_count_deterministic() {
    let (n, k, m) = (257, 129, 67); // 2.2M MACs > PAR_MIN_MACS (1<<21)
    let mut rng = Rng::new(1234);
    let a_nk = randv(&mut rng, n * k);
    let a_nm = randv(&mut rng, n * m);
    let b_km = randv(&mut rng, k * m);
    let b_nm = randv(&mut rng, n * m);
    type Kern = fn(&[f32], &[f32], usize, usize, usize, &mut [f32]);
    // Each row: (label, serial kernel, par kernel, a, b, dims (d1,d2,d3) in
    // the kernel's calling order, output length).
    let cases: [(&str, Kern, Kern, &[f32], &[f32], (usize, usize, usize), usize); 6] = [
        ("blocked nn", gemm::mm_nn, gemm::mm_nn_par, &a_nk, &b_km, (n, k, m), n * m),
        ("blocked tn", gemm::mm_tn, gemm::mm_tn_par, &a_nk, &b_nm, (n, k, m), k * m),
        ("blocked nt", gemm::mm_nt, gemm::mm_nt_par, &a_nm, &b_km, (n, m, k), n * k),
        ("simd nn", simd::mm_nn, simd::mm_nn_par, &a_nk, &b_km, (n, k, m), n * m),
        ("simd tn", simd::mm_tn, simd::mm_tn_par, &a_nk, &b_nm, (n, k, m), k * m),
        ("simd nt", simd::mm_nt, simd::mm_nt_par, &a_nm, &b_km, (n, m, k), n * k),
    ];
    for (name, serial, par, a, b, (d1, d2, d3), len) in cases {
        let mut s = vec![0f32; len];
        serial(a, b, d1, d2, d3, &mut s);
        let mut p1 = vec![0f32; len];
        par(a, b, d1, d2, d3, &mut p1);
        assert_eq!(s, p1, "{name}: par ≡ serial");
        let mut p2 = vec![0f32; len];
        par(a, b, d1, d2, d3, &mut p2);
        assert_eq!(p1, p2, "{name}: bitwise rerun");
        let mut p3 = vec![0f32; len];
        serial_compute(|| par(a, b, d1, d2, d3, &mut p3));
        assert_eq!(p1, p3, "{name}: thread-count independent");
    }
}

// ------------------------------------------------------- lowp fused GEMMs --

/// The fused low-precision GEMMs' defining contract: bitwise-equal to
/// decoding the weights and running the blocked f32 GEMM — and therefore
/// within the oracle bound of the scalar reference on the decoded matrix.
#[test]
fn lowp_fused_gemms_are_bitwise_decode_then_gemm_and_hold_to_the_oracle() {
    const LOWP_SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (7, 16, 9),
        (8, 33, 31),
        (17, 64, 65),
    ];
    let mut rng = Rng::new(99);
    for &(n, k, m) in LOWP_SHAPES {
        let a = randv(&mut rng, n * k);
        let w = randv(&mut rng, k * m);
        let label = format!("({n},{k},{m})");

        let enc = Bf16Mat::encode(&w, k, m);
        let mut fused = vec![0f32; n * m];
        mm_nn_bf16(&a, &enc, n, &mut fused);
        let dec = enc.decode();
        let mut via_f32 = vec![0f32; n * m];
        gemm::mm_nn(&a, &dec, n, k, m, &mut via_f32);
        assert_eq!(fused, via_f32, "{label} bf16: fused ≡ decode-then-GEMM");
        let mut oracle = vec![0f32; n * m];
        reference::mm_nn(&a, &dec, n, k, m, &mut oracle);
        let dt = transpose(&dec, k, m);
        let zeros = vec![0f32; n * m];
        assert_close(
            &format!("{label} bf16 vs oracle"),
            &fused,
            &oracle,
            n,
            m,
            &zeros,
            &|i| a[i * k..(i + 1) * k].to_vec(),
            &|j| dt[j * k..(j + 1) * k].to_vec(),
        );

        let enc = Int8Mat::encode(&w, k, m);
        let mut fused = vec![0f32; n * m];
        mm_nn_i8(&a, &enc, n, &mut fused);
        let dec = enc.decode();
        let mut via_f32 = vec![0f32; n * m];
        gemm::mm_nn(&a, &dec, n, k, m, &mut via_f32);
        assert_eq!(fused, via_f32, "{label} int8: fused ≡ decode-then-GEMM");

        // Rerun determinism of the fused path (encode + decode + GEMM are
        // all pure, but pin it end to end).
        let mut again = vec![0f32; n * m];
        mm_nn_i8(&a, &Int8Mat::encode(&w, k, m), n, &mut again);
        assert_eq!(fused, again, "{label} int8: bitwise rerun");
    }
}

// ------------------------------------------------ end-to-end --precision --

/// The three zoo models the e2e precision floors are pinned on: a dense
/// LM, a sparse LM, and a sparse vision tower.
const E2E_MODELS: &[&str] = &["lm_tiny_dense", "lm_tiny_moe_e8_c2", "vit_tiny_moe_e8_c2"];

fn e2e_setup(name: &str) -> (ModelEntry, LoadedModel, Vec<Tensor>, Vec<Tensor>) {
    let manifest = Manifest::native();
    let entry = manifest.model(name).unwrap().clone();
    // Default blocked-kernel runtime on purpose: the expected values here
    // must be identical with and without the `simd` cargo feature.
    let runtime = Runtime::new().unwrap();
    let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
    let params = tensors_from_checkpoint(&init_params(&entry, 11).unwrap(), &entry.params).unwrap();
    let trace = synthetic_trace(&entry, 8, 23, 0);
    let inputs = stack_inputs(&trace).unwrap();
    (entry, model, params, inputs)
}

/// `infer_prec` IS `infer` over `quantize_params` — bitwise. This pins the
/// seam: quantization happens exactly once, at the parameter boundary, and
/// the executable underneath is precision-blind.
#[test]
fn infer_prec_is_bitwise_infer_over_quantized_params() {
    for name in E2E_MODELS {
        let (entry, model, params, inputs) = e2e_setup(name);
        for p in [Precision::F32, Precision::Bf16, Precision::Int8PerChannel] {
            let direct = model.infer_prec(&params, &inputs, p).unwrap();
            let q = quantize_params(&entry, &params, p).unwrap();
            let via_q = model.infer(&q, &inputs).unwrap();
            assert_eq!(direct.predictions, via_q.predictions, "{name} {}", p.as_str());
            let d: Vec<u32> = direct.scores.iter().map(|s| s.to_bits()).collect();
            let v: Vec<u32> = via_q.scores.iter().map(|s| s.to_bits()).collect();
            assert_eq!(d, v, "{name} {}: scores must be bitwise", p.as_str());
        }
    }
}

/// Quantized inference is bitwise run-to-run and thread-count
/// deterministic, like every other serving path in this repo.
#[test]
fn quantized_inference_is_bitwise_rerun_and_thread_count_deterministic() {
    for name in E2E_MODELS {
        let (_entry, model, params, inputs) = e2e_setup(name);
        for p in [Precision::Bf16, Precision::Int8PerChannel] {
            let a = model.infer_prec(&params, &inputs, p).unwrap();
            let b = model.infer_prec(&params, &inputs, p).unwrap();
            assert_eq!(a.predictions, b.predictions, "{name} {}", p.as_str());
            let c = serial_compute(|| model.infer_prec(&params, &inputs, p)).unwrap();
            assert_eq!(a.predictions, c.predictions, "{name} {}: serial", p.as_str());
            for ((x, y), z) in a.scores.iter().zip(&b.scores).zip(&c.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name} {}", p.as_str());
                assert_eq!(x.to_bits(), z.to_bits(), "{name} {}: serial", p.as_str());
            }
        }
    }
}

/// The accuracy side of the precision trade, pinned per model on a fixed
/// batch and seed: bf16 (8 mantissa bits kept) must agree with f32 on at
/// least 75% of argmax predictions with mean |score delta| ≤ 0.2; int8
/// per-channel gets the looser 60% / 1.0 floors. These are deliberate
/// under-estimates of typical behavior (usually ≥95% agreement) so the
/// test pins the contract without flaking across toolchains; the bench's
/// `quantized_inference` section reports the measured values.
#[test]
fn quantized_predictions_hold_agreement_floors_against_f32() {
    for name in E2E_MODELS {
        let (_entry, model, params, inputs) = e2e_setup(name);
        let full = model.infer(&params, &inputs).unwrap();
        let full_preds = full.predictions.i32s().unwrap();
        for (p, min_agree, max_mean_delta) in [
            (Precision::Bf16, 0.75f64, 0.2f64),
            (Precision::Int8PerChannel, 0.6, 1.0),
        ] {
            let q = model.infer_prec(&params, &inputs, p).unwrap();
            let q_preds = q.predictions.i32s().unwrap();
            assert_eq!(q_preds.len(), full_preds.len(), "{name} {}", p.as_str());
            let agree = full_preds.iter().zip(q_preds).filter(|(a, b)| a == b).count() as f64
                / full_preds.len().max(1) as f64;
            assert!(
                agree >= min_agree,
                "{name} {}: argmax agreement {agree:.3} below floor {min_agree}",
                p.as_str()
            );
            let mean_delta = full
                .scores
                .iter()
                .zip(&q.scores)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / full.scores.len().max(1) as f64;
            assert!(
                mean_delta <= max_mean_delta,
                "{name} {}: mean |score delta| {mean_delta:.4} above {max_mean_delta}",
                p.as_str()
            );
        }
    }
}
