//! Property tests for the upcycling surgery: the paper's Figure-1
//! identity-at-init claim, and the optimizer-state broadcast/zeroing
//! invariants of Appendix B.6.
//!
//! **Identity at init.** With `expert_noise = 0` and combine-weight
//! renormalization on, every expert of a freshly-upcycled MoE block
//! computes the dense parent's MLP, and each routed token's combine
//! weights sum to 1 — so as long as every token is kept by at least one
//! expert (`coverage == 1`), the upcycled forward *is* the dense forward.
//! For top-1 routing the renormalized gate is exactly `1.0`, so the match
//! is bitwise; for top-2 and Expert Choice the gate-weighted sum of
//! identical outputs reintroduces ~1-ulp float rounding, so those assert a
//! tight tolerance instead. The sweep covers E ∈ {2, 4, 8, 16} and all
//! three router families by rewriting zoo entries in a cloned manifest
//! (renormalize on; EC capacity raised to E so no token can be dropped).

use sparse_upcycle::checkpoint::Checkpoint;
use sparse_upcycle::init::init_params;
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::runtime::{tensors_from_checkpoint, Runtime};
use sparse_upcycle::tensor::Tensor;
use sparse_upcycle::upcycle::{
    upcycle_opt_state, upcycle_params, SharedInit, UpcycleOptions, UpcycleStrategy,
};

/// Rewrite a sparse zoo entry's routing: force combine-weight
/// renormalization, optionally change the router family, optionally raise
/// the capacity factor (EC with C = E keeps every token by construction).
fn rewrite_routing(
    manifest: &mut Manifest,
    name: &str,
    router: Option<&str>,
    capacity: Option<f64>,
) {
    let e = manifest.models.get_mut(name).expect("zoo entry");
    for moe in [e.config.enc_moe.as_mut(), e.config.dec_moe.as_mut()]
        .into_iter()
        .flatten()
    {
        moe.renormalize = true;
        if let Some(r) = router {
            moe.router_type = r.to_string();
        }
        if let Some(c) = capacity {
            moe.capacity_factor = c;
        }
    }
}

fn lm_batch(entry: &sparse_upcycle::manifest::ModelEntry, seed: u64) -> Vec<Tensor> {
    sparse_upcycle::data::text::TextPipeline::new(
        sparse_upcycle::data::text::HmmCorpus::new(
            sparse_upcycle::data::text::HmmSpec {
                vocab_size: entry.config.vocab_size,
                ..Default::default()
            },
            seed,
        ),
        entry.config.batch_size,
        entry.config.enc_len,
        entry.config.dec_len,
        seed,
        0,
    )
    .next_batch()
}

/// The identity-at-init sweep: upcycled (noise-free, renorm on) ==
/// dense parent forward, across expert counts and router families.
#[test]
fn upcycled_forward_matches_dense_parent_at_init() {
    // (sparse zoo entry, router override, capacity override, bitwise?)
    let cases: &[(&str, Option<&str>, Option<f64>, bool)] = &[
        // Expert Choice with C = E: every expert keeps every token.
        ("lm_tiny_moe_e2_c2", None, Some(2.0), false),
        ("lm_tiny_moe_e8_c2", None, Some(8.0), false),
        // Top-1: the renormalized gate is exactly 1.0 → bitwise identity.
        ("lm_tiny_moe_e8_c2_top1", None, None, true),
        ("lm_tiny_moe_e4_c2", Some("top1"), None, true),
        // Top-2: two identical outputs, gates summing to 1 → ~ulp rounding.
        ("lm_tiny_moe_e8_c2_top2", None, None, false),
        ("lm_tiny_moe_e16_c2", Some("top2"), None, false),
    ];
    let runtime = Runtime::new().unwrap();
    for seed in [3u64, 11] {
        let mut manifest = Manifest::native();
        for &(name, router, capacity, _) in cases {
            rewrite_routing(&mut manifest, name, router, capacity);
        }
        let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
        let dense_model =
            runtime.load_model(&manifest, "lm_tiny_dense", &["eval"]).unwrap();
        let dense_ck = init_params(&dense_entry, seed).unwrap();
        let dense_params =
            tensors_from_checkpoint(&dense_ck, &dense_entry.params).unwrap();
        let batch = lm_batch(&dense_entry, seed);
        let dense_m = dense_model.eval_step(&dense_params, &batch).unwrap();

        for &(name, _, _, bitwise) in cases {
            let entry = manifest.model(name).unwrap().clone();
            let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
            let opts = UpcycleOptions { expert_noise: 0.0, seed, ..Default::default() };
            let sparse_ck = upcycle_params(&dense_ck, &entry, &opts).unwrap();
            let sparse_params = tensors_from_checkpoint(&sparse_ck, &entry.params).unwrap();
            let m = model.eval_step(&sparse_params, &batch).unwrap();
            let tag = format!("{name} seed {seed}");
            assert_eq!(
                m["coverage"], 1.0,
                "{tag}: the identity claim needs every token kept by >= 1 expert"
            );
            if bitwise {
                assert_eq!(
                    m["loss"].to_bits(),
                    dense_m["loss"].to_bits(),
                    "{tag}: top-1 + renorm must preserve the dense function bitwise \
                     ({} vs {})",
                    m["loss"],
                    dense_m["loss"]
                );
                assert_eq!(m["accuracy"].to_bits(), dense_m["accuracy"].to_bits(), "{tag}");
                // The forward-only serving path agrees too.
                let d_out = dense_model.infer(&dense_params, &batch[..2]).unwrap();
                let s_out = model.infer(&sparse_params, &batch[..2]).unwrap();
                assert_eq!(d_out.predictions, s_out.predictions, "{tag}: infer predictions");
            } else {
                let dl = (m["loss"] - dense_m["loss"]).abs();
                assert!(
                    dl < 1e-3,
                    "{tag}: loss must match the dense parent (|Δ| = {dl}, {} vs {})",
                    m["loss"],
                    dense_m["loss"]
                );
                assert!((m["accuracy"] - dense_m["accuracy"]).abs() < 0.02, "{tag}");
            }
        }
    }
}

/// The vision side of the same property: the paper's ViT recipe (Expert
/// Choice + renormalized combine weights, §3.1) preserves the dense
/// function at init when capacity covers every token.
#[test]
fn upcycled_vit_forward_matches_dense_parent_at_init() {
    let mut manifest = Manifest::native();
    rewrite_routing(&mut manifest, "vit_tiny_moe_e8_c2", None, Some(8.0));
    let runtime = Runtime::new().unwrap();
    let dense_entry = manifest.model("vit_tiny_dense").unwrap().clone();
    let dense_model = runtime.load_model(&manifest, "vit_tiny_dense", &["eval"]).unwrap();
    let dense_ck = init_params(&dense_entry, 5).unwrap();
    let dense_params = tensors_from_checkpoint(&dense_ck, &dense_entry.params).unwrap();
    let batch = sparse_upcycle::data::vision::VisionPipeline::new(
        sparse_upcycle::data::vision::VisionSpec {
            image_size: dense_entry.config.image_size,
            ..Default::default()
        },
        dense_entry.config.batch_size,
        5,
        0,
    )
    .next_batch()
    .0;
    let dense_m = dense_model.eval_step(&dense_params, &batch).unwrap();

    let entry = manifest.model("vit_tiny_moe_e8_c2").unwrap().clone();
    let model = runtime.load_model(&manifest, "vit_tiny_moe_e8_c2", &["eval"]).unwrap();
    let ck = upcycle_params(&dense_ck, &entry, &UpcycleOptions::default()).unwrap();
    let params = tensors_from_checkpoint(&ck, &entry.params).unwrap();
    let m = model.eval_step(&params, &batch).unwrap();
    assert_eq!(m["coverage"], 1.0);
    let dl = (m["loss"] - dense_m["loss"]).abs();
    assert!(dl < 1e-3, "vit: |Δloss| = {dl} ({} vs {})", m["loss"], dense_m["loss"]);
    assert!((m["accuracy"] - dense_m["accuracy"]).abs() < 0.02, "vit accuracy");
}

/// The property is *about* renormalization: without it, the same surgery
/// visibly moves the function (each token's output is scaled by its
/// sub-unit router probability) — the Fig. 15 initial drop.
#[test]
fn no_renorm_breaks_the_identity() {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
    let dense_model = runtime.load_model(&manifest, "lm_tiny_dense", &["eval"]).unwrap();
    let dense_ck = init_params(&dense_entry, 3).unwrap();
    let dense_params = tensors_from_checkpoint(&dense_ck, &dense_entry.params).unwrap();
    let batch = lm_batch(&dense_entry, 3);
    let dense_loss = dense_model.eval_step(&dense_params, &batch).unwrap()["loss"];

    // lm_tiny_moe_e8_c2 ships with renormalize = false.
    let entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
    let model = runtime.load_model(&manifest, "lm_tiny_moe_e8_c2", &["eval"]).unwrap();
    let ck = upcycle_params(&dense_ck, &entry, &UpcycleOptions::default()).unwrap();
    let params = tensors_from_checkpoint(&ck, &entry.params).unwrap();
    let loss = model.eval_step(&params, &batch).unwrap()["loss"];
    assert!(
        (loss - dense_loss).abs() > 1e-3,
        "without renorm the initial drop must be visible: {loss} vs {dense_loss}"
    );
}

/// Optimizer-state upcycling invariants (Appendix B.6): zeroing when the
/// optimizer is not carried over; dense-accumulator broadcast across
/// experts (exact copies) + router zeroing when it is; and determinism —
/// noise-free *by construction* now that the no-noise replication path
/// takes no RNG at all (the `upcycle_opt_state` regression).
#[test]
fn opt_state_upcycling_broadcast_and_zeroing_invariants() {
    let m = Manifest::native();
    let dense = m.model("lm_tiny_dense").unwrap();
    let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
    // A dense optimizer checkpoint with distinctive nonzero accumulators.
    let mut dense_opt = Checkpoint::new("lm_tiny_dense", 40, "props");
    for (i, spec) in dense.opt_state.iter().enumerate() {
        let n: usize = spec.shape.iter().product();
        let data: Vec<f32> = (0..n).map(|j| (i * 131 + j) as f32 * 1e-3 + 0.25).collect();
        dense_opt.insert(&spec.name, Tensor::from_f32(&spec.shape, data));
    }

    // load_optimizer = false (the language recipe): everything zeroed.
    let zeroed = upcycle_opt_state(&dense_opt, sparse, false, &UpcycleStrategy::Replicate).unwrap();
    for spec in &sparse.opt_state {
        let t = zeroed.get(&spec.name).unwrap();
        assert!(t.f32s().unwrap().iter().all(|&x| x == 0.0), "`{}` must be zero", spec.name);
    }

    // load_optimizer = true (the vision recipe): broadcast + router zeroing.
    let carried = upcycle_opt_state(&dense_opt, sparse, true, &UpcycleStrategy::Replicate).unwrap();
    for spec in &sparse.opt_state {
        let t = carried.get(&spec.name).unwrap();
        assert_eq!(t.shape, spec.shape, "`{}`", spec.name);
        if spec.name.contains("/moe/router/") {
            assert!(
                t.f32s().unwrap().iter().all(|&x| x == 0.0),
                "`{}`: routers have nothing to resume",
                spec.name
            );
        } else if spec.name.contains("/moe/wi/") || spec.name.contains("/moe/wo/") {
            let src = dense_opt.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
            let (data, src_data) = (t.f32s().unwrap(), src.f32s().unwrap());
            let e = spec.shape[0];
            assert_eq!(data.len(), e * src_data.len());
            for x in 0..e {
                assert_eq!(
                    &data[x * src_data.len()..(x + 1) * src_data.len()],
                    src_data,
                    "`{}` expert {x} must be an exact broadcast copy",
                    spec.name
                );
            }
        } else {
            assert_eq!(t, dense_opt.get(&spec.name).unwrap(), "`{}`", spec.name);
        }
    }

    // Deterministic by construction: a second run is bitwise-identical.
    let again = upcycle_opt_state(&dense_opt, sparse, true, &UpcycleStrategy::Replicate).unwrap();
    for spec in &sparse.opt_state {
        assert_eq!(
            carried.get(&spec.name).unwrap(),
            again.get(&spec.name).unwrap(),
            "`{}`: opt-state upcycling must be deterministic",
            spec.name
        );
    }
}

// ---------------------------------------------------------------------------
// Strategy-matrix properties: the `UpcycleStrategy` seam must not move the
// paper's surgery (Replicate bitwise-golden), the degenerate strategy
// parameters must collapse onto Replicate bitwise, a genuinely different
// strategy must visibly break the identity without producing garbage, and
// every strategy must be bitwise-deterministic — across runs and threads.
// ---------------------------------------------------------------------------

/// Assert two checkpoints hold bitwise-identical tensors for `specs`.
fn assert_bitwise_eq(
    a: &Checkpoint,
    b: &Checkpoint,
    specs: &[sparse_upcycle::manifest::TensorSpec],
    tag: &str,
) {
    for spec in specs {
        let (ta, tb) = (a.get(&spec.name).unwrap(), b.get(&spec.name).unwrap());
        assert_eq!(ta.shape, tb.shape, "{tag}: `{}` shape", spec.name);
        let (da, db) = (ta.f32s().unwrap(), tb.f32s().unwrap());
        for (j, (x, y)) in da.iter().zip(db).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{tag}: `{}`[{j}] differs bitwise ({x} vs {y})",
                spec.name
            );
        }
    }
}

/// `Replicate` is the paper's surgery, **bitwise-unchanged** by the
/// strategy refactor. The golden here is an inline re-implementation of
/// the pre-refactor loop (per-spec forked RNG stream, fresh N(0, 0.02)
/// routers, exact expert tiling, pass-through shared params) — if the
/// seam ever reorders RNG consumption or touches a tensor it shouldn't,
/// this catches it at the bit level, on both the LM and ViT geometries.
#[test]
fn replicate_matches_pre_refactor_surgery_bitwise() {
    use sparse_upcycle::util::rng::Rng;
    let manifest = Manifest::native();
    for (dense_name, sparse_name, seed) in [
        ("lm_tiny_dense", "lm_tiny_moe_e8_c2", 7u64),
        ("vit_tiny_dense", "vit_tiny_moe_e8_c2", 13),
    ] {
        let dense_entry = manifest.model(dense_name).unwrap();
        let sparse_entry = manifest.model(sparse_name).unwrap();
        let dense_ck = init_params(dense_entry, seed).unwrap();
        let opts = UpcycleOptions { seed, ..Default::default() };
        let new = upcycle_params(&dense_ck, sparse_entry, &opts).unwrap();

        // The pre-refactor algorithm, verbatim.
        let mut rng = Rng::new(seed);
        let mut golden = Checkpoint::new(sparse_name, dense_ck.step, "golden");
        for (i, spec) in sparse_entry.params.iter().enumerate() {
            let mut sub = rng.fork(i as u64);
            let n: usize = spec.shape.iter().product();
            let t = if spec.name.contains("/moe/router") {
                Tensor::from_f32(&spec.shape, sub.normal_vec(n, 0.02))
            } else if spec.name.contains("/moe/wi") || spec.name.contains("/moe/wo") {
                let src = dense_ck.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
                let data = src.f32s().unwrap();
                let mut out = Vec::with_capacity(spec.shape[0] * data.len());
                for _ in 0..spec.shape[0] {
                    out.extend_from_slice(data);
                }
                Tensor::from_f32(&spec.shape, out)
            } else {
                dense_ck.get(&spec.name).unwrap().clone()
            };
            golden.insert(&spec.name, t);
        }
        assert_bitwise_eq(&new, &golden, &sparse_entry.params, &format!("{sparse_name} golden"));
    }
}

/// `DropUpcycle { reinit_fraction: 0 }` and `Split { granularity: 1 }` are
/// the degenerate corners of their strategies and must collapse onto
/// `Replicate` **bitwise** — params and optimizer state both — for any
/// strategy seed and even with expert noise in play.
#[test]
fn degenerate_drop_and_split_collapse_onto_replicate_bitwise() {
    let m = Manifest::native();
    let dense = m.model("lm_tiny_dense").unwrap();
    let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
    let dense_ck = init_params(dense, 3).unwrap();
    let mut dense_opt = Checkpoint::new("lm_tiny_dense", 0, "props");
    for spec in &dense.opt_state {
        let n: usize = spec.shape.iter().product();
        dense_opt.insert(&spec.name, Tensor::from_f32(&spec.shape, vec![0.125; n]));
    }
    for noise in [0.0f32, 0.01] {
        let base = UpcycleOptions { seed: 3, expert_noise: noise, ..Default::default() };
        let replicate = upcycle_params(&dense_ck, sparse, &base).unwrap();
        for strategy in [
            UpcycleStrategy::DropUpcycle { reinit_fraction: 0.0, seed: 999 },
            UpcycleStrategy::Split { granularity: 1, expansion: 8 },
        ] {
            let tag = format!("{} (noise {noise})", strategy.name());
            let opts = UpcycleOptions { strategy: strategy.clone(), ..base.clone() };
            let got = upcycle_params(&dense_ck, sparse, &opts).unwrap();
            assert_bitwise_eq(&got, &replicate, &sparse.params, &tag);
            let opt_rep =
                upcycle_opt_state(&dense_opt, sparse, true, &UpcycleStrategy::Replicate).unwrap();
            let opt_got = upcycle_opt_state(&dense_opt, sparse, true, &strategy).unwrap();
            assert_bitwise_eq(&opt_got, &opt_rep, &sparse.opt_state, &tag);
        }
    }
}

/// The counterexample the property harness owes the reader: a *positive*
/// `reinit_fraction` genuinely re-initializes expert units, so the
/// identity-at-init property must **fail** — the upcycled loss visibly
/// moves away from the dense parent — while every output stays finite
/// (re-init is surgery, not corruption).
#[test]
fn positive_reinit_fraction_breaks_identity_but_stays_finite() {
    let mut manifest = Manifest::native();
    rewrite_routing(&mut manifest, "lm_tiny_moe_e8_c2_top1", None, None);
    let runtime = Runtime::new().unwrap();
    let dense_entry = manifest.model("lm_tiny_dense").unwrap().clone();
    let dense_model = runtime.load_model(&manifest, "lm_tiny_dense", &["eval"]).unwrap();
    let dense_ck = init_params(&dense_entry, 3).unwrap();
    let dense_params = tensors_from_checkpoint(&dense_ck, &dense_entry.params).unwrap();
    let batch = lm_batch(&dense_entry, 3);
    let dense_loss = dense_model.eval_step(&dense_params, &batch).unwrap()["loss"];

    let entry = manifest.model("lm_tiny_moe_e8_c2_top1").unwrap().clone();
    let model = runtime.load_model(&manifest, "lm_tiny_moe_e8_c2_top1", &["eval"]).unwrap();
    let opts = UpcycleOptions {
        strategy: UpcycleStrategy::DropUpcycle { reinit_fraction: 0.5, seed: 17 },
        seed: 3,
        ..Default::default()
    };
    let ck = upcycle_params(&dense_ck, &entry, &opts).unwrap();
    for spec in &entry.params {
        assert!(
            ck.get(&spec.name).unwrap().f32s().unwrap().iter().all(|x| x.is_finite()),
            "`{}`: drop-upcycled params must stay finite",
            spec.name
        );
    }
    let m = model.eval_step(&tensors_from_checkpoint(&ck, &entry.params).unwrap(), &batch).unwrap();
    assert!(m["loss"].is_finite(), "drop-upcycled loss must be finite, got {}", m["loss"]);
    assert!(
        (m["loss"] - dense_loss).abs() > 1e-4,
        "reinit_fraction = 0.5 must break the identity: {} vs dense {dense_loss}",
        m["loss"]
    );
}

/// Every strategy is **bitwise-deterministic**: two runs in this thread
/// and one run on each of two spawned threads all produce identical bits,
/// for params and optimizer state. (The RNG is explicit and thread-count
/// must be irrelevant — this is the contract `docs/UPCYCLING.md` states.)
#[test]
fn every_strategy_is_bitwise_deterministic_across_runs_and_threads() {
    let m = Manifest::native();
    let dense = m.model("lm_tiny_dense").unwrap();
    let dense_ck = init_params(dense, 5).unwrap();
    let mut dense_opt = Checkpoint::new("lm_tiny_dense", 0, "props");
    for (i, spec) in dense.opt_state.iter().enumerate() {
        let n: usize = spec.shape.iter().product();
        let data: Vec<f32> = (0..n).map(|j| (i + j) as f32 * 1e-4 + 0.5).collect();
        dense_opt.insert(&spec.name, Tensor::from_f32(&spec.shape, data));
    }
    // MultiCheckpoint needs a second dense parent on disk.
    let dir = std::env::temp_dir().join(format!("supc_strategy_props_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let second = dir.join("second_parent.supc");
    init_params(dense, 21).unwrap().save(&second).unwrap();

    let cases: Vec<(&str, UpcycleStrategy)> = vec![
        ("lm_tiny_moe_e8_c2", UpcycleStrategy::Replicate),
        ("lm_tiny_moe_e8_c2", UpcycleStrategy::DropUpcycle { reinit_fraction: 0.3, seed: 9 }),
        ("lm_tiny_moe_split_g2e8", UpcycleStrategy::Split { granularity: 2, expansion: 4 }),
        (
            "lm_tiny_moe_e8_c2",
            UpcycleStrategy::MultiCheckpoint {
                checkpoint_paths: vec![second.to_string_lossy().into_owned()],
                shared: SharedInit::Average,
            },
        ),
    ];
    for (target, strategy) in cases {
        let sparse = m.model(target).unwrap().clone();
        let opts = UpcycleOptions { strategy: strategy.clone(), seed: 5, ..Default::default() };
        let run = {
            let dense_ck = dense_ck.clone();
            let dense_opt = dense_opt.clone();
            let sparse = sparse.clone();
            let opts = opts.clone();
            let strategy = strategy.clone();
            move || {
                let p = upcycle_params(&dense_ck, &sparse, &opts).unwrap();
                let o = upcycle_opt_state(&dense_opt, &sparse, true, &strategy).unwrap();
                (p, o)
            }
        };
        let (p0, o0) = run();
        let tag = format!("{} -> {target}", strategy.name());
        let (p1, o1) = run(); // same thread, second run
        assert_bitwise_eq(&p1, &p0, &sparse.params, &format!("{tag} rerun"));
        assert_bitwise_eq(&o1, &o0, &sparse.opt_state, &format!("{tag} rerun opt"));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let run = run.clone();
                std::thread::spawn(run)
            })
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            let (p, o) = h.join().unwrap();
            assert_bitwise_eq(&p, &p0, &sparse.params, &format!("{tag} thread {k}"));
            assert_bitwise_eq(&o, &o0, &sparse.opt_state, &format!("{tag} thread {k} opt"));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `Split { granularity: 2 }` is real surgery, not replication: each
/// expert gets a *contiguous column block* of the wide dense FFN, experts
/// sharing a partition index are bitwise-identical, and the two partitions
/// tile the dense matrices exactly (nothing dropped, nothing invented).
#[test]
fn split_g2_partitions_the_dense_ffn_exactly() {
    let m = Manifest::native();
    let dense = m.model("lm_tiny_dense").unwrap();
    let sparse = m.model("lm_tiny_moe_split_g2e8").unwrap();
    let dense_ck = init_params(dense, 5).unwrap();
    let opts = UpcycleOptions {
        strategy: UpcycleStrategy::Split { granularity: 2, expansion: 4 },
        seed: 5,
        ..Default::default()
    };
    let ck = upcycle_params(&dense_ck, sparse, &opts).unwrap();
    for spec in &sparse.params {
        if !spec.name.contains("/moe/wi") {
            continue;
        }
        let wi = ck.get(&spec.name).unwrap();
        let src = dense_ck.get(&spec.name.replace("/moe/", "/mlp/")).unwrap();
        let (e, d, f) = (spec.shape[0], spec.shape[1], spec.shape[2]);
        let (wi_d, src_d) = (wi.f32s().unwrap(), src.f32s().unwrap());
        let big_f = src.shape[1];
        assert_eq!(big_f, 2 * f, "`{}`: split target must be half-width", spec.name);
        for x in 0..e {
            let p = x % 2; // partition index (granularity 2)
            for r in 0..d {
                for j in 0..f {
                    assert_eq!(
                        wi_d[x * d * f + r * f + j].to_bits(),
                        src_d[r * big_f + p * f + j].to_bits(),
                        "`{}` expert {x} row {r} col {j}",
                        spec.name
                    );
                }
            }
        }
    }
}
