//! Chaos suite: the elastic trainer's bitwise-recovery contract under a
//! deterministic fault sweep.
//!
//! Every test here runs `coordinator::trainer::train_mesh_elastic` on a
//! real DP×EP mesh (rank threads, sharded expert weights, live all-to-all
//! collectives) with a `resilience::FaultPlan` that kills one rank at one
//! step inside one phase, and asserts that the run recovers — rollback to
//! the last SUPC snapshot, replay of the rolled-back steps — to a final
//! state **bitwise-identical** to the uninterrupted run, down to the bytes
//! of the final snapshot bundle on disk.
//!
//! * [`chaos_smoke`] is one mid-step kill — the blocking CI job.
//! * [`chaos_sweep_all_phases_and_steps`] sweeps the full steps × phases
//!   grid (router / dispatch / exchange / expert_mlp / combine / backward
//!   / optimizer). It runs under `cargo test --release` (the same profile
//!   as the bench gate) and is `#[ignore]`d in debug builds, where the
//!   21-point grid would dominate the test wall time.
//! * [`snapshot_save_crash_leaves_previous_loadable`] is the
//!   crash-consistency half: a kill *during* a snapshot save must leave
//!   the previous snapshot loadable.

use std::path::Path;

use sparse_upcycle::checkpoint;
use sparse_upcycle::coordinator::{
    train_mesh_elastic, Evaluator, MeshConfig, Schedule, TrainConfig, TrainState,
};
use sparse_upcycle::data::text::{HmmCorpus, HmmSpec, TextPipeline};
use sparse_upcycle::init::{init_opt_state, init_params};
use sparse_upcycle::manifest::{Manifest, ModelEntry};
use sparse_upcycle::resilience::{
    ElasticConfig, ElasticReport, FaultPhase, FaultPlan, FaultSchedule,
};
use sparse_upcycle::runtime::{LoadedModel, Runtime};

const MODEL: &str = "lm_tiny_moe_e8_c2";
const STEPS: u64 = 3;
const SNAPSHOT_EVERY: u64 = 2;

fn setup() -> (ModelEntry, LoadedModel) {
    let manifest = Manifest::native();
    let runtime = Runtime::new().unwrap();
    let entry = manifest.model(MODEL).unwrap().clone();
    let model = runtime.load_model(&manifest, MODEL, &["train", "eval"]).unwrap();
    (entry, model)
}

fn pipeline(entry: &ModelEntry, shard: u64) -> TextPipeline {
    TextPipeline::new(
        HmmCorpus::new(
            HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
            1,
        ),
        entry.config.batch_size,
        entry.config.enc_len,
        entry.config.dec_len,
        1,
        shard,
    )
}

/// One elastic run from a fixed fresh state; returns the final state, the
/// report, and the bytes of the final snapshot bundle.
fn run(
    entry: &ModelEntry,
    model: &LoadedModel,
    mesh: &MeshConfig,
    dir: &Path,
    faults: FaultSchedule,
) -> (TrainState, ElasticReport, Vec<u8>) {
    std::fs::remove_dir_all(dir).ok();
    let mut state = TrainState::from_checkpoints(
        entry,
        &init_params(entry, 7).unwrap(),
        &init_opt_state(entry).unwrap(),
    )
    .unwrap();
    let mut data = pipeline(entry, 0);
    let mut held = pipeline(entry, 1000);
    let evaluator = Evaluator::from_source(&mut held, 1);
    let cfg = TrainConfig {
        steps: STEPS,
        schedule: Schedule::t5_pretrain(0.01, 2),
        weight_decay: 0.01,
        eval_every: 0,
        log_every: 0,
    };
    let mut ecfg = ElasticConfig::new(dir);
    ecfg.snapshot_every = SNAPSHOT_EVERY;
    ecfg.snapshot_keep = 2;
    ecfg.faults = faults;
    let (_series, report) = train_mesh_elastic(
        model, &mut state, &mut data, &evaluator, &cfg, mesh, &ecfg, "chaos",
    )
    .unwrap();
    let final_snap = checkpoint::snapshot_path(dir, state.step);
    let bytes = std::fs::read(&final_snap).expect("final snapshot must exist");
    (state, report, bytes)
}

fn assert_bitwise(entry: &ModelEntry, a: &TrainState, b: &TrainState, what: &str) {
    assert_eq!(a.step, b.step, "{what}: final step");
    for ((x, y), spec) in a.params.iter().zip(&b.params).zip(&entry.params) {
        assert_eq!(x, y, "{what}: param `{}` must match bitwise", spec.name);
    }
    for ((x, y), spec) in a.opt_state.iter().zip(&b.opt_state).zip(&entry.opt_state) {
        assert_eq!(x, y, "{what}: opt slot `{}` must match bitwise", spec.name);
    }
}

/// One injected mid-step kill on a live 1x2 mesh recovers bitwise — the
/// blocking CI chaos-smoke check.
#[test]
fn chaos_smoke() {
    let (entry, model) = setup();
    let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
    let base = std::env::temp_dir().join("supc_chaos_smoke");
    let (ref_state, ref_report, ref_bytes) =
        run(&entry, &model, &mesh, &base.join("ref"), FaultSchedule::default());
    assert!(ref_report.recoveries.is_empty());

    let plan = FaultPlan { rank: 1, step: 3, phase: FaultPhase::ExpertMlp };
    let (state, report, bytes) =
        run(&entry, &model, &mesh, &base.join("fault"), FaultSchedule::single(plan));
    assert_eq!(report.recoveries.len(), 1, "{:?}", report.recoveries);
    let ev = &report.recoveries[0];
    assert!(ev.injected, "{}", ev.cause);
    assert_eq!((ev.failed_step, ev.rolled_back_to), (3, 2));
    assert_bitwise(&entry, &ref_state, &state, "smoke");
    assert_eq!(ref_bytes, bytes, "final SUPC bundles must be byte-identical");
    std::fs::remove_dir_all(&base).ok();
}

/// The full grid: for every phase in the step pipeline and every step of
/// the run, kill a rank there and assert bitwise recovery. Rank-side
/// phases kill EP rank 1 of the 1x2 mesh; the optimizer phase kills the
/// coordinator mid-update (the torn-state case). Release-profile only —
/// CI runs it via `cargo test --release` next to the bench gate.
#[cfg_attr(debug_assertions, ignore = "21-point grid; runs in the release test pass")]
#[test]
fn chaos_sweep_all_phases_and_steps() {
    let (entry, model) = setup();
    let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
    let base = std::env::temp_dir().join("supc_chaos_sweep");
    let (ref_state, _, ref_bytes) =
        run(&entry, &model, &mesh, &base.join("ref"), FaultSchedule::default());

    for phase in FaultPhase::ALL {
        for step in 1..=STEPS {
            let rank = if phase.on_coordinator() { 0 } else { 1 };
            let plan = FaultPlan { rank, step, phase };
            let dir = base.join(format!("fault_{phase}_{step}"));
            let (state, report, bytes) =
                run(&entry, &model, &mesh, &dir, FaultSchedule::single(plan));
            let what = format!("fault {plan}");
            assert_eq!(report.recoveries.len(), 1, "{what}: {:?}", report.recoveries);
            let ev = &report.recoveries[0];
            assert!(ev.injected, "{what}: {}", ev.cause);
            assert_eq!(ev.failed_step, step, "{what}");
            // Rollback lands on the last snapshot at or before step-1.
            let expect_rollback = (step - 1) / SNAPSHOT_EVERY * SNAPSHOT_EVERY;
            assert_eq!(ev.rolled_back_to, expect_rollback, "{what}");
            assert_bitwise(&entry, &ref_state, &state, &what);
            assert_eq!(ref_bytes, bytes, "{what}: final SUPC bundle bytes");
            std::fs::remove_dir_all(&dir).ok();
        }
    }
    std::fs::remove_dir_all(&base).ok();
}

/// Faults must also recover on a 2x2 mesh (two DP groups — the failure is
/// in one group; the other blocks on gradient fan-in and must be released
/// cleanly by the scope teardown, not deadlock).
#[cfg_attr(debug_assertions, ignore = "runs in the release test pass")]
#[test]
fn chaos_recovers_on_2x2_mesh() {
    let (entry, model) = setup();
    let mesh = MeshConfig { dp: 2, ep: 2, parallel: true, microbatches: 1 };
    let base = std::env::temp_dir().join("supc_chaos_2x2");
    let (ref_state, _, ref_bytes) =
        run(&entry, &model, &mesh, &base.join("ref"), FaultSchedule::default());
    // Global rank 2 = DP group 1, EP rank 0.
    let plan = FaultPlan { rank: 2, step: 2, phase: FaultPhase::Backward };
    let (state, report, bytes) =
        run(&entry, &model, &mesh, &base.join("fault"), FaultSchedule::single(plan));
    assert_eq!(report.recoveries.len(), 1);
    assert_bitwise(&entry, &ref_state, &state, "2x2");
    assert_eq!(ref_bytes, bytes);
    std::fs::remove_dir_all(&base).ok();
}

/// Two faults in one run (different steps) both recover.
#[cfg_attr(debug_assertions, ignore = "runs in the release test pass")]
#[test]
fn chaos_recovers_from_multiple_faults() {
    let (entry, model) = setup();
    let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
    let base = std::env::temp_dir().join("supc_chaos_multi");
    let (ref_state, _, ref_bytes) =
        run(&entry, &model, &mesh, &base.join("ref"), FaultSchedule::default());
    let faults = FaultSchedule::new(vec![
        FaultPlan { rank: 0, step: 1, phase: FaultPhase::Router },
        FaultPlan { rank: 1, step: 3, phase: FaultPhase::Optimizer },
    ]);
    let (state, report, bytes) = run(&entry, &model, &mesh, &base.join("fault"), faults);
    assert_eq!(report.recoveries.len(), 2, "{:?}", report.recoveries);
    assert_bitwise(&entry, &ref_state, &state, "multi");
    assert_eq!(ref_bytes, bytes);
    std::fs::remove_dir_all(&base).ok();
}

/// A rank killed inside the split-phase all-to-all window — after
/// `start_exchange` posted its sends, before `finish_exchange` drained the
/// receives — recovers bitwise, with the pipeline overlapping microbatches
/// (`microbatches: 2`). The clean reference runs the fused single-slot
/// schedule (`microbatches: 1`), so this test also re-asserts the
/// overlapped ≡ fused bitwise contract under fault recovery.
#[test]
fn chaos_fault_inside_split_phase_exchange_window() {
    let (entry, model) = setup();
    let fused = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
    let overlapped = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 2 };
    let base = std::env::temp_dir().join("supc_chaos_exchange");
    let (ref_state, ref_report, ref_bytes) =
        run(&entry, &model, &fused, &base.join("ref"), FaultSchedule::default());
    assert!(ref_report.recoveries.is_empty());

    let plan = FaultPlan { rank: 1, step: 2, phase: FaultPhase::Exchange };
    let (state, report, bytes) = run(
        &entry,
        &model,
        &overlapped,
        &base.join("fault"),
        FaultSchedule::single(plan),
    );
    assert_eq!(report.recoveries.len(), 1, "{:?}", report.recoveries);
    let ev = &report.recoveries[0];
    assert!(ev.injected, "{}", ev.cause);
    assert_eq!((ev.failed_step, ev.rolled_back_to), (2, 0));
    assert_bitwise(&entry, &ref_state, &state, "exchange-window fault");
    assert_eq!(ref_bytes, bytes, "final SUPC bundles must be byte-identical");
    std::fs::remove_dir_all(&base).ok();
}

/// Crash consistency of the snapshot rotation: a process killed mid-save
/// (simulated byte-exactly: the temp file exists, the target either does
/// not exist yet or holds a torn write) leaves the *previous* snapshot
/// loadable, and recovery proceeds from it.
#[test]
fn snapshot_save_crash_leaves_previous_loadable() {
    let (entry, _model) = setup();
    let dir = std::env::temp_dir().join("supc_chaos_crashsave");
    std::fs::remove_dir_all(&dir).ok();
    let state = TrainState::from_checkpoints(
        &entry,
        &init_params(&entry, 7).unwrap(),
        &init_opt_state(&entry).unwrap(),
    )
    .unwrap();
    checkpoint::save_snapshot(&dir, &entry, &state.params, &state.opt_state, 4, 3).unwrap();

    // Crash schedule A: killed before the rename — only the temp exists.
    std::fs::write(dir.join("snap_000000000006.tmp"), b"half a snapshot").unwrap();
    let (_, _, step, _) = checkpoint::load_latest_snapshot(&dir, &entry).unwrap();
    assert_eq!(step, 4, "an in-flight temp file must be invisible to recovery");

    // Crash schedule B: the newest snapshot is torn (truncated mid-write).
    let good = std::fs::read(checkpoint::snapshot_path(&dir, 4)).unwrap();
    std::fs::write(checkpoint::snapshot_path(&dir, 6), &good[..good.len() / 2]).unwrap();
    let (params, opt, step, path) = checkpoint::load_latest_snapshot(&dir, &entry).unwrap();
    assert_eq!(step, 4, "a torn newest snapshot must fall back to the previous one");
    assert_eq!(path, checkpoint::snapshot_path(&dir, 4));
    for (t, spec) in params.iter().zip(&entry.params) {
        assert_eq!(t.shape, spec.shape);
    }
    assert_eq!(opt.len(), entry.opt_state.len());
    std::fs::remove_dir_all(&dir).ok();
}

/// The injected fault's root cause travels to the surviving ranks: a
/// surviving peer's "collective aborted" error names the injected kill,
/// so operators (and the recovery log) see *why* the group died.
#[test]
fn surviving_ranks_report_the_root_cause() {
    use sparse_upcycle::coordinator::mesh_train_step_faulted;
    let (entry, model) = setup();
    let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
    let mut data = pipeline(&entry, 0);
    let state = TrainState::from_checkpoints(
        &entry,
        &init_params(&entry, 7).unwrap(),
        &init_opt_state(&entry).unwrap(),
    )
    .unwrap();
    let batch = sparse_upcycle::coordinator::BatchSource::next(&mut data);
    let plan = FaultPlan { rank: 0, step: 1, phase: FaultPhase::Dispatch };
    let err = mesh_train_step_faulted(
        &model,
        state.params,
        state.opt_state,
        &batch,
        1e-3,
        0.0,
        1,
        &mesh,
        Some(plan),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        sparse_upcycle::resilience::is_injected_fault(&msg),
        "the step error must surface the injected root cause, got: {msg}"
    );
}
