//! Property tests for the expert-parallel dispatch/combine machinery:
//! under random routing, packing per-expert token buffers by owner,
//! exchanging them all-to-all, and returning them must move every token
//! row to exactly one owner and back unchanged — dispatch → combine is a
//! lossless permutation of the routed rows (the invariant the mesh
//! trainer's bitwise guarantee rests on).

use sparse_upcycle::manifest::MoeSpec;
use sparse_upcycle::parallel::collectives::all_to_all;
use sparse_upcycle::parallel::ExpertPlacement;
use sparse_upcycle::runtime::ep::{pack_dispatch, unpack_combine, EpPayload};
use sparse_upcycle::runtime::native::route_tokens;
use sparse_upcycle::util::rng::Rng;

const D: usize = 4;

fn spec(router: &str, e: usize, c: f64) -> MoeSpec {
    MoeSpec {
        num_experts: e,
        capacity_factor: c,
        router_type: router.to_string(),
        moe_layers: vec![0],
        group_size: 0,
        renormalize: false,
        bpr: false,
    }
}

fn random_probs(n: usize, e: usize, rng: &mut Rng) -> Vec<f32> {
    let mut p = vec![0f32; n * e];
    for row in 0..n {
        let mut sum = 0f32;
        for x in 0..e {
            let v = 0.05 + rng.f32();
            p[row * e + x] = v;
            sum += v;
        }
        for x in 0..e {
            p[row * e + x] /= sum;
        }
    }
    p
}

/// Per-expert buffers for one source rank, every row tagged with a unique
/// (rank, expert, row) sentinel so misrouted or duplicated rows are
/// detectable by value.
fn tagged_buffers(rank: usize, rows_per_expert: &[usize]) -> Vec<Vec<f32>> {
    rows_per_expert
        .iter()
        .enumerate()
        .map(|(x, &rows)| {
            let mut buf = vec![0f32; rows * D];
            for j in 0..rows {
                for c in 0..D {
                    buf[j * D + c] = (rank * 1_000_000 + x * 10_000 + j * 10 + c) as f32;
                }
            }
            buf
        })
        .collect()
}

/// Round-trip the dispatch for `ranks` sources under `spec` routing:
/// every row reaches exactly one owner, owners see ascending expert order,
/// and the combine return reassembles each source's buffers bitwise.
fn roundtrip(spec: &MoeSpec, ranks: usize, tokens_per_rank: usize, seed: u64) {
    let e = spec.num_experts;
    let placement = ExpertPlacement::new(e, ranks);
    let mut rng = Rng::new(seed);

    let mut originals: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut sends: Vec<Vec<EpPayload>> = Vec::new();
    let mut routed_rows: Vec<Vec<usize>> = Vec::new();
    for rank in 0..ranks {
        let probs = random_probs(tokens_per_rank, e, &mut rng);
        let routing = route_tokens(spec, &probs, tokens_per_rank);
        let rows: Vec<usize> = routing.expert_tok.iter().map(|t| t.len()).collect();
        let bufs = tagged_buffers(rank, &rows);
        originals.push(bufs.clone());
        sends.push(pack_dispatch(bufs, &placement, D));
        routed_rows.push(rows);
    }

    // Dispatch: sends[src][dst] → recv[dst][src].
    let recv = all_to_all(sends).unwrap();
    assert_eq!(recv.len(), ranks);

    // Every (src, expert) buffer lands at exactly the owner, ascending
    // expert order within each payload, data intact.
    let mut seen = vec![vec![false; e]; ranks];
    for (dst, from_each_src) in recv.iter().enumerate() {
        for (src, payload) in from_each_src.iter().enumerate() {
            let experts: Vec<usize> = payload.iter().map(|b| b.expert).collect();
            let mut sorted = experts.clone();
            sorted.sort_unstable();
            assert_eq!(experts, sorted, "payload must be ascending in expert");
            for buf in payload {
                assert_eq!(placement.owner(buf.expert), dst, "row delivered to a non-owner");
                assert_eq!(buf.rows, routed_rows[src][buf.expert], "row count changed in flight");
                assert_eq!(buf.data, originals[src][buf.expert], "data changed in flight");
                assert!(!seen[src][buf.expert], "expert buffer delivered twice");
                seen[src][buf.expert] = true;
            }
        }
    }
    for (src, flags) in seen.iter().enumerate() {
        assert!(flags.iter().all(|&f| f), "rank {src}: every expert buffer must arrive once");
    }

    // Combine return: owners echo the buffers back; each source must
    // reassemble its original per-expert view exactly.
    let mut ret_sends: Vec<Vec<EpPayload>> = (0..ranks).map(|_| Vec::new()).collect();
    for (dst, from_each_src) in recv.into_iter().enumerate() {
        // ret_sends[dst][src]: what owner `dst` returns to source `src`.
        for payload in from_each_src {
            ret_sends[dst].push(payload);
        }
    }
    let back = all_to_all(ret_sends).unwrap();
    for (src, from_each_owner) in back.into_iter().enumerate() {
        let rebuilt = unpack_combine(from_each_owner, e).unwrap();
        assert_eq!(rebuilt, originals[src], "rank {src}: combine must invert dispatch");
    }
}

#[test]
fn ec_routing_roundtrips_every_token_exactly_once() {
    for ranks in [1usize, 2, 4] {
        roundtrip(&spec("ec", 8, 2.0), ranks, 32, 7);
    }
}

#[test]
fn token_choice_roundtrips_with_uneven_buffers() {
    // Top-2 with a binding capacity: buffers are uneven and some may be
    // empty — the permutation property must still hold.
    for ranks in [2usize, 4] {
        roundtrip(&spec("top2", 8, 1.0), ranks, 24, 11);
        roundtrip(&spec("top1", 8, 0.5), ranks, 16, 13);
    }
}

#[test]
fn uneven_expert_counts_still_partition() {
    // 5 experts over 2 ranks: rank 0 owns 3, rank 1 owns 2.
    roundtrip(&spec("ec", 5, 1.0), 2, 20, 17);
}
