//! Property tests for the data substrates and supporting math
//! (hand-rolled case generation; deterministic seeds).

use sparse_upcycle::coordinator::Schedule;
use sparse_upcycle::data::text::{
    sentinel, span_corrupt, ClassificationPipeline, HmmCorpus, HmmSpec, TextPipeline, EOS,
    FIRST_CONTENT, NUM_SENTINELS, PAD,
};
use sparse_upcycle::data::vision::{VisionPipeline, VisionSpec, NUM_CLASSES};
use sparse_upcycle::linalg::{argmax_rows, ridge, Mat};
use sparse_upcycle::util::rng::Rng;

/// Property: span corruption always produces fixed-shape, well-formed
/// examples over random raw lengths, vocab sizes and shapes.
#[test]
fn prop_span_corruption_wellformed() {
    let mut rng = Rng::new(1);
    for case in 0..128 {
        let vocab = [128usize, 256, 512][rng.below(3)];
        let enc_len = rng.range(12, 48);
        let dec_len = rng.range(8, 24);
        let raw_len = rng.range(10, 80);
        let corpus = HmmCorpus::new(HmmSpec { vocab_size: vocab, ..Default::default() }, case);
        let raw = corpus.sample(raw_len, &mut rng);
        let ex = span_corrupt(&raw, vocab, enc_len, dec_len, &mut rng);

        assert_eq!(ex.enc_tokens.len(), enc_len);
        assert_eq!(ex.dec_tokens.len(), dec_len);
        assert_eq!(ex.targets.len(), dec_len);
        assert_eq!(ex.loss_mask.len(), dec_len);
        // Shift-right invariant.
        assert_eq!(ex.dec_tokens[0], PAD);
        for i in 1..dec_len {
            assert_eq!(ex.dec_tokens[i], ex.targets[i - 1], "case {case} pos {i}");
        }
        // Mask ⊆ non-pad targets; sentinels within range; ids in vocab.
        for i in 0..dec_len {
            if ex.loss_mask[i] == 0.0 {
                assert_eq!(ex.targets[i], PAD);
            }
            assert!((ex.targets[i] as usize) < vocab);
        }
        for &t in &ex.enc_tokens {
            assert!((t as usize) < vocab);
            assert!(t >= PAD);
        }
        // Every sentinel that appears in the targets also appears in the
        // encoder input (T5 pairing invariant), as long as it wasn't
        // truncated away from the encoder side.
        let first_sent = sentinel(vocab, NUM_SENTINELS - 1);
        let enc_sents: Vec<i32> =
            ex.enc_tokens.iter().copied().filter(|&t| t >= first_sent).collect();
        for (k, &s) in enc_sents.iter().enumerate() {
            assert_eq!(s, sentinel(vocab, k), "sentinels in order");
        }
    }
}

/// Property: corruption rate lands near the T5 target (15%) on average.
#[test]
fn prop_corruption_rate() {
    let corpus = HmmCorpus::new(HmmSpec::default(), 5);
    let mut rng = Rng::new(5);
    let mut masked = 0usize;
    let mut total = 0usize;
    for _ in 0..200 {
        let raw = corpus.sample(60, &mut rng);
        let ex = span_corrupt(&raw, 256, 64, 32, &mut rng);
        // Count masked source tokens = targets that are content (not
        // sentinel/EOS/PAD).
        let first_sent = sentinel(256, NUM_SENTINELS - 1);
        masked += ex
            .targets
            .iter()
            .filter(|&&t| t >= FIRST_CONTENT && t < first_sent)
            .count();
        total += 60;
    }
    let rate = masked as f64 / total as f64;
    assert!((0.08..=0.22).contains(&rate), "corruption rate {rate} outside band");
}

/// Property: pipeline shards are deterministic, disjoint, and batches are
/// always the right shape.
#[test]
fn prop_pipeline_sharding() {
    for shard in 0..4u64 {
        let mk = || {
            let c = HmmCorpus::new(HmmSpec::default(), 1);
            TextPipeline::new(c, 4, 32, 16, 9, shard)
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..3 {
            let (ba, bb) = (a.next_batch(), b.next_batch());
            assert_eq!(ba[0], bb[0], "shard {shard} must be deterministic");
            assert_eq!(ba[0].shape, vec![4, 32]);
            assert_eq!(ba[3].shape, vec![4, 16]);
        }
    }
}

/// Property: classification batches encode labels consistently and the
/// label token never collides with PAD/EOS.
#[test]
fn prop_classification_labels() {
    let mut p = ClassificationPipeline::new(8, 256, 8, 32, 16, 2);
    for _ in 0..10 {
        let (tensors, labels) = p.next_batch();
        let tgt = tensors[2].i32s().unwrap();
        let mask = tensors[3].f32s().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            let tok = ClassificationPipeline::label_token(l);
            assert!(tok > EOS);
            assert_eq!(tgt[i * 16], tok);
            assert_eq!(tgt[i * 16 + 1], EOS);
            assert_eq!(mask[i * 16], 1.0);
            assert_eq!(&mask[i * 16 + 2..i * 16 + 16], &[0.0; 14]);
        }
    }
}

/// Property: vision batches hit every class eventually and pixel stats stay
/// in a sane range for any seed.
#[test]
fn prop_vision_coverage_and_range() {
    let mut seen = vec![false; NUM_CLASSES];
    let mut p = VisionPipeline::new(VisionSpec::default(), 32, 4, 0);
    for _ in 0..10 {
        let (tensors, labels) = p.next_batch();
        for l in labels {
            seen[l] = true;
        }
        let px = tensors[0].f32s().unwrap();
        let mean = px.iter().sum::<f32>() / px.len() as f32;
        assert!((0.2..0.8).contains(&mean), "mean pixel {mean}");
        assert!(px.iter().all(|v| (-1.0..=2.0).contains(v)));
    }
    assert!(seen.iter().all(|&s| s), "all 16 classes must appear");
}

/// Property: ridge regression separates the (noiseless) vision classes from
/// raw pixels — a sanity floor for the few-shot probe machinery.
#[test]
fn prop_ridge_separates_easy_classes() {
    let spec = VisionSpec { noise: 0.0, distractors: 0, ..Default::default() };
    let mut train = VisionPipeline::new(spec.clone(), 1, 7, 0);
    let (tensors, labels) = train.class_balanced(5);
    let px = tensors[0].f32s().unwrap();
    let n = labels.len();
    let dim = px.len() / n;
    let rows: Vec<Vec<f64>> =
        (0..n).map(|i| px[i * dim..(i + 1) * dim].iter().map(|&v| v as f64).collect()).collect();
    let x = Mat::from_rows(&rows);
    let mut y = Mat::zeros(n, NUM_CLASSES);
    for (i, &l) in labels.iter().enumerate() {
        *y.at_mut(i, l) = 1.0;
    }
    let w = ridge(&x, &y, 1e-3).unwrap();
    let preds = argmax_rows(&x.mul(&w));
    let train_acc =
        preds.iter().zip(&labels).filter(|(p, l)| **p == **l).count() as f64 / n as f64;
    assert!(train_acc > 0.9, "pixel ridge should fit the support set, got {train_acc}");
}

/// Property: LR schedule is non-negative, warmup is monotone increasing,
/// decay is monotone decreasing, for random schedule parameters.
#[test]
fn prop_schedule_shape() {
    let mut rng = Rng::new(11);
    for _ in 0..64 {
        let warmup = rng.range(1, 200) as u64;
        let peak = 0.001 + rng.f64() * 0.1;
        let s = Schedule::t5_pretrain(peak, warmup);
        let mut prev = 0.0;
        for step in 1..=warmup {
            let lr = s.lr(step);
            assert!(lr >= prev - 1e-12, "warmup must be monotone");
            prev = lr;
        }
        let mut prev = f64::MAX;
        for step in (warmup..warmup + 500).step_by(7) {
            let lr = s.lr(step.max(1));
            assert!(lr <= prev + 1e-12, "decay must be monotone");
            assert!(lr >= 0.0 && lr <= peak * 1.0001);
            prev = lr;
        }
    }
}
