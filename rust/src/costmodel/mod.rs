//! Cost model: translate training steps into the paper's x-axis units.
//!
//! The paper reports cost as **TPU-core-days** (Figs. 2–6) and **ExaFLOPs**
//! (Tables 4–5), both *relative to the dense checkpoint's sunk cost*. Our
//! testbed is a CPU PJRT client, so absolute wall-clock is meaningless for
//! comparison; instead we account analytic FLOPs (recorded per-step in the
//! manifest by `python/compile/flops.py`) and convert with a fixed effective
//! throughput. Relative costs — the quantity every figure actually plots —
//! are exact under this model because all branches share the constant.

use crate::manifest::ModelEntry;

/// Effective sustained FLOP/s per TPU core used for the core-day conversion:
/// TPUv3 peak 61.5 TFLOP/s (bf16, per chip = 2 cores → 30.75e12/core) at the
/// ~45% MFU large transformer training typically sustains.
pub const EFFECTIVE_FLOPS_PER_CORE: f64 = 30.75e12 * 0.45;

pub const SECONDS_PER_DAY: f64 = 86_400.0;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cost {
    pub flops: f64,
}

impl Cost {
    pub fn zero() -> Cost {
        Cost { flops: 0.0 }
    }

    pub fn of_steps(entry: &ModelEntry, steps: u64) -> Cost {
        Cost { flops: entry.flops.train_step * steps as f64 }
    }

    pub fn add(self, other: Cost) -> Cost {
        Cost { flops: self.flops + other.flops }
    }

    pub fn core_days(&self) -> f64 {
        self.flops / (EFFECTIVE_FLOPS_PER_CORE * SECONDS_PER_DAY)
    }

    pub fn exaflops(&self) -> f64 {
        self.flops / 1e18
    }

    /// Cost relative to a reference (the dense checkpoint's sunk cost), in
    /// percent — the paper's "Relative Extra" columns.
    pub fn relative_pct(&self, reference: &Cost) -> f64 {
        if reference.flops == 0.0 {
            return 0.0;
        }
        100.0 * self.flops / reference.flops
    }
}

/// Per-step cost ratio between two models (e.g. MoE C=2 vs dense ≈ how much
/// slower each upcycled step is — the x-axis stretching in Figs. 2/9).
pub fn step_cost_ratio(a: &ModelEntry, b: &ModelEntry) -> f64 {
    a.flops.train_step / b.flops.train_step
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn cost_arithmetic() {
        let a = Cost { flops: 2e18 };
        let b = Cost { flops: 1e18 };
        assert_eq!(a.add(b).flops, 3e18);
        assert!((a.exaflops() - 2.0).abs() < 1e-12);
        assert!((a.relative_pct(&b) - 200.0).abs() < 1e-9);
        assert!(a.core_days() > 0.0);
        assert_eq!(Cost::zero().relative_pct(&Cost::zero()), 0.0);
    }

    #[test]
    fn moe_costs_more_per_step_than_dense() {
        let m = Manifest::native();
        let dense = m.model("lm_tiny_dense").unwrap();
        let c1 = m.model("lm_tiny_moe_e8_c1").unwrap();
        let c2 = m.model("lm_tiny_moe_e8_c2").unwrap();
        let c3 = m.model("lm_tiny_moe_e8_c3").unwrap();
        // Monotone in capacity factor; C=1 ≈ dense + router (paper §2.1).
        assert!(step_cost_ratio(c1, dense) > 1.0);
        assert!(step_cost_ratio(c1, dense) < 1.5);
        assert!(step_cost_ratio(c2, c1) > 1.0);
        assert!(step_cost_ratio(c3, c2) > 1.0);
    }

    #[test]
    fn experts_do_not_change_flops_much() {
        // Paper §3.1: adding experts does not significantly affect FLOPs.
        let m = Manifest::native();
        let e2 = m.model("lm_tiny_moe_e2_c2").unwrap();
        let e16 = m.model("lm_tiny_moe_e16_c2").unwrap();
        let ratio = step_cost_ratio(e16, e2);
        assert!(ratio < 1.1, "experts should be ~FLOPs-neutral, got {ratio}");
    }
}
