//! Cost model: translate training steps into the paper's x-axis units.
//!
//! The paper reports cost as **TPU-core-days** (Figs. 2–6) and **ExaFLOPs**
//! (Tables 4–5), both *relative to the dense checkpoint's sunk cost*. Our
//! testbed is a CPU PJRT client, so absolute wall-clock is meaningless for
//! comparison; instead we account analytic FLOPs (recorded per-step in the
//! manifest by `python/compile/flops.py`) and convert with a fixed effective
//! throughput. Relative costs — the quantity every figure actually plots —
//! are exact under this model because all branches share the constant.

use crate::manifest::ModelEntry;
use crate::upcycle::{drop_reinit_units, UpcycleStrategy};

/// Effective sustained FLOP/s per TPU core used for the core-day conversion:
/// TPUv3 peak 61.5 TFLOP/s (bf16, per chip = 2 cores → 30.75e12/core) at the
/// ~45% MFU large transformer training typically sustains.
pub const EFFECTIVE_FLOPS_PER_CORE: f64 = 30.75e12 * 0.45;

pub const SECONDS_PER_DAY: f64 = 86_400.0;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cost {
    pub flops: f64,
}

impl Cost {
    pub fn zero() -> Cost {
        Cost { flops: 0.0 }
    }

    pub fn of_steps(entry: &ModelEntry, steps: u64) -> Cost {
        Cost { flops: entry.flops.train_step * steps as f64 }
    }

    pub fn add(self, other: Cost) -> Cost {
        Cost { flops: self.flops + other.flops }
    }

    pub fn core_days(&self) -> f64 {
        self.flops / (EFFECTIVE_FLOPS_PER_CORE * SECONDS_PER_DAY)
    }

    pub fn exaflops(&self) -> f64 {
        self.flops / 1e18
    }

    /// Cost relative to a reference (the dense checkpoint's sunk cost), in
    /// percent — the paper's "Relative Extra" columns. A zero-cost
    /// reference has no meaningful ratio, so it yields `NaN` (which every
    /// downstream writer renders visibly) rather than a misleading "0% of
    /// sunk cost" when there is no sunk cost at all.
    pub fn relative_pct(&self, reference: &Cost) -> f64 {
        if reference.flops == 0.0 {
            return f64::NAN;
        }
        100.0 * self.flops / reference.flops
    }
}

/// Per-step cost ratio between two models (e.g. MoE C=2 vs dense ≈ how much
/// slower each upcycled step is — the x-axis stretching in Figs. 2/9).
pub fn step_cost_ratio(a: &ModelEntry, b: &ModelEntry) -> f64 {
    a.flops.train_step / b.flops.train_step
}

/// One-shot cost of the checkpoint surgery itself, per strategy.
///
/// Surgery is cheap next to training, but the strategies are *not* equally
/// cheap: multi-checkpoint reads S dense bundles and (under `Average`)
/// reduces every shared tensor; Drop-Upcycling redraws the dropped units.
/// Pricing it here keeps `upcycle --strategy` honest about the difference
/// (printed by the CLI next to the param expansion).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SurgeryCost {
    /// Bytes copied from source checkpoints into the sparse target (f32).
    pub bytes_copied: u64,
    /// Values drawn fresh from an RNG (routers + Drop-Upcycling re-init).
    pub values_reinitialized: u64,
    /// Dense source bundles read (1, or 1 + extra multi-checkpoint paths).
    pub sources_loaded: u64,
    /// FLOPs of shared-parameter reduction (`SharedInit::Average`): one add
    /// per extra source per shared value.
    pub reduce_flops: u64,
}

/// Price `strategy`'s surgery onto `sparse` from its param specs alone —
/// no tensors are touched. Mirrors the actual surgery in `upcycle::
/// upcycle_params`, sharing [`drop_reinit_units`] so a priced and a
/// performed Drop-Upcycling can never disagree on the re-init count.
pub fn surgery_cost(sparse: &ModelEntry, strategy: &UpcycleStrategy) -> SurgeryCost {
    let mut cost = SurgeryCost { sources_loaded: 1, ..Default::default() };
    let extra_sources = match strategy {
        UpcycleStrategy::MultiCheckpoint { checkpoint_paths, .. } => {
            cost.sources_loaded += checkpoint_paths.len() as u64;
            checkpoint_paths.len() as u64
        }
        _ => 0,
    };
    let average = matches!(
        strategy,
        UpcycleStrategy::MultiCheckpoint { shared: crate::upcycle::SharedInit::Average, .. }
    );
    for spec in &sparse.params {
        let numel: usize = spec.shape.iter().product();
        if spec.name.contains("/moe/router") {
            cost.values_reinitialized += numel as u64;
        } else if spec.name.contains("/moe/wi") || spec.name.contains("/moe/wo") {
            // Every strategy materializes the full [E, ...] expert tensor
            // from dense data (replicated, sliced, or round-robined).
            cost.bytes_copied += 4 * numel as u64;
            if let UpcycleStrategy::DropUpcycle { reinit_fraction, .. } = strategy {
                let (e, a, b) = (spec.shape[0], spec.shape[1], spec.shape[2]);
                let is_wi = spec.name.contains("/moe/wi");
                let f = if is_wi { b } else { a };
                let per_unit = if is_wi { a } else { b };
                let k = drop_reinit_units(f, *reinit_fraction);
                cost.values_reinitialized += (e * k * per_unit) as u64;
            }
        } else {
            cost.bytes_copied += 4 * numel as u64;
            if average {
                cost.reduce_flops += numel as u64 * extra_sources;
            }
        }
    }
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn cost_arithmetic() {
        let a = Cost { flops: 2e18 };
        let b = Cost { flops: 1e18 };
        assert_eq!(a.add(b).flops, 3e18);
        assert!((a.exaflops() - 2.0).abs() < 1e-12);
        assert!((a.relative_pct(&b) - 200.0).abs() < 1e-9);
        assert!(a.core_days() > 0.0);
        // A zero-cost reference is meaningless, never "0%".
        assert!(Cost::zero().relative_pct(&Cost::zero()).is_nan());
        assert!(a.relative_pct(&Cost::zero()).is_nan());
    }

    #[test]
    fn moe_costs_more_per_step_than_dense() {
        let m = Manifest::native();
        let dense = m.model("lm_tiny_dense").unwrap();
        let c1 = m.model("lm_tiny_moe_e8_c1").unwrap();
        let c2 = m.model("lm_tiny_moe_e8_c2").unwrap();
        let c3 = m.model("lm_tiny_moe_e8_c3").unwrap();
        // Monotone in capacity factor; C=1 ≈ dense + router (paper §2.1).
        assert!(step_cost_ratio(c1, dense) > 1.0);
        assert!(step_cost_ratio(c1, dense) < 1.5);
        assert!(step_cost_ratio(c2, c1) > 1.0);
        assert!(step_cost_ratio(c3, c2) > 1.0);
    }

    #[test]
    fn surgery_cost_prices_the_strategies_apart() {
        use crate::upcycle::SharedInit;
        let m = Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let replicate = surgery_cost(sparse, &UpcycleStrategy::Replicate);
        assert!(replicate.bytes_copied > 0);
        assert_eq!(replicate.sources_loaded, 1);
        assert_eq!(replicate.reduce_flops, 0);
        // Replicate's only fresh values are the routers.
        let router_numel: u64 = sparse
            .params
            .iter()
            .filter(|s| s.name.contains("/moe/router"))
            .map(|s| s.shape.iter().product::<usize>() as u64)
            .sum();
        assert_eq!(replicate.values_reinitialized, router_numel);

        // Split moves exactly as many bytes as Replicate (slices, not
        // copies of the whole wide FFN) and redraws nothing extra.
        let split_target = m.model("lm_tiny_moe_split_g2e8").unwrap();
        let split =
            surgery_cost(split_target, &UpcycleStrategy::Split { granularity: 2, expansion: 4 });
        let split_rep = surgery_cost(split_target, &UpcycleStrategy::Replicate);
        assert_eq!(split.bytes_copied, split_rep.bytes_copied);
        assert_eq!(split.values_reinitialized, split_rep.values_reinitialized);

        // Drop-Upcycling: re-init count is 0 at fraction 0 (== Replicate),
        // strictly monotone in the fraction, and covers every expert FFN
        // value at fraction 1.
        let frac = |f: f32| {
            surgery_cost(
                sparse,
                &UpcycleStrategy::DropUpcycle { reinit_fraction: f, seed: 0 },
            )
        };
        assert_eq!(frac(0.0), replicate);
        let (q, h, full) = (frac(0.25), frac(0.5), frac(1.0));
        assert!(replicate.values_reinitialized < q.values_reinitialized);
        assert!(q.values_reinitialized < h.values_reinitialized);
        assert!(h.values_reinitialized < full.values_reinitialized);
        let expert_numel: u64 = sparse
            .params
            .iter()
            .filter(|s| s.name.contains("/moe/wi") || s.name.contains("/moe/wo"))
            .map(|s| s.shape.iter().product::<usize>() as u64)
            .sum();
        assert_eq!(full.values_reinitialized, router_numel + expert_numel);

        // Multi-checkpoint: prices the extra source loads, and `Average`
        // additionally prices one add per extra source per shared value.
        let multi = |paths: usize, shared: SharedInit| {
            surgery_cost(
                sparse,
                &UpcycleStrategy::MultiCheckpoint {
                    checkpoint_paths: (0..paths).map(|i| format!("p{i}.supc")).collect(),
                    shared,
                },
            )
        };
        let primary = multi(3, SharedInit::Primary);
        assert_eq!(primary.sources_loaded, 4);
        assert_eq!(primary.reduce_flops, 0);
        assert_eq!(primary.bytes_copied, replicate.bytes_copied);
        let avg1 = multi(1, SharedInit::Average);
        let avg3 = multi(3, SharedInit::Average);
        assert!(avg1.reduce_flops > 0);
        assert_eq!(avg3.reduce_flops, 3 * avg1.reduce_flops);
    }

    #[test]
    fn experts_do_not_change_flops_much() {
        // Paper §3.1: adding experts does not significantly affect FLOPs.
        let m = Manifest::native();
        let e2 = m.model("lm_tiny_moe_e2_c2").unwrap();
        let e16 = m.model("lm_tiny_moe_e16_c2").unwrap();
        let ratio = step_cost_ratio(e16, e2);
        assert!(ratio < 1.1, "experts should be ~FLOPs-neutral, got {ratio}");
    }
}
