//! Versioned binary checkpoint format (the byte-level spec lives in
//! `docs/SERVING.md`).
//!
//! A checkpoint bundles named tensors (parameters and, optionally, the
//! optimizer accumulators — the paper's optimizer-state resumption knob,
//! Appendix B.6) plus metadata: the model name it belongs to, the training
//! step it was taken at, and free-form provenance (e.g. "upcycled from X").
//!
//! Layout (little-endian):
//!   magic  b"SUPC"         4 bytes
//!   version u32            (currently 1)
//!   header_len u64         JSON header length in bytes
//!   header JSON            { model, step, provenance, tensors: [ {name,
//!                            shape, dtype, offset, len_bytes} ] }
//!   raw tensor data        concatenated, offsets relative to data section
//!
//! On top of the raw container, [`save_train_state`] / [`load_train_state`]
//! define the **trained-checkpoint bundle**: one file holding a model's
//! parameters *and* optimizer state (names disjoint by construction:
//! `opt/<param>/{m,v}`), validated against the manifest signature on load —
//! the artifact `upcycle train --save` writes and `upcycle serve` /
//! `upcycle infer --load` consume. Loading rejects wrong magic, unsupported
//! versions, truncated payloads and signature mismatches with named errors.
//!
//! The [`quant`] submodule applies **inference-only weight quantization**
//! (bf16 / per-channel int8) to a loaded parameter vector — a pure
//! post-load map; the bundle bytes and the SUPC dtype set never change.
//!
//! Round trip:
//!
//! ```
//! use sparse_upcycle::checkpoint::Checkpoint;
//! use sparse_upcycle::tensor::Tensor;
//!
//! let mut ck = Checkpoint::new("demo", 42, "doctest");
//! ck.insert("w", Tensor::from_f32(&[2, 2], vec![1.0, -2.0, 3.0, 4.5]));
//! let path = std::env::temp_dir().join("supc_doctest").join("demo.supc");
//! ck.save(&path).unwrap();
//! let back = Checkpoint::load(&path).unwrap();
//! assert_eq!(back.model, "demo");
//! assert_eq!(back.step, 42);
//! assert_eq!(back.get("w").unwrap(), ck.get("w").unwrap());
//! # std::fs::remove_file(&path).ok();
//! ```

pub mod quant;

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{ModelEntry, TensorSpec};
use crate::tensor::{numel, Data, DType, Tensor};
use crate::util::json::{arr, num, obj, s, Json};

const MAGIC: &[u8; 4] = b"SUPC";
const VERSION: u32 = 1;

/// FNV-1a 64-bit running hash: the integrity checksum of a SUPC bundle.
///
/// Covers the model name, the step counter, and — per tensor, in order —
/// the tensor's name, shape dims (u64 LE), dtype tag and payload bytes.
/// Stored in the JSON header as the hex `integrity` field — an *additive*
/// header field, so version-1 readers and files without it stay
/// compatible. Covering the per-tensor metadata matters: a header flip
/// that transposes a shape or renames a tensor preserves the payload byte
/// stream, so a payload-only digest would pass it. It is what turns a
/// flipped payload or header bit into a named load error instead of a
/// silently-wrong checkpoint (fuzz-asserted by `tests/supc_fuzz.rs`).
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub provenance: String,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new(model: &str, step: u64, provenance: &str) -> Checkpoint {
        Checkpoint {
            model: model.to_string(),
            step,
            provenance: provenance.to_string(),
            tensors: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor `{name}`"))
    }

    /// Tensors in a fixed name order (the manifest's flat signature order).
    pub fn ordered(&self, names: &[String]) -> Result<Vec<&Tensor>> {
        names.iter().map(|n| self.get(n)).collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.numel() * t.dtype().size_bytes()).sum()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut entries = Vec::new();
        let mut offset = 0u64;
        // Integrity pass: hash model + step + every payload byte in write
        // order, so the header can carry the checksum ahead of the data.
        let mut integ = Fnv64::new();
        integ.update(self.model.as_bytes());
        integ.update(&self.step.to_le_bytes());
        for (name, t) in &self.tensors {
            let len = (t.numel() * t.dtype().size_bytes()) as u64;
            entries.push(obj(vec![
                ("name", s(name)),
                ("shape", arr(t.shape.iter().map(|&d| num(d as f64)).collect())),
                ("dtype", s(t.dtype().as_str())),
                ("offset", num(offset as f64)),
                ("len_bytes", num(len as f64)),
            ]));
            offset += len;
            integ.update(name.as_bytes());
            for &d in &t.shape {
                integ.update(&(d as u64).to_le_bytes());
            }
            integ.update(t.dtype().as_str().as_bytes());
            match &t.data {
                Data::F32(v) => {
                    for x in v {
                        integ.update(&x.to_le_bytes());
                    }
                }
                Data::I32(v) => {
                    for x in v {
                        integ.update(&x.to_le_bytes());
                    }
                }
            }
        }
        let header = obj(vec![
            ("integrity", s(&integ.hex())),
            ("model", s(&self.model)),
            ("step", num(self.step as f64)),
            ("provenance", s(&self.provenance)),
            ("tensors", arr(entries)),
        ])
        .to_string();

        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for t in self.tensors.values() {
                match &t.data {
                    Data::F32(v) => {
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                    Data::I32(v) => {
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                }
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?; // atomic publish
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        // Every length parsed out of the file is bounded against the bytes
        // actually on disk *before* any allocation: a corrupt or bit-flipped
        // length field must produce a named error, never an absurd
        // allocation or a panic (asserted by `tests/supc_fuzz.rs`).
        let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut f = std::io::BufReader::new(file);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic).with_context(|| format!("{path:?}: truncated magic"))?;
        if &magic != MAGIC {
            bail!("{path:?}: not a SUPC checkpoint");
        }
        let mut v4 = [0u8; 4];
        f.read_exact(&mut v4).with_context(|| format!("{path:?}: truncated version field"))?;
        let version = u32::from_le_bytes(v4);
        if version != VERSION {
            bail!(
                "{path:?}: unsupported checkpoint version {version} (this build reads \
                 version {VERSION})"
            );
        }
        let mut l8 = [0u8; 8];
        f.read_exact(&mut l8).with_context(|| format!("{path:?}: truncated header length"))?;
        let hlen = u64::from_le_bytes(l8);
        let preamble = (MAGIC.len() + 4 + 8) as u64;
        if hlen > file_len.saturating_sub(preamble) {
            bail!(
                "{path:?}: header length {hlen} exceeds the {file_len}-byte file \
                 (corrupt header length)"
            );
        }
        let hlen = hlen as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)
            .with_context(|| format!("{path:?}: truncated header ({hlen} bytes expected)"))?;
        let header = Json::parse(
            std::str::from_utf8(&hbuf)
                .with_context(|| format!("{path:?}: checkpoint header is not UTF-8"))?,
        )
        .with_context(|| format!("{path:?}: malformed checkpoint header"))?;

        let mut ck = Checkpoint::new(
            header.get("model")?.as_str()?,
            header.get("step")?.as_f64()? as u64,
            header.get("provenance")?.as_str()?,
        );
        // Bytes left for tensor payloads after the header.
        let mut data_left = file_len - preamble - hlen as u64;
        let mut integ = Fnv64::new();
        integ.update(ck.model.as_bytes());
        integ.update(&ck.step.to_le_bytes());
        for e in header.get("tensors")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let shape: Vec<usize> = e
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let dtype = DType::from_str(e.get("dtype")?.as_str()?)?;
            let n = shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .with_context(|| {
                    format!("{path:?}: tensor `{name}` shape {shape:?} overflows")
                })?;
            let bytes = n.checked_mul(4).with_context(|| {
                format!("{path:?}: tensor `{name}` byte size overflows ({n} elements)")
            })?;
            if bytes as u64 > data_left {
                bail!(
                    "{path:?}: truncated payload reading tensor `{name}` ({bytes} bytes \
                     expected, {data_left} left in the file)"
                );
            }
            data_left -= bytes as u64;
            let mut raw = vec![0u8; bytes];
            f.read_exact(&mut raw).with_context(|| {
                format!(
                    "{path:?}: truncated payload reading tensor `{name}` ({bytes} bytes expected)"
                )
            })?;
            integ.update(name.as_bytes());
            for &d in &shape {
                integ.update(&(d as u64).to_le_bytes());
            }
            integ.update(dtype.as_str().as_bytes());
            integ.update(&raw);
            debug_assert_eq!(n, numel(&shape));
            let t = match dtype {
                DType::F32 => Tensor::from_f32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                ),
                DType::I32 => Tensor::from_i32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                ),
            };
            ck.tensors.insert(name, t);
        }
        // Optional integrity verification: files written by this build
        // carry the checksum; older version-1 files without it still load.
        if let Some(want) = header.opt("integrity") {
            let want = want.as_str()?;
            let got = integ.hex();
            if want != got {
                bail!(
                    "{path:?}: integrity checksum mismatch (header says {want}, content \
                     hashes to {got}) — the file is corrupt"
                );
            }
        }
        Ok(ck)
    }
}

// ---------------------------------------------------------------------------
// Trained-checkpoint bundles (params + optimizer state + step in one file)
// ---------------------------------------------------------------------------

/// Bind a checkpoint's tensors to a flat signature order, validating
/// shapes. The one spec-binding implementation in the tree:
/// `runtime::tensors_from_checkpoint` delegates here.
pub fn bind_tensors(ck: &Checkpoint, specs: &[TensorSpec]) -> Result<Vec<Tensor>> {
    specs
        .iter()
        .map(|spec| {
            let t = ck.get(&spec.name)?;
            if t.shape != spec.shape {
                bail!("tensor `{}` shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
            }
            Ok(t.clone())
        })
        .collect()
}

/// Persist a full training state — parameters *and* optimizer accumulators,
/// in `entry`'s signature order, plus the global step — as one SUPC bundle
/// at `path`. The two tensor families cannot collide: optimizer slots are
/// namespaced `opt/<param>/{m,v}` by the manifest contract.
///
/// [`load_train_state`] restores the bundle bitwise, so `train → save →
/// load → resume` continues the exact trajectory of an uninterrupted run
/// (asserted in `coordinator::trainer`'s tests).
pub fn save_train_state(
    path: impl AsRef<Path>,
    entry: &ModelEntry,
    params: &[Tensor],
    opt_state: &[Tensor],
    step: u64,
    provenance: &str,
) -> Result<()> {
    if params.len() != entry.params.len() || opt_state.len() != entry.opt_state.len() {
        bail!(
            "save_train_state `{}`: got {}/{} params/opt tensors, signature wants {}/{}",
            entry.name,
            params.len(),
            opt_state.len(),
            entry.params.len(),
            entry.opt_state.len()
        );
    }
    let mut ck = Checkpoint::new(&entry.name, step, provenance);
    for (spec, t) in entry.params.iter().zip(params).chain(entry.opt_state.iter().zip(opt_state))
    {
        if t.shape != spec.shape {
            bail!("tensor `{}` shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
        }
        ck.insert(&spec.name, t.clone());
    }
    ck.save(path)
}

/// Bind an already-loaded checkpoint as a train-state bundle —
/// `(params, opt_state, step)` in `entry`'s signature order — rejecting
/// bundles written for a different model or with missing/mis-shaped
/// tensors. [`load_train_state`] is the from-disk wrapper; callers that
/// already read the file (the CLI peeks at the header for the model name)
/// bind from memory instead of parsing the payload twice.
pub fn bind_train_state(
    ck: &Checkpoint,
    entry: &ModelEntry,
) -> Result<(Vec<Tensor>, Vec<Tensor>, u64)> {
    if ck.model != entry.name {
        bail!(
            "this is a `{}` checkpoint, not `{}` (pass the matching --model or omit it to \
             use the bundle's own model)",
            ck.model,
            entry.name
        );
    }
    let want = entry.params.len() + entry.opt_state.len();
    if ck.tensors.len() != want {
        bail!(
            "{} tensors but the `{}` train-state signature has {want} — not a train-state \
             bundle? (params-only checkpoints load via `Checkpoint::load`)",
            ck.tensors.len(),
            entry.name
        );
    }
    let params = bind_tensors(ck, &entry.params)
        .with_context(|| format!("binding params to the `{}` signature", entry.name))?;
    let opt_state = bind_tensors(ck, &entry.opt_state)
        .with_context(|| format!("binding optimizer state to the `{}` signature", entry.name))?;
    Ok((params, opt_state, ck.step))
}

/// Load a [`save_train_state`] bundle back into `(params, opt_state, step)`
/// in `entry`'s signature order; see [`bind_train_state`] for the
/// validation it applies.
pub fn load_train_state(
    path: impl AsRef<Path>,
    entry: &ModelEntry,
) -> Result<(Vec<Tensor>, Vec<Tensor>, u64)> {
    let path = path.as_ref();
    let ck = Checkpoint::load(path)
        .with_context(|| format!("loading train state from {path:?}"))?;
    bind_train_state(&ck, entry).with_context(|| format!("loading train state from {path:?}"))
}

// ---------------------------------------------------------------------------
// Snapshot rotation (elastic training's rollback targets)
// ---------------------------------------------------------------------------

/// File-name prefix of rotated snapshots: `snap_<step:012>.supc`.
pub const SNAPSHOT_PREFIX: &str = "snap_";

/// Path of the rotated snapshot for `step` under `dir`.
pub fn snapshot_path(dir: impl AsRef<Path>, step: u64) -> std::path::PathBuf {
    dir.as_ref().join(format!("{SNAPSHOT_PREFIX}{step:012}.supc"))
}

/// Write one rotated train-state snapshot and prune the rotation to the
/// `keep` newest. The write is crash-consistent: [`Checkpoint::save`]
/// writes to a temp file and atomically renames it into place, so a
/// process killed mid-save leaves the previous snapshot untouched and
/// loadable (the chaos suite asserts this). Pruning runs *after* the new
/// snapshot is durable, so the rotation never drops below `keep` loadable
/// files on any crash schedule.
pub fn save_snapshot(
    dir: impl AsRef<Path>,
    entry: &ModelEntry,
    params: &[Tensor],
    opt_state: &[Tensor],
    step: u64,
    keep: usize,
) -> Result<std::path::PathBuf> {
    let dir = dir.as_ref();
    let path = snapshot_path(dir, step);
    save_train_state(&path, entry, params, opt_state, step, "elastic snapshot")
        .with_context(|| format!("writing snapshot {path:?}"))?;
    let keep = keep.max(1);
    let snaps = list_snapshots(dir)?;
    if snaps.len() > keep {
        for (_, old) in &snaps[..snaps.len() - keep] {
            // Best-effort: a prune failure must never fail the training
            // step that triggered it (the snapshot itself is durable).
            let _ = std::fs::remove_file(old);
        }
    }
    Ok(path)
}

/// All rotated snapshots under `dir`, ascending by step. A missing
/// directory is an empty rotation, not an error; files that do not parse
/// as `snap_<step>.supc` (including in-flight `.tmp` writes) are ignored.
pub fn list_snapshots(dir: impl AsRef<Path>) -> Result<Vec<(u64, std::path::PathBuf)>> {
    let dir = dir.as_ref();
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("listing snapshots in {dir:?}")),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(step) = name
            .strip_prefix(SNAPSHOT_PREFIX)
            .and_then(|r| r.strip_suffix(".supc"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        out.push((step, entry.path()));
    }
    out.sort_by_key(|(s, _)| *s);
    Ok(out)
}

/// Load the newest *loadable* snapshot of the rotation — the elastic
/// trainer's rollback target. A corrupt newest snapshot (e.g. a machine
/// died mid-write in a way that beat the atomic rename) falls back to the
/// next older one instead of failing the recovery; only an empty or fully
/// corrupt rotation errors, naming every attempt.
pub fn load_latest_snapshot(
    dir: impl AsRef<Path>,
    entry: &ModelEntry,
) -> Result<(Vec<Tensor>, Vec<Tensor>, u64, std::path::PathBuf)> {
    let dir = dir.as_ref();
    let snaps = list_snapshots(dir)?;
    if snaps.is_empty() {
        bail!("no snapshots in {dir:?} to recover from");
    }
    let mut attempts = Vec::new();
    for (step, path) in snaps.iter().rev() {
        match load_train_state(path, entry) {
            Ok((params, opt, loaded_step)) => {
                if loaded_step != *step {
                    // A mis-named file (hand-restored copy?) is just another
                    // failed candidate — keep falling back, per the contract.
                    attempts.push(format!(
                        "{path:?}: named step {step} but contains step {loaded_step}"
                    ));
                    continue;
                }
                return Ok((params, opt, loaded_step, path.clone()));
            }
            Err(e) => attempts.push(format!("{path:?}: {e:#}")),
        }
    }
    bail!(
        "no loadable snapshot among {} candidate(s) in {dir:?}:\n  {}",
        attempts.len(),
        attempts.join("\n  ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint::new("lm_tiny_dense", 1234, "unit-test");
        ck.insert("a/w", Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4.5, 0., -0.5]));
        ck.insert("b/tokens", Tensor::from_i32(&[4], vec![9, 8, 7, -6]));
        ck.insert("c/scalar", Tensor::scalar_f32(0.125));
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("ck.supc");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.model, "lm_tiny_dense");
        assert_eq!(back.step, 1234);
        assert_eq!(back.provenance, "unit-test");
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("a/w").unwrap(), ck.get("a/w").unwrap());
        assert_eq!(back.get("b/tokens").unwrap(), ck.get("b/tokens").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("supc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.supc");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// An unsupported format version must be rejected by name, not parsed.
    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("badver.supc");
        let mut ck = Checkpoint::new("m", 1, "");
        ck.insert("a", Tensor::scalar_f32(1.0));
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("unsupported checkpoint version 99"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    /// A payload cut short mid-tensor must name the tensor it died in.
    #[test]
    fn rejects_truncated_payload() {
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("trunc.supc");
        let mut ck = Checkpoint::new("m", 1, "");
        ck.insert("big", Tensor::from_f32(&[64], vec![0.5; 64]));
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("truncated payload"), "{err}");
        assert!(err.contains("`big`"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    /// save_train_state → load_train_state restores params, optimizer state
    /// and step bitwise, and rejects a bundle loaded against the wrong
    /// model signature.
    #[test]
    fn train_state_bundle_roundtrips_and_validates() {
        let m = crate::manifest::Manifest::native();
        let entry = m.model("lm_tiny_dense").unwrap();
        let mut params = Vec::new();
        for (i, spec) in entry.params.iter().enumerate() {
            let n: usize = spec.shape.iter().product();
            let data: Vec<f32> = (0..n).map(|j| (i * 31 + j) as f32 * 0.01 - 1.0).collect();
            params.push(Tensor::from_f32(&spec.shape, data));
        }
        let opt: Vec<Tensor> = entry.opt_state.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let path = std::env::temp_dir().join("supc_test").join("bundle.supc");
        save_train_state(&path, entry, &params, &opt, 77, "unit-test").unwrap();
        let (p2, o2, step) = load_train_state(&path, entry).unwrap();
        assert_eq!(step, 77);
        assert_eq!(params, p2, "params must round-trip bitwise");
        assert_eq!(opt, o2, "optimizer state must round-trip bitwise");
        // Loading against another model's signature fails by name.
        let other = m.model("lm_tiny_moe_e8_c2").unwrap();
        let err = format!("{:#}", load_train_state(&path, other).unwrap_err());
        assert!(err.contains("lm_tiny_dense"), "{err}");
        // A params-only checkpoint is not a train-state bundle.
        let ppath = std::env::temp_dir().join("supc_test").join("params_only.supc");
        crate::init::init_params(entry, 3).unwrap().save(&ppath).unwrap();
        let err = format!("{:#}", load_train_state(&ppath, entry).unwrap_err());
        assert!(err.contains("train-state"), "{err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&ppath).ok();
    }

    fn tiny_state(entry: &ModelEntry, salt: f32) -> (Vec<Tensor>, Vec<Tensor>) {
        let params: Vec<Tensor> = entry
            .params
            .iter()
            .map(|s| {
                let n: usize = s.shape.iter().product();
                Tensor::from_f32(&s.shape, (0..n).map(|j| salt + j as f32 * 0.5).collect())
            })
            .collect();
        let opt: Vec<Tensor> = entry.opt_state.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        (params, opt)
    }

    /// The rotation keeps exactly the newest `keep` snapshots, the listing
    /// is step-ordered, and the latest loadable snapshot wins.
    #[test]
    fn snapshot_rotation_prunes_and_loads_latest() {
        let m = crate::manifest::Manifest::native();
        let entry = m.model("lm_tiny_dense").unwrap();
        let dir = std::env::temp_dir().join("supc_test_rotation");
        std::fs::remove_dir_all(&dir).ok();
        let (params, opt) = tiny_state(entry, 1.0);
        for step in [2u64, 4, 6, 8] {
            save_snapshot(&dir, entry, &params, &opt, step, 2).unwrap();
        }
        let snaps = list_snapshots(&dir).unwrap();
        assert_eq!(
            snaps.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![6, 8],
            "keep=2 retains only the two newest"
        );
        let (p, o, step, path) = load_latest_snapshot(&dir, entry).unwrap();
        assert_eq!(step, 8);
        assert_eq!(path, snapshot_path(&dir, 8));
        assert_eq!(p, params, "snapshot params must round-trip bitwise");
        assert_eq!(o, opt);
        // An empty rotation errors by name.
        let empty = std::env::temp_dir().join("supc_test_rotation_empty");
        std::fs::remove_dir_all(&empty).ok();
        assert!(list_snapshots(&empty).unwrap().is_empty());
        let err = format!("{:#}", load_latest_snapshot(&empty, entry).unwrap_err());
        assert!(err.contains("no snapshots"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Crash consistency: a snapshot save killed mid-write (simulated by a
    /// leftover temp file and a truncated newest snapshot) must leave the
    /// previous snapshot loadable — `load_latest_snapshot` falls back past
    /// the corrupt file instead of failing the recovery.
    #[test]
    fn corrupt_newest_snapshot_falls_back_to_previous() {
        let m = crate::manifest::Manifest::native();
        let entry = m.model("lm_tiny_dense").unwrap();
        let dir = std::env::temp_dir().join("supc_test_fallback");
        std::fs::remove_dir_all(&dir).ok();
        let (params, opt) = tiny_state(entry, 2.0);
        save_snapshot(&dir, entry, &params, &opt, 10, 3).unwrap();
        save_snapshot(&dir, entry, &params, &opt, 20, 3).unwrap();
        // Corrupt the newest in place (a torn write that beat the rename).
        let newest = snapshot_path(&dir, 20);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 3]).unwrap();
        // Leave an in-flight temp file lying around too; it must be ignored.
        std::fs::write(dir.join("snap_000000000030.tmp"), b"partial").unwrap();
        let (p, _, step, path) = load_latest_snapshot(&dir, entry).unwrap();
        assert_eq!(step, 10, "recovery must fall back to the loadable snapshot");
        assert_eq!(path, snapshot_path(&dir, 10));
        assert_eq!(p, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A flipped payload bit is a *named* integrity error, never a
    /// silently different checkpoint.
    #[test]
    fn payload_bitflip_fails_the_integrity_check() {
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("bitflip.supc");
        let mut ck = Checkpoint::new("m", 9, "integrity");
        ck.insert("w", Tensor::from_f32(&[32], (0..32).map(|i| i as f32).collect()));
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 5;
        bytes[last] ^= 0x10; // flip one payload bit
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("integrity checksum mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    /// Header-metadata corruption that preserves the payload byte stream
    /// (here: a transposed shape) must still fail integrity — the digest
    /// covers per-tensor names/shapes/dtypes, not just payload bytes.
    #[test]
    fn header_shape_transposition_fails_the_integrity_check() {
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("shapeflip.supc");
        let mut ck = Checkpoint::new("m", 2, "integrity");
        ck.insert("w", Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let needle = b"\"shape\":[2,3]";
        let at = bytes
            .windows(needle.len())
            .position(|w| w == needle)
            .expect("test assumes compact header serialization");
        // Transpose the dims in place: same header length, same payload.
        bytes[at..at + needle.len()].copy_from_slice(b"\"shape\":[3,2]");
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("integrity checksum mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    /// A corrupt header-length field must produce a named error, not a
    /// multi-gigabyte allocation (the byte-level guard behind the fuzz
    /// suite in `tests/supc_fuzz.rs`).
    #[test]
    fn absurd_header_length_is_rejected() {
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("hugehdr.supc");
        let mut ck = Checkpoint::new("m", 1, "");
        ck.insert("a", Tensor::scalar_f32(1.0));
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(err.contains("header length"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ordered_respects_order() {
        let mut ck = Checkpoint::new("m", 0, "");
        ck.insert("z", Tensor::scalar_f32(1.0));
        ck.insert("a", Tensor::scalar_f32(2.0));
        let names = vec!["z".to_string(), "a".to_string()];
        let ts = ck.ordered(&names).unwrap();
        assert_eq!(ts[0].f32s().unwrap()[0], 1.0);
        assert_eq!(ts[1].f32s().unwrap()[0], 2.0);
        assert!(ck.ordered(&["missing".to_string()]).is_err());
    }
}
