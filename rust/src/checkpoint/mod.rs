//! Versioned binary checkpoint format.
//!
//! A checkpoint bundles named tensors (parameters and, optionally, the
//! Adafactor accumulators — the paper's optimizer-state resumption knob,
//! Appendix B.6) plus metadata: the model name it belongs to, the training
//! step it was taken at, and free-form provenance (e.g. "upcycled from X").
//!
//! Layout (little-endian):
//!   magic  b"SUPC"         4 bytes
//!   version u32            (currently 1)
//!   header_len u64         JSON header length in bytes
//!   header JSON            { model, step, provenance, tensors: [ {name,
//!                            shape, dtype, offset, len_bytes} ] }
//!   raw tensor data        concatenated, offsets relative to data section

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{numel, Data, DType, Tensor};
use crate::util::json::{arr, num, obj, s, Json};

const MAGIC: &[u8; 4] = b"SUPC";
const VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub provenance: String,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new(model: &str, step: u64, provenance: &str) -> Checkpoint {
        Checkpoint {
            model: model.to_string(),
            step,
            provenance: provenance.to_string(),
            tensors: BTreeMap::new(),
        }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor `{name}`"))
    }

    /// Tensors in a fixed name order (the manifest's flat signature order).
    pub fn ordered(&self, names: &[String]) -> Result<Vec<&Tensor>> {
        names.iter().map(|n| self.get(n)).collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.numel() * t.dtype().size_bytes()).sum()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut entries = Vec::new();
        let mut offset = 0u64;
        for (name, t) in &self.tensors {
            let len = (t.numel() * t.dtype().size_bytes()) as u64;
            entries.push(obj(vec![
                ("name", s(name)),
                ("shape", arr(t.shape.iter().map(|&d| num(d as f64)).collect())),
                ("dtype", s(t.dtype().as_str())),
                ("offset", num(offset as f64)),
                ("len_bytes", num(len as f64)),
            ]));
            offset += len;
        }
        let header = obj(vec![
            ("model", s(&self.model)),
            ("step", num(self.step as f64)),
            ("provenance", s(&self.provenance)),
            ("tensors", arr(entries)),
        ])
        .to_string();

        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
            );
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for t in self.tensors.values() {
                match &t.data {
                    Data::F32(v) => {
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                    Data::I32(v) => {
                        for x in v {
                            f.write_all(&x.to_le_bytes())?;
                        }
                    }
                }
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?; // atomic publish
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a SUPC checkpoint");
        }
        let mut v4 = [0u8; 4];
        f.read_exact(&mut v4)?;
        let version = u32::from_le_bytes(v4);
        if version != VERSION {
            bail!("{path:?}: unsupported checkpoint version {version}");
        }
        let mut l8 = [0u8; 8];
        f.read_exact(&mut l8)?;
        let hlen = u64::from_le_bytes(l8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;

        let mut ck = Checkpoint::new(
            header.get("model")?.as_str()?,
            header.get("step")?.as_f64()? as u64,
            header.get("provenance")?.as_str()?,
        );
        for e in header.get("tensors")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let shape: Vec<usize> = e
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let dtype = DType::from_str(e.get("dtype")?.as_str()?)?;
            let n = numel(&shape);
            let mut raw = vec![0u8; n * 4];
            f.read_exact(&mut raw)?;
            let t = match dtype {
                DType::F32 => Tensor::from_f32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                ),
                DType::I32 => Tensor::from_i32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                ),
            };
            ck.tensors.insert(name, t);
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint::new("lm_tiny_dense", 1234, "unit-test");
        ck.insert("a/w", Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4.5, 0., -0.5]));
        ck.insert("b/tokens", Tensor::from_i32(&[4], vec![9, 8, 7, -6]));
        ck.insert("c/scalar", Tensor::scalar_f32(0.125));
        let dir = std::env::temp_dir().join("supc_test");
        let path = dir.join("ck.supc");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.model, "lm_tiny_dense");
        assert_eq!(back.step, 1234);
        assert_eq!(back.provenance, "unit-test");
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("a/w").unwrap(), ck.get("a/w").unwrap());
        assert_eq!(back.get("b/tokens").unwrap(), ck.get("b/tokens").unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("supc_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.supc");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ordered_respects_order() {
        let mut ck = Checkpoint::new("m", 0, "");
        ck.insert("z", Tensor::scalar_f32(1.0));
        ck.insert("a", Tensor::scalar_f32(2.0));
        let names = vec!["z".to_string(), "a".to_string()];
        let ts = ck.ordered(&names).unwrap();
        assert_eq!(ts[0].f32s().unwrap()[0], 1.0);
        assert_eq!(ts[1].f32s().unwrap()[0], 2.0);
        assert!(ck.ordered(&["missing".to_string()]).is_err());
    }
}
