//! Load-time weight quantization: the `Precision` seam of the serving
//! path.
//!
//! Quantization happens **after** a SUPC checkpoint is loaded and bound to
//! the manifest signature — the bundle on disk and the in-memory
//! `Checkpoint` are never mutated, and the `Tensor`/SUPC dtype set stays
//! f32/i32. [`quantize_params`] maps a full parameter vector through the
//! storage codecs in [`crate::linalg::lowp`] (encode then decode, i.e. the
//! exact values a fused low-precision GEMM computes with) and returns a new
//! f32 vector the unchanged `Executable::infer` path consumes. This is the
//! inference-only contract: `train` never sees a `Precision` other than
//! implicit f32, so every training bitwise invariant (resume, mesh≡serial,
//! fault recovery) is untouched.
//!
//! What gets quantized: every f32 parameter with ≥ 2 dims — expert and
//! dense FFN weights (`moe/wi|wo`, `mlp/wi|wo`, with the trailing
//! `[rows, cols]` matrix of an `[E, rows, cols]` expert stack quantized
//! per expert), embeddings and projection heads. What stays full
//! precision: **router weights** (name contains `router`; routing
//! decisions are too sensitive to weight noise, and the per-channel cost
//! is negligible), 1-D tensors (biases/norms), and i32 tensors.
//!
//! Determinism: both codecs are element-wise deterministic maps, so
//! `quantize_params` is a pure function of `(params, precision)` —
//! quantized serving inherits the bitwise rerun and thread-count
//! determinism contracts of the f32 path. Accuracy is the traded
//! quantity; `tests/kernel_props.rs` pins per-model agreement floors and
//! the bench's `quantized_inference` section measures the tokens/s side.

use anyhow::{bail, Result};

use crate::linalg::lowp::{Bf16Mat, Int8Mat};
use crate::manifest::ModelEntry;
use crate::tensor::Tensor;

/// Inference weight precision, selected by `--precision` on
/// `upcycle infer` / `upcycle serve`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full precision — the unchanged serving path.
    #[default]
    F32,
    /// bf16 weight storage (round-to-nearest-even), f32 accumulation.
    Bf16,
    /// Per-output-channel symmetric int8 weight storage, f32 accumulation.
    Int8PerChannel,
}

impl Precision {
    /// Parse the CLI spelling; unknown values fail by name.
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            "int8" => Ok(Precision::Int8PerChannel),
            other => bail!("unknown precision `{other}` (expected f32|bf16|int8)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::Int8PerChannel => "int8",
        }
    }
}

/// Whether a parameter is quantized under a non-f32 precision: f32, at
/// least 2-D, and not a router weight.
fn quantizes(spec_name: &str, t: &Tensor) -> bool {
    t.dtype() == crate::tensor::DType::F32 && t.shape.len() >= 2 && !spec_name.contains("router")
}

/// Quantize a parameter vector for inference at `precision`: returns new
/// f32 tensors holding the encode→decode round trip of every eligible
/// weight (see the module docs for the eligibility rules), leaving `params`
/// and the checkpoint they came from untouched. `Precision::F32` is the
/// identity (a plain clone).
pub fn quantize_params(
    entry: &ModelEntry,
    params: &[Tensor],
    precision: Precision,
) -> Result<Vec<Tensor>> {
    if params.len() != entry.params.len() {
        bail!(
            "quantize_params on `{}`: got {} tensors for a {}-tensor signature",
            entry.name,
            params.len(),
            entry.params.len()
        );
    }
    if precision == Precision::F32 {
        return Ok(params.to_vec());
    }
    let mut out = Vec::with_capacity(params.len());
    for (spec, t) in entry.params.iter().zip(params) {
        if !quantizes(&spec.name, t) {
            out.push(t.clone());
            continue;
        }
        let nd = t.shape.len();
        let (rows, cols) = (t.shape[nd - 2], t.shape[nd - 1]);
        let reps = t.shape[..nd - 2].iter().product::<usize>().max(1);
        let src = t.f32s()?;
        let mut data = Vec::with_capacity(src.len());
        // Each trailing [rows, cols] matrix (e.g. one expert of an
        // [E, d, ff] stack) is quantized independently, with per-`cols`
        // channel scales for int8.
        for r in 0..reps {
            let w = &src[r * rows * cols..(r + 1) * rows * cols];
            match precision {
                Precision::F32 => unreachable!("handled above"),
                Precision::Bf16 => data.extend(Bf16Mat::encode(w, rows, cols).decode()),
                Precision::Int8PerChannel => data.extend(Int8Mat::encode(w, rows, cols).decode()),
            }
        }
        out.push(Tensor::from_f32(&t.shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::init_params;
    use crate::linalg::lowp::bf16_roundtrip;
    use crate::manifest::Manifest;
    use crate::runtime::tensors_from_checkpoint;

    fn setup(name: &str) -> (ModelEntry, Vec<Tensor>) {
        let manifest = Manifest::native();
        let entry = manifest.model(name).unwrap().clone();
        let params =
            tensors_from_checkpoint(&init_params(&entry, 7).unwrap(), &entry.params).unwrap();
        (entry, params)
    }

    #[test]
    fn precision_parse_matrix() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("bf16").unwrap(), Precision::Bf16);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8PerChannel);
        for bad in ["fp16", "int4", "", "BF16"] {
            let err = Precision::parse(bad).unwrap_err();
            assert!(format!("{err:#}").contains("unknown precision"), "{bad}: {err:#}");
        }
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::Int8PerChannel.as_str(), "int8");
    }

    #[test]
    fn f32_precision_is_the_identity() {
        let (entry, params) = setup("lm_tiny_moe_e8_c1");
        let q = quantize_params(&entry, &params, Precision::F32).unwrap();
        assert_eq!(params, q);
    }

    #[test]
    fn bf16_round_trips_weights_and_skips_routers() {
        let (entry, params) = setup("lm_tiny_moe_e8_c1");
        let q = quantize_params(&entry, &params, Precision::Bf16).unwrap();
        let mut saw_router = false;
        let mut saw_changed = false;
        for ((spec, orig), quant) in entry.params.iter().zip(&params).zip(&q) {
            if spec.name.contains("router") {
                saw_router = true;
                assert_eq!(orig, quant, "{}: routers stay full precision", spec.name);
            } else if orig.shape.len() >= 2 {
                let (o, g) = (orig.f32s().unwrap(), quant.f32s().unwrap());
                for (x, y) in o.iter().zip(g) {
                    assert_eq!(y.to_bits(), bf16_roundtrip(*x).to_bits(), "{}", spec.name);
                }
                saw_changed |= o.iter().zip(g).any(|(x, y)| x.to_bits() != y.to_bits());
            }
        }
        assert!(saw_router, "fixture must contain router weights");
        assert!(saw_changed, "random init weights cannot all be bf16-representable");
    }

    #[test]
    fn quantization_is_deterministic() {
        let (entry, params) = setup("lm_tiny_dense");
        for p in [Precision::Bf16, Precision::Int8PerChannel] {
            let a = quantize_params(&entry, &params, p).unwrap();
            let b = quantize_params(&entry, &params, p).unwrap();
            assert_eq!(a, b, "{}", p.as_str());
        }
    }

    #[test]
    fn arity_mismatch_fails_by_name() {
        let (entry, params) = setup("lm_tiny_dense");
        let err = quantize_params(&entry, &params[1..], Precision::Bf16).unwrap_err();
        assert!(format!("{err:#}").contains("quantize_params"), "{err:#}");
    }
}
