//! Explicitly vectorized f32 GEMM kernels: the inference compute tier
//! behind [`crate::linalg::gemm::GemmKernels::Simd`].
//!
//! The blocked tier in [`crate::linalg::gemm`] computes one output column
//! per dot product; this tier register-blocks **four output columns** per
//! pass over a row of `a`, so each element of `a` loaded from L1 feeds four
//! multiply-accumulates instead of one, and the four independent 8-lane
//! accumulators give the CPU enough instruction-level parallelism to keep
//! its FMA pipes full. Two implementations sit behind one seam:
//!
//! * **portable** (always compiled): safe Rust whose fixed-width lane
//!   arrays auto-vectorize on every target;
//! * **AVX2+FMA** (cargo feature `simd`, `x86_64` only): the same
//!   4-column micro-kernel written with `std::arch` intrinsics, selected
//!   at runtime via `is_x86_feature_detected!` and falling back to the
//!   portable path on machines without AVX2/FMA.
//!
//! Determinism contract (same shape as the blocked tier's): every output
//! element uses a reduction order fixed by the operand shapes and the
//! resolved implementation — never by the thread count — and the `*_par`
//! forms shard disjoint output rows over [`crate::linalg::gemm::par_rows`],
//! so they are bitwise-identical to their serial counterparts. Across
//! implementations the tier is *not* bitwise-stable: FMA fuses the
//! round-off of multiply and add, so the AVX2 path differs from the
//! portable path (and both differ from the blocked tier) by rounding
//! noise. That is why this tier is **inference-only**: the trainers keep
//! the blocked kernels, and `tests/kernel_props.rs` holds every simd
//! kernel to the `gemm::reference` oracle with an error bound derived
//! from the f32 epsilon and the reduction length.

use crate::linalg::gemm::{par_rows, transpose, COL_TILE, PAR_MIN_MACS, ROW_TILE};

/// Lane width of the portable accumulators (matches one AVX2 register).
const LANES: usize = 8;
/// Output columns computed per micro-kernel invocation.
const COLS: usize = 4;

/// Portable 4-column dot product: `[dot(ai,b0), dot(ai,b1), dot(ai,b2),
/// dot(ai,b3)]` with four independent 8-lane accumulators.
#[inline]
fn dot4_portable(ai: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; COLS] {
    let len = ai.len();
    let chunks = len / LANES;
    let mut a0 = [0.0f32; LANES];
    let mut a1 = [0.0f32; LANES];
    let mut a2 = [0.0f32; LANES];
    let mut a3 = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let x = &ai[o..o + LANES];
        let y0 = &b0[o..o + LANES];
        let y1 = &b1[o..o + LANES];
        let y2 = &b2[o..o + LANES];
        let y3 = &b3[o..o + LANES];
        for l in 0..LANES {
            a0[l] += x[l] * y0[l];
            a1[l] += x[l] * y1[l];
            a2[l] += x[l] * y2[l];
            a3[l] += x[l] * y3[l];
        }
    }
    let mut s = [
        a0.iter().sum::<f32>(),
        a1.iter().sum::<f32>(),
        a2.iter().sum::<f32>(),
        a3.iter().sum::<f32>(),
    ];
    for j in chunks * LANES..len {
        s[0] += ai[j] * b0[j];
        s[1] += ai[j] * b1[j];
        s[2] += ai[j] * b2[j];
        s[3] += ai[j] * b3[j];
    }
    s
}

/// Single-column dot product for the `cols % 4` remainder lanes (8-lane
/// unrolled, same reduction order as the blocked tier's `dot`).
#[inline]
fn dot1(x: &[f32], y: &[f32]) -> f32 {
    let chunks = x.len() / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let xs = &x[c * LANES..(c + 1) * LANES];
        let ys = &y[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for j in chunks * LANES..x.len() {
        s += x[j] * y[j];
    }
    s
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! AVX2+FMA micro-kernel; only reachable after runtime detection.
    use std::arch::x86_64::*;

    /// Whether AVX2 and FMA are both present (detected once, cached).
    pub fn available() -> bool {
        use std::sync::OnceLock;
        static AVAIL: OnceLock<bool> = OnceLock::new();
        *AVAIL.get_or_init(|| {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        })
    }

    /// Horizontal sum with a fixed lane order (store + left fold), so the
    /// reduction order is shape-determined like the portable path's.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        lanes.iter().sum()
    }

    /// 4-column FMA dot product over equal-length slices.
    ///
    /// # Safety
    /// Callers must have verified [`available`] (AVX2 + FMA present).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot4(ai: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        let len = ai.len();
        let chunks = len / 8;
        let mut v0 = _mm256_setzero_ps();
        let mut v1 = _mm256_setzero_ps();
        let mut v2 = _mm256_setzero_ps();
        let mut v3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let o = c * 8;
            let x = _mm256_loadu_ps(ai.as_ptr().add(o));
            v0 = _mm256_fmadd_ps(x, _mm256_loadu_ps(b0.as_ptr().add(o)), v0);
            v1 = _mm256_fmadd_ps(x, _mm256_loadu_ps(b1.as_ptr().add(o)), v1);
            v2 = _mm256_fmadd_ps(x, _mm256_loadu_ps(b2.as_ptr().add(o)), v2);
            v3 = _mm256_fmadd_ps(x, _mm256_loadu_ps(b3.as_ptr().add(o)), v3);
        }
        let mut s = [hsum(v0), hsum(v1), hsum(v2), hsum(v3)];
        for j in chunks * 8..len {
            s[0] += ai[j] * b0[j];
            s[1] += ai[j] * b1[j];
            s[2] += ai[j] * b2[j];
            s[3] += ai[j] * b3[j];
        }
        s
    }
}

/// Resolve the active 4-column micro-kernel: AVX2+FMA when the feature is
/// compiled in and the CPU has it, portable lanes otherwise.
#[inline]
fn dot4(ai: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; COLS] {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if x86::available() {
            // SAFETY: gated on runtime AVX2+FMA detection above.
            return unsafe { x86::dot4(ai, b0, b1, b2, b3) };
        }
    }
    dot4_portable(ai, b0, b1, b2, b3)
}

/// 4-column dot-product core over a row range of the output: the simd
/// counterpart of `gemm::dot_block`, walking [`ROW_TILE`] row blocks and
/// [`COLS`]-wide column groups (remainder columns via [`dot1`]).
fn dot_block4(
    a: &[f32],
    bt: &[f32],
    inner: usize,
    cols: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    let mut i0 = 0;
    while i0 < rows {
        let i1 = (i0 + ROW_TILE).min(rows);
        let mut j0 = 0;
        while j0 + COLS <= cols {
            let b0 = &bt[j0 * inner..(j0 + 1) * inner];
            let b1 = &bt[(j0 + 1) * inner..(j0 + 2) * inner];
            let b2 = &bt[(j0 + 2) * inner..(j0 + 3) * inner];
            let b3 = &bt[(j0 + 3) * inner..(j0 + 4) * inner];
            for i in i0..i1 {
                let ai = &a[(row0 + i) * inner..(row0 + i + 1) * inner];
                let s = dot4(ai, b0, b1, b2, b3);
                let o = i * cols + j0;
                out[o] += s[0];
                out[o + 1] += s[1];
                out[o + 2] += s[2];
                out[o + 3] += s[3];
            }
            j0 += COLS;
        }
        for j in j0..cols {
            let bj = &bt[j * inner..(j + 1) * inner];
            for i in i0..i1 {
                let ai = &a[(row0 + i) * inner..(row0 + i + 1) * inner];
                out[i * cols + j] += dot1(ai, bj);
            }
        }
        i0 = i1;
    }
}

/// Saxpy core of [`mm_tn`] over output rows `l0..l1`, unrolling the sweep
/// over `n` four rows of `b` at a time so the inner loop carries four
/// independent multiply-adds per output element.
fn tn_block4(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    l0: usize,
    l1: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (l1 - l0) * m);
    let mut t0 = l0;
    while t0 < l1 {
        let t1 = (t0 + COL_TILE).min(l1);
        let mut i0 = 0;
        while i0 + 4 <= n {
            let b0 = &b[i0 * m..(i0 + 1) * m];
            let b1 = &b[(i0 + 1) * m..(i0 + 2) * m];
            let b2 = &b[(i0 + 2) * m..(i0 + 3) * m];
            let b3 = &b[(i0 + 3) * m..(i0 + 4) * m];
            for l in t0..t1 {
                let av0 = a[i0 * k + l];
                let av1 = a[(i0 + 1) * k + l];
                let av2 = a[(i0 + 2) * k + l];
                let av3 = a[(i0 + 3) * k + l];
                if av0 == 0.0 && av1 == 0.0 && av2 == 0.0 && av3 == 0.0 {
                    continue;
                }
                let orow = &mut out[(l - l0) * m..(l - l0 + 1) * m];
                for j in 0..m {
                    orow[j] += av0 * b0[j] + av1 * b1[j] + av2 * b2[j] + av3 * b3[j];
                }
            }
            i0 += 4;
        }
        for i in i0..n {
            let brow = &b[i * m..(i + 1) * m];
            for l in t0..t1 {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[(l - l0) * m..(l - l0 + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
        t0 = t1;
    }
}

/// `out[n,m] += a[n,k] · b[k,m]` (vectorized, transposed-B).
pub fn mm_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    let bt = transpose(b, k, m);
    dot_block4(a, &bt, k, m, 0, n, out);
}

/// `out[k,m] += aᵀ · b` with `a[n,k]`, `b[n,m]` (vectorized saxpy).
pub fn mm_tn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(out.len(), k * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    tn_block4(a, b, n, k, m, 0, k, out);
}

/// `out[n,k] += a · bᵀ` with `a[n,m]`, `b[k,m]` (vectorized dot products).
pub fn mm_nt(a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * k);
    if n == 0 || m == 0 || k == 0 {
        return;
    }
    dot_block4(a, b, m, k, 0, n, out);
}

/// [`mm_nn`], sharding output rows across threads for large products.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_nn_par(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    if n * k * m < PAR_MIN_MACS {
        mm_nn(a, b, n, k, m, out);
        return;
    }
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    let bt = transpose(b, k, m);
    par_rows(n, m, out, |row0, rows, chunk| dot_block4(a, &bt, k, m, row0, rows, chunk));
}

/// [`mm_tn`], sharding output rows (columns of `a`) across threads.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_tn_par(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    if n * k * m < PAR_MIN_MACS {
        mm_tn(a, b, n, k, m, out);
        return;
    }
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(out.len(), k * m);
    par_rows(k, m, out, |l0, rows, chunk| tn_block4(a, b, n, k, m, l0, l0 + rows, chunk));
}

/// [`mm_nt`], sharding output rows across threads for large products.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_nt_par(a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
    if n * m * k < PAR_MIN_MACS {
        mm_nt(a, b, n, m, k, out);
        return;
    }
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * k);
    par_rows(n, k, out, |row0, rows, chunk| dot_block4(a, b, m, k, row0, rows, chunk));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{reference, GemmKernels};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Absolute error bound for one output element: `2·ε·len·Σ|aᵢ·bᵢ|`
    /// covers both reduction orders' worst-case accumulated rounding.
    fn bound(ai: &[f32], bj: &[f32]) -> f64 {
        let abs_sum: f64 = ai.iter().zip(bj).map(|(x, y)| (x * y).abs() as f64).sum();
        2.0 * f32::EPSILON as f64 * (ai.len().max(1) as f64) * abs_sum + 1e-7
    }

    /// Odd/prime shapes plus lane (8) and column-group (4) boundaries ±1.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 8, 4),
        (5, 9, 3),
        (17, 13, 11),
        (23, 1, 19),
        (7, 16, 9),
        (67, 8, 64),
        (63, 65, 31),
    ];

    #[test]
    fn simd_matches_reference_on_boundary_shapes() {
        let mut rng = Rng::new(29);
        for &(n, k, m) in SHAPES {
            let a = randv(&mut rng, n * k);
            let b = randv(&mut rng, k * m);
            let seed = randv(&mut rng, n * m);
            let mut got = seed.clone();
            let mut want = seed.clone();
            mm_nn(&a, &b, n, k, m, &mut got);
            reference::mm_nn(&a, &b, n, k, m, &mut want);
            let bt = crate::linalg::gemm::transpose(&b, k, m);
            for i in 0..n {
                for j in 0..m {
                    let e = (got[i * m + j] as f64 - want[i * m + j] as f64).abs();
                    let tol = bound(&a[i * k..(i + 1) * k], &bt[j * k..(j + 1) * k]);
                    assert!(e <= tol, "mm_nn {n}x{k}x{m} [{i},{j}]: err {e} > {tol}");
                }
            }
        }
    }

    #[test]
    fn simd_par_is_bitwise_identical_to_serial() {
        let mut rng = Rng::new(31);
        let (n, k, m) = (257, 129, 67);
        let a = randv(&mut rng, n * k);
        let b = randv(&mut rng, k * m);
        let mut serial = vec![0f32; n * m];
        let mut par = vec![0f32; n * m];
        mm_nn(&a, &b, n, k, m, &mut serial);
        mm_nn_par(&a, &b, n, k, m, &mut par);
        assert_eq!(serial, par, "simd mm_nn_par must be bitwise-deterministic");
    }

    #[test]
    fn selector_dispatches_the_simd_tier() {
        let mut rng = Rng::new(37);
        let (n, k, m) = (6, 12, 8);
        let a = randv(&mut rng, n * k);
        let b = randv(&mut rng, k * m);
        let mut via_selector = vec![0f32; n * m];
        let mut direct = vec![0f32; n * m];
        GemmKernels::Simd.mm_nn(&a, &b, n, k, m, &mut via_selector);
        mm_nn(&a, &b, n, k, m, &mut direct);
        assert_eq!(via_selector, direct);
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut out = vec![3.0f32; 6];
        mm_nn(&[], &[], 2, 0, 3, &mut out);
        mm_nt(&[], &[], 2, 0, 3, &mut out);
        assert_eq!(out, vec![3.0; 6]);
        let mut empty: Vec<f32> = Vec::new();
        mm_tn(&[], &[], 0, 0, 0, &mut empty);
    }
}
