//! Blocked f32 GEMM kernels for the native backend's hot path.
//!
//! Three accumulating, row-major kernels cover every matmul in the forward
//! and backward passes of `runtime::native`:
//!
//! * [`mm_nn`]: `out[n,m] += a[n,k] · b[k,m]`
//! * [`mm_tn`]: `out[k,m] += aᵀ · b` with `a[n,k]`, `b[n,m]` (weight grads)
//! * [`mm_nt`]: `out[n,k] += a · bᵀ` with `a[n,m]`, `b[k,m]` (tied-embedding
//!   logits and activation grads)
//!
//! Layout strategy: the product kernels run in *transposed-B* form — `mm_nn`
//! transposes `b` once into a scratch panel so that, like `mm_nt`, every
//! output element is a contiguous dot product, computed with an 8-lane
//! unrolled accumulator (auto-vectorizes; a naive `s += x[j]*y[j]` loop is a
//! serial dependence chain the compiler must not reorder). Output rows are
//! walked in [`ROW_TILE`] blocks so the active slice of `a` stays in L1
//! while each row of the (transposed) `b` panel streams through — for the
//! zoo's large vocabulary projections `b` no longer re-streams from memory
//! once per token. `mm_tn` keeps the saxpy form but tiles output rows in
//! [`COL_TILE`] blocks so the accumulator panel stays cache-resident across
//! the full sweep over `n`.
//!
//! Determinism contract: every output element is computed with a fixed
//! floating-point reduction order that depends only on the operand shapes —
//! never on the thread count. The `*_par` entry points shard disjoint output
//! rows across scoped threads (above [`PAR_MIN_MACS`] multiply-accumulates)
//! and are bitwise-identical to their serial counterparts; the data-parallel
//! trainer's replica-invariance guarantee rests on this.
//!
//! The [`reference`] module preserves the scalar kernels these replaced
//! (the "PR 1 path"): `cargo bench --bench runtime_step` measures blocked
//! vs. reference on every run and records the speedup in
//! `BENCH_runtime.json` (see `docs/BENCHMARKS.md`), and the unit tests
//! check the blocked kernels against them on odd/prime shapes.
//!
//! A third, inference-only tier lives in [`crate::linalg::simd`]
//! (selected via [`GemmKernels::Simd`]); `tests/kernel_props.rs` holds
//! every fast tier to the `reference` oracle over a randomized shape grid.
//!
//! ```
//! // 2×2 GEMM: out += a·b, row-major, accumulating into `out`.
//! let a = [1.0f32, 2.0, 3.0, 4.0];
//! let b = [5.0f32, 6.0, 7.0, 8.0];
//! let mut out = [100.0f32; 4];
//! sparse_upcycle::linalg::gemm::mm_nn(&a, &b, 2, 2, 2, &mut out);
//! assert_eq!(out, [119.0, 122.0, 143.0, 150.0]);
//! ```

/// Output rows processed per cache block in the dot-product kernels.
pub const ROW_TILE: usize = 64;
/// Output-row tile of `mm_tn` kept hot across the sweep over `n`.
pub const COL_TILE: usize = 32;
/// Unroll width of the dot-product accumulator.
const LANES: usize = 8;
/// Minimum multiply-accumulate count before `*_par` spawns threads; below
/// this, thread spawn overhead exceeds the parallel win.
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Contiguous dot product with a fixed 8-lane unrolled reduction order.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let xs = &x[c * LANES..(c + 1) * LANES];
        let ys = &y[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for j in chunks * LANES..x.len() {
        s += x[j] * y[j];
    }
    s
}

/// Scratch transpose: returns `bᵀ` (shape `[m,k]`) of row-major `b[k,m]`.
pub(crate) fn transpose(b: &[f32], k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(b.len(), k * m);
    let mut bt = vec![0f32; k * m];
    for i in 0..k {
        let brow = &b[i * m..(i + 1) * m];
        for (j, &v) in brow.iter().enumerate() {
            bt[j * k + i] = v;
        }
    }
    bt
}

/// Dot-product core over a row range: `out[i,j] += dot(a_row(row0+i), bt_row(j))`
/// for `i in 0..rows`, `j in 0..cols`, with `inner` the shared length.
/// `out` is the chunk holding exactly rows `row0..row0+rows`.
pub(crate) fn dot_block(
    a: &[f32],
    bt: &[f32],
    inner: usize,
    cols: usize,
    row0: usize,
    rows: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    let mut i0 = 0;
    while i0 < rows {
        let i1 = (i0 + ROW_TILE).min(rows);
        for j in 0..cols {
            let bj = &bt[j * inner..(j + 1) * inner];
            for i in i0..i1 {
                let ai = &a[(row0 + i) * inner..(row0 + i + 1) * inner];
                out[i * cols + j] += dot(ai, bj);
            }
        }
        i0 = i1;
    }
}

/// Saxpy core of `mm_tn` over output rows `l0..l1` (columns of `a`).
/// `out` is the chunk holding exactly rows `l0..l1`.
fn tn_block(
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    l0: usize,
    l1: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (l1 - l0) * m);
    let mut t0 = l0;
    while t0 < l1 {
        let t1 = (t0 + COL_TILE).min(l1);
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * m..(i + 1) * m];
            for l in t0..t1 {
                let av = arow[l];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[(l - l0) * m..(l - l0 + 1) * m];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        t0 = t1;
    }
}

/// Shard `out` into contiguous row chunks over scoped threads. Each row is
/// produced by exactly one thread with shape-determined arithmetic, so the
/// result is bitwise-independent of the thread count.
pub(crate) fn par_rows<F: Fn(usize, usize, &mut [f32]) + Sync>(
    rows: usize,
    row_len: usize,
    out: &mut [f32],
    body: F,
) {
    let threads = if crate::util::in_serial_compute() {
        1
    } else {
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1).min(rows).max(1)
    };
    if threads <= 1 {
        body(0, rows, out);
        return;
    }
    let chunk_rows = (rows + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(chunk_rows * row_len).enumerate() {
            let body = &body;
            s.spawn(move || {
                body(ci * chunk_rows, chunk.len() / row_len, chunk);
            });
        }
    });
}

/// `out[n,m] += a[n,k] · b[k,m]` (blocked, transposed-B).
pub fn mm_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    let bt = transpose(b, k, m);
    dot_block(a, &bt, k, m, 0, n, out);
}

/// `out[k,m] += aᵀ · b` with `a[n,k]`, `b[n,m]` (blocked saxpy).
pub fn mm_tn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(out.len(), k * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    tn_block(a, b, n, k, m, 0, k, out);
}

/// `out[n,k] += a · bᵀ` with `a[n,m]`, `b[k,m]` (blocked dot products).
pub fn mm_nt(a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * k);
    if n == 0 || m == 0 || k == 0 {
        return;
    }
    dot_block(a, b, m, k, 0, n, out);
}

/// [`mm_nn`], sharding output rows across threads for large products.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_nn_par(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    if n * k * m < PAR_MIN_MACS {
        mm_nn(a, b, n, k, m, out);
        return;
    }
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    let bt = transpose(b, k, m);
    par_rows(n, m, out, |row0, rows, chunk| dot_block(a, &bt, k, m, row0, rows, chunk));
}

/// [`mm_tn`], sharding output rows (columns of `a`) across threads.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_tn_par(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    if n * k * m < PAR_MIN_MACS {
        mm_tn(a, b, n, k, m, out);
        return;
    }
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(out.len(), k * m);
    par_rows(k, m, out, |l0, rows, chunk| tn_block(a, b, n, k, m, l0, l0 + rows, chunk));
}

/// [`mm_nt`], sharding output rows across threads for large products.
/// Bitwise-identical to the serial kernel for any thread count.
pub fn mm_nt_par(a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
    if n * m * k < PAR_MIN_MACS {
        mm_nt(a, b, n, m, k, out);
        return;
    }
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * k);
    par_rows(n, k, out, |row0, rows, chunk| dot_block(a, b, m, k, row0, rows, chunk));
}

/// Kernel family selector: the native backend is built with [`Blocked`]
/// kernels; [`Reference`] preserves the PR 1 scalar path so the bench can
/// measure the end-to-end step speedup on every run; [`Simd`] is the
/// explicitly vectorized inference tier (`crate::linalg::simd`), opted
/// into by the serving path only — the trainers never construct it, so
/// every training bitwise contract is untouched.
///
/// [`Blocked`]: GemmKernels::Blocked
/// [`Reference`]: GemmKernels::Reference
/// [`Simd`]: GemmKernels::Simd
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKernels {
    Blocked,
    Reference,
    Simd,
}

impl GemmKernels {
    /// Serial `out[n,m] += a·b` (used inside already-parallel regions).
    pub fn mm_nn(self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_nn(a, b, n, k, m, out),
            GemmKernels::Reference => reference::mm_nn(a, b, n, k, m, out),
            GemmKernels::Simd => crate::linalg::simd::mm_nn(a, b, n, k, m, out),
        }
    }

    /// Serial `out[k,m] += aᵀ·b`.
    pub fn mm_tn(self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_tn(a, b, n, k, m, out),
            GemmKernels::Reference => reference::mm_tn(a, b, n, k, m, out),
            GemmKernels::Simd => crate::linalg::simd::mm_tn(a, b, n, k, m, out),
        }
    }

    /// Serial `out[n,k] += a·bᵀ`.
    pub fn mm_nt(self, a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_nt(a, b, n, m, k, out),
            GemmKernels::Reference => reference::mm_nt(a, b, n, m, k, out),
            GemmKernels::Simd => crate::linalg::simd::mm_nt(a, b, n, m, k, out),
        }
    }

    /// Row-parallel `mm_nn` for tower-level products (Reference stays
    /// serial: it reproduces the PR 1 execution exactly).
    pub fn mm_nn_big(self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_nn_par(a, b, n, k, m, out),
            GemmKernels::Reference => reference::mm_nn(a, b, n, k, m, out),
            GemmKernels::Simd => crate::linalg::simd::mm_nn_par(a, b, n, k, m, out),
        }
    }

    /// Row-parallel `mm_tn` for tower-level products.
    pub fn mm_tn_big(self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_tn_par(a, b, n, k, m, out),
            GemmKernels::Reference => reference::mm_tn(a, b, n, k, m, out),
            GemmKernels::Simd => crate::linalg::simd::mm_tn_par(a, b, n, k, m, out),
        }
    }

    /// Row-parallel `mm_nt` for tower-level products.
    pub fn mm_nt_big(self, a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
        match self {
            GemmKernels::Blocked => mm_nt_par(a, b, n, m, k, out),
            GemmKernels::Reference => reference::mm_nt(a, b, n, m, k, out),
            GemmKernels::Simd => crate::linalg::simd::mm_nt_par(a, b, n, m, k, out),
        }
    }
}

/// The scalar kernels the blocked path replaced (PR 1), kept as the
/// correctness reference for tests and as the bench's speedup baseline.
pub mod reference {
    /// out[n,m] += a[n,k] · b[k,m]
    pub fn mm_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), n * k);
        debug_assert_eq!(b.len(), k * m);
        debug_assert_eq!(out.len(), n * m);
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (l, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[l * m..(l + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
    }

    /// out[k,m] += aᵀ · b  with a[n,k], b[n,m]
    pub fn mm_tn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), n * k);
        debug_assert_eq!(b.len(), n * m);
        debug_assert_eq!(out.len(), k * m);
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * m..(i + 1) * m];
            for (l, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[l * m..(l + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
    }

    /// out[n,k] += a · bᵀ  with a[n,m], b[k,m]
    pub fn mm_nt(a: &[f32], b: &[f32], n: usize, m: usize, k: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), n * m);
        debug_assert_eq!(b.len(), k * m);
        debug_assert_eq!(out.len(), n * k);
        for i in 0..n {
            let arow = &a[i * m..(i + 1) * m];
            for l in 0..k {
                let brow = &b[l * m..(l + 1) * m];
                let mut s = 0.0f32;
                for j in 0..m {
                    s += arow[j] * brow[j];
                }
                out[i * k + l] += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-3 + 1e-4 * w.abs();
            assert!((g - w).abs() <= tol, "{ctx}[{i}]: blocked {g} vs reference {w}");
        }
    }

    /// Odd and prime shapes exercise every tail path of the tiled kernels.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (17, 13, 11),
        (23, 1, 19),
        (5, 31, 2),
        (2, 97, 3),
        (67, 8, 64),
        (129, 65, 33),
    ];

    #[test]
    fn blocked_matches_reference_on_odd_shapes() {
        let mut rng = Rng::new(11);
        for &(n, k, m) in SHAPES {
            let a = randv(&mut rng, n * k);
            let b = randv(&mut rng, k * m);
            // Accumulation semantics: start from a non-zero out.
            let seed = randv(&mut rng, n * m);
            let mut got = seed.clone();
            let mut want = seed.clone();
            mm_nn(&a, &b, n, k, m, &mut got);
            reference::mm_nn(&a, &b, n, k, m, &mut want);
            assert_close(&got, &want, &format!("mm_nn {n}x{k}x{m}"));

            let bt = randv(&mut rng, n * m);
            let seed = randv(&mut rng, k * m);
            let mut got = seed.clone();
            let mut want = seed.clone();
            mm_tn(&a, &bt, n, k, m, &mut got);
            reference::mm_tn(&a, &bt, n, k, m, &mut want);
            assert_close(&got, &want, &format!("mm_tn {n}x{k}x{m}"));

            let am = randv(&mut rng, n * m);
            let bm = randv(&mut rng, k * m);
            let seed = randv(&mut rng, n * k);
            let mut got = seed.clone();
            let mut want = seed.clone();
            mm_nt(&am, &bm, n, m, k, &mut got);
            reference::mm_nt(&am, &bm, n, m, k, &mut want);
            assert_close(&got, &want, &format!("mm_nt {n}x{m}x{k}"));
        }
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        let mut rng = Rng::new(5);
        // Big enough to clear PAR_MIN_MACS and actually spawn threads.
        let (n, k, m) = (257, 129, 67);
        let a = randv(&mut rng, n * k);
        let b = randv(&mut rng, k * m);
        let mut serial = vec![0f32; n * m];
        let mut par = vec![0f32; n * m];
        mm_nn(&a, &b, n, k, m, &mut serial);
        mm_nn_par(&a, &b, n, k, m, &mut par);
        assert_eq!(serial, par, "mm_nn_par must be bitwise-deterministic");

        let bt = randv(&mut rng, n * m);
        let mut serial = vec![0f32; k * m];
        let mut par = vec![0f32; k * m];
        mm_tn(&a, &bt, n, k, m, &mut serial);
        mm_tn_par(&a, &bt, n, k, m, &mut par);
        assert_eq!(serial, par, "mm_tn_par must be bitwise-deterministic");

        let am = randv(&mut rng, n * m);
        let bm = randv(&mut rng, k * m);
        let mut serial = vec![0f32; n * k];
        let mut par = vec![0f32; n * k];
        mm_nt(&am, &bm, n, m, k, &mut serial);
        mm_nt_par(&am, &bm, n, m, k, &mut par);
        assert_eq!(serial, par, "mm_nt_par must be bitwise-deterministic");
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut out = vec![7.0f32; 0];
        mm_nn(&[], &[], 0, 0, 0, &mut out);
        let mut out = vec![3.0f32; 6];
        // Inner dim 0: += 0, out unchanged.
        mm_nn(&[], &[], 2, 0, 3, &mut out);
        assert_eq!(out, vec![3.0; 6]);
        mm_nt(&[], &[], 2, 0, 3, &mut out);
        assert_eq!(out, vec![3.0; 6]);
    }

    #[test]
    fn transpose_roundtrip() {
        let b: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 3x4
        let bt = transpose(&b, 3, 4);
        let bb = transpose(&bt, 4, 3);
        assert_eq!(b, bb);
        assert_eq!(bt[0], b[0]);
        assert_eq!(bt[2 * 3 + 1], b[4 + 2]);
    }

    #[test]
    fn kernel_selector_dispatches_both_families() {
        let mut rng = Rng::new(3);
        let (n, k, m) = (7, 11, 5);
        let a = randv(&mut rng, n * k);
        let b = randv(&mut rng, k * m);
        let mut blocked = vec![0f32; n * m];
        let mut refr = vec![0f32; n * m];
        GemmKernels::Blocked.mm_nn(&a, &b, n, k, m, &mut blocked);
        GemmKernels::Reference.mm_nn(&a, &b, n, k, m, &mut refr);
        assert_close(&blocked, &refr, "selector mm_nn");
    }
}
