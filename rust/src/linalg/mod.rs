//! Dense linear algebra: the native backend's GEMM kernel tiers plus the
//! Cholesky/ridge solvers behind the few-shot probe.
//!
//! Several tiers live here, with different performance contracts:
//!
//! * [`gemm`] — cache-blocked, transposed-B f32 matmul kernels shared by the
//!   forward and backward passes of `runtime::native` (the training hot
//!   path). Invariants: kernels *accumulate* into `out`, use a fixed
//!   shape-determined floating-point reduction order, and their `*_par`
//!   variants are bitwise-identical to the serial forms for any thread
//!   count — the data-parallel trainer's determinism guarantee
//!   (`coordinator::trainer`) depends on this. The selector
//!   [`gemm::GemmKernels`] also carries the scalar `reference` oracle and
//!   the vectorized tier below.
//! * [`simd`] — explicitly vectorized f32 kernels (multi-column register
//!   blocking; an AVX2+FMA path behind the `simd` cargo feature with a
//!   portable fallback). Inference-only tier: selected by
//!   `GemmKernels::Simd`, never by the trainers. Same accumulate +
//!   thread-count-determinism contract as [`gemm`], but its reduction
//!   order differs from the blocked tier's, so it is held to the
//!   `gemm::reference` oracle by `tests/kernel_props.rs` instead of
//!   bitwise equality.
//! * [`lowp`] — low-precision weight storage (bf16, per-channel symmetric
//!   int8) with f32-accumulate GEMMs for the quantized inference path
//!   (`checkpoint::quant`). Decoding a stored matrix and running the f32
//!   kernels is bitwise-identical to the fused decode-and-multiply forms
//!   by construction.
//! * [`Mat`] / [`cholesky`] / [`ridge`] — f64 solvers for the paper's
//!   few-shot linear evaluation (§A.2.2): a least-squares regressor from
//!   frozen image representations to one-hot labels with fixed L2
//!   regularization (the paper fixes λ = 1024 on normalized features; we
//!   keep λ configurable and default to their choice). These run once per
//!   probe, not per step, and stay in readable scalar form.

pub mod gemm;
pub mod lowp;
pub mod simd;

use anyhow::{bail, Result};

/// Row-major dense matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// AᵀA (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let mut s = 0.0;
                for r in 0..self.rows {
                    s += self.at(r, i) * self.at(r, j);
                }
                *g.at_mut(i, j) = s;
                *g.at_mut(j, i) = s;
            }
        }
        g
    }

    /// AᵀB.
    pub fn t_mul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.cols, b.cols);
        for r in 0..self.rows {
            for i in 0..self.cols {
                let a = self.at(r, i);
                if a == 0.0 {
                    continue;
                }
                for j in 0..b.cols {
                    *out.at_mut(i, j) += a * b.at(r, j);
                }
            }
        }
        out
    }

    /// AB.
    pub fn mul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut out = Mat::zeros(self.rows, b.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(r, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..b.cols {
                    *out.at_mut(r, j) += a * b.at(k, j);
                }
            }
        }
        out
    }
}

/// In-place Cholesky factorization A = LLᵀ (lower triangle). Fails on
/// non-SPD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky needs a square matrix");
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite (pivot {i}: {s})");
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve A X = B for SPD A via Cholesky (forward + back substitution).
pub fn solve_spd(a: &Mat, b: &Mat) -> Result<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    let m = b.cols;
    // Forward: L Y = B.
    let mut y = Mat::zeros(n, m);
    for c in 0..m {
        for i in 0..n {
            let mut s = b.at(i, c);
            for k in 0..i {
                s -= l.at(i, k) * y.at(k, c);
            }
            *y.at_mut(i, c) = s / l.at(i, i);
        }
    }
    // Back: Lᵀ X = Y.
    let mut x = Mat::zeros(n, m);
    for c in 0..m {
        for i in (0..n).rev() {
            let mut s = y.at(i, c);
            for k in i + 1..n {
                s -= l.at(k, i) * x.at(k, c);
            }
            *x.at_mut(i, c) = s / l.at(i, i);
        }
    }
    Ok(x)
}

/// Ridge regression: W = (XᵀX + λI)⁻¹ XᵀY.
pub fn ridge(x: &Mat, y: &Mat, lambda: f64) -> Result<Mat> {
    let mut g = x.gram();
    for i in 0..g.rows {
        *g.at_mut(i, i) += lambda;
    }
    let xty = x.t_mul(y);
    solve_spd(&g, &xty)
}

/// Per-row argmax (class prediction).
pub fn argmax_rows(m: &Mat) -> Vec<usize> {
    (0..m.rows)
        .map(|r| {
            (0..m.cols)
                .max_by(|&a, &b| m.at(r, a).partial_cmp(&m.at(r, b)).unwrap())
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_recomposes() {
        let a = Mat::from_rows(&[
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.0],
            vec![0.6, 1.0, 3.0],
        ]);
        let l = cholesky(&a).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solve_matches_direct() {
        let a = Mat::from_rows(&[vec![3.0, 1.0], vec![1.0, 2.0]]);
        let b = Mat::from_rows(&[vec![9.0], vec![8.0]]);
        let x = solve_spd(&a, &b).unwrap();
        // 3x + y = 9, x + 2y = 8 → x = 2, y = 3.
        assert!((x.at(0, 0) - 2.0).abs() < 1e-10);
        assert!((x.at(1, 0) - 3.0).abs() < 1e-10);
    }

    #[test]
    fn ridge_interpolates_exactly_at_zero_lambda() {
        // Overdetermined but consistent system.
        let x = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]]);
        let w_true = Mat::from_rows(&[vec![2.0], vec![-1.0]]);
        let y = x.mul(&w_true);
        let w = ridge(&x, &y, 1e-12).unwrap();
        assert!((w.at(0, 0) - 2.0).abs() < 1e-6);
        assert!((w.at(1, 0) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn ridge_shrinks_with_lambda() {
        let x = Mat::from_rows(&[vec![1.0], vec![1.0]]);
        let y = Mat::from_rows(&[vec![1.0], vec![1.0]]);
        let w0 = ridge(&x, &y, 1e-9).unwrap().at(0, 0);
        let w1 = ridge(&x, &y, 10.0).unwrap().at(0, 0);
        assert!(w0 > w1 && w1 > 0.0);
    }

    #[test]
    fn argmax_rows_works() {
        let m = Mat::from_rows(&[vec![0.1, 0.9], vec![2.0, -1.0]]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }
}
