//! Low-precision weight storage for the quantized inference path: bf16 and
//! per-output-channel symmetric int8, both with **f32 accumulation**.
//!
//! Storage-only quantization: a weight matrix is encoded once (at SUPC
//! load time, by `checkpoint::quant` — the bundle on disk is never
//! mutated), and every GEMM decodes the stored values back to f32 and runs
//! the full-precision kernels. The fused entry points ([`mm_nn_bf16`] /
//! [`mm_nn_i8`]) decode into a transposed f32 panel and reuse
//! `gemm::dot_block`, which makes them **bitwise-identical by
//! construction** to decoding the whole matrix first and calling
//! `gemm::mm_nn` — the property `tests/kernel_props.rs` pins. Activations
//! stay f32 throughout; only weights lose precision.
//!
//! Numerics:
//! * **bf16** — the top 16 bits of an f32, rounded to nearest-even.
//!   Relative error ≤ 2⁻⁸ per weight; any value whose mantissa already
//!   fits in 7 bits round-trips exactly.
//! * **int8 per-channel** — each output channel (last-axis column `j`)
//!   gets a symmetric scale `s_j = max|w[:,j]| / 127` and stores
//!   `round(w/s_j)` clamped to `[-127, 127]` (no zero point). An all-zero
//!   channel gets `s_j = 0` and decodes to exact zeros; a single-value
//!   channel decodes to its value up to one rounding of `127·(|v|/127)`.
//!
//! Both encodings are deterministic element-wise maps, so every decoded
//! matrix — and therefore every quantized inference result — is bitwise
//! run-to-run reproducible.

use crate::linalg::gemm::dot_block;

/// Round an f32 to bf16 (top 16 bits, round-to-nearest-even).
pub fn bf16_of_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Quiet the NaN and keep it a NaN after truncation.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

/// Widen a bf16 back to f32 (exact: low mantissa bits are zero).
pub fn f32_of_bf16(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// `f32 → bf16 → f32` round trip (the storage error of one weight).
pub fn bf16_roundtrip(x: f32) -> f32 {
    f32_of_bf16(bf16_of_f32(x))
}

/// A row-major `[rows, cols]` matrix stored as bf16.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bf16Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u16>,
}

impl Bf16Mat {
    /// Encode a row-major f32 matrix (round-to-nearest-even per element).
    pub fn encode(w: &[f32], rows: usize, cols: usize) -> Bf16Mat {
        debug_assert_eq!(w.len(), rows * cols);
        Bf16Mat { rows, cols, data: w.iter().map(|&x| bf16_of_f32(x)).collect() }
    }

    /// Decode back to a row-major f32 matrix.
    pub fn decode(&self) -> Vec<f32> {
        self.data.iter().map(|&h| f32_of_bf16(h)).collect()
    }

    /// Decode directly into the transposed `[cols, rows]` panel the
    /// dot-product kernels consume. Element-for-element the same values as
    /// `transpose(decode())`.
    pub fn decode_transposed(&self) -> Vec<f32> {
        let (k, m) = (self.rows, self.cols);
        let mut wt = vec![0f32; k * m];
        for i in 0..k {
            for j in 0..m {
                wt[j * k + i] = f32_of_bf16(self.data[i * m + j]);
            }
        }
        wt
    }
}

/// A row-major `[rows, cols]` matrix stored as int8 with one symmetric
/// scale per output channel (column).
#[derive(Debug, Clone, PartialEq)]
pub struct Int8Mat {
    pub rows: usize,
    pub cols: usize,
    /// Quantized values, row-major, in `[-127, 127]`.
    pub data: Vec<i8>,
    /// Per-column dequantization scale; `0.0` marks an all-zero channel.
    pub scales: Vec<f32>,
}

impl Int8Mat {
    /// Encode with per-column symmetric scales `max|w[:,j]| / 127`.
    pub fn encode(w: &[f32], rows: usize, cols: usize) -> Int8Mat {
        debug_assert_eq!(w.len(), rows * cols);
        let mut scales = vec![0f32; cols];
        for j in 0..cols {
            let mut mx = 0f32;
            for i in 0..rows {
                mx = mx.max(w[i * cols + j].abs());
            }
            scales[j] = mx / 127.0;
        }
        let mut data = vec![0i8; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                let s = scales[j];
                if s > 0.0 {
                    let q = (w[i * cols + j] / s).round().clamp(-127.0, 127.0);
                    data[i * cols + j] = q as i8;
                }
            }
        }
        Int8Mat { rows, cols, data, scales }
    }

    /// Decode back to a row-major f32 matrix (`q · s_j`, one rounding).
    pub fn decode(&self) -> Vec<f32> {
        let mut w = vec![0f32; self.rows * self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                w[i * self.cols + j] = self.data[i * self.cols + j] as f32 * self.scales[j];
            }
        }
        w
    }

    /// Decode into the transposed `[cols, rows]` panel; same values as
    /// `transpose(decode())`.
    pub fn decode_transposed(&self) -> Vec<f32> {
        let (k, m) = (self.rows, self.cols);
        let mut wt = vec![0f32; k * m];
        for i in 0..k {
            for j in 0..m {
                wt[j * k + i] = self.data[i * m + j] as f32 * self.scales[j];
            }
        }
        wt
    }
}

/// `out[n, w.cols] += a[n, w.rows] · decode(w)` — bf16-stored weights,
/// f32 accumulation (identical arithmetic to `gemm::mm_nn` on the decoded
/// matrix).
pub fn mm_nn_bf16(a: &[f32], w: &Bf16Mat, n: usize, out: &mut [f32]) {
    let (k, m) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    let wt = w.decode_transposed();
    dot_block(a, &wt, k, m, 0, n, out);
}

/// `out[n, w.cols] += a[n, w.rows] · decode(w)` — int8-stored weights with
/// per-channel scales, f32 accumulation.
pub fn mm_nn_i8(a: &[f32], w: &Int8Mat, n: usize, out: &mut [f32]) {
    let (k, m) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(out.len(), n * m);
    if n == 0 || k == 0 || m == 0 {
        return;
    }
    let wt = w.decode_transposed();
    dot_block(a, &wt, k, m, 0, n, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_representable_values_round_trip_exactly() {
        // 7-bit mantissas, powers of two, zero, and signs survive exactly.
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 96.0, -0.15625, 3.140625] {
            assert_eq!(bf16_roundtrip(v).to_bits(), v.to_bits(), "{v}");
        }
        assert_eq!(bf16_roundtrip(f32::INFINITY), f32::INFINITY);
        assert!(bf16_roundtrip(f32::NAN).is_nan());
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // Exactly halfway between two bf16 values: ties go to the even one.
        let down = f32::from_bits(0x3F80_8000); // between 0x3F80 and 0x3F81
        assert_eq!(bf16_of_f32(down), 0x3F80);
        let up = f32::from_bits(0x3F81_8000); // between 0x3F81 and 0x3F82
        assert_eq!(bf16_of_f32(up), 0x3F82);
        // Relative error of a non-representable value stays under 2^-8.
        let x = 1.0f32 / 3.0;
        assert!((bf16_roundtrip(x) - x).abs() / x <= 1.0 / 256.0);
    }

    #[test]
    fn int8_all_zero_channel_decodes_to_exact_zeros() {
        // Column 1 is all zeros: scale 0.0, decoded values exactly 0.0.
        let w = vec![1.0f32, 0.0, -2.0, 0.0, 0.5, 0.0];
        let q = Int8Mat::encode(&w, 3, 2);
        assert_eq!(q.scales[1], 0.0);
        let d = q.decode();
        for i in 0..3 {
            assert_eq!(d[i * 2 + 1].to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn int8_single_value_channel_is_near_exact() {
        // One distinct magnitude per channel quantizes to ±127 and decodes
        // back within one rounding of 127·(|v|/127).
        let w = vec![0.37f32, -4.25, 0.37, -4.25];
        let q = Int8Mat::encode(&w, 2, 2);
        assert_eq!(q.data, vec![127, -127, 127, -127]);
        let d = q.decode();
        for (got, want) in d.iter().zip(&w) {
            assert!((got - want).abs() <= 2.0 * f32::EPSILON * want.abs(), "{got} vs {want}");
        }
    }

    #[test]
    fn int8_values_clamp_to_symmetric_range() {
        let mut rng = Rng::new(41);
        let w: Vec<f32> = (0..7 * 5).map(|_| rng.normal()).collect();
        let q = Int8Mat::encode(&w, 7, 5);
        assert!(q.data.iter().all(|&v| (-127..=127).contains(&v)));
        // Per-channel max decodes to the channel scale times ±127.
        let d = q.decode();
        for j in 0..5 {
            let mx = (0..7).map(|i| d[i * 5 + j].abs()).fold(0f32, f32::max);
            assert!((mx - q.scales[j] * 127.0).abs() <= f32::EPSILON * 127.0 * q.scales[j]);
        }
    }

    #[test]
    fn fused_gemm_is_bitwise_decode_then_f32_gemm() {
        let mut rng = Rng::new(43);
        let (n, k, m) = (9, 13, 6);
        let a: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * m).map(|_| rng.normal()).collect();

        let qb = Bf16Mat::encode(&w, k, m);
        let mut fused = vec![0f32; n * m];
        mm_nn_bf16(&a, &qb, n, &mut fused);
        let mut two_step = vec![0f32; n * m];
        gemm::mm_nn(&a, &qb.decode(), n, k, m, &mut two_step);
        assert_eq!(fused, two_step, "bf16 fused GEMM must equal decode-then-GEMM bitwise");

        let qi = Int8Mat::encode(&w, k, m);
        let mut fused = vec![0f32; n * m];
        mm_nn_i8(&a, &qi, n, &mut fused);
        let mut two_step = vec![0f32; n * m];
        gemm::mm_nn(&a, &qi.decode(), n, k, m, &mut two_step);
        assert_eq!(fused, two_step, "int8 fused GEMM must equal decode-then-GEMM bitwise");
    }
}
