//! # sparse-upcycle
//!
//! Rust + JAX + Pallas reproduction of **"Sparse Upcycling: Training
//! Mixture-of-Experts from Dense Checkpoints"** (ICLR 2023).
//!
//! Three layers (see DESIGN.md):
//! * **L1** — Pallas kernels (`python/compile/kernels/`): grouped expert MLP
//!   and fused router, AOT-lowered into the model HLO.
//! * **L2** — JAX models (`python/compile/`): T5-style LM and ViT with
//!   Expert Choice / Top-K MoE layers, Adafactor train step; lowered once to
//!   `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the training coordinator. Loads the artifacts via
//!   PJRT (`runtime`), owns data (`data`), schedules (`coordinator`),
//!   checkpoints (`checkpoint`), and — the paper's contribution — the
//!   **upcycling checkpoint surgery** (`upcycle`). The experiment harness
//!   (`experiments`) regenerates every figure and table of the paper.
//!
//! Python never runs on the training path: after `make artifacts` the
//! binary is self-contained.

pub mod checkpoint;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod init;
pub mod linalg;
pub mod manifest;
pub mod metrics;
pub mod parallel;
pub mod runtime;
pub mod tensor;
pub mod upcycle;
pub mod util;

/// Default artifacts directory (relative to the repo root / CWD).
pub const ARTIFACTS_DIR: &str = "artifacts";
/// Default experiment-output directory.
pub const RESULTS_DIR: &str = "results";
