//! # sparse-upcycle
//!
//! Rust reproduction of **"Sparse Upcycling: Training Mixture-of-Experts
//! from Dense Checkpoints"** (ICLR 2023), built around a swappable
//! execution [`runtime::Backend`]:
//!
//! * **Native CPU backend** (`runtime::native`, the default): a pure-Rust
//!   implementation of the full MoE training path — token embedding →
//!   Expert Choice / Top-K routing → grouped expert MLP → loss + auxiliary
//!   load-balance loss — with hand-written backward passes and an Adam
//!   optimizer, over the built-in model zoo (`manifest::zoo`). A clean
//!   checkout runs `cargo test` / `cargo run -- quickstart` with **zero**
//!   Python, XLA or network artifacts.
//! * **PJRT backend** (`runtime::pjrt`, cargo feature `pjrt`, off by
//!   default): executes AOT-compiled HLO artifacts produced by the JAX +
//!   Pallas layer (`python/compile/`), for runs on real accelerators. The
//!   workspace vendors an API stub (`vendor/xla`); link the real bindings
//!   to enable it.
//!
//! Around the backend sit the training coordinator (`coordinator`), data
//! substrates (`data`), checkpoints (`checkpoint`), cost accounting
//! (`costmodel`), the parallelism simulator (`parallel`), the forward-only
//! **inference engine** (`serve`: continuous batching over
//! `Executable::infer`, fed by `upcycle train --save` checkpoint bundles)
//! and — the paper's contribution — the **upcycling checkpoint surgery**
//! (`upcycle`). The experiment harness (`experiments`) regenerates every
//! figure and table of the paper on either backend.

pub mod checkpoint;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod experiments;
pub mod init;
pub mod linalg;
pub mod manifest;
pub mod metrics;
pub mod parallel;
pub mod resilience;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod tensor;
pub mod upcycle;
pub mod util;

/// `surgery` is an alias for [`upcycle`]: the checkpoint-surgery strategy
/// zoo plus the [`surgery::diversity`](upcycle::diversity) metrics live
/// under either path (`docs/UPCYCLING.md`).
pub use crate::upcycle as surgery;

/// Default artifacts directory (relative to the repo root / CWD).
pub const ARTIFACTS_DIR: &str = "artifacts";
/// Default experiment-output directory.
pub const RESULTS_DIR: &str = "results";
