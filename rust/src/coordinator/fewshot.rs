//! Few-shot linear evaluation (paper §A.2.2): a ridge regressor from frozen
//! image representations to one-hot labels, 10 examples per class, averaged
//! over 5 random support seeds, with fixed L2 regularization.

use anyhow::Result;

use crate::data::vision::{VisionPipeline, VisionSpec, NUM_CLASSES};
use crate::linalg::{argmax_rows, ridge, Mat};
use crate::runtime::LoadedModel;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct FewShotConfig {
    pub shots: usize,
    pub seeds: usize,
    pub test_examples: usize,
    /// Paper fixes λ = 1024 (on their feature scale); default matches.
    pub l2: f64,
}

impl Default for FewShotConfig {
    fn default() -> Self {
        FewShotConfig { shots: 10, seeds: 5, test_examples: 256, l2: 1024.0 }
    }
}

/// Extract features for a [N,H,W,C] image tensor by slicing into the
/// model's fixed batch size (padding the tail batch by repetition).
fn batched_features(model: &LoadedModel, params: &[Tensor], images: &Tensor) -> Result<Mat> {
    let b = model.entry.config.batch_size;
    let (n, h, w, c) = (
        images.shape[0],
        images.shape[1],
        images.shape[2],
        images.shape[3],
    );
    let px = h * w * c;
    let data = images.f32s()?;
    let mut rows: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        let take = (n - i).min(b);
        let mut chunk = vec![0f32; b * px];
        for j in 0..b {
            let src = (i + j.min(take - 1)) * px; // repeat last row as padding
            chunk[j * px..(j + 1) * px].copy_from_slice(&data[src..src + px]);
        }
        let feats = model.features(params, &Tensor::from_f32(&[b, h, w, c], chunk))?;
        let d = feats.shape[1];
        let fv = feats.f32s()?;
        for j in 0..take {
            rows.push(fv[j * d..(j + 1) * d].iter().map(|&x| x as f64).collect());
        }
        i += take;
    }
    Ok(Mat::from_rows(&rows))
}

fn one_hot_mat(labels: &[usize], classes: usize) -> Mat {
    let mut m = Mat::zeros(labels.len(), classes);
    for (i, &l) in labels.iter().enumerate() {
        *m.at_mut(i, l) = 1.0;
    }
    m
}

/// 10-shot accuracy of frozen representations (mean over support seeds).
pub fn fewshot_accuracy(
    model: &LoadedModel,
    params: &[Tensor],
    cfg: &FewShotConfig,
    base_seed: u64,
) -> Result<f64> {
    let image_size = model.entry.config.image_size;
    // Held-out test set: one fixed shard shared by every seed.
    let mut test_pipe = VisionPipeline::new(
        VisionSpec { image_size, ..Default::default() },
        cfg.test_examples,
        0xeeee,
        7,
    );
    let (test_tensors, test_labels) = test_pipe.next_batch();
    let x_test = batched_features(model, params, &test_tensors[0])?;

    let mut accs = Vec::with_capacity(cfg.seeds);
    for s in 0..cfg.seeds {
        let mut pipe = VisionPipeline::new(
            VisionSpec { image_size, ..Default::default() },
            1,
            base_seed + s as u64,
            11 + s as u64,
        );
        let (sup_tensors, sup_labels) = pipe.class_balanced(cfg.shots);
        let x = batched_features(model, params, &sup_tensors[0])?;
        let y = one_hot_mat(&sup_labels, NUM_CLASSES);
        let w = ridge(&x, &y, cfg.l2)?;
        let preds = argmax_rows(&x_test.mul(&w));
        let correct = preds
            .iter()
            .zip(&test_labels)
            .filter(|(p, l)| **p == **l)
            .count();
        accs.push(correct as f64 / test_labels.len() as f64);
    }
    Ok(accs.iter().sum::<f64>() / accs.len() as f64)
}
