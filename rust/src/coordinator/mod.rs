//! Layer-3 coordinator: schedules, the training orchestrator, and the
//! few-shot linear probe. The experiment harness (`crate::experiments`)
//! composes these into the paper's figures and tables.

pub mod fewshot;
pub mod schedule;
pub mod trainer;

pub use schedule::{Schedule, ScheduleKind};
pub use trainer::{
    dp_train_step, mesh_train_step, mesh_train_step_faulted, shard_batch, train, train_dp,
    train_mesh, train_mesh_elastic, BatchSource, DpConfig, Evaluator, MeshConfig, TrainConfig,
    TrainState,
};
