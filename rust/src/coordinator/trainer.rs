//! Training orchestrator: owns the step loop, the LR schedule, periodic
//! evaluation, checkpointing, and data-parallel replicated training. This
//! is where "dense continuation", "upcycled" and "MoE from scratch"
//! branches become concrete runs.
//!
//! **Data parallelism.** [`dp_train_step`] splits the global batch into
//! [`DpConfig::replicas`] contiguous shards, computes per-shard gradients
//! on [`DpConfig::workers`] scoped worker threads, all-reduces them through
//! `parallel::collectives::reduce_sum_ordered`, and applies **one** Adam
//! update (`runtime::adam_update`) to the replicated state. Replica
//! workers run with `util::serial_compute` in effect, so the backend's
//! kernel- and expert-level threading stands down inside them — DP
//! parallelizes *across* replicas instead of *within* kernels, and the two
//! levels never contend for the same cores.
//!
//! **Gradient-reduction ordering invariant.** Shard gradients are always
//! combined in ascending shard order — `((g₀ + g₁) + g₂) + …` — which is
//! exactly the floating-point reduction a single worker performs when it
//! accumulates the same microbatches sequentially. Combined with the
//! thread-count-independent kernels (`linalg::gemm`) and `util::par_map`'s
//! slot determinism, this makes the trained state a pure function of the
//! *shard decomposition*, never of the worker count:
//! `DpConfig { replicas: N, workers: N }` (N replicas) is bitwise-identical
//! to `DpConfig { replicas: N, workers: 1 }` (single-replica gradient
//! accumulation over the same effective batch) — asserted by this module's
//! tests. Note that the shard count *does* change the arithmetic (each
//! shard routes its own tokens and normalizes its own loss, as on a real
//! data-parallel mesh), so `replicas: N` vs `replicas: 1` are equal in
//! expectation, not bitwise.
//!
//! Replica counts are validated against the model's batch geometry and the
//! host's parallelism when a [`DpConfig`] is constructed
//! (`parallel::MeshSpec::validate` in data-parallel mode) —
//! misconfiguration fails at setup time with an actionable message, not
//! deep inside the step loop.
//!
//! **Expert parallelism (DP×EP mesh).** [`mesh_train_step`] shards the
//! global batch into `dp·ep` token shards and runs one rank thread per
//! shard. Ranks in the same DP group form an expert-parallel group: each
//! owns only its round-robin shard of every MoE block's expert weights
//! (`runtime::ep::EpRankExchange`), computes router + dispatch on its own
//! tokens, and exchanges token buffers with its peers through real
//! all-to-all collectives (`parallel::collectives::EpGroup`) at every MoE
//! block, forward and backward. Gradients reduce hierarchically — within
//! each EP group in ascending source order, then across DP groups in group
//! order — and one Adam update applies to the replicated state.
//!
//! The mesh determinism guarantee extends the DP one: a
//! [`MeshConfig`] with `parallel: true` (one thread per rank, sharded
//! expert weights, live collectives) is **bitwise-identical** to
//! `parallel: false` (the same shard decomposition stepped serially by one
//! worker holding the full expert set), asserted by this module's tests.
//! The two paths share no expert-execution code — the serial baseline goes
//! through `LoadedModel::grads` — so the test pins the entire distributed
//! machinery (dispatch packing, all-to-all, shard GEMMs, combine, ordered
//! accumulation) to the plain local arithmetic. With one DP group the
//! hierarchy collapses and `1xE` is additionally bitwise-identical to
//! [`DpConfig`] gradient accumulation over `E` shards.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::costmodel::Cost;
use crate::manifest::ModelEntry;
use crate::metrics::Series;
use crate::parallel::collectives::{reduce_sum_ordered, EpGroup, EP_ABORTED_MSG};
use crate::resilience::{self, ElasticConfig, ElasticReport, FaultPlan, RecoveryEvent};
use crate::runtime::ep::{EpPayload, EpRankExchange};
use crate::runtime::{
    adam_update, checkpoint_from_tensors, tensors_from_checkpoint, LoadedModel, Metrics,
    StepOutput,
};
use crate::tensor::{Data, Tensor};
use crate::util::bench::phase;
use crate::util::par_map_workers;

use super::schedule::Schedule;

/// Anything that yields training batches in manifest batch order.
pub trait BatchSource {
    fn next(&mut self) -> Vec<Tensor>;
}

impl BatchSource for crate::data::text::TextPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch()
    }
}

impl BatchSource for crate::data::text::ClassificationPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch().0
    }
}

impl BatchSource for crate::data::vision::VisionPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch().0
    }
}

/// Live training state: host tensors in manifest order + the global step
/// counter (which also drives the optimizer's bias correction and the LR
/// schedule).
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub opt_state: Vec<Tensor>,
    pub step: u64,
}

impl TrainState {
    pub fn from_checkpoints(
        entry: &ModelEntry,
        params: &Checkpoint,
        opt: &Checkpoint,
    ) -> Result<TrainState> {
        Ok(TrainState {
            params: tensors_from_checkpoint(params, &entry.params)
                .context("binding params to manifest signature")?,
            opt_state: tensors_from_checkpoint(opt, &entry.opt_state)
                .context("binding optimizer state to manifest signature")?,
            step: params.step,
        })
    }

    /// Persist this state as one trained-checkpoint bundle — params +
    /// optimizer state + step in a single SUPC file
    /// (`checkpoint::save_train_state`). This is what `upcycle train
    /// --save` writes and what `upcycle serve` / `upcycle infer --load`
    /// consume.
    pub fn save(
        &self,
        entry: &ModelEntry,
        path: impl AsRef<std::path::Path>,
        provenance: &str,
    ) -> Result<()> {
        crate::checkpoint::save_train_state(
            path,
            entry,
            &self.params,
            &self.opt_state,
            self.step,
            provenance,
        )
    }

    /// Restore a bundle written by [`TrainState::save`]. Resuming from the
    /// result is bitwise-identical to never having stopped (asserted by
    /// this module's tests): the checkpoint holds the full f32 state and
    /// the step counter that drives bias correction and the LR schedule.
    pub fn load(entry: &ModelEntry, path: impl AsRef<std::path::Path>) -> Result<TrainState> {
        let (params, opt_state, step) = crate::checkpoint::load_train_state(path, entry)?;
        Ok(TrainState { params, opt_state, step })
    }

    pub fn to_checkpoints(
        &self,
        entry: &ModelEntry,
        provenance: &str,
    ) -> Result<(Checkpoint, Checkpoint)> {
        let p = checkpoint_from_tensors(
            &entry.name,
            self.step,
            provenance,
            &entry.params,
            &self.params,
        )?;
        let o = checkpoint_from_tensors(
            &entry.name,
            self.step,
            provenance,
            &entry.opt_state,
            &self.opt_state,
        )?;
        Ok((p, o))
    }
}

/// Fixed held-out evaluation set (deterministic shard, reused across all
/// branches of an experiment so curves are comparable).
pub struct Evaluator {
    batches: Vec<Vec<Tensor>>,
}

impl Evaluator {
    pub fn from_source(src: &mut dyn BatchSource, n_batches: usize) -> Evaluator {
        Evaluator { batches: (0..n_batches).map(|_| src.next()).collect() }
    }

    pub fn eval(&self, model: &LoadedModel, state: &TrainState) -> Result<Metrics> {
        let mut acc: BTreeMap<String, f64> = BTreeMap::new();
        for b in &self.batches {
            for (k, v) in model.eval_step(&state.params, b)? {
                *acc.entry(k).or_insert(0.0) += v;
            }
        }
        let n = self.batches.len().max(1) as f64;
        Ok(acc.into_iter().map(|(k, v)| (k, v / n)).collect())
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: u64,
    pub schedule: Schedule,
    pub weight_decay: f64,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: u64,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: u64,
}

// ---------------------------------------------------------------------------
// Data-parallel replicated training
// ---------------------------------------------------------------------------

/// Data-parallel execution shape for one training run.
///
/// `replicas` fixes the shard decomposition of every global batch (and with
/// it the arithmetic — see the module docs); `workers` only chooses how
/// many scoped threads step those shards concurrently.
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// Number of batch shards (data-parallel replicas).
    pub replicas: usize,
    /// Worker threads stepping the shards: `== replicas` for replicated
    /// execution, `1` for single-replica gradient accumulation.
    pub workers: usize,
}

impl DpConfig {
    /// N worker replicas, one shard each. Validates `replicas` against the
    /// model's batch geometry *and* the host's available parallelism.
    pub fn replicated(entry: &ModelEntry, replicas: usize) -> Result<DpConfig> {
        crate::parallel::MeshSpec::data_parallel_only(replicas)
            .validate(entry, crate::parallel::MeshMode::DataParallel { max_workers: None })?;
        Ok(DpConfig { replicas, workers: replicas })
    }

    /// Single worker accumulating over `microbatches` shards — the same
    /// arithmetic as [`DpConfig::replicated`] with `replicas ==
    /// microbatches`, without needing that many hardware threads.
    pub fn accumulated(entry: &ModelEntry, microbatches: usize) -> Result<DpConfig> {
        crate::parallel::MeshSpec::data_parallel_only(microbatches).validate(
            entry,
            crate::parallel::MeshMode::DataParallel { max_workers: Some(usize::MAX) },
        )?;
        Ok(DpConfig { replicas: microbatches, workers: 1 })
    }
}

/// Rows `r0..r1` of a batch tensor (leading dim = example index).
fn slice_rows(t: &Tensor, r0: usize, r1: usize) -> Result<Tensor> {
    let b = *t.shape.first().context("batch tensor has no leading dim")?;
    if r1 > b || r0 >= r1 {
        bail!("row slice {r0}..{r1} out of range for leading dim {b}");
    }
    let row = t.numel() / b;
    let mut shape = t.shape.clone();
    shape[0] = r1 - r0;
    Ok(match &t.data {
        Data::F32(v) => Tensor::from_f32(&shape, v[r0 * row..r1 * row].to_vec()),
        Data::I32(v) => Tensor::from_i32(&shape, v[r0 * row..r1 * row].to_vec()),
    })
}

/// Split a global batch into `shards` contiguous equal shards along the
/// leading (example) dimension of every batch tensor.
pub fn shard_batch(batch: &[Tensor], shards: usize) -> Result<Vec<Vec<Tensor>>> {
    if shards == 0 {
        bail!("cannot shard a batch into 0 shards");
    }
    if shards == 1 {
        return Ok(vec![batch.to_vec()]);
    }
    let b = batch.first().and_then(|t| t.shape.first().copied()).unwrap_or(0);
    if b == 0 {
        bail!("cannot shard an empty batch");
    }
    for t in batch {
        if t.shape.first() != Some(&b) {
            bail!("batch tensors disagree on the leading dim: {:?} vs {b}", t.shape);
        }
    }
    if b % shards != 0 {
        bail!("batch dim {b} does not split into {shards} equal shards");
    }
    let per = b / shards;
    (0..shards)
        .map(|s| batch.iter().map(|t| slice_rows(t, s * per, (s + 1) * per)).collect())
        .collect()
}

/// One data-parallel training step: shard the batch, compute per-shard
/// gradients on worker threads, all-reduce in shard order, apply a single
/// Adam update. Metrics are the mean over shards. See the module docs for
/// the determinism guarantee.
#[allow(clippy::too_many_arguments)]
pub fn dp_train_step(
    model: &LoadedModel,
    mut params: Vec<Tensor>,
    mut opt_state: Vec<Tensor>,
    batch: &[Tensor],
    lr: f64,
    wd: f64,
    step: u64,
    dp: &DpConfig,
) -> Result<StepOutput> {
    let shards = shard_batch(batch, dp.replicas)?;
    let r = shards.len();
    // Replica fan-out: each worker computes gradients of its shard's mean
    // loss against the same replicated params. Workers run their kernels in
    // serial-compute mode so replica- and kernel-level parallelism never
    // stack up and oversubscribe the host (bitwise-identical either way).
    let results: Vec<Result<(Metrics, Vec<Tensor>)>> = par_map_workers(dp.workers.max(1), r, |i| {
        crate::util::serial_compute(|| model.grads(&params, &shards[i]))
    });
    let mut metric_sums: Metrics = Metrics::new();
    let mut shard_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(r);
    for (i, res) in results.into_iter().enumerate() {
        let (m, g) = res.with_context(|| format!("replica {i} gradient computation"))?;
        for (k, v) in m {
            *metric_sums.entry(k).or_insert(0.0) += v;
        }
        shard_grads.push(g.into_iter().map(Tensor::into_f32s).collect::<Result<Vec<_>>>()?);
    }
    // Rank-ordered all-reduce per parameter, then scale to the mean.
    let inv = 1.0 / r as f32;
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    for p in 0..params.len() {
        let parts: Vec<Vec<f32>> =
            shard_grads.iter_mut().map(|s| std::mem::take(&mut s[p])).collect();
        let mut g = reduce_sum_ordered(parts)?;
        for v in g.iter_mut() {
            *v *= inv;
        }
        grads.push(g);
    }
    // Single optimizer update on the replicated state.
    {
        let _ph = phase("optimizer");
        adam_update(&mut params, &mut opt_state, &grads, lr, wd, step)?;
    }
    let metrics = metric_sums.into_iter().map(|(k, v)| (k, v / r as f64)).collect();
    Ok(StepOutput { params, opt_state, metrics })
}

// ---------------------------------------------------------------------------
// Expert-parallel (DP×EP mesh) training
// ---------------------------------------------------------------------------

/// Execution shape of one DP×EP mesh run: `dp` data-parallel groups of
/// `ep` expert-parallel ranks, `dp·ep` token shards. See the module docs
/// for the arithmetic and the determinism contract.
#[derive(Debug, Clone, Copy)]
pub struct MeshConfig {
    /// Data-parallel groups.
    pub dp: usize,
    /// Expert-parallel ranks per group (experts round-robin sharded).
    pub ep: usize,
    /// `true`: one worker thread per rank, expert weights sharded, live
    /// all-to-all collectives. `false`: the same shard decomposition
    /// stepped serially by this thread with the full expert set local —
    /// the 1-worker reference arithmetic. Bitwise-identical by contract.
    pub parallel: bool,
    /// Microbatch chunks per MoE block traversal on each rank's exchange
    /// pipeline (>= 1; 1 = the fused schedule). Higher values overlap more
    /// all-to-all behind expert compute; the step arithmetic is
    /// bitwise-identical for every value by the exchange contract.
    pub microbatches: usize,
}

impl MeshConfig {
    /// Parse a `DxE` mesh spec ("2x2" → dp 2, ep 2). Deprecated alias
    /// syntax of the `--mesh` flag; `--topology dp=D,ep=E` and
    /// [`parallel::MeshSpec::parse`] are the front door.
    pub fn parse(spec: &str) -> Result<(usize, usize)> {
        let (d, e) = spec
            .split_once('x')
            .with_context(|| format!("mesh `{spec}` must be DxE (e.g. 2x2)"))?;
        let dp: usize =
            d.trim().parse().with_context(|| format!("bad data-parallel axis in `{spec}`"))?;
        let ep: usize =
            e.trim().parse().with_context(|| format!("bad expert-parallel axis in `{spec}`"))?;
        Ok((dp, ep))
    }

    /// Validated mesh from one parsed topology plan — the single
    /// [`parallel::MeshSpec`] front door shared by `train`,
    /// [`train_mesh_elastic`] and `serve::mesh_infer`. `parallel` picks
    /// threaded ranks vs. the serial 1-worker reference.
    pub fn from_topology(
        entry: &ModelEntry,
        topo: &crate::parallel::MeshSpec,
        parallel: bool,
    ) -> Result<MeshConfig> {
        topo.validate(entry, crate::parallel::MeshMode::Exec)?;
        Ok(MeshConfig {
            dp: topo.data_parallel,
            ep: topo.expert_parallel,
            parallel,
            microbatches: 1,
        })
    }

    /// Validated mesh with one worker thread per rank.
    pub fn replicated(entry: &ModelEntry, dp: usize, ep: usize) -> Result<MeshConfig> {
        MeshConfig::from_topology(entry, &crate::parallel::MeshSpec::new(dp, ep), true)
    }

    /// The same mesh arithmetic executed serially by the calling thread
    /// (the 1-worker baseline of the bitwise-identity contract).
    pub fn accumulated(entry: &ModelEntry, dp: usize, ep: usize) -> Result<MeshConfig> {
        MeshConfig::from_topology(entry, &crate::parallel::MeshSpec::new(dp, ep), false)
    }

    /// Set the exchange pipeline depth (clamped to >= 1).
    pub fn with_microbatches(mut self, m: usize) -> MeshConfig {
        self.microbatches = m.max(1);
        self
    }

    /// Total ranks (= token shards) on the mesh.
    pub fn ranks(&self) -> usize {
        self.dp.max(1) * self.ep.max(1)
    }
}

/// Text of a caught panic payload (rank threads die with `String`/`&str`
/// payloads — injected faults always do).
fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "mesh rank panicked".to_string()
    }
}

/// Per-rank shard gradients of the parallel mesh path: one thread per rank,
/// expert weights sharded over each DP group's EP ranks, token buffers
/// exchanged through the group's collectives. Results arrive in rank order
/// `(dp_group · ep + ep_rank)`.
///
/// `fault` is the elastic trainer's injection seam: when the plan names a
/// rank of this step, that rank's thread arms the thread-local trigger
/// right after spawn, and dies (by panic) at the planned phase entry — the
/// surviving ranks detect it through the aborted collectives exactly as
/// they would a real crash.
fn mesh_rank_grads(
    model: &LoadedModel,
    params: &[Tensor],
    shards: &[Vec<Tensor>],
    mesh: &MeshConfig,
    fault: Option<FaultPlan>,
) -> Result<Vec<(Metrics, Vec<Vec<f32>>)>> {
    let dp = mesh.dp.max(1);
    let ep = mesh.ep.max(1);
    // One rendezvous group per DP row of the mesh.
    let groups: Vec<Arc<EpGroup<EpPayload>>> =
        (0..dp).map(|_| Arc::new(EpGroup::new(ep))).collect();
    let results: Vec<Result<(Metrics, Vec<Vec<f32>>)>> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(dp * ep);
        for r in 0..dp * ep {
            let group = groups[r / ep].clone();
            let shard = &shards[r];
            handles.push(s.spawn(move || {
                let rank = r % ep;
                // The fault-injection seam in the rank spawn path: arm the
                // doomed rank before it takes its first step. The guard
                // disarms on unwind, so nothing leaks past this thread.
                let _fault_guard = match fault {
                    Some(f) if f.rank == r && !f.phase.on_coordinator() => {
                        Some(resilience::arm_fault(f.phase))
                    }
                    _ => None,
                };
                let body = || -> Result<(Metrics, Vec<Vec<f32>>)> {
                    // Rank threads force nested kernel/expert threading
                    // serial, exactly like DP replica workers.
                    crate::util::serial_compute(|| {
                        let mut exch =
                            EpRankExchange::new(&model.entry, params, rank, group.clone())?
                                .with_microbatches(mesh.microbatches);
                        let (m, g) = model.grads_ep(params, shard, &mut exch)?;
                        let g = g.into_iter().map(Tensor::into_f32s).collect::<Result<Vec<_>>>()?;
                        Ok((m, g))
                    })
                };
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
                match out {
                    Ok(res) => {
                        // A failed rank must release peers blocked on the
                        // group's collectives before reporting.
                        if let Err(e) = &res {
                            group.abort_with(&format!("{e:#}"));
                        }
                        res
                    }
                    Err(p) => {
                        // A dead rank: release the peers *with* the root
                        // cause, then report it as this rank's error.
                        let msg = panic_text(p);
                        group.abort_with(&msg);
                        Err(anyhow!("{msg}"))
                    }
                }
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("mesh rank thread died"))))
            .collect()
    });
    // Report the root cause: a failed rank aborts its group, so peers also
    // error with a secondary "collective aborted" message — prefer the
    // first error that is NOT one of those echoes.
    let mut out = Vec::with_capacity(results.len());
    let mut root_cause: Option<anyhow::Error> = None;
    let mut first_abort: Option<anyhow::Error> = None;
    for (r, res) in results.into_iter().enumerate() {
        match res {
            Ok(v) => out.push(v),
            Err(e) => {
                let e =
                    e.context(format!("mesh rank {r} (dp group {}, ep rank {})", r / ep, r % ep));
                if format!("{e:#}").contains(EP_ABORTED_MSG) {
                    if first_abort.is_none() {
                        first_abort = Some(e);
                    }
                } else if root_cause.is_none() {
                    root_cause = Some(e);
                }
            }
        }
    }
    if let Some(e) = root_cause.or(first_abort) {
        return Err(e);
    }
    Ok(out)
}

/// One DP×EP mesh training step: shard the batch over all ranks, compute
/// per-shard gradients (expert-parallel threads or the serial 1-worker
/// reference, per [`MeshConfig::parallel`]), reduce hierarchically in rank
/// order, apply a single Adam update. Metrics are the mean over ranks.
#[allow(clippy::too_many_arguments)]
pub fn mesh_train_step(
    model: &LoadedModel,
    params: Vec<Tensor>,
    opt_state: Vec<Tensor>,
    batch: &[Tensor],
    lr: f64,
    wd: f64,
    step: u64,
    mesh: &MeshConfig,
) -> Result<StepOutput> {
    mesh_train_step_faulted(model, params, opt_state, batch, lr, wd, step, mesh, None)
}

/// [`mesh_train_step`] with an optional injected fault — the elastic
/// trainer's step executor. Rank-phase faults arm the named rank's thread
/// (or the shard's serial execution under `parallel: false`);
/// coordinator-phase faults (`optimizer`) arm this thread around the Adam
/// update. The injected death propagates exactly like a real one: as an
/// error for rank faults, as a panic for coordinator faults (the elastic
/// loop catches both).
#[allow(clippy::too_many_arguments)]
pub fn mesh_train_step_faulted(
    model: &LoadedModel,
    mut params: Vec<Tensor>,
    mut opt_state: Vec<Tensor>,
    batch: &[Tensor],
    lr: f64,
    wd: f64,
    step: u64,
    mesh: &MeshConfig,
    fault: Option<FaultPlan>,
) -> Result<StepOutput> {
    let ranks = mesh.ranks();
    let shards = shard_batch(batch, ranks)?;
    let results: Vec<(Metrics, Vec<Vec<f32>>)> = if mesh.parallel && ranks > 1 {
        mesh_rank_grads(model, &params, &shards, mesh, fault)?
    } else {
        // 1-worker reference: every token shard steps with the full expert
        // set local; only the reduction below is mesh-shaped. Rank faults
        // arm around the doomed shard's serial execution, so even the
        // reference path is chaos-testable.
        let mut out = Vec::with_capacity(ranks);
        for (r, shard) in shards.iter().enumerate() {
            let _fault_guard = match fault {
                Some(f) if f.rank == r && !f.phase.on_coordinator() => {
                    Some(resilience::arm_fault(f.phase))
                }
                _ => None,
            };
            let (m, g) = model
                .grads(&params, shard)
                .with_context(|| format!("mesh rank {r} (serial) gradient computation"))?;
            out.push((m, g.into_iter().map(Tensor::into_f32s).collect::<Result<Vec<_>>>()?));
        }
        out
    };
    let mut metric_sums: Metrics = Metrics::new();
    let mut rank_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(ranks);
    for (m, g) in results {
        for (k, v) in m {
            *metric_sums.entry(k).or_insert(0.0) += v;
        }
        rank_grads.push(g);
    }
    // Hierarchical rank-ordered reduction: sources within each EP group
    // first, then across DP groups — both ascending, so the parallel and
    // serial paths perform the identical float additions.
    let inv = 1.0 / ranks as f32;
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    for p in 0..params.len() {
        let mut group_sums: Vec<Vec<f32>> = Vec::with_capacity(mesh.dp.max(1));
        for dp_group in rank_grads.chunks_mut(mesh.ep.max(1)) {
            let parts: Vec<Vec<f32>> =
                dp_group.iter_mut().map(|rg| std::mem::take(&mut rg[p])).collect();
            group_sums.push(reduce_sum_ordered(parts)?);
        }
        let mut g = reduce_sum_ordered(group_sums)?;
        for v in g.iter_mut() {
            *v *= inv;
        }
        grads.push(g);
    }
    {
        // The optimizer is its own fault phase. The injected kill lands at
        // phase *entry* (before the in-place Adam update mutates anything);
        // a real crash could additionally tear the update halfway, but the
        // recovery path cannot tell the difference by construction — the
        // failed attempt's tensors are dropped wholesale and state reloads
        // from the snapshot, so their content (torn or pristine) is never
        // read again.
        let _fault_guard = match fault {
            Some(f) if f.phase.on_coordinator() => Some(resilience::arm_fault(f.phase)),
            _ => None,
        };
        let _ph = phase("optimizer");
        adam_update(&mut params, &mut opt_state, &grads, lr, wd, step)?;
    }
    let metrics = metric_sums.into_iter().map(|(k, v)| (k, v / ranks as f64)).collect();
    Ok(StepOutput { params, opt_state, metrics })
}

// ---------------------------------------------------------------------------
// Step loops
// ---------------------------------------------------------------------------

/// Shared step loop behind [`train`] and [`train_dp`]: schedules, evals,
/// logging, series bookkeeping; `step_fn` performs one optimizer step.
///
/// NOTE: [`train_mesh_elastic`] reimplements this bookkeeping (initial /
/// cadence / final eval pushes, log lines) because its rollback-and-replay
/// control flow cannot be expressed through `step_fn`. Changes to the
/// series semantics here must be mirrored there, or elastic series stop
/// being comparable to plain ones.
fn run_loop<F>(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    series_name: &str,
    mut step_fn: F,
) -> Result<Series>
where
    F: FnMut(Vec<Tensor>, Vec<Tensor>, &[Tensor], f64, u64) -> Result<StepOutput>,
{
    let mut series = Series::new(series_name);
    let start_step = state.step;
    let flops_per_step = model.entry.flops.train_step;

    // Point at the branch start (extra cost 0) — the paper's horizontal
    // reference lines come from these.
    let m0 = evaluator.eval(model, state)?;
    series.push(state.step, 0.0, m0.into_iter().collect());

    let mut last_train_loss = f64::NAN;
    for i in 1..=cfg.steps {
        let step = start_step + i;
        let lr = cfg.schedule.lr(step);
        let batch = data.next();
        let params = std::mem::take(&mut state.params);
        let opt = std::mem::take(&mut state.opt_state);
        let out = step_fn(params, opt, &batch, lr, step)
            .with_context(|| format!("train step {step}"))?;
        state.params = out.params;
        state.opt_state = out.opt_state;
        state.step = step;
        last_train_loss = *out.metrics.get("loss").unwrap_or(&f64::NAN);

        if cfg.log_every > 0 && i % cfg.log_every == 0 {
            println!(
                "    [{series_name}] step {step} lr={lr:.5} train_loss={last_train_loss:.4}"
            );
        }
        if cfg.eval_every > 0 && i % cfg.eval_every == 0 && i != cfg.steps {
            let mut m = evaluator.eval(model, state)?;
            m.insert("train_loss".into(), last_train_loss);
            series.push(step, flops_per_step * i as f64, m.into_iter().collect());
        }
    }
    let mut m = evaluator.eval(model, state)?;
    m.insert("train_loss".into(), last_train_loss);
    series.push(state.step, flops_per_step * cfg.steps as f64, m.into_iter().collect());
    Ok(series)
}

/// Run `cfg.steps` steps; returns the eval curve (extra-cost x-axis measured
/// from the state's starting step, in this model's per-step FLOPs).
pub fn train(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    series_name: &str,
) -> Result<Series> {
    run_loop(model, state, data, evaluator, cfg, series_name, |p, o, b, lr, step| {
        model.train_step(p, o, b, lr, cfg.weight_decay, step)
    })
}

/// [`train`], stepping each batch data-parallel under `dp` (see
/// [`dp_train_step`]).
pub fn train_dp(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    dp: &DpConfig,
    series_name: &str,
) -> Result<Series> {
    run_loop(model, state, data, evaluator, cfg, series_name, |p, o, b, lr, step| {
        dp_train_step(model, p, o, b, lr, cfg.weight_decay, step, dp)
    })
}

/// [`train`], stepping each batch on a DP×EP mesh under `mesh` (see
/// [`mesh_train_step`]). Evaluation runs on the replicated parameters —
/// on a real mesh every rank holds the dense weights and the gathered
/// expert weights are only resident shard-wise during the step.
pub fn train_mesh(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    mesh: &MeshConfig,
    series_name: &str,
) -> Result<Series> {
    run_loop(model, state, data, evaluator, cfg, series_name, |p, o, b, lr, step| {
        mesh_train_step(model, p, o, b, lr, cfg.weight_decay, step, mesh)
    })
}

/// [`train_mesh`] with elasticity: periodic SUPC snapshots (atomic rotation
/// with retention, `checkpoint::save_snapshot`), detection of mid-step rank
/// failures (real or injected via [`ElasticConfig::faults`]), and automatic
/// step-boundary rollback + replay from the last snapshot.
///
/// **The bitwise-recovery contract.** The final state — and the final
/// snapshot bundle this function always writes — is bitwise-identical to
/// the uninterrupted run at the same step, for *any* fault schedule:
///
/// * a failed step never publishes state (its in-flight tensors are
///   discarded whole; nothing torn survives into the retry);
/// * rollback restores the last snapshot bitwise
///   (`checkpoint::load_train_state`'s round-trip guarantee);
/// * the rolled-back steps replay with the *exact* original batches — the
///   driver keeps every batch since the last snapshot in memory (bounded
///   by `snapshot_every`) instead of assuming the data source can rewind;
/// * the step executor is a pure function of `(params, opt_state, batch,
///   lr, step)` and the LR schedule / Adam bias correction key off the
///   absolute step, which the snapshot carries.
///
/// Asserted per fault point across the steps × phases grid by
/// `tests/chaos.rs`. Evaluation points ride on the same cadence as
/// [`train_mesh`] (and are never duplicated by a replay), so the returned
/// [`Series`] is comparable; the [`ElasticReport`] records every snapshot
/// and recovery.
///
/// Error contract: when recovery is abandoned (max recoveries, lost
/// rollback target), `state` is first rolled back to the newest loadable
/// snapshot, so the caller never sees the failed attempt's consumed
/// tensors. Only if no snapshot loads at all is `state` left unspecified
/// (the error chain says so).
#[allow(clippy::too_many_arguments)]
pub fn train_mesh_elastic(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    mesh: &MeshConfig,
    ecfg: &ElasticConfig,
    series_name: &str,
) -> Result<(Series, ElasticReport)> {
    ecfg.validate()?;
    let entry = &model.entry;
    let mut faults = ecfg.faults.clone();
    let mut report = ElasticReport::default();
    let mut series = Series::new(series_name);
    let start_step = state.step;
    let flops_per_step = entry.flops.train_step;

    let m0 = evaluator.eval(model, state)?;
    series.push(state.step, 0.0, m0.into_iter().collect());

    // This run owns the rotation directory: snapshots left by a previous
    // run are a different lineage — the retention prune would evict this
    // run's rollback targets in favor of stale files, and a rollback could
    // silently load another run's weights. Clear them before snapshot 0.
    for (_, stale) in crate::checkpoint::list_snapshots(&ecfg.dir)? {
        std::fs::remove_file(&stale)
            .with_context(|| format!("clearing stale snapshot {stale:?}"))?;
    }
    // Snapshot the branch point before stepping: rollback is possible from
    // the very first step.
    crate::checkpoint::save_snapshot(
        &ecfg.dir,
        entry,
        &state.params,
        &state.opt_state,
        state.step,
        ecfg.snapshot_keep,
    )?;
    report.snapshots_written += 1;
    let mut snap_step = state.step;
    // Batches for steps `snap_step + 1 ..= pulled`, in order — the replay
    // buffer. Bounded: drained at every snapshot.
    let mut batch_cache: Vec<Vec<Tensor>> = Vec::new();

    let mut last_train_loss = f64::NAN;
    // High-water mark of eval points already pushed: replayed steps after a
    // rollback must not re-push (or re-run) evaluations the series already
    // has, or the faulted run's series would diverge from the
    // uninterrupted one despite the bitwise-state contract.
    let mut evaluated_through: u64 = 0;
    let mut i: u64 = 1;
    while i <= cfg.steps {
        let step = start_step + i;
        let cache_idx = (step - snap_step - 1) as usize;
        while batch_cache.len() <= cache_idx {
            batch_cache.push(data.next());
        }
        let lr = cfg.schedule.lr(step);
        let fault = faults.take_for_step(i);
        let params = std::mem::take(&mut state.params);
        let opt = std::mem::take(&mut state.opt_state);
        let batch = &batch_cache[cache_idx];
        // Coordinator-phase faults surface as panics; catch them here like
        // the rank spawn sites catch rank-thread deaths.
        let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            mesh_train_step_faulted(
                model,
                params,
                opt,
                batch,
                lr,
                cfg.weight_decay,
                step,
                mesh,
                fault,
            )
        }));
        let res = match attempt {
            Ok(r) => r,
            Err(p) => Err(anyhow!("{}", panic_text(p))),
        };
        match res {
            Ok(out) => {
                state.params = out.params;
                state.opt_state = out.opt_state;
                state.step = step;
                last_train_loss = *out.metrics.get("loss").unwrap_or(&f64::NAN);
                if cfg.log_every > 0 && i % cfg.log_every == 0 {
                    println!(
                        "    [{series_name}] step {step} lr={lr:.5} \
                         train_loss={last_train_loss:.4}"
                    );
                }
                if cfg.eval_every > 0
                    && i % cfg.eval_every == 0
                    && i != cfg.steps
                    && i > evaluated_through
                {
                    let mut m = evaluator.eval(model, state)?;
                    m.insert("train_loss".into(), last_train_loss);
                    series.push(step, flops_per_step * i as f64, m.into_iter().collect());
                    evaluated_through = i;
                }
                if i % ecfg.snapshot_every == 0 {
                    crate::checkpoint::save_snapshot(
                        &ecfg.dir,
                        entry,
                        &state.params,
                        &state.opt_state,
                        state.step,
                        ecfg.snapshot_keep,
                    )?;
                    report.snapshots_written += 1;
                    snap_step = step;
                    batch_cache.drain(..=cache_idx);
                }
                i += 1;
            }
            Err(e) => {
                let cause = format!("{e:#}");
                let injected = resilience::is_injected_fault(&cause);
                // Restore a valid state from the rotation *before* deciding
                // whether to keep going: the failed attempt consumed the
                // caller's tensors, and even a give-up return must not hand
                // back a gutted TrainState.
                let (p, o, loaded_step, _path) =
                    crate::checkpoint::load_latest_snapshot(&ecfg.dir, entry)
                        .context("recovering after a failed step")?;
                state.params = p;
                state.opt_state = o;
                state.step = loaded_step;
                if report.recoveries.len() >= ecfg.max_recoveries {
                    return Err(e.context(format!(
                        "step {step} failed after {} recoveries (max_recoveries reached); \
                         state rolled back to step {loaded_step}",
                        report.recoveries.len()
                    )));
                }
                if loaded_step != snap_step {
                    bail!(
                        "snapshot rotation lost the rollback target: wanted step {snap_step}, \
                         newest loadable snapshot is step {loaded_step} (state rolled back \
                         there)"
                    );
                }
                if cfg.log_every > 0 {
                    println!(
                        "    [{series_name}] step {step} FAILED ({}), rolled back to step \
                         {loaded_step}, replaying",
                        if injected { "injected fault" } else { "rank failure" }
                    );
                }
                report.recoveries.push(RecoveryEvent {
                    failed_step: step,
                    rolled_back_to: loaded_step,
                    cause,
                    injected,
                });
                i = loaded_step - start_step + 1;
            }
        }
    }
    // The final snapshot is the run's durable artifact (the bundle the
    // bitwise-recovery contract is asserted on); skip only if the cadence
    // already wrote it at this exact step.
    if snap_step != state.step {
        crate::checkpoint::save_snapshot(
            &ecfg.dir,
            entry,
            &state.params,
            &state.opt_state,
            state.step,
            ecfg.snapshot_keep,
        )?;
        report.snapshots_written += 1;
    }
    let mut m = evaluator.eval(model, state)?;
    m.insert("train_loss".into(), last_train_loss);
    series.push(state.step, flops_per_step * cfg.steps as f64, m.into_iter().collect());
    Ok((series, report))
}

/// Total extra cost of a finished series' final point.
pub fn final_cost(series: &Series) -> Cost {
    Cost { flops: series.last().map(|p| p.extra_flops).unwrap_or(0.0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::{HmmCorpus, HmmSpec, TextPipeline};
    use crate::init::{init_opt_state, init_params};
    use crate::manifest::Manifest;
    use crate::runtime::Runtime;

    const MODEL: &str = "lm_tiny_moe_e8_c2";

    fn setup() -> (ModelEntry, LoadedModel, Vec<Vec<Tensor>>) {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let entry = manifest.model(MODEL).unwrap().clone();
        let model = runtime.load_model(&manifest, MODEL, &["train", "eval"]).unwrap();
        let mut pipe = TextPipeline::new(
            HmmCorpus::new(
                HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        );
        let batches = (0..3).map(|_| pipe.next_batch()).collect();
        (entry, model, batches)
    }

    fn fresh_state(entry: &ModelEntry) -> TrainState {
        TrainState::from_checkpoints(
            entry,
            &init_params(entry, 7).unwrap(),
            &init_opt_state(entry).unwrap(),
        )
        .unwrap()
    }

    /// The serving tentpole's resume invariant: train → save → load →
    /// resume is bitwise-identical to training straight through. The
    /// bundle carries params, optimizer accumulators and the step counter,
    /// so Adam bias correction continues exactly where it stopped.
    #[test]
    fn save_load_resume_is_bitwise_identical() {
        let (entry, model, batches) = setup();
        let step_once = |st: &mut TrainState, b: &[Tensor]| {
            let out = model
                .train_step(
                    std::mem::take(&mut st.params),
                    std::mem::take(&mut st.opt_state),
                    b,
                    1e-3,
                    0.01,
                    st.step + 1,
                )
                .unwrap();
            st.params = out.params;
            st.opt_state = out.opt_state;
            st.step += 1;
        };
        // Straight-through reference: three uninterrupted steps.
        let mut straight = fresh_state(&entry);
        for b in &batches {
            step_once(&mut straight, b);
        }
        // Interrupted run: two steps, save, load, one more step.
        let mut first = fresh_state(&entry);
        step_once(&mut first, &batches[0]);
        step_once(&mut first, &batches[1]);
        let path = std::env::temp_dir().join("supc_trainer").join("resume.supc");
        first.save(&entry, &path, "resume-test").unwrap();
        let mut resumed = TrainState::load(&entry, &path).unwrap();
        assert_eq!(resumed.step, 2, "the bundle must carry the step counter");
        step_once(&mut resumed, &batches[2]);
        assert_eq!(straight.step, resumed.step);
        for ((a, b), spec) in straight.params.iter().zip(&resumed.params).zip(&entry.params) {
            assert_eq!(a, b, "param `{}` must match bitwise after resume", spec.name);
        }
        let opt_pairs = straight.opt_state.iter().zip(&resumed.opt_state);
        for ((a, b), spec) in opt_pairs.zip(&entry.opt_state) {
            assert_eq!(a, b, "opt slot `{}` must match bitwise after resume", spec.name);
        }
        std::fs::remove_file(&path).ok();
    }

    /// The PR acceptance invariant: N-replica data-parallel training is
    /// bitwise-identical to single-replica training (gradient accumulation)
    /// on the same effective batch — params, optimizer state and metrics.
    #[test]
    fn data_parallel_is_bitwise_identical_to_single_replica() {
        let (entry, model, batches) = setup();
        let replicas = 4; // fixed shard decomposition; worker count varies
        let run = |workers: usize| {
            let dp = DpConfig { replicas, workers };
            let mut st = fresh_state(&entry);
            let mut losses = Vec::new();
            for (i, b) in batches.iter().enumerate() {
                let out = dp_train_step(
                    &model,
                    std::mem::take(&mut st.params),
                    std::mem::take(&mut st.opt_state),
                    b,
                    1e-3,
                    0.01,
                    (i + 1) as u64,
                    &dp,
                )
                .unwrap();
                st.params = out.params;
                st.opt_state = out.opt_state;
                losses.push(out.metrics["loss"]);
            }
            (st.params, st.opt_state, losses)
        };
        let (p1, o1, l1) = run(1); // single replica stepping all 4 shards
        let (p4, o4, l4) = run(4); // four worker replicas, one shard each
        assert_eq!(l1, l4, "per-step loss must match exactly");
        for ((a, b), spec) in p1.iter().zip(&p4).zip(&entry.params) {
            assert_eq!(a, b, "param `{}` must match bitwise", spec.name);
        }
        for ((a, b), spec) in o1.iter().zip(&o4).zip(&entry.opt_state) {
            assert_eq!(a, b, "opt slot `{}` must match bitwise", spec.name);
        }
        assert!(l1.iter().all(|l| l.is_finite()));
    }

    /// Run `steps` mesh training steps from a fresh state; returns the
    /// final (params, opt_state, per-step losses).
    fn run_mesh(
        entry: &ModelEntry,
        model: &LoadedModel,
        batches: &[Vec<Tensor>],
        mesh: &MeshConfig,
    ) -> (Vec<Tensor>, Vec<Tensor>, Vec<f64>) {
        let mut st = fresh_state(entry);
        let mut losses = Vec::new();
        for (i, b) in batches.iter().enumerate() {
            let out = mesh_train_step(
                model,
                std::mem::take(&mut st.params),
                std::mem::take(&mut st.opt_state),
                b,
                1e-3,
                0.01,
                (i + 1) as u64,
                mesh,
            )
            .unwrap();
            st.params = out.params;
            st.opt_state = out.opt_state;
            losses.push(out.metrics["loss"]);
        }
        (st.params, st.opt_state, losses)
    }

    /// The PR acceptance invariant: a 2x2 mesh — 4 rank threads, expert
    /// weights sharded over each DP group's EP pair, token buffers moving
    /// through real all-to-all collectives — is bitwise-identical to the
    /// same shard decomposition stepped serially by one worker holding the
    /// full expert set (an independent code path through `grads`).
    #[test]
    fn mesh_2x2_is_bitwise_identical_to_one_worker() {
        let (entry, model, batches) = setup();
        let parallel = MeshConfig { dp: 2, ep: 2, parallel: true, microbatches: 1 };
        let serial = MeshConfig { dp: 2, ep: 2, parallel: false, microbatches: 1 };
        let (p_par, o_par, l_par) = run_mesh(&entry, &model, &batches, &parallel);
        let (p_ser, o_ser, l_ser) = run_mesh(&entry, &model, &batches, &serial);
        assert_eq!(l_par, l_ser, "per-step loss must match exactly");
        for ((a, b), spec) in p_par.iter().zip(&p_ser).zip(&entry.params) {
            assert_eq!(a, b, "param `{}` must match bitwise", spec.name);
        }
        for ((a, b), spec) in o_par.iter().zip(&o_ser).zip(&entry.opt_state) {
            assert_eq!(a, b, "opt slot `{}` must match bitwise", spec.name);
        }
        assert!(l_par.iter().all(|l| l.is_finite()));
    }

    /// The overlap property: the double-buffered microbatch pipeline is
    /// bitwise-identical to the serial 1-worker reference for every
    /// microbatch count × mesh shape. The chunked forward/backward halves
    /// are row-exact and the weight grads defer to one fused GEMM per
    /// (expert, source), so the float arithmetic never depends on the
    /// pipeline depth — only the all-to-all / compute overlap does.
    #[test]
    fn overlapped_pipeline_is_bitwise_serial_for_all_microbatch_counts() {
        let (entry, model, batches) = setup();
        let batches = &batches[..batches.len().min(2)];
        for (dp, ep) in [(1usize, 1usize), (1, 2), (2, 2)] {
            let serial = MeshConfig { dp, ep, parallel: false, microbatches: 1 };
            let (p_ser, o_ser, l_ser) = run_mesh(&entry, &model, batches, &serial);
            for m in [1usize, 2, 4] {
                let mesh = MeshConfig { dp, ep, parallel: true, microbatches: m };
                let (p_par, o_par, l_par) = run_mesh(&entry, &model, batches, &mesh);
                assert_eq!(l_par, l_ser, "{dp}x{ep} m={m}: per-step loss must match exactly");
                for ((a, b), spec) in p_par.iter().zip(&p_ser).zip(&entry.params) {
                    assert_eq!(a, b, "{dp}x{ep} m={m}: param `{}` mismatch", spec.name);
                }
                for ((a, b), spec) in o_par.iter().zip(&o_ser).zip(&entry.opt_state) {
                    assert_eq!(a, b, "{dp}x{ep} m={m}: opt slot `{}` mismatch", spec.name);
                }
            }
        }
    }

    /// With one DP group the hierarchical reduction collapses to the flat
    /// one, so a 1xE mesh must also be bitwise-identical to plain DP
    /// gradient accumulation over E shards — tying the expert-parallel
    /// arithmetic to the established data-parallel guarantee.
    #[test]
    fn mesh_1x2_matches_dp_accumulation_bitwise() {
        let (entry, model, batches) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        let (p_mesh, o_mesh, l_mesh) = run_mesh(&entry, &model, &batches, &mesh);
        let dp = DpConfig { replicas: 2, workers: 1 };
        let mut st = fresh_state(&entry);
        let mut losses = Vec::new();
        for (i, b) in batches.iter().enumerate() {
            let out = dp_train_step(
                &model,
                std::mem::take(&mut st.params),
                std::mem::take(&mut st.opt_state),
                b,
                1e-3,
                0.01,
                (i + 1) as u64,
                &dp,
            )
            .unwrap();
            st.params = out.params;
            st.opt_state = out.opt_state;
            losses.push(out.metrics["loss"]);
        }
        assert_eq!(l_mesh, losses, "per-step loss must match exactly");
        for ((a, b), spec) in p_mesh.iter().zip(&st.params).zip(&entry.params) {
            assert_eq!(a, b, "param `{}` must match bitwise", spec.name);
        }
        for ((a, b), spec) in o_mesh.iter().zip(&st.opt_state).zip(&entry.opt_state) {
            assert_eq!(a, b, "opt slot `{}` must match bitwise", spec.name);
        }
    }

    #[test]
    fn mesh_config_validates_and_parses() {
        let (entry, _, _) = setup();
        assert_eq!(MeshConfig::parse("2x2").unwrap(), (2, 2));
        assert_eq!(MeshConfig::parse("1x8").unwrap(), (1, 8));
        assert!(MeshConfig::parse("2").is_err());
        assert!(MeshConfig::parse("ax2").is_err());
        let mesh = MeshConfig::replicated(&entry, 2, 2).unwrap();
        assert_eq!((mesh.dp, mesh.ep, mesh.ranks()), (2, 2, 4));
        assert!(mesh.parallel);
        assert!(!MeshConfig::accumulated(&entry, 2, 2).unwrap().parallel);
        // batch 8 does not shard over 3 ranks; E=8 caps the expert axis.
        assert!(MeshConfig::replicated(&entry, 3, 1).is_err());
        assert!(MeshConfig::replicated(&entry, 1, 16).is_err());
    }

    /// A rank failure mid-step must surface as an error, not a deadlock.
    #[test]
    fn mesh_step_fails_loudly_on_bad_batch() {
        let (entry, model, batches) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        // Truncate one batch tensor so shard 1 is malformed.
        let mut bad = batches[0].clone();
        bad.pop();
        let mut st = fresh_state(&entry);
        let res = mesh_train_step(
            &model,
            std::mem::take(&mut st.params),
            std::mem::take(&mut st.opt_state),
            &bad,
            1e-3,
            0.0,
            1,
            &mesh,
        );
        assert!(res.is_err(), "malformed batch must error, not hang");
    }

    #[test]
    fn shard_batch_partitions_leading_dim() {
        let (_, _, batches) = setup();
        let batch = &batches[0];
        let shards = shard_batch(batch, 4).unwrap();
        assert_eq!(shards.len(), 4);
        for shard in &shards {
            assert_eq!(shard.len(), batch.len());
            for (s, t) in shard.iter().zip(batch) {
                assert_eq!(s.shape[0], t.shape[0] / 4);
                assert_eq!(s.shape[1..], t.shape[1..]);
            }
        }
        // Concatenating the shards reproduces the original tensors.
        let enc0 = batch[0].i32s().unwrap();
        let cat: Vec<i32> = shards
            .iter()
            .flat_map(|s| s[0].i32s().unwrap().iter().copied())
            .collect();
        assert_eq!(enc0, &cat[..]);
        // Indivisible and degenerate shard counts fail loudly.
        assert!(shard_batch(batch, 3).is_err());
        assert!(shard_batch(batch, 0).is_err());
        assert!(shard_batch(&[], 2).is_err());
    }

    #[test]
    fn dp_config_validates_at_construction_time() {
        let (entry, _, _) = setup();
        // batch_size 8 does not split 3 ways.
        assert!(DpConfig::accumulated(&entry, 3).is_err());
        let dp = DpConfig::accumulated(&entry, 8).unwrap();
        assert_eq!((dp.replicas, dp.workers), (8, 1));
        // Replicated mode is additionally bounded by host parallelism.
        assert!(DpConfig::replicated(&entry, 1024).is_err());
    }

    fn make_pipe(entry: &ModelEntry, shard: u64) -> TextPipeline {
        TextPipeline::new(
            HmmCorpus::new(
                HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            shard,
        )
    }

    /// Run `steps` elastic mesh steps from a fresh state with the given
    /// fault schedule; returns (final state, report, final-snapshot bytes).
    fn run_elastic(
        entry: &ModelEntry,
        model: &LoadedModel,
        mesh: &MeshConfig,
        steps: u64,
        dir: &std::path::Path,
        faults: crate::resilience::FaultSchedule,
    ) -> (TrainState, ElasticReport, Vec<u8>) {
        std::fs::remove_dir_all(dir).ok();
        let mut state = fresh_state(entry);
        let mut data = make_pipe(entry, 0);
        let mut held = make_pipe(entry, 1000);
        let evaluator = Evaluator::from_source(&mut held, 1);
        let cfg = TrainConfig {
            steps,
            schedule: Schedule::constant(1e-3),
            weight_decay: 0.01,
            eval_every: 0,
            log_every: 0,
        };
        let mut ecfg = ElasticConfig::new(dir);
        ecfg.snapshot_every = 2;
        ecfg.snapshot_keep = 2;
        ecfg.faults = faults;
        let (_series, report) = train_mesh_elastic(
            model, &mut state, &mut data, &evaluator, &cfg, mesh, &ecfg, "elastic",
        )
        .unwrap();
        let final_snap = crate::checkpoint::snapshot_path(dir, state.step);
        let bytes = std::fs::read(&final_snap).expect("final snapshot written");
        (state, report, bytes)
    }

    fn assert_states_bitwise(entry: &ModelEntry, a: &TrainState, b: &TrainState) {
        assert_eq!(a.step, b.step);
        for ((x, y), spec) in a.params.iter().zip(&b.params).zip(&entry.params) {
            assert_eq!(x, y, "param `{}` must match bitwise", spec.name);
        }
        for ((x, y), spec) in a.opt_state.iter().zip(&b.opt_state).zip(&entry.opt_state) {
            assert_eq!(x, y, "opt slot `{}` must match bitwise", spec.name);
        }
    }

    /// The elastic tentpole invariant, in miniature: a 1x2 mesh run with a
    /// rank killed mid-step recovers by rollback + replay and ends
    /// bitwise-identical to the uninterrupted run — state *and* the final
    /// SUPC snapshot bundle's bytes.
    #[test]
    fn elastic_recovery_is_bitwise_identical_to_uninterrupted() {
        use crate::resilience::{FaultPhase, FaultSchedule};
        let (entry, model, _) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        let base = std::env::temp_dir().join("supc_trainer_elastic");
        let (ref_state, ref_report, ref_bytes) = run_elastic(
            &entry,
            &model,
            &mesh,
            3,
            &base.join("ref"),
            FaultSchedule::default(),
        );
        assert!(ref_report.recoveries.is_empty());
        let plan = FaultPlan { rank: 1, step: 3, phase: FaultPhase::Combine };
        let (f_state, f_report, f_bytes) = run_elastic(
            &entry,
            &model,
            &mesh,
            3,
            &base.join("faulted"),
            FaultSchedule::single(plan),
        );
        assert_eq!(f_report.recoveries.len(), 1, "{:?}", f_report.recoveries);
        let ev = &f_report.recoveries[0];
        assert!(ev.injected, "cause must carry the injected marker: {}", ev.cause);
        assert_eq!((ev.failed_step, ev.rolled_back_to), (3, 2));
        assert_states_bitwise(&entry, &ref_state, &f_state);
        assert_eq!(ref_bytes, f_bytes, "final snapshot bundles must be byte-identical");
        std::fs::remove_dir_all(&base).ok();
    }

    /// A coordinator-side kill entering the optimizer phase also recovers
    /// bitwise. (The kill lands at phase entry; a genuinely torn mid-update
    /// state would be equally unobservable because the failed attempt's
    /// tensors are discarded wholesale and never read again.)
    #[test]
    fn elastic_recovers_from_optimizer_phase_fault() {
        use crate::resilience::{FaultPhase, FaultSchedule};
        let (entry, model, _) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        let base = std::env::temp_dir().join("supc_trainer_elastic_opt");
        let (ref_state, _, _) = run_elastic(
            &entry,
            &model,
            &mesh,
            3,
            &base.join("ref"),
            FaultSchedule::default(),
        );
        let plan = FaultPlan { rank: 0, step: 1, phase: FaultPhase::Optimizer };
        let (f_state, f_report, _) = run_elastic(
            &entry,
            &model,
            &mesh,
            3,
            &base.join("faulted"),
            FaultSchedule::single(plan),
        );
        assert_eq!(f_report.recoveries.len(), 1);
        assert_eq!(f_report.recoveries[0].rolled_back_to, 0, "step 1 rolls back to the branch");
        assert_states_bitwise(&entry, &ref_state, &f_state);
        std::fs::remove_dir_all(&base).ok();
    }

    /// A replay after rollback must not re-push (or re-run) eval points the
    /// series already has: roll back past two eval points and check the
    /// series still has exactly one point per step.
    #[test]
    fn elastic_replay_does_not_duplicate_eval_points() {
        use crate::resilience::{FaultPhase, FaultSchedule};
        let (entry, model, _) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        let dir = std::env::temp_dir().join("supc_trainer_elastic_evals");
        std::fs::remove_dir_all(&dir).ok();
        let mut state = fresh_state(&entry);
        let mut data = make_pipe(&entry, 0);
        let mut held = make_pipe(&entry, 1000);
        let evaluator = Evaluator::from_source(&mut held, 1);
        let cfg = TrainConfig {
            steps: 3,
            schedule: Schedule::constant(1e-3),
            weight_decay: 0.0,
            eval_every: 1,
            log_every: 0,
        };
        let mut ecfg = ElasticConfig::new(&dir);
        // One snapshot at the branch point only: the step-3 fault rolls all
        // the way back and replays steps 1 and 2 — whose eval points were
        // already pushed.
        ecfg.snapshot_every = 3;
        ecfg.faults = FaultSchedule::single(FaultPlan {
            rank: 1,
            step: 3,
            phase: FaultPhase::Backward,
        });
        let (series, report) = train_mesh_elastic(
            &model, &mut state, &mut data, &evaluator, &cfg, &mesh, &ecfg, "evals",
        )
        .unwrap();
        assert_eq!(report.recoveries.len(), 1);
        assert_eq!(report.recoveries[0].rolled_back_to, 0);
        let steps: Vec<u64> = series.points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 1, 2, 3], "one point per step, no replay duplicates");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A fault schedule that kills the same rank on every attempt would
    /// never converge; the one-shot schedule plus max_recoveries bounds it.
    /// Here: an unrecoverable genuine failure (malformed batch on every
    /// attempt) gives up after max_recoveries instead of spinning.
    #[test]
    fn elastic_gives_up_after_max_recoveries() {
        let (entry, model, batches) = setup();
        let mesh = MeshConfig { dp: 1, ep: 2, parallel: true, microbatches: 1 };
        let dir = std::env::temp_dir().join("supc_trainer_elastic_giveup");
        std::fs::remove_dir_all(&dir).ok();
        struct BadSource {
            batch: Vec<Tensor>,
        }
        impl BatchSource for BadSource {
            fn next(&mut self) -> Vec<Tensor> {
                self.batch.clone() // truncated: rank grads always fail
            }
        }
        let mut bad = batches[0].clone();
        bad.pop();
        let mut data = BadSource { batch: bad };
        let mut state = fresh_state(&entry);
        let mut held = make_pipe(&entry, 1000);
        let evaluator = Evaluator::from_source(&mut held, 1);
        let cfg = TrainConfig {
            steps: 2,
            schedule: Schedule::constant(1e-3),
            weight_decay: 0.0,
            eval_every: 0,
            log_every: 0,
        };
        let mut ecfg = ElasticConfig::new(&dir);
        ecfg.snapshot_every = 1;
        ecfg.max_recoveries = 2;
        let err = train_mesh_elastic(
            &model, &mut state, &mut data, &evaluator, &cfg, &mesh, &ecfg, "giveup",
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("max_recoveries"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// train_dp drives the same loop as train and improves the loss.
    #[test]
    fn train_dp_reduces_loss() {
        let (entry, model, _) = setup();
        let mut pipe = TextPipeline::new(
            HmmCorpus::new(
                HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            3,
        );
        let mut held = TextPipeline::new(
            HmmCorpus::new(
                HmmSpec { vocab_size: entry.config.vocab_size, ..Default::default() },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            99,
        );
        let evaluator = Evaluator::from_source(&mut held, 1);
        let mut state = fresh_state(&entry);
        let cfg = TrainConfig {
            steps: 20,
            schedule: Schedule::constant(0.01),
            weight_decay: 0.0,
            eval_every: 0,
            log_every: 0,
        };
        let dp = DpConfig { replicas: 2, workers: 2 };
        let series =
            train_dp(&model, &mut state, &mut pipe, &evaluator, &cfg, &dp, "dp").unwrap();
        let first = series.points.first().unwrap().values["loss"];
        let last = series.points.last().unwrap().values["loss"];
        assert!(last < first, "dp training must reduce held-out loss: {first} -> {last}");
        assert_eq!(state.step, 20);
    }
}
