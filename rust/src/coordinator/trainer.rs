//! Training orchestrator: owns the step loop, the LR schedule, periodic
//! evaluation and checkpointing. This is where "dense continuation",
//! "upcycled" and "MoE from scratch" branches become concrete runs.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::costmodel::Cost;
use crate::manifest::ModelEntry;
use crate::metrics::Series;
use crate::runtime::{checkpoint_from_tensors, tensors_from_checkpoint, LoadedModel, Metrics};
use crate::tensor::Tensor;

use super::schedule::Schedule;

/// Anything that yields training batches in manifest batch order.
pub trait BatchSource {
    fn next(&mut self) -> Vec<Tensor>;
}

impl BatchSource for crate::data::text::TextPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch()
    }
}

impl BatchSource for crate::data::text::ClassificationPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch().0
    }
}

impl BatchSource for crate::data::vision::VisionPipeline {
    fn next(&mut self) -> Vec<Tensor> {
        self.next_batch().0
    }
}

/// Live training state: host tensors in manifest order + the global step
/// counter (which also drives the optimizer's bias correction and the LR
/// schedule).
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub opt_state: Vec<Tensor>,
    pub step: u64,
}

impl TrainState {
    pub fn from_checkpoints(
        entry: &ModelEntry,
        params: &Checkpoint,
        opt: &Checkpoint,
    ) -> Result<TrainState> {
        Ok(TrainState {
            params: tensors_from_checkpoint(params, &entry.params)
                .context("binding params to manifest signature")?,
            opt_state: tensors_from_checkpoint(opt, &entry.opt_state)
                .context("binding optimizer state to manifest signature")?,
            step: params.step,
        })
    }

    pub fn to_checkpoints(
        &self,
        entry: &ModelEntry,
        provenance: &str,
    ) -> Result<(Checkpoint, Checkpoint)> {
        let p = checkpoint_from_tensors(
            &entry.name, self.step, provenance, &entry.params, &self.params)?;
        let o = checkpoint_from_tensors(
            &entry.name, self.step, provenance, &entry.opt_state, &self.opt_state)?;
        Ok((p, o))
    }
}

/// Fixed held-out evaluation set (deterministic shard, reused across all
/// branches of an experiment so curves are comparable).
pub struct Evaluator {
    batches: Vec<Vec<Tensor>>,
}

impl Evaluator {
    pub fn from_source(src: &mut dyn BatchSource, n_batches: usize) -> Evaluator {
        Evaluator { batches: (0..n_batches).map(|_| src.next()).collect() }
    }

    pub fn eval(&self, model: &LoadedModel, state: &TrainState) -> Result<Metrics> {
        let mut acc: BTreeMap<String, f64> = BTreeMap::new();
        for b in &self.batches {
            for (k, v) in model.eval_step(&state.params, b)? {
                *acc.entry(k).or_insert(0.0) += v;
            }
        }
        let n = self.batches.len().max(1) as f64;
        Ok(acc.into_iter().map(|(k, v)| (k, v / n)).collect())
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: u64,
    pub schedule: Schedule,
    pub weight_decay: f64,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: u64,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: u64,
}

/// Run `cfg.steps` steps; returns the eval curve (extra-cost x-axis measured
/// from the state's starting step, in this model's per-step FLOPs).
pub fn train(
    model: &LoadedModel,
    state: &mut TrainState,
    data: &mut dyn BatchSource,
    evaluator: &Evaluator,
    cfg: &TrainConfig,
    series_name: &str,
) -> Result<Series> {
    let mut series = Series::new(series_name);
    let start_step = state.step;
    let flops_per_step = model.entry.flops.train_step;

    // Point at the branch start (extra cost 0) — the paper's horizontal
    // reference lines come from these.
    let m0 = evaluator.eval(model, state)?;
    series.push(state.step, 0.0, m0.into_iter().collect());

    let mut last_train_loss = f64::NAN;
    for i in 1..=cfg.steps {
        let step = start_step + i;
        let lr = cfg.schedule.lr(step);
        let batch = data.next();
        let params = std::mem::take(&mut state.params);
        let opt = std::mem::take(&mut state.opt_state);
        let out = model
            .train_step(params, opt, &batch, lr, cfg.weight_decay, step)
            .with_context(|| format!("train step {step}"))?;
        state.params = out.params;
        state.opt_state = out.opt_state;
        state.step = step;
        last_train_loss = *out.metrics.get("loss").unwrap_or(&f64::NAN);

        if cfg.log_every > 0 && i % cfg.log_every == 0 {
            println!(
                "    [{series_name}] step {step} lr={lr:.5} train_loss={last_train_loss:.4}"
            );
        }
        if cfg.eval_every > 0 && i % cfg.eval_every == 0 && i != cfg.steps {
            let mut m = evaluator.eval(model, state)?;
            m.insert("train_loss".into(), last_train_loss);
            series.push(step, flops_per_step * i as f64, m.into_iter().collect());
        }
    }
    let mut m = evaluator.eval(model, state)?;
    m.insert("train_loss".into(), last_train_loss);
    series.push(state.step, flops_per_step * cfg.steps as f64,
                m.into_iter().collect());
    Ok(series)
}

/// Total extra cost of a finished series' final point.
pub fn final_cost(series: &Series) -> Cost {
    Cost { flops: series.last().map(|p| p.extra_flops).unwrap_or(0.0) }
}
