//! Learning-rate schedules (paper §4.1 / §A.1).
//!
//! The critical property for upcycling is **continuity**: the upcycled model
//! resumes the dense checkpoint's inverse-square-root schedule at the step
//! where the parent left off ("training can be continued without
//! discontinuities in the learning rate schedule"). Vision runs add a
//! terminal linear cooldown to zero (Fig. 7 shows branches with cooldowns).

#[derive(Debug, Clone, Copy)]
pub enum ScheduleKind {
    /// T5: peak · min(1, step/warmup) · 1/sqrt(max(step, warmup)/warmup)
    /// i.e. linear warmup then rsqrt decay with the warmup step as timescale.
    InverseSqrt,
    /// ViT (§A.1.2): linear warmup, rsqrt decay with an explicit timescale.
    InverseSqrtTimescale { timescale: u64 },
    /// Constant (finetuning, §A.2.1).
    Constant,
}

#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub peak_lr: f64,
    pub warmup_steps: u64,
    /// If set: (cooldown_start, cooldown_steps) — linear decay to 0.
    pub cooldown: Option<(u64, u64)>,
}

impl Schedule {
    pub fn t5_pretrain(peak_lr: f64, warmup_steps: u64) -> Schedule {
        Schedule { kind: ScheduleKind::InverseSqrt, peak_lr, warmup_steps, cooldown: None }
    }

    pub fn vit_pretrain(peak_lr: f64, warmup_steps: u64, timescale: u64) -> Schedule {
        Schedule {
            kind: ScheduleKind::InverseSqrtTimescale { timescale },
            peak_lr,
            warmup_steps,
            cooldown: None,
        }
    }

    pub fn constant(lr: f64) -> Schedule {
        Schedule { kind: ScheduleKind::Constant, peak_lr: lr, warmup_steps: 0, cooldown: None }
    }

    pub fn with_cooldown(mut self, start: u64, steps: u64) -> Schedule {
        self.cooldown = Some((start, steps));
        self
    }

    /// Learning rate at (1-based) step.
    pub fn lr(&self, step: u64) -> f64 {
        let s = step.max(1) as f64;
        let base = match self.kind {
            ScheduleKind::Constant => self.peak_lr,
            ScheduleKind::InverseSqrt => {
                let w = self.warmup_steps.max(1) as f64;
                if s < w {
                    self.peak_lr * s / w
                } else {
                    self.peak_lr * (w / s).sqrt()
                }
            }
            ScheduleKind::InverseSqrtTimescale { timescale } => {
                let w = self.warmup_steps.max(1) as f64;
                let t = timescale.max(1) as f64;
                if s < w {
                    self.peak_lr * s / w
                } else {
                    self.peak_lr * (t / (t + s - w)).sqrt()
                }
            }
        };
        match self.cooldown {
            Some((start, steps)) if step >= start => {
                let frac = 1.0 - ((step - start) as f64 / steps.max(1) as f64).min(1.0);
                base * frac
            }
            _ => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let s = Schedule::t5_pretrain(0.01, 100);
        assert!(s.lr(1) < s.lr(50));
        assert!(s.lr(50) < s.lr(100));
        assert!((s.lr(100) - 0.01).abs() < 1e-4);
        assert!(s.lr(400) < s.lr(100));
        // rsqrt: lr(400) = peak * sqrt(100/400) = peak/2.
        assert!((s.lr(400) - 0.005).abs() < 1e-6);
    }

    /// The upcycling boundary introduces no LR discontinuity: the schedule
    /// is a pure function of the global step, so resuming at step S gives
    /// exactly the value the dense run would have used.
    #[test]
    fn continuity_at_branch_point() {
        let s = Schedule::t5_pretrain(0.01, 100);
        let branch = 600u64;
        let dense_next = s.lr(branch + 1);
        let upcycled_next = s.lr(branch + 1); // same schedule object semantics
        assert_eq!(dense_next, upcycled_next);
        // And the jump from S to S+1 is tiny (smooth decay).
        assert!((s.lr(branch) - s.lr(branch + 1)).abs() / s.lr(branch) < 0.01);
    }

    #[test]
    fn cooldown_reaches_zero() {
        let s = Schedule::vit_pretrain(4e-4, 10, 100).with_cooldown(500, 50);
        assert!(s.lr(499) > 0.0);
        assert!(s.lr(525) < s.lr(499));
        assert!(s.lr(550) == 0.0 || s.lr(550) < 1e-9);
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::constant(1e-3);
        assert_eq!(s.lr(1), s.lr(100_000));
    }
}
