//! Deterministic RNG substrate: PCG32 core + the distributions the data
//! pipelines and surgery need (normal, categorical, Zipf, permutation).
//!
//! Offline environment: the `rand` crate is unavailable; the coordinator
//! needs *reproducible* streams anyway (every experiment is keyed by an
//! explicit seed so figure regeneration is deterministic).

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut r = Rng { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    /// Derive an independent child stream (hash-mixes the label).
    pub fn fork(&mut self, label: u64) -> Rng {
        let s = self.next_u64() ^ splitmix(label);
        Rng::with_stream(s, splitmix(s ^ 0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire rejection-free for our sizes).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-9 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_vec(&mut self, n: usize, stddev: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * stddev).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (unigram skew for
    /// the synthetic corpus; matches natural-language token statistics).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on the harmonic partial sums would need a table; for
        // data generation we use the rejection-free approximation of
        // bounded inverse sampling, adequate for corpus statistics.
        let u = self.f64();
        let hmax = harmonic(n, s);
        let target = u * hmax;
        // Binary search over the monotone partial-sum function.
        let (mut lo, mut hi) = (1usize, n);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if harmonic(mid, s) < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), order randomized.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Precomputed Zipf CDF: O(log n) sampling with zero per-sample `powf`.
///
/// `Rng::zipf` recomputes generalized harmonic numbers inside its binary
/// search — O(n log n) powf calls per sample, which made large-vocab corpus
/// generation cost ~38 ms/batch (see EXPERIMENTS.md §Perf). Pipelines hold
/// one table per distribution instead.
#[derive(Clone, Debug)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> ZipfTable {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let target = rng.f64() * self.cdf.last().copied().unwrap_or(1.0);
        match self.cdf.binary_search_by(|v| v.partial_cmp(&target).unwrap()) {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn harmonic(n: usize, s: f64) -> f64 {
    // Cached generalized harmonic numbers would matter for huge n; our
    // vocabularies are ≤ 32k and generation is not the bottleneck (see
    // rust/benches/data_pipeline.rs).
    (1..=n).map(|k| (k as f64).powf(-s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut r = Rng::new(1);
        let mut c1 = r.fork(1);
        let mut c2 = r.fork(2);
        let xs: Vec<u32> = (0..8).map(|_| c1.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| c2.next_u32()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 50];
        for _ in 0..5000 {
            counts[r.zipf(50, 1.1)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49] * 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2);
        let mut xs: Vec<usize> = (0..40).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..40).collect::<Vec<_>>());
    }
}

#[cfg(test)]
mod zipf_table_tests {
    use super::*;

    #[test]
    fn table_matches_direct_zipf_distribution() {
        let n = 50;
        let s = 1.1;
        let table = ZipfTable::new(n, s);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; n];
        for _ in 0..20000 {
            counts[table.sample(&mut rng)] += 1;
        }
        // Same qualitative shape as Rng::zipf's test: heavy head.
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49] * 4);
        // Head frequency close to analytic p(0) = 1/H(n,s).
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let expect = 20000.0 / h;
        assert!((counts[0] as f64 - expect).abs() < expect * 0.15,
                "head count {} vs analytic {expect}", counts[0]);
    }

    #[test]
    fn table_sample_in_range() {
        let table = ZipfTable::new(7, 1.3);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(table.sample(&mut rng) < 7);
        }
    }
}
