//! Minimal JSON parser/writer (offline environment: serde is unavailable).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes metrics/results. Supports the full JSON grammar; numbers are
//! held as f64 (the manifest never needs integers above 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking for `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            _ => self.number(),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            bail!("bad keyword at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected `,` or `]`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number `{text}`: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "a": [1], "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(!v.get("b").unwrap().as_bool().unwrap());
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☃");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }
}
