//! Doc hygiene checks for the repo's markdown docs, behind `upcycle
//! check-docs` (mirrored by `make docs` and the blocking CI docs job).
//!
//! Two checks:
//!
//! * **Relative links** — scans markdown files for inline links and
//!   images — `[text](target)` — and verifies that every *relative*
//!   target resolves to an existing file or directory next to the
//!   document. External schemes (`http://`, `https://`, `mailto:`) and
//!   pure in-page anchors (`#…`) are skipped; a `path#anchor` target is
//!   checked for its file part only. Fenced code blocks are ignored so
//!   `arr[i](x)`-shaped code in examples cannot false-positive.
//! * **Deprecated CLI flags** — flags retired by the unified `--topology`
//!   and `--serve` plans ([`DEPRECATED_FLAGS`]) must not appear inside
//!   fenced code blocks: examples are what readers copy, so a doc example
//!   carrying `--replicas`/`--mesh` or `--batch-tokens`/`--unbatched`
//!   would keep teaching the dead API. Prose (the deprecation tables in
//!   `docs/CLI.md`) mentions them freely.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One broken link: the document it appears in, the raw target, and the
/// path it resolved to (which does not exist).
#[derive(Debug)]
pub struct DeadLink {
    pub file: PathBuf,
    pub target: String,
    pub resolved: PathBuf,
}

fn is_external(target: &str) -> bool {
    target.starts_with("http://")
        || target.starts_with("https://")
        || target.starts_with("mailto:")
        || target.starts_with('#')
}

/// Inline markdown link targets of `text`, in order, skipping fenced code
/// blocks. A ` "title"` suffix inside the parentheses is dropped.
pub fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let bytes = line.as_bytes();
        let mut i = 0;
        while i + 1 < bytes.len() {
            if bytes[i] == b']' && bytes[i + 1] == b'(' {
                let start = i + 2;
                if let Some(len) = line[start..].find(')') {
                    let target = line[start..start + len].split_whitespace().next().unwrap_or("");
                    if !target.is_empty() {
                        out.push(target.to_string());
                    }
                    i = start + len + 1;
                    continue;
                }
            }
            i += 1;
        }
    }
    out
}

/// Check every relative link in `files`, returning the dead ones (an empty
/// vec means the doc set is link-clean).
pub fn check_files(files: &[PathBuf]) -> Result<Vec<DeadLink>> {
    let mut dead = Vec::new();
    for f in files {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f:?}"))?;
        let dir = f.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
        for target in link_targets(&text) {
            if is_external(&target) {
                continue;
            }
            let file_part = target.split('#').next().unwrap_or("");
            if file_part.is_empty() {
                continue;
            }
            let resolved = dir.join(file_part);
            if !resolved.exists() {
                dead.push(DeadLink { file: f.clone(), target: target.clone(), resolved });
            }
        }
    }
    Ok(dead)
}

/// CLI flags retired by the unified `--topology dp=D,ep=E[,tp=T]` and
/// `--serve policy=…,budget=…` plans (see docs/CLI.md's deprecation
/// tables). They still parse — with a printed warning — but doc examples
/// must show the replacement.
pub const DEPRECATED_FLAGS: &[&str] = &[
    "--replicas",
    "--mesh",
    "--ep",
    "--dp",
    "--mp",
    "--batch-tokens",
    "--max-batch",
    "--unbatched",
    "--gap-us",
];

/// One deprecated flag sighting inside a fenced code block.
#[derive(Debug)]
pub struct StaleFlag {
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub flag: &'static str,
    /// The offending line, trimmed.
    pub text: String,
}

/// Deprecated-flag hits inside fenced code blocks of `text`:
/// `(1-based line, flag, trimmed line)`. A boundary check keeps prefixes
/// honest (`--epochs` is not `--ep`, `--mesh-foo` is not `--mesh`).
pub fn deprecated_flag_hits(text: &str) -> Vec<(usize, &'static str, String)> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for (idx, line) in text.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if !in_fence {
            continue;
        }
        for flag in DEPRECATED_FLAGS {
            let hit = line.match_indices(flag).any(|(pos, _)| {
                let after = line[pos + flag.len()..].chars().next();
                !after.map(|c| c.is_alphanumeric() || c == '-' || c == '_').unwrap_or(false)
            });
            if hit {
                out.push((idx + 1, *flag, line.trim().to_string()));
            }
        }
    }
    out
}

/// Scan `files` for deprecated CLI flags in fenced examples, returning
/// every sighting (an empty vec means the examples teach the live API).
pub fn check_deprecated_flags(files: &[PathBuf]) -> Result<Vec<StaleFlag>> {
    let mut stale = Vec::new();
    for f in files {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f:?}"))?;
        for (line, flag, text) in deprecated_flag_hits(&text) {
            stale.push(StaleFlag { file: f.clone(), line, flag, text });
        }
    }
    Ok(stale)
}

/// The repo's checked documentation set: `README.md` plus every
/// `docs/*.md` under `root`, sorted for stable reporting.
pub fn doc_files(root: impl AsRef<Path>) -> Result<Vec<PathBuf>> {
    let root = root.as_ref();
    let mut files = Vec::new();
    let readme = root.join("README.md");
    if readme.exists() {
        files.push(readme);
    }
    let docs = root.join("docs");
    if docs.is_dir() {
        let mut md: Vec<PathBuf> = std::fs::read_dir(&docs)
            .with_context(|| format!("reading {docs:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map(|x| x == "md").unwrap_or(false))
            .collect();
        md.sort();
        files.extend(md);
    }
    if files.is_empty() {
        bail!("no markdown docs found under {root:?} (need README.md or docs/*.md)");
    }
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_targets_outside_fences() {
        let md = "\
see [a](docs/a.md) and ![img](img.png \"title\")\n\
```\nlet x = v[i](j); // not a link\n```\n\
[anchor](#section) [ext](https://example.com) [both](b.md#top)\n";
        let targets = link_targets(md);
        let want = vec!["docs/a.md", "img.png", "#section", "https://example.com", "b.md#top"];
        assert_eq!(targets, want);
    }

    #[test]
    fn flags_dead_relative_links_only() {
        let dir = std::env::temp_dir().join("supc_doclinks");
        std::fs::create_dir_all(dir.join("docs")).unwrap();
        std::fs::write(dir.join("docs/real.md"), "present").unwrap();
        let f = dir.join("README.md");
        let body = "[ok](docs/real.md) [anchor ok](docs/real.md#x) [http](https://x.y) \
                    [gone](docs/missing.md)";
        std::fs::write(&f, body).unwrap();
        let dead = check_files(&[f.clone()]).unwrap();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].target, "docs/missing.md");
        assert_eq!(dead[0].file, f);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deprecated_flags_gate_fenced_examples_only() {
        let md = "\
Use `--topology dp=2,ep=2`; the old `--mesh 2x2` spelling is deprecated.\n\
```sh\nupcycle train --model m --topology dp=2,ep=2 --microbatches 2\n```\n";
        assert!(deprecated_flag_hits(md).is_empty(), "prose mentions are fine");

        let bad = "\
```sh\nupcycle train --model m --mesh 2x2\nupcycle train --model m --replicas 4\n```\n";
        let hits = deprecated_flag_hits(bad);
        assert_eq!(hits.len(), 2, "{hits:?}");
        assert_eq!((hits[0].0, hits[0].1), (2, "--mesh"));
        assert_eq!((hits[1].0, hits[1].1), (3, "--replicas"));

        // The retired serve flags are gated too; `--serve` itself is fine.
        let serve = "\
```sh\nupcycle serve --load ck.supc --batch-tokens 256 --unbatched\n\
upcycle serve --load ck.supc --serve policy=fifo,budget=256\n```\n";
        let hits = deprecated_flag_hits(serve);
        assert_eq!(hits.len(), 2, "{hits:?}");
        assert_eq!((hits[0].0, hits[0].1), (2, "--batch-tokens"));
        assert_eq!((hits[1].0, hits[1].1), (2, "--unbatched"));

        // Boundary check: flag-shaped prefixes of longer flags don't trip.
        let near_miss =
            "```sh\nupcycle train --epochs 3 --mesh-style x --dperf 1 --max-batch-rows 2\n```\n";
        assert!(deprecated_flag_hits(near_miss).is_empty());
    }

    #[test]
    fn doc_files_finds_readme_and_docs() {
        let dir = std::env::temp_dir().join("supc_doclinks_set");
        std::fs::create_dir_all(dir.join("docs")).unwrap();
        std::fs::write(dir.join("README.md"), "").unwrap();
        std::fs::write(dir.join("docs/B.md"), "").unwrap();
        std::fs::write(dir.join("docs/A.md"), "").unwrap();
        std::fs::write(dir.join("docs/notes.txt"), "").unwrap();
        let files = doc_files(&dir).unwrap();
        let names: Vec<String> =
            files.iter().map(|p| p.file_name().unwrap().to_string_lossy().into_owned()).collect();
        assert_eq!(names, vec!["README.md", "A.md", "B.md"]);
        assert!(doc_files(std::env::temp_dir().join("supc_doclinks_none")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
