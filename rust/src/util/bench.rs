//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/σ/min reporting, used by `rust/benches/*`.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10}  mean {:>12}  σ {:>10}  min {:>12}",
            self.name,
            format!("x{}", self.iters),
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
        );
    }

    pub fn throughput(&self, items: f64, unit: &str) {
        let per_sec = items / (self.mean_ns / 1e9);
        println!("{:<44} {:>24}", format!("  ↳ {}", self.name), format!("{per_sec:.1} {unit}/s"));
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` with automatic iteration count targeting ~`target_ms` of total
/// measurement time (min 3 iters), after 1 warmup call.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    f(); // warmup
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos() as f64;
    let iters = ((target_ms as f64 * 1e6 / once_ns.max(1.0)) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 1, || {
            n = n.wrapping_add(1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
