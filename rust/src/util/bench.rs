//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/σ/min and latency-percentile reporting, used
//! by `rust/benches/*`, plus the per-phase step profiler behind the
//! machine-readable `BENCH_runtime.json` baseline (see `docs/BENCHMARKS.md`
//! for the schema and the recorded numbers).
//!
//! The phase profiler is a process-global accumulator keyed by static phase
//! names ("router", "dispatch", "expert_mlp", "combine", "backward",
//! "optimizer"). It is off by default and costs one relaxed atomic load per
//! [`phase`] call when disabled, so the instrumentation can stay in the hot
//! path of `runtime::native` permanently.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Latency percentiles over the timed iterations (p50/p90/p99).
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10}  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            format!("x{}", self.iters),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }

    pub fn throughput(&self, items: f64, unit: &str) {
        let per_sec = items / (self.mean_ns / 1e9);
        println!("{:<44} {:>24}", format!("  ↳ {}", self.name), format!("{per_sec:.1} {unit}/s"));
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Percentile (0..=100) of `samples` by nearest-rank on a sorted copy.
/// Returns 0.0 for an empty slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Linearly-interpolated percentile (0..=100) of `samples` on a sorted
/// copy. Unlike [`percentile`]'s nearest-rank estimate this interpolates
/// between the two adjacent order statistics, which matters for extreme
/// tails (p999) over small sample sets where nearest-rank collapses onto
/// the max. Returns 0.0 for an empty slice and the single sample for a
/// one-element slice.
pub fn percentile_interpolated(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return sorted[lo];
    }
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Run `f` with automatic iteration count targeting ~`target_ms` of total
/// measurement time (min 3 iters), after 1 warmup call.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    f(); // warmup
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos() as f64;
    let iters = ((target_ms as f64 * 1e6 / once_ns.max(1.0)) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let sq_sum: f64 = samples.iter().map(|s| (s - mean) * (s - mean)).sum();
    let var = sq_sum / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
        p50_ns: percentile(&samples, 50.0),
        p90_ns: percentile(&samples, 90.0),
        p99_ns: percentile(&samples, 99.0),
    };
    r.print();
    r
}

// ---------------------------------------------------------------------------
// Phase profiler
// ---------------------------------------------------------------------------

static PHASES_ENABLED: AtomicBool = AtomicBool::new(false);

fn phase_store() -> &'static Mutex<BTreeMap<&'static str, (u128, u64)>> {
    static STORE: OnceLock<Mutex<BTreeMap<&'static str, (u128, u64)>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Turn per-phase accumulation on or off (off by default).
pub fn phases_enable(on: bool) {
    PHASES_ENABLED.store(on, Ordering::Relaxed);
}

/// Clear all accumulated phase totals.
pub fn phases_reset() {
    phase_store().lock().expect("phase store").clear();
}

/// Snapshot of accumulated phases: (name, total_ns, calls), name-sorted.
pub fn phases_snapshot() -> Vec<(String, f64, u64)> {
    phase_store()
        .lock()
        .expect("phase store")
        .iter()
        .map(|(k, (ns, calls))| (k.to_string(), *ns as f64, *calls))
        .collect()
}

/// RAII phase timer: accumulates elapsed wall time under `name` on drop.
/// Near-free when profiling is disabled (one relaxed atomic load).
pub struct PhaseGuard {
    name: &'static str,
    start: Option<Instant>,
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let ns = t0.elapsed().as_nanos();
            let mut store = phase_store().lock().expect("phase store");
            let slot = store.entry(self.name).or_insert((0, 0));
            slot.0 += ns;
            slot.1 += 1;
        }
    }
}

/// Start timing a phase; the returned guard records on drop. See the module
/// docs for the phase names used by the native backend.
///
/// Every phase entry also reports to `resilience::on_phase` — the seam the
/// fault-injection harness uses to kill a rank thread deterministically
/// *inside* a chosen phase of a chosen step. When no fault is armed on the
/// calling thread (always, outside chaos tests and `--inject-fault` runs)
/// that hook is a single thread-local read.
pub fn phase(name: &'static str) -> PhaseGuard {
    crate::resilience::on_phase(name);
    let enabled = PHASES_ENABLED.load(Ordering::Relaxed);
    PhaseGuard { name, start: if enabled { Some(Instant::now()) } else { None } }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 1, || {
            n = n.wrapping_add(1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolated_edges_and_midpoints() {
        assert_eq!(percentile_interpolated(&[], 99.9), 0.0);
        assert_eq!(percentile_interpolated(&[7.0], 99.9), 7.0);
        // Two samples: p50 lands exactly between them.
        assert_eq!(percentile_interpolated(&[0.0, 10.0], 50.0), 5.0);
        // p999 over 1..=100 interpolates just below the max instead of
        // collapsing onto it like nearest-rank does.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p999 = percentile_interpolated(&xs, 99.9);
        assert!(p999 > 99.0 && p999 < 100.0, "{p999}");
        assert_eq!(percentile_interpolated(&xs, 100.0), 100.0);
        assert_eq!(percentile_interpolated(&xs, 0.0), 1.0);
    }

    #[test]
    fn phase_profiler_accumulates_only_when_enabled() {
        phases_reset();
        {
            let _g = phase("test_disabled");
        }
        assert!(phases_snapshot().iter().all(|(n, _, _)| n != "test_disabled"));

        phases_enable(true);
        {
            let _g = phase("test_enabled");
        }
        {
            let _g = phase("test_enabled");
        }
        phases_enable(false);
        let snap = phases_snapshot();
        let (_, ns, calls) =
            snap.iter().find(|(n, _, _)| n == "test_enabled").expect("phase recorded");
        assert_eq!(*calls, 2);
        assert!(*ns >= 0.0);
        phases_reset();
        assert!(phases_snapshot().iter().all(|(n, _, _)| n != "test_enabled"));
    }
}
