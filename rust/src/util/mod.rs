//! In-tree utility substrates (this environment has no network registry, so
//! JSON, RNG, CLI parsing, the bench harness, the markdown link checker and
//! the scoped-thread map are implemented here).

pub mod bench;
pub mod cli;
pub mod doclinks;
pub mod json;
pub mod rng;

use std::cell::Cell;

thread_local! {
    static SERIAL_COMPUTE: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread is inside [`serial_compute`]: the nested
/// parallel helpers ([`par_map`], [`par_map_workers`], and the row-sharded
/// GEMM kernels in `linalg::gemm`) then run serially instead of spawning
/// threads. The data-parallel trainer wraps each replica worker in this so
/// replica-level and kernel-level parallelism never stack up and
/// oversubscribe the host. Results are unaffected either way — the serial
/// and threaded paths are bitwise-identical by contract.
pub fn in_serial_compute() -> bool {
    SERIAL_COMPUTE.with(|c| c.get())
}

/// Run `f` with nested parallel helpers forced serial on this thread.
pub fn serial_compute<R>(f: impl FnOnce() -> R) -> R {
    SERIAL_COMPUTE.with(|c| {
        let prev = c.get();
        c.set(true);
        let out = f();
        c.set(prev);
        out
    })
}

/// Map `f` over `0..n` on up to `workers` scoped threads.
///
/// Determinism contract: slot `i` of the result always holds `f(i)`, and
/// each `f(i)` call runs exactly once on exactly one thread — the worker
/// count changes only *where* an index is evaluated, never the arithmetic
/// performed for it. Callers that keep every `f(i)` independent of thread
/// identity (everything in this crate does) therefore get results that are
/// bitwise-identical for any `workers >= 1`.
pub fn par_map_workers<T: Send, F: Fn(usize) -> T + Sync>(
    workers: usize,
    n: usize,
    f: F,
) -> Vec<T> {
    let threads = if in_serial_compute() { 1 } else { workers.min(n).max(1) };
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = (n + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ci, slots) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot filled")).collect()
}

/// [`par_map_workers`] with one worker per available hardware thread.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let workers = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    par_map_workers(workers, n, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let sq = par_map(37, |i| i * i);
        assert_eq!(sq, (0..37).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(par_map(1, |i| i + 10), vec![10]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn serial_compute_forces_single_thread_and_restores() {
        assert!(!in_serial_compute());
        let out = serial_compute(|| {
            assert!(in_serial_compute());
            par_map(7, |i| i * 2)
        });
        assert_eq!(out, (0..7).map(|i| i * 2).collect::<Vec<_>>());
        assert!(!in_serial_compute());
    }

    #[test]
    fn worker_count_never_changes_results() {
        let expect: Vec<usize> = (0..23).map(|i| i * 3 + 1).collect();
        for workers in [1, 2, 3, 8, 64] {
            assert_eq!(par_map_workers(workers, 23, |i| i * 3 + 1), expect);
        }
    }
}
