//! In-tree utility substrates (this environment has no network registry, so
//! JSON, RNG, CLI parsing and the bench harness are implemented here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
