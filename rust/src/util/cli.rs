//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse("experiment fig2 --steps 100 --scale=tiny --verbose --out results");
        assert_eq!(a.positional, vec!["experiment", "fig2"]);
        assert_eq!(a.u64("steps", 0).unwrap(), 100);
        assert_eq!(a.str("scale", ""), "tiny");
        assert!(a.bool("verbose"));
        assert_eq!(a.str("out", ""), "results");
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("run");
        assert_eq!(a.u64("steps", 42).unwrap(), 42);
        assert!(a.req("model").is_err());
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("--steps banana");
        assert!(a.u64("steps", 0).is_err());
    }
}
