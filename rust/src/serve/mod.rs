//! Forward-only inference engine: a multi-threaded request scheduler with
//! continuous (dynamic) batching over [`crate::runtime::Executable::infer`],
//! grown into a policy-driven serving subsystem:
//!
//! - [`spec`] — [`ServeSpec`], the one validated serving plan (policy,
//!   token budget, bounded queue, shed mode, service model), parsed from
//!   the CLI's consolidated `--serve` flag.
//! - [`policy`] — the [`SchedulerPolicy`] seam: FIFO (the default,
//!   bitwise-identical to the pre-policy engine), strict [`policy::Priority`]
//!   with an anti-starvation aging floor, [`policy::FairShare`] per-tenant
//!   deficit round-robin, and [`policy::SloDeadline`] earliest-deadline-first
//!   with deadline-based eviction.
//! - [`admission`] — bounded-queue backpressure with explicit load-shedding:
//!   every request completes or is shed with a named [`ShedReason`]; the
//!   engine errors if the accounting ever fails to balance (no silent
//!   drops).
//! - [`trafficgen`] — deterministic traces: the jittered-gap
//!   [`synthetic_trace`] plus bursty/diurnal/adversarial multi-tenant
//!   heavy-traffic generation ([`TrafficSpec`]).
//!
//! **The serving model.** A [`Request`] is one example (every input tensor
//! has leading dim 1) with a virtual arrival time on a fixed trace. The
//! [`Engine`] plays a trace through a producer thread that delivers
//! requests into a shared queue, while the scheduler thread offers arrived
//! requests to the admission queue and composes the *next* micro-batch by
//! walking the policy's preference order up to a token budget and an
//! optional request cap, stacks the picks along the batch dim, and
//! executes one forward-only `infer` call per micro-batch. Requests that
//! arrive while a batch is in service join the queue and are eligible for
//! the following batch: continuous batching, not fixed-size batching.
//!
//! **Determinism contract** (spelled out in `docs/SERVING.md`): admission
//! runs on a *virtual clock*. A micro-batch's service time is the
//! deterministic model `service_base_us + service_per_token_us · tokens`,
//! and policies see only request metadata and virtual time, so batch
//! composition, completion order, every shed decision and every virtual
//! timestamp are a pure function of `(trace, ServeSpec)` — real thread
//! scheduling affects only *when* a request crosses the queue, never
//! *which batch* it lands in or whether it is shed. Since the batch
//! contents are deterministic and the backend is deterministic, the
//! returned predictions are bitwise-reproducible run to run. Measured wall
//! time appears only in [`BatchStat::wall_ns`] (the throughput numbers
//! benches report), never in scheduling decisions. Note that batching
//! itself changes MoE routing (capacity is computed over the co-batched
//! tokens), exactly as on a real capacity-constrained server — the
//! contract is "same trace ⇒ same outputs", not "outputs independent of
//! co-batched traffic".
//!
//! Continuous batching, end to end:
//!
//! ```
//! use sparse_upcycle::manifest::Manifest;
//! use sparse_upcycle::runtime::Runtime;
//! use sparse_upcycle::serve::{synthetic_trace, tokens_per_request, Engine, ServeSpec};
//!
//! let manifest = Manifest::native();
//! let runtime = Runtime::new().unwrap();
//! let model = runtime.load_model(&manifest, "lm_tiny_dense", &["eval"]).unwrap();
//! let entry = model.entry.clone();
//! let params = sparse_upcycle::runtime::tensors_from_checkpoint(
//!     &sparse_upcycle::init::init_params(&entry, 0).unwrap(),
//!     &entry.params,
//! )
//! .unwrap();
//!
//! // Four requests arriving at once; budget of two requests per micro-batch.
//! let trace = synthetic_trace(&entry, 4, 7, 0);
//! let spec = ServeSpec {
//!     max_batch_tokens: 2 * tokens_per_request(&entry),
//!     ..ServeSpec::default()
//! };
//! let report = Engine::new(&model, &params, spec).unwrap().run_trace(trace).unwrap();
//! assert_eq!(report.completions.len(), 4);
//! assert!(report.sheds.is_empty()); // unbounded queue: nothing sheds
//! assert_eq!(report.batches.len(), 2); // two per micro-batch, FIFO
//! assert!(report.batches.iter().all(|b| b.requests == 2));
//! ```
//!
//! [`mesh_infer`] extends the same forward path across expert-parallel
//! ranks: the batch shards over `ep` rank threads, each holding only its
//! round-robin expert-weight shard (`runtime::ep::EpRankExchange`), token
//! buffers crossing real all-to-all collectives — bitwise-identical to
//! stepping the same shards serially with every expert local. It takes a
//! [`Precision`] and quantizes the weights **once** before the rank
//! fan-out (`checkpoint::quant`), so every rank serves the same quantized
//! snapshot; the engine's quantized path works the same way — the CLI
//! quantizes once at load and hands the engine the quantized vector.

pub mod admission;
pub mod policy;
pub mod spec;
pub mod trafficgen;

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::quant::{quantize_params, Precision};
use crate::coordinator::shard_batch;
use crate::manifest::ModelEntry;
use crate::parallel::collectives::{EpGroup, EP_ABORTED_MSG};
use crate::runtime::ep::{EpPayload, EpRankExchange};
use crate::runtime::{InferOutput, LoadedModel};
use crate::tensor::{Data, Tensor};
use crate::util::bench::{percentile, percentile_interpolated};

pub use admission::{Admission, ShedReason, ShedRecord};
pub use policy::{policy_for, QueuedRequest, SchedulerPolicy};
pub use spec::{PolicyKind, ServeSpec, ShedMode};
pub use trafficgen::{
    generate, synthetic_inputs, synthetic_trace, ArrivalProcess, TenantSpec, TrafficSpec,
};

/// One inference request: a single example (leading dim 1 on every input
/// tensor, manifest inference order — [`ModelEntry::infer_batch`]) plus its
/// virtual arrival time on the trace and serving metadata (tenant,
/// priority class, optional absolute deadline).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Virtual arrival time, microseconds since trace start (nondecreasing
    /// across a trace).
    pub arrival_us: u64,
    /// Traffic class for fairness accounting (default 0).
    pub tenant: u64,
    /// Larger = more urgent; only the `priority` policy reads it.
    pub priority: u8,
    /// Absolute virtual deadline (0 = none; the SLO policy's
    /// `slo_default_us` then applies, if set).
    pub deadline_us: u64,
    pub inputs: Vec<Tensor>,
}

impl Request {
    /// A plain single-tenant request: priority 0, no deadline.
    pub fn new(id: u64, arrival_us: u64, inputs: Vec<Tensor>) -> Request {
        Request { id, arrival_us, tenant: 0, priority: 0, deadline_us: 0, inputs }
    }
}

/// One served request: virtual timeline plus the model output row.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tenant: u64,
    pub arrival_us: u64,
    /// Virtual start of the micro-batch that served this request.
    pub start_us: u64,
    /// Virtual completion time (`start + service`).
    pub finish_us: u64,
    /// Index into [`ServeReport::batches`].
    pub batch_index: usize,
    /// This request's prediction row (leading dim 1).
    pub predictions: Tensor,
    /// Mean log-probability of the predicted ids (serving confidence).
    pub score: f32,
}

impl Completion {
    /// Queueing + service latency on the virtual clock.
    pub fn latency_us(&self) -> u64 {
        self.finish_us - self.arrival_us
    }
}

/// One executed micro-batch.
#[derive(Debug, Clone)]
pub struct BatchStat {
    pub index: usize,
    pub requests: usize,
    pub tokens: usize,
    pub start_us: u64,
    pub finish_us: u64,
    /// Measured wall time of the `infer` call (reporting only — never used
    /// for scheduling).
    pub wall_ns: f64,
}

/// Everything one trace run produced: per-request completions (service
/// order — trace order under the FIFO default), per-micro-batch stats,
/// and every shed decision. `completions.len() + sheds.len()` always
/// equals the trace length — [`Engine::run_trace`] errors otherwise.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub batches: Vec<BatchStat>,
    pub sheds: Vec<ShedRecord>,
}

impl ServeReport {
    pub fn latencies_us(&self) -> Vec<f64> {
        self.completions.iter().map(|c| c.latency_us() as f64).collect()
    }

    /// Nearest-rank p50 latency; 0.0 on an empty trace.
    pub fn p50_latency_us(&self) -> f64 {
        percentile(&self.latencies_us(), 50.0)
    }

    /// Nearest-rank p99 latency; 0.0 on an empty trace.
    pub fn p99_latency_us(&self) -> f64 {
        percentile(&self.latencies_us(), 99.0)
    }

    /// Interpolated p999 tail latency
    /// ([`crate::util::bench::percentile_interpolated`]): guarded for small
    /// traces — 0.0 when empty, the single sample when there is only one,
    /// and a linear interpolation between the two highest order statistics
    /// instead of nearest-rank's collapse onto the max.
    pub fn p999_latency_us(&self) -> f64 {
        percentile_interpolated(&self.latencies_us(), 99.9)
    }

    /// Shed requests as a fraction of the whole trace (0.0 when empty).
    pub fn shed_rate(&self) -> f64 {
        let total = self.completions.len() + self.sheds.len();
        if total == 0 {
            0.0
        } else {
            self.sheds.len() as f64 / total as f64
        }
    }

    /// Shed counts grouped by reason name, name-sorted.
    pub fn sheds_by_reason(&self) -> Vec<(&'static str, usize)> {
        let mut by: BTreeMap<&'static str, usize> = BTreeMap::new();
        for s in &self.sheds {
            *by.entry(s.reason.name()).or_insert(0) += 1;
        }
        by.into_iter().collect()
    }

    /// Per-tenant `(tenant, completed, shed)` counts, tenant-sorted — the
    /// goodput ledger the fairness bench reports.
    pub fn tenant_counts(&self) -> Vec<(u64, usize, usize)> {
        let mut by: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
        for c in &self.completions {
            by.entry(c.tenant).or_insert((0, 0)).0 += 1;
        }
        for s in &self.sheds {
            by.entry(s.tenant).or_insert((0, 0)).1 += 1;
        }
        by.into_iter().map(|(t, (done, shed))| (t, done, shed)).collect()
    }

    /// Tokens executed across all micro-batches.
    pub fn total_tokens(&self) -> usize {
        self.batches.iter().map(|b| b.tokens).sum()
    }

    /// Total measured execution wall time across micro-batches.
    pub fn exec_wall_ns(&self) -> f64 {
        self.batches.iter().map(|b| b.wall_ns).sum()
    }

    /// Measured execution throughput: tokens per second of `infer` wall
    /// time (the batched-vs-unbatched comparison number); 0.0 on an empty
    /// trace.
    pub fn tokens_per_s(&self) -> f64 {
        let wall = self.exec_wall_ns();
        if wall > 0.0 {
            self.total_tokens() as f64 * 1e9 / wall
        } else {
            0.0
        }
    }
}

/// Token cost of one request against the batch budget: the tokens one
/// example pushes through the towers (`enc_len + dec_len` for LM entries,
/// the patch count for vision).
pub fn tokens_per_request(entry: &ModelEntry) -> usize {
    let c = &entry.config;
    if entry.family == "lm" {
        c.enc_len + c.dec_len
    } else {
        (c.image_size / c.patch_size.max(1)).pow(2)
    }
}

/// Stack per-request inputs (leading dim 1 each) into one batch along the
/// leading dim, position by position, validating shape agreement.
pub fn stack_inputs(reqs: &[Request]) -> Result<Vec<Tensor>> {
    let first = reqs.first().context("cannot stack an empty micro-batch")?;
    let mut out = Vec::with_capacity(first.inputs.len());
    for i in 0..first.inputs.len() {
        let proto = &first.inputs[i];
        if proto.shape.first() != Some(&1) {
            bail!("request {} input {i} must have leading dim 1, got {:?}", first.id, proto.shape);
        }
        let mut shape = proto.shape.clone();
        shape[0] = reqs.len();
        let check = |r: &Request| -> Result<()> {
            let t = r
                .inputs
                .get(i)
                .with_context(|| format!("request {} is missing input {i}", r.id))?;
            if t.shape != proto.shape || t.dtype() != proto.dtype() {
                bail!(
                    "request {} input {i} is {:?} {:?}, batch peer has {:?} {:?}",
                    r.id,
                    t.dtype(),
                    t.shape,
                    proto.dtype(),
                    proto.shape
                );
            }
            Ok(())
        };
        match &proto.data {
            Data::I32(_) => {
                let mut data = Vec::with_capacity(reqs.len() * proto.numel());
                for r in reqs {
                    check(r)?;
                    data.extend_from_slice(r.inputs[i].i32s()?);
                }
                out.push(Tensor::from_i32(&shape, data));
            }
            Data::F32(_) => {
                let mut data = Vec::with_capacity(reqs.len() * proto.numel());
                for r in reqs {
                    check(r)?;
                    data.extend_from_slice(r.inputs[i].f32s()?);
                }
                out.push(Tensor::from_f32(&shape, data));
            }
        }
    }
    Ok(out)
}

/// Row `row` of a batched prediction tensor, as a leading-dim-1 tensor.
fn prediction_row(t: &Tensor, row: usize) -> Result<Tensor> {
    let b = *t.shape.first().context("prediction tensor has no batch dim")?;
    if row >= b {
        bail!("prediction row {row} out of range {b}");
    }
    let per = t.numel() / b;
    let mut shape = t.shape.clone();
    shape[0] = 1;
    Ok(Tensor::from_i32(&shape, t.i32s()?[row * per..(row + 1) * per].to_vec()))
}

/// The inference engine: owns the validated serving plan, borrows the
/// loaded model and its (trained) parameters. See the module docs for
/// semantics.
pub struct Engine<'m> {
    model: &'m LoadedModel,
    params: &'m [Tensor],
    spec: ServeSpec,
    /// Token budget resolved against the model entry
    /// ([`ServeSpec::resolved_batch_tokens`]).
    budget: usize,
}

impl<'m> Engine<'m> {
    pub fn new(
        model: &'m LoadedModel,
        params: &'m [Tensor],
        spec: ServeSpec,
    ) -> Result<Engine<'m>> {
        spec.validate(&model.entry)?;
        let budget = spec.resolved_batch_tokens(&model.entry);
        Ok(Engine { model, params, spec, budget })
    }

    /// Play `trace` through the engine: a producer thread delivers requests
    /// in arrival order while this thread offers them to admission and
    /// schedules micro-batches under the plan's policy. Every request ends
    /// up in exactly one completion or one shed record (an error
    /// otherwise — shedding is never silent). An empty trace returns an
    /// empty report.
    pub fn run_trace(&self, trace: Vec<Request>) -> Result<ServeReport> {
        if trace.windows(2).any(|w| w[0].arrival_us > w[1].arrival_us) {
            bail!("trace arrivals must be nondecreasing");
        }
        let arrivals: Vec<u64> = trace.iter().map(|r| r.arrival_us).collect();
        let n = arrivals.len();
        if n == 0 {
            return Ok(ServeReport {
                completions: Vec::new(),
                batches: Vec::new(),
                sheds: Vec::new(),
            });
        }
        let tpr = tokens_per_request(&self.model.entry).max(1);
        let mut policy = policy_for(&self.spec);
        let queue: Mutex<VecDeque<Request>> = Mutex::new(VecDeque::new());
        let delivered = Condvar::new();

        std::thread::scope(|scope| -> Result<ServeReport> {
            // Producer: deliver requests in trace order. It never blocks,
            // so a scheduler-side error can never deadlock the scope.
            scope.spawn(|| {
                for req in trace {
                    queue.lock().expect("serve queue").push_back(req);
                    delivered.notify_all();
                }
            });

            // Scheduler: virtual clock + policy-ordered continuous
            // admission. `inbox` buffers requests pulled off the shared
            // queue ahead of their virtual arrival; `admission` holds only
            // requests that have virtually arrived, so policies never see
            // the future.
            let mut admission = Admission::new(&self.spec);
            let mut inbox: VecDeque<Request> = VecDeque::new();
            let mut taken = 0usize; // pulled off the shared queue
            let mut offered = 0usize; // handed to the admission queue
            let mut v_now = 0u64;
            let mut completions = Vec::with_capacity(n);
            let mut batches = Vec::new();
            while completions.len() + admission.shed_count() < n {
                // Idle: jump the virtual clock to the next arrival.
                if admission.is_empty() {
                    debug_assert!(offered < n, "empty queue with the whole trace accounted");
                    if arrivals[offered] > v_now {
                        v_now = arrivals[offered];
                    }
                }
                // Everything that has virtually arrived must be in hand
                // before composing the batch (determinism: composition
                // depends on the trace, not on thread timing).
                let due = arrivals.partition_point(|&a| a <= v_now);
                while taken < due {
                    let mut q = queue.lock().expect("serve queue");
                    while q.is_empty() {
                        q = delivered.wait(q).expect("serve queue");
                    }
                    while let Some(r) = q.pop_front() {
                        inbox.push_back(r);
                        taken += 1;
                    }
                }
                while offered < due {
                    let req = inbox.pop_front().expect("offered <= taken");
                    admission.offer(req, policy.as_ref(), v_now, tpr);
                    offered += 1;
                }
                // Deadline-based eviction at this instant (a no-op for
                // every policy but SLO).
                admission.evict_expired(policy.as_ref(), v_now);
                if admission.is_empty() {
                    continue; // everything due was shed; jump to the next arrival
                }
                // Compose the batch: walk the policy's preference order up
                // to the token budget / request cap. The first pick always
                // fits: an oversized request runs as a batch of one rather
                // than starving.
                let order = policy.order(admission.meta(), v_now);
                let mut picked: Vec<usize> = Vec::new();
                let mut tokens = 0usize;
                for &i in &order {
                    let full = tokens + tpr > self.budget
                        || (self.spec.max_batch_requests > 0
                            && picked.len() >= self.spec.max_batch_requests);
                    if !picked.is_empty() && full {
                        break;
                    }
                    picked.push(i);
                    tokens += tpr;
                }
                debug_assert!(!picked.is_empty());
                let (batch_reqs, batch_meta) = admission.take(&picked);

                let inputs = stack_inputs(&batch_reqs)?;
                let t0 = Instant::now();
                let out = self.model.infer(self.params, &inputs)?;
                let wall_ns = t0.elapsed().as_nanos() as f64;
                let service =
                    self.spec.service_base_us + self.spec.service_per_token_us * tokens as u64;
                let (start, finish) = (v_now, v_now + service);
                v_now = finish;
                let index = batches.len();
                for (row, req) in batch_reqs.iter().enumerate() {
                    completions.push(Completion {
                        id: req.id,
                        tenant: req.tenant,
                        arrival_us: req.arrival_us,
                        start_us: start,
                        finish_us: finish,
                        batch_index: index,
                        predictions: prediction_row(&out.predictions, row)?,
                        score: out.scores[row],
                    });
                }
                policy.on_served(&batch_meta);
                batches.push(BatchStat {
                    index,
                    requests: batch_reqs.len(),
                    tokens,
                    start_us: start,
                    finish_us: finish,
                    wall_ns,
                });
            }
            let sheds = admission.into_sheds();
            if completions.len() + sheds.len() != n {
                bail!(
                    "serve accounting violated: {} completion(s) + {} shed(s) != {n} request(s)",
                    completions.len(),
                    sheds.len()
                );
            }
            Ok(ServeReport { completions, batches, sheds })
        })
    }
}

/// EP-sharded inference on one batch, consuming the same
/// [`crate::parallel::MeshSpec`] plan as the trainers: shard `inputs` into
/// `ep` contiguous example shards (one expert-parallel rank thread each,
/// like a `1xE` mesh), run each shard's forward with the expert weights
/// sharded round-robin over the group ([`EpRankExchange`]) and token
/// buffers moving through real all-to-all collectives (split-phase, with
/// `microbatches` overlapping pipeline slots per exchange), then
/// concatenate the per-rank outputs in rank order.
///
/// One `mesh_infer` call serves one batch, so the plan's `dp` axis must be
/// 1 — data parallelism in serving is running concurrent engine replicas,
/// not splitting a single call.
///
/// Determinism: bitwise-identical to running the same shards serially with
/// every expert local, for every microbatch count (each rank's rows see
/// exactly the arithmetic the local path performs — forward is
/// row-independent and nothing about an expert's computation depends on
/// *where* or in *which pipeline slot* it runs). Asserted by this module's
/// tests.
pub fn mesh_infer(
    model: &LoadedModel,
    params: &[Tensor],
    inputs: &[Tensor],
    topo: &crate::parallel::MeshSpec,
    microbatches: usize,
    precision: Precision,
) -> Result<InferOutput> {
    topo.validate(&model.entry, crate::parallel::MeshMode::Sim)?;
    if topo.data_parallel.max(1) != 1 {
        bail!(
            "mesh_infer serves one batch on a 1xE plan; got dp={} — run concurrent engine \
             replicas for data parallelism",
            topo.data_parallel
        );
    }
    // Quantize once, before the rank fan-out: every rank shard binds the
    // same quantized weight snapshot, so EP-sharded quantized serving is
    // bitwise-identical to the serial quantized path.
    let quantized;
    let params: &[Tensor] = if precision == Precision::F32 {
        params
    } else {
        quantized = quantize_params(&model.entry, params, precision)?;
        &quantized
    };
    let ep = topo.expert_parallel.max(1);
    let microbatches = microbatches.max(1);
    if ep == 1 {
        return model.infer(params, inputs);
    }
    let shards = shard_batch(inputs, ep)?;
    let group: Arc<EpGroup<EpPayload>> = Arc::new(EpGroup::new(ep));
    let results: Vec<Result<InferOutput>> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ep);
        for (rank, shard) in shards.iter().enumerate() {
            let group = group.clone();
            handles.push(s.spawn(move || {
                let body = || -> Result<InferOutput> {
                    crate::util::serial_compute(|| {
                        let mut exch =
                            EpRankExchange::new(&model.entry, params, rank, group.clone())?
                                .with_microbatches(microbatches);
                        model.infer_ep(params, shard, &mut exch)
                    })
                };
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
                match out {
                    Ok(res) => {
                        if res.is_err() {
                            group.abort();
                        }
                        res
                    }
                    Err(_) => {
                        group.abort();
                        Err(anyhow!("inference rank panicked"))
                    }
                }
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("inference rank thread died"))))
            .collect()
    });
    // Prefer the root cause over "collective aborted" echoes from peers.
    let mut outs = Vec::with_capacity(ep);
    let mut root_cause: Option<anyhow::Error> = None;
    let mut first_abort: Option<anyhow::Error> = None;
    for (r, res) in results.into_iter().enumerate() {
        match res {
            Ok(v) => outs.push(v),
            Err(e) => {
                let e = e.context(format!("inference rank {r} of {ep}"));
                if format!("{e:#}").contains(EP_ABORTED_MSG) {
                    if first_abort.is_none() {
                        first_abort = Some(e);
                    }
                } else if root_cause.is_none() {
                    root_cause = Some(e);
                }
            }
        }
    }
    if let Some(e) = root_cause.or(first_abort) {
        return Err(e);
    }
    let mut shape = outs[0].predictions.shape.clone();
    shape[0] = outs.iter().map(|o| o.predictions.shape[0]).sum();
    let mut data = Vec::new();
    let mut scores = Vec::new();
    for o in &outs {
        data.extend_from_slice(o.predictions.i32s()?);
        scores.extend_from_slice(&o.scores);
    }
    Ok(InferOutput { predictions: Tensor::from_i32(&shape, data), scores })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::init_params;
    use crate::manifest::Manifest;
    use crate::runtime::{tensors_from_checkpoint, Runtime};
    use crate::util::rng::Rng;

    fn setup(name: &str) -> (ModelEntry, LoadedModel, Vec<Tensor>) {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
        let params =
            tensors_from_checkpoint(&init_params(&entry, 5).unwrap(), &entry.params).unwrap();
        (entry, model, params)
    }

    /// An empty trace terminates immediately with an empty report — the
    /// scheduler must not block waiting for arrivals that never come — and
    /// every summary statistic is 0.0 instead of a panic or NaN.
    #[test]
    fn empty_trace_completes_empty() {
        let (_entry, model, params) = setup("lm_tiny_dense");
        let engine = Engine::new(&model, &params, ServeSpec::default()).unwrap();
        let report = engine.run_trace(Vec::new()).unwrap();
        assert!(report.completions.is_empty());
        assert!(report.batches.is_empty());
        assert!(report.sheds.is_empty());
        assert_eq!(report.tokens_per_s(), 0.0);
        assert_eq!(report.p50_latency_us(), 0.0);
        assert_eq!(report.p99_latency_us(), 0.0);
        assert_eq!(report.p999_latency_us(), 0.0);
        assert_eq!(report.shed_rate(), 0.0);
    }

    /// A request costing more than the whole token budget still runs —
    /// alone — instead of starving the queue behind it.
    #[test]
    fn oversized_request_is_admitted_alone() {
        let (entry, model, params) = setup("lm_tiny_dense");
        let spec = ServeSpec { max_batch_tokens: 1, ..ServeSpec::default() };
        assert!(tokens_per_request(&entry) > 1);
        let engine = Engine::new(&model, &params, spec).unwrap();
        let report = engine.run_trace(synthetic_trace(&entry, 3, 1, 0)).unwrap();
        assert_eq!(report.completions.len(), 3);
        assert_eq!(report.batches.len(), 3, "every oversized request runs as a batch of one");
        assert!(report.batches.iter().all(|b| b.requests == 1));
    }

    /// Saturation: a burst far deeper than the budget drains FIFO in
    /// budget-sized micro-batches, and queueing delay accumulates.
    #[test]
    fn saturated_queue_drains_fifo_within_budget() {
        let (entry, model, params) = setup("lm_tiny_dense");
        let tpr = tokens_per_request(&entry);
        let spec = ServeSpec { max_batch_tokens: 2 * tpr, ..ServeSpec::default() };
        let engine = Engine::new(&model, &params, spec).unwrap();
        let report = engine.run_trace(synthetic_trace(&entry, 9, 2, 0)).unwrap();
        assert_eq!(report.completions.len(), 9);
        assert_eq!(report.batches.len(), 5, "9 requests / budget 2 = 5 micro-batches");
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..9).collect::<Vec<_>>(), "FIFO admission");
        // Later arrivals wait longer: latency is nondecreasing in a burst.
        let lat: Vec<u64> = report.completions.iter().map(|c| c.latency_us()).collect();
        assert!(lat.windows(2).all(|w| w[0] <= w[1]), "{lat:?}");
        assert!(report.p99_latency_us() >= report.p50_latency_us());
        assert!(report.p999_latency_us() >= report.p99_latency_us() - 1e-9);
    }

    /// Requests arriving while a batch is in service join the *next*
    /// micro-batch (continuous batching), and composition follows the
    /// virtual clock exactly.
    #[test]
    fn late_arrivals_join_the_next_batch() {
        let (entry, model, params) = setup("lm_tiny_dense");
        let mut trace = synthetic_trace(&entry, 3, 3, 0);
        trace[1].arrival_us = 10;
        trace[2].arrival_us = 20;
        let spec = ServeSpec {
            max_batch_tokens: 100 * tokens_per_request(&entry),
            service_base_us: 100,
            service_per_token_us: 0,
            ..ServeSpec::default()
        };
        let engine = Engine::new(&model, &params, spec).unwrap();
        let report = engine.run_trace(trace).unwrap();
        // t=0: only request 0 has arrived → batch [0], finishes at 100.
        // t=100: requests 1 and 2 arrived during service → batch [1, 2].
        assert_eq!(report.batches.len(), 2);
        assert_eq!(report.batches[0].requests, 1);
        assert_eq!(report.batches[1].requests, 2);
        assert_eq!(report.batches[1].start_us, 100);
        let by_batch: Vec<usize> = report.completions.iter().map(|c| c.batch_index).collect();
        assert_eq!(by_batch, vec![0, 1, 1]);
    }

    /// The whole run is deterministic given the trace: identical batch
    /// composition, virtual timestamps, and bitwise-identical predictions.
    #[test]
    fn run_is_deterministic_given_the_trace() {
        let (entry, model, params) = setup("lm_tiny_moe_e8_c2");
        let tpr = tokens_per_request(&entry);
        let spec = ServeSpec { max_batch_tokens: 4 * tpr, ..ServeSpec::default() };
        let engine = Engine::new(&model, &params, spec).unwrap();
        let a = engine.run_trace(synthetic_trace(&entry, 8, 11, 500)).unwrap();
        let b = engine.run_trace(synthetic_trace(&entry, 8, 11, 500)).unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            let xa = (x.id, x.start_us, x.finish_us, x.batch_index);
            let ya = (y.id, y.start_us, y.finish_us, y.batch_index);
            assert_eq!(xa, ya, "virtual timeline must be deterministic");
            assert_eq!(x.predictions, y.predictions, "request {} output must be bitwise", x.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
        // Out-of-order traces are rejected loudly.
        let mut bad = synthetic_trace(&entry, 2, 1, 100);
        bad[0].arrival_us = bad[1].arrival_us + 1;
        assert!(engine.run_trace(bad).is_err());
    }

    /// Scheduler property test: for *random* arrival traces and serve
    /// specs (not hand-picked edge cases), the admission invariants hold
    /// on every run —
    ///
    /// 1. FIFO admission order is preserved (completions in arrival order,
    ///    batch indices nondecreasing);
    /// 2. the token budget is never exceeded, except by a single oversized
    ///    request admitted alone (and the request cap always holds);
    /// 3. every request appears in exactly one micro-batch;
    /// 4. micro-batches never overlap on the virtual clock and never start
    ///    before their members arrived;
    /// 5. a rerun of the same trace is bitwise-deterministic.
    #[test]
    fn scheduler_invariants_hold_on_random_traces() {
        let (entry, model, params) = setup("lm_tiny_dense");
        let tpr = tokens_per_request(&entry);
        let mut rng = Rng::new(0xb00b1e5);
        for case in 0..12u64 {
            let n = 1 + rng.below(9);
            let gap = [0u64, 40, 400, 2500][rng.below(4)];
            let budget_requests = 1 + rng.below(5);
            let spec = ServeSpec {
                max_batch_tokens: budget_requests * tpr,
                max_batch_requests: if rng.below(3) == 0 { 1 + rng.below(4) } else { 0 },
                ..ServeSpec::default()
            };
            let trace = synthetic_trace(&entry, n, 1000 + case, gap);
            let engine = Engine::new(&model, &params, spec).unwrap();
            let a = engine.run_trace(trace.clone()).unwrap();

            // (3) exactly-once: n completions, ids unique, batch sizes sum
            // to n and every completion points into a real batch.
            assert_eq!(a.completions.len(), n, "case {case}");
            assert!(a.sheds.is_empty(), "case {case}: unbounded queue never sheds");
            let ids: Vec<u64> = a.completions.iter().map(|c| c.id).collect();
            assert_eq!(
                ids,
                (0..n as u64).collect::<Vec<_>>(),
                "case {case}: FIFO admission must preserve arrival order"
            );
            assert_eq!(a.batches.iter().map(|b| b.requests).sum::<usize>(), n, "case {case}");

            // (1) batch indices follow admission order.
            let order: Vec<usize> = a.completions.iter().map(|c| c.batch_index).collect();
            assert!(order.windows(2).all(|w| w[0] <= w[1]), "case {case}: {order:?}");

            // (2) budgets.
            for b in &a.batches {
                assert_eq!(b.tokens, b.requests * tpr, "case {case}");
                assert!(
                    b.tokens <= spec.max_batch_tokens || b.requests == 1,
                    "case {case}: batch {} blew the token budget with {} requests",
                    b.index,
                    b.requests
                );
                if spec.max_batch_requests > 0 {
                    assert!(b.requests <= spec.max_batch_requests, "case {case}");
                }
            }

            // (4) virtual-clock sanity.
            for w in a.batches.windows(2) {
                assert!(w[0].finish_us <= w[1].start_us, "case {case}: batches overlap");
            }
            for c in &a.completions {
                assert!(c.start_us >= c.arrival_us, "case {case}: served before arrival");
                let b = &a.batches[c.batch_index];
                assert_eq!((c.start_us, c.finish_us), (b.start_us, b.finish_us), "case {case}");
            }

            // (5) bitwise-deterministic rerun.
            let b2 = engine.run_trace(trace).unwrap();
            assert_eq!(a.batches.len(), b2.batches.len(), "case {case}");
            for (x, y) in a.completions.iter().zip(&b2.completions) {
                assert_eq!(
                    (x.id, x.start_us, x.finish_us, x.batch_index),
                    (y.id, y.start_us, y.finish_us, y.batch_index),
                    "case {case}: virtual timeline must be reproducible"
                );
                assert_eq!(x.predictions, y.predictions, "case {case}");
                assert_eq!(x.score.to_bits(), y.score.to_bits(), "case {case}");
            }
        }
    }

    /// EP-sharded inference (2 rank threads, sharded expert weights, real
    /// all-to-all) is bitwise-identical to the same shards run serially
    /// with all experts local — the serving side of the mesh contract —
    /// for every microbatch count of the overlapped pipeline.
    #[test]
    fn mesh_infer_matches_serial_shards_bitwise() {
        let (entry, model, params) = setup("lm_tiny_moe_e8_c2");
        let trace = synthetic_trace(&entry, 4, 13, 0);
        let inputs = stack_inputs(&trace).unwrap();
        let shards = shard_batch(&inputs, 2).unwrap();
        let mut preds = Vec::new();
        let mut scores = Vec::new();
        for shard in &shards {
            let o = model.infer(&params, shard).unwrap();
            preds.extend_from_slice(o.predictions.i32s().unwrap());
            scores.extend_from_slice(&o.scores);
        }
        let topo = crate::parallel::MeshSpec::new(1, 2);
        for m in [1usize, 2, 4] {
            let ep_out = mesh_infer(&model, &params, &inputs, &topo, m, Precision::F32).unwrap();
            assert_eq!(ep_out.predictions.i32s().unwrap(), &preds[..], "microbatches {m}");
            assert_eq!(ep_out.scores, scores, "microbatches {m}");
            assert_eq!(ep_out.predictions.shape[0], 4);
        }

        // The unified plan is validated: a dp axis on a single serve call
        // is rejected up front.
        let err = mesh_infer(
            &model,
            &params,
            &inputs,
            &crate::parallel::MeshSpec::new(2, 2),
            1,
            Precision::F32,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("dp=2"), "{err:#}");
    }

    /// Quantized EP-sharded serving keeps the mesh contract: for each
    /// non-f32 precision, `mesh_infer` over 2 ranks is bitwise-identical
    /// to running the same shards serially on the once-quantized weights.
    #[test]
    fn quantized_mesh_infer_matches_serial_quantized_shards() {
        let (entry, model, params) = setup("lm_tiny_moe_e8_c2");
        let trace = synthetic_trace(&entry, 4, 19, 0);
        let inputs = stack_inputs(&trace).unwrap();
        let topo = crate::parallel::MeshSpec::new(1, 2);
        for precision in [Precision::Bf16, Precision::Int8PerChannel] {
            let q = crate::checkpoint::quant::quantize_params(&entry, &params, precision).unwrap();
            let mut preds = Vec::new();
            let mut scores = Vec::new();
            for shard in &shard_batch(&inputs, 2).unwrap() {
                let o = model.infer(&q, shard).unwrap();
                preds.extend_from_slice(o.predictions.i32s().unwrap());
                scores.extend_from_slice(&o.scores);
            }
            let ep_out = mesh_infer(&model, &params, &inputs, &topo, 2, precision).unwrap();
            assert_eq!(
                ep_out.predictions.i32s().unwrap(),
                &preds[..],
                "{} mesh predictions must match serial quantized shards",
                precision.as_str()
            );
            assert_eq!(ep_out.scores, scores, "{}", precision.as_str());
        }
    }
}
