//! Deterministic traffic generation: the simple jittered-gap
//! [`synthetic_trace`] the CLI defaults to, plus the heavy-traffic
//! generator ([`TrafficSpec`] / [`generate`]) — bursty, diurnal and
//! adversarial arrival processes over multiple tenants with per-tenant
//! weights, priority classes and relative deadlines. Every trace is a pure
//! function of `(entry, spec)`: request payloads come from the model's
//! seeded synthetic data pipeline and arrivals from a dedicated RNG
//! stream, so the serving benches and property tests replay identical
//! traffic on every run.

use anyhow::{bail, Result};

use crate::manifest::ModelEntry;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::Request;

/// Deterministic per-request input tensors (leading dim 1 each, manifest
/// inference order) for `n` requests, drawn from the model's synthetic
/// data pipeline seeded with `seed`.
pub fn synthetic_inputs(entry: &ModelEntry, n: usize, seed: u64) -> Vec<Vec<Tensor>> {
    let k = entry.infer_batch().len();
    let mut out = Vec::with_capacity(n);
    if entry.family == "lm" {
        let mut pipe = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                seed,
            ),
            1,
            entry.config.enc_len,
            entry.config.dec_len,
            seed,
            0,
        );
        for _ in 0..n {
            out.push(pipe.next_batch().into_iter().take(k).collect());
        }
    } else {
        let spec = crate::data::vision::VisionSpec {
            image_size: entry.config.image_size,
            ..Default::default()
        };
        let mut pipe = crate::data::vision::VisionPipeline::new(spec, 1, seed, 0);
        for _ in 0..n {
            out.push(pipe.next_batch().0.into_iter().take(k).collect());
        }
    }
    out
}

/// A deterministic synthetic arrival trace: `n` single-example requests
/// drawn from the model's synthetic data pipeline (seeded), arriving
/// `gap_us` apart on average with deterministic ±50% jitter (`gap_us = 0`
/// is a burst: everything arrives at t = 0). Single-tenant, priority 0, no
/// deadlines — the multi-tenant shapes live in [`generate`].
pub fn synthetic_trace(entry: &ModelEntry, n: usize, seed: u64, gap_us: u64) -> Vec<Request> {
    let mut rng = Rng::with_stream(seed, 0x5e7e);
    let mut arrival = 0u64;
    let mut out = Vec::with_capacity(n);
    for (id, inputs) in synthetic_inputs(entry, n, seed).into_iter().enumerate() {
        out.push(Request::new(id as u64, arrival, inputs));
        if gap_us > 0 {
            arrival += gap_us / 2 + rng.below(gap_us as usize + 1) as u64;
        }
    }
    out
}

/// How virtual inter-arrival gaps evolve along a generated trace. All
/// nonzero gaps get the same deterministic ±50% jitter as
/// [`synthetic_trace`].
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Constant mean gap (the `synthetic_trace` shape).
    Uniform { gap_us: u64 },
    /// `burst` back-to-back arrivals, then one quiet gap sized so the
    /// long-run mean stays `mean_gap_us` per request.
    Bursty { mean_gap_us: u64, burst: usize },
    /// Triangle-wave load swing: the mean gap sweeps from `max_gap_us`
    /// (trough traffic) down to `min_gap_us` (peak traffic) and back over
    /// `period` requests.
    Diurnal { min_gap_us: u64, max_gap_us: u64, period: usize },
    /// Background trickle at `gap_us`, punctuated every `flood_every`
    /// requests by a flood of `flood` simultaneous arrivals — all from
    /// tenant 0, the noisy neighbor fairness policies must contain.
    Adversarial { gap_us: u64, flood_every: usize, flood: usize },
}

impl ArrivalProcess {
    /// The named CLI shapes (`--traffic uniform|bursty|diurnal|adversarial`),
    /// scaled off one mean gap.
    pub fn from_name(name: &str, gap_us: u64) -> Result<ArrivalProcess> {
        match name {
            "uniform" => Ok(ArrivalProcess::Uniform { gap_us }),
            "bursty" => Ok(ArrivalProcess::Bursty { mean_gap_us: gap_us, burst: 8 }),
            "diurnal" => Ok(ArrivalProcess::Diurnal {
                min_gap_us: gap_us / 4,
                max_gap_us: gap_us * 2,
                period: 16,
            }),
            "adversarial" => {
                Ok(ArrivalProcess::Adversarial { gap_us, flood_every: 8, flood: 4 })
            }
            other => bail!(
                "unknown traffic shape `{other}` (expected uniform|bursty|diurnal|adversarial)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Uniform { .. } => "uniform",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::Adversarial { .. } => "adversarial",
        }
    }

    /// The gap preceding request `id` (request 0 always arrives at t = 0).
    fn gap(&self, id: usize, rng: &mut Rng) -> u64 {
        let jitter = |g: u64, rng: &mut Rng| {
            if g == 0 {
                0
            } else {
                g / 2 + rng.below(g as usize + 1) as u64
            }
        };
        match *self {
            ArrivalProcess::Uniform { gap_us } => jitter(gap_us, rng),
            ArrivalProcess::Bursty { mean_gap_us, burst } => {
                if id % burst.max(1) == 0 {
                    jitter(mean_gap_us * burst.max(1) as u64, rng)
                } else {
                    0
                }
            }
            ArrivalProcess::Diurnal { min_gap_us, max_gap_us, period } => {
                let period = period.max(1);
                let t = (id % period) as f64 / period as f64;
                let wave = (2.0 * t - 1.0).abs(); // 1 at the edges, 0 mid-period
                let span = max_gap_us.saturating_sub(min_gap_us);
                jitter(min_gap_us + (span as f64 * wave) as u64, rng)
            }
            ArrivalProcess::Adversarial { gap_us, flood_every, flood } => {
                let phase = id % flood_every.max(1);
                if phase != 0 && phase < flood {
                    0
                } else {
                    jitter(gap_us, rng)
                }
            }
        }
    }

    /// Whether request `id` belongs to an adversarial flood (forced onto
    /// tenant 0).
    fn flood_member(&self, id: usize) -> bool {
        match *self {
            ArrivalProcess::Adversarial { flood_every, flood, .. } => {
                id % flood_every.max(1) < flood
            }
            _ => false,
        }
    }
}

/// One traffic class: arrival weight, priority and (relative) SLO of a
/// tenant's requests.
#[derive(Debug, Clone, Copy)]
pub struct TenantSpec {
    pub tenant: u64,
    /// Relative arrival rate (categorical weight across tenants).
    pub weight: f32,
    pub priority: u8,
    /// Relative deadline stamped on this tenant's requests (0 = none; the
    /// SLO policy's `slo_default_us` then applies, if set).
    pub deadline_us: u64,
}

/// A complete heavy-traffic scenario: arrival process, tenant mix, trace
/// length and seed. [`generate`] turns it into a concrete trace.
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    pub process: ArrivalProcess,
    pub tenants: Vec<TenantSpec>,
    pub requests: usize,
    pub seed: u64,
}

impl TrafficSpec {
    /// An equal-weight tenant mix with rotating priority classes
    /// (`tenant % 3`) and no per-tenant deadlines — the shape the serving
    /// bench and smoke drive.
    pub fn standard(
        process: ArrivalProcess,
        tenants: usize,
        requests: usize,
        seed: u64,
    ) -> TrafficSpec {
        let tenants = (0..tenants.max(1) as u64)
            .map(|t| TenantSpec {
                tenant: t,
                weight: 1.0,
                priority: (t % 3) as u8,
                deadline_us: 0,
            })
            .collect();
        TrafficSpec { process, tenants, requests, seed }
    }
}

/// Generate the deterministic multi-tenant trace a [`TrafficSpec`]
/// describes: arrivals follow the process, each request is assigned a
/// tenant by categorical draw over the tenant weights (floods force tenant
/// 0), and priority / absolute deadline come from the tenant spec.
/// Arrivals are nondecreasing by construction.
pub fn generate(entry: &ModelEntry, spec: &TrafficSpec) -> Result<Vec<Request>> {
    if spec.tenants.is_empty() {
        bail!("traffic spec needs at least one tenant");
    }
    let weights: Vec<f32> = spec.tenants.iter().map(|t| t.weight).collect();
    if weights.iter().any(|w| !w.is_finite() || *w < 0.0) || weights.iter().sum::<f32>() <= 0.0 {
        bail!("tenant weights must be nonnegative with a positive sum");
    }
    let mut rng = Rng::with_stream(spec.seed, 0x7af1c);
    let mut arrival = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for (id, inputs) in synthetic_inputs(entry, spec.requests, spec.seed).into_iter().enumerate() {
        if id > 0 {
            arrival += spec.process.gap(id, &mut rng);
        }
        let slot =
            if spec.process.flood_member(id) { 0 } else { rng.categorical(&weights) };
        let tenant = &spec.tenants[slot];
        let mut req = Request::new(id as u64, arrival, inputs);
        req.tenant = tenant.tenant;
        req.priority = tenant.priority;
        if tenant.deadline_us > 0 {
            req.deadline_us = arrival + tenant.deadline_us;
        }
        out.push(req);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    fn entry() -> ModelEntry {
        Manifest::native().model("lm_tiny_dense").unwrap().clone()
    }

    fn key(r: &Request) -> (u64, u64, u64, u8, u64) {
        (r.id, r.arrival_us, r.tenant, r.priority, r.deadline_us)
    }

    #[test]
    fn generate_is_deterministic_and_nondecreasing() {
        let e = entry();
        for name in ["uniform", "bursty", "diurnal", "adversarial"] {
            let spec =
                TrafficSpec::standard(ArrivalProcess::from_name(name, 300).unwrap(), 4, 24, 9);
            let a = generate(&e, &spec).unwrap();
            let b = generate(&e, &spec).unwrap();
            assert_eq!(a.len(), 24, "{name}");
            assert!(
                a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
                "{name}: arrivals must be nondecreasing"
            );
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(key(x), key(y), "{name}: trace must be a pure function of the spec");
                assert_eq!(x.inputs, y.inputs, "{name}");
            }
        }
    }

    #[test]
    fn bursty_arrivals_come_in_back_to_back_groups() {
        let e = entry();
        let spec = TrafficSpec::standard(
            ArrivalProcess::Bursty { mean_gap_us: 200, burst: 4 },
            2,
            16,
            3,
        );
        let trace = generate(&e, &spec).unwrap();
        for group in trace.chunks(4) {
            assert!(
                group.iter().all(|r| r.arrival_us == group[0].arrival_us),
                "burst members arrive simultaneously"
            );
        }
        assert!(trace[0].arrival_us < trace[4].arrival_us, "quiet gap between bursts");
    }

    #[test]
    fn adversarial_floods_come_from_tenant_zero() {
        let e = entry();
        let spec = TrafficSpec::standard(
            ArrivalProcess::Adversarial { gap_us: 500, flood_every: 8, flood: 4 },
            4,
            32,
            5,
        );
        let trace = generate(&e, &spec).unwrap();
        for r in &trace {
            if (r.id as usize) % 8 < 4 {
                assert_eq!(r.tenant, 0, "flood request {} must be the noisy neighbor", r.id);
            }
        }
        // Tenant priorities/deadlines follow the tenant table.
        for r in &trace {
            assert_eq!(r.priority, (r.tenant % 3) as u8);
            assert_eq!(r.deadline_us, 0);
        }
    }

    #[test]
    fn generate_rejects_degenerate_tenant_mixes() {
        let e = entry();
        let mut spec = TrafficSpec::standard(ArrivalProcess::Uniform { gap_us: 0 }, 2, 4, 1);
        spec.tenants.clear();
        assert!(generate(&e, &spec).is_err());
        let mut spec = TrafficSpec::standard(ArrivalProcess::Uniform { gap_us: 0 }, 2, 4, 1);
        spec.tenants[0].weight = -1.0;
        assert!(generate(&e, &spec).is_err());
    }
}
