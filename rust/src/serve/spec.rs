//! The validated serving plan: [`ServeSpec`], the one front door to the
//! engine's scheduling knobs — mirroring how `parallel::MeshSpec` is the
//! one front door to the mesh. The CLI's consolidated
//! `--serve policy=…,budget=…,queue=…` flag parses into a `ServeSpec`
//! ([`ServeSpec::parse`]), every construction path funnels through
//! [`ServeSpec::validate`], and the engine takes the spec whole — there is
//! no second bag of loose scheduling arguments.

use anyhow::{bail, Context, Result};

use crate::manifest::ModelEntry;

/// Which [`crate::serve::SchedulerPolicy`] composes micro-batches. The
/// policy table (semantics, knobs, shed behavior) lives in
/// `docs/SERVING.md`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Arrival order — bitwise-identical to the pre-policy engine.
    Fifo,
    /// Higher [`crate::serve::Request::priority`] first; an optional aging
    /// floor ([`ServeSpec::priority_floor_us`]) promotes requests that have
    /// waited too long so low-priority traffic cannot starve.
    Priority,
    /// Per-tenant deficit round-robin on served tokens: the tenant with the
    /// fewest tokens served so far goes first.
    FairShare,
    /// Earliest-deadline-first with deadline-based eviction: requests whose
    /// deadline has already passed are shed (never served late silently).
    SloDeadline,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "fifo" => Ok(PolicyKind::Fifo),
            "priority" => Ok(PolicyKind::Priority),
            "fair" => Ok(PolicyKind::FairShare),
            "slo" => Ok(PolicyKind::SloDeadline),
            other => bail!("unknown serve policy `{other}` (expected fifo|priority|fair|slo)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Priority => "priority",
            PolicyKind::FairShare => "fair",
            PolicyKind::SloDeadline => "slo",
        }
    }
}

/// What happens when an offer hits a full bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedMode {
    /// Tail-drop the incoming request (shed reason `queue_full`).
    Reject,
    /// Shed the *least-preferred* request under the active policy — the
    /// incoming one if the policy ranks it last (`queue_full`), otherwise a
    /// queued victim (`evicted`).
    Evict,
}

impl ShedMode {
    pub fn parse(s: &str) -> Result<ShedMode> {
        match s {
            "reject" => Ok(ShedMode::Reject),
            "evict" => Ok(ShedMode::Evict),
            other => bail!("unknown shed mode `{other}` (expected reject|evict)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedMode::Reject => "reject",
            ShedMode::Evict => "evict",
        }
    }
}

/// The complete, validated serving plan. All times are virtual
/// microseconds; every field participates in the determinism contract —
/// scheduling is a pure function of `(trace, ServeSpec)`.
#[derive(Debug, Clone, Copy)]
pub struct ServeSpec {
    pub policy: PolicyKind,
    /// Token budget per micro-batch (0 = auto: 8 requests' worth,
    /// resolved against the model entry — [`ServeSpec::resolved_batch_tokens`]).
    /// A single request whose cost exceeds the budget is still admitted —
    /// alone — so no request can starve on size.
    pub max_batch_tokens: usize,
    /// Request cap per micro-batch (0 = unlimited; 1 = unbatched serving).
    pub max_batch_requests: usize,
    /// Admission-queue capacity (0 = unbounded: no backpressure, nothing is
    /// ever shed — the bitwise-FIFO-preserving default).
    pub queue_capacity: usize,
    /// Full-queue behavior; only meaningful with `queue_capacity > 0`.
    pub shed: ShedMode,
    /// Mean virtual inter-arrival gap of the default synthetic trace
    /// (0 = burst). The single default both `serve` and `infer` draw from.
    pub gap_us: u64,
    /// Virtual service-time model: a micro-batch of `t` tokens occupies the
    /// engine for `service_base_us + service_per_token_us · t`.
    pub service_base_us: u64,
    pub service_per_token_us: u64,
    /// `Priority` only: waiting this long promotes a request ahead of all
    /// fresher traffic regardless of priority class (0 = pure priority).
    pub priority_floor_us: u64,
    /// `SloDeadline` only: default relative deadline applied to requests
    /// that carry none (0 = deadline-less requests never expire).
    pub slo_default_us: u64,
}

impl Default for ServeSpec {
    fn default() -> ServeSpec {
        ServeSpec {
            policy: PolicyKind::Fifo,
            max_batch_tokens: 0,
            max_batch_requests: 0,
            queue_capacity: 0,
            shed: ShedMode::Reject,
            gap_us: 300,
            service_base_us: 200,
            service_per_token_us: 2,
            priority_floor_us: 0,
            slo_default_us: 0,
        }
    }
}

impl ServeSpec {
    /// One request per micro-batch — the no-batching reference the bench
    /// compares continuous batching against on the same trace.
    pub fn unbatched() -> ServeSpec {
        ServeSpec { max_batch_requests: 1, ..ServeSpec::default() }
    }

    /// Parse the consolidated CLI spelling: `policy=fifo|priority|fair|slo,
    /// budget=T,max-batch=N,queue=Q,shed=reject|evict,gap=G,floor=F,slo=D`
    /// (every key optional, any order, each at most once). Syntax only —
    /// cross-field rules live in [`ServeSpec::validate`].
    pub fn parse(s: &str) -> Result<ServeSpec> {
        let mut spec = ServeSpec::default();
        let mut seen: Vec<&str> = Vec::new();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("serve spec `{s}`: expected `key=value`, got `{part}`"))?;
            if seen.contains(&key) {
                bail!("serve spec `{s}`: key `{key}` given twice");
            }
            seen.push(key);
            let num = |v: &str| -> Result<usize> {
                v.parse::<usize>()
                    .with_context(|| format!("serve spec `{s}`: `{key}={v}` is not a number"))
            };
            match key {
                "policy" => spec.policy = PolicyKind::parse(value)?,
                "budget" => spec.max_batch_tokens = num(value)?,
                "max-batch" => spec.max_batch_requests = num(value)?,
                "queue" => spec.queue_capacity = num(value)?,
                "shed" => spec.shed = ShedMode::parse(value)?,
                "gap" => spec.gap_us = num(value)? as u64,
                "floor" => spec.priority_floor_us = num(value)? as u64,
                "slo" => spec.slo_default_us = num(value)? as u64,
                other => bail!(
                    "serve spec `{s}`: unknown key `{other}` (expected \
                     policy|budget|max-batch|queue|shed|gap|floor|slo)"
                ),
            }
        }
        // Policy-foreign knobs are rejected at parse time so a typo'd plan
        // fails loudly instead of being silently ignored.
        if spec.priority_floor_us > 0 && spec.policy != PolicyKind::Priority {
            bail!("serve spec `{s}`: `floor` only applies to policy=priority");
        }
        if spec.slo_default_us > 0 && spec.policy != PolicyKind::SloDeadline {
            bail!("serve spec `{s}`: `slo` only applies to policy=slo");
        }
        if seen.contains(&"shed") && spec.queue_capacity == 0 {
            bail!("serve spec `{s}`: `shed` needs a bounded queue (`queue=Q` with Q >= 1)");
        }
        Ok(spec)
    }

    /// The one semantic entry point, mirroring `MeshSpec::validate`: every
    /// engine construction funnels through here.
    pub fn validate(&self, entry: &ModelEntry) -> Result<()> {
        if self.resolved_batch_tokens(entry) == 0 {
            bail!("serve spec: resolved token budget must be >= 1");
        }
        if self.shed == ShedMode::Evict && self.queue_capacity == 0 {
            bail!("serve spec: shed=evict needs a bounded queue (queue=Q with Q >= 1)");
        }
        if self.priority_floor_us > 0 && self.policy != PolicyKind::Priority {
            bail!("serve spec: priority_floor_us only applies to policy=priority");
        }
        if self.slo_default_us > 0 && self.policy != PolicyKind::SloDeadline {
            bail!("serve spec: slo_default_us only applies to policy=slo");
        }
        Ok(())
    }

    /// The effective per-micro-batch token budget: `max_batch_tokens`, or —
    /// when 0 (auto) — eight requests' worth for this model.
    pub fn resolved_batch_tokens(&self, entry: &ModelEntry) -> usize {
        if self.max_batch_tokens > 0 {
            self.max_batch_tokens
        } else {
            8 * super::tokens_per_request(entry).max(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    fn entry() -> ModelEntry {
        Manifest::native().model("lm_tiny_dense").unwrap().clone()
    }

    #[test]
    fn parse_round_trips_every_key() {
        let spec =
            ServeSpec::parse("policy=slo,budget=96,max-batch=4,queue=8,shed=evict,gap=0,slo=5000")
                .unwrap();
        assert_eq!(spec.policy, PolicyKind::SloDeadline);
        assert_eq!(spec.max_batch_tokens, 96);
        assert_eq!(spec.max_batch_requests, 4);
        assert_eq!(spec.queue_capacity, 8);
        assert_eq!(spec.shed, ShedMode::Evict);
        assert_eq!(spec.gap_us, 0);
        assert_eq!(spec.slo_default_us, 5000);
        spec.validate(&entry()).unwrap();
        // An empty spec is the default plan.
        let dflt = ServeSpec::parse("").unwrap();
        assert_eq!(dflt.policy, PolicyKind::Fifo);
        assert_eq!(dflt.gap_us, 300);
        dflt.validate(&entry()).unwrap();
    }

    #[test]
    fn parse_rejects_malformed_specs_loudly() {
        for (spec, needle) in [
            ("policy", "expected `key=value`"),
            ("policy=lifo", "unknown serve policy"),
            ("budget=ten", "is not a number"),
            ("budget=8,budget=9", "given twice"),
            ("tenant=3", "unknown key"),
            ("shed=banana,queue=4", "unknown shed mode"),
            ("floor=100", "only applies to policy=priority"),
            ("slo=100", "only applies to policy=slo"),
            ("shed=evict", "needs a bounded queue"),
        ] {
            let err = ServeSpec::parse(spec).unwrap_err();
            assert!(format!("{err:#}").contains(needle), "{spec}: {err:#}");
        }
    }

    #[test]
    fn validate_is_the_single_semantic_gate() {
        let e = entry();
        let bad = ServeSpec { shed: ShedMode::Evict, ..ServeSpec::default() };
        assert!(bad.validate(&e).is_err(), "evict without a bounded queue");
        let bad = ServeSpec { priority_floor_us: 5, ..ServeSpec::default() };
        assert!(bad.validate(&e).is_err(), "floor outside policy=priority");
        let bad = ServeSpec { slo_default_us: 5, ..ServeSpec::default() };
        assert!(bad.validate(&e).is_err(), "slo outside policy=slo");
        let ok = ServeSpec {
            policy: PolicyKind::SloDeadline,
            queue_capacity: 4,
            shed: ShedMode::Evict,
            slo_default_us: 100,
            ..ServeSpec::default()
        };
        ok.validate(&e).unwrap();
    }

    #[test]
    fn auto_budget_resolves_to_eight_requests() {
        let e = entry();
        let tpr = crate::serve::tokens_per_request(&e);
        assert_eq!(ServeSpec::default().resolved_batch_tokens(&e), 8 * tpr);
        let explicit = ServeSpec { max_batch_tokens: 5, ..ServeSpec::default() };
        assert_eq!(explicit.resolved_batch_tokens(&e), 5);
    }
}
