//! Bounded-queue admission with backpressure and explicit load-shedding.
//! The [`Admission`] queue sits between the trace producer and the batch
//! composer: every request that enters the engine either completes or is
//! shed with a named [`ShedReason`] — never a silent drop ([`Engine::run_trace`]
//! errors if the accounting does not balance). With
//! `queue_capacity = 0` (the default) the queue is unbounded and nothing
//! is ever shed, preserving the pre-policy engine bitwise.
//!
//! [`Engine::run_trace`]: super::Engine::run_trace

use super::policy::{QueuedRequest, SchedulerPolicy};
use super::spec::{ServeSpec, ShedMode};
use super::Request;

/// Why a request was shed. Every variant has a stable CLI/bench name —
/// the taxonomy table lives in `docs/SERVING.md`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ShedReason {
    /// The bounded admission queue was full when this request arrived and
    /// it was the least-preferred choice (always the incoming one under
    /// [`ShedMode::Reject`]).
    QueueFull,
    /// A queued request was displaced by a more-preferred arrival under
    /// [`ShedMode::Evict`].
    Evicted,
    /// The request's absolute deadline lapsed before service started
    /// (SLO policy's deadline-based eviction).
    DeadlineExpired,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Evicted => "evicted",
            ShedReason::DeadlineExpired => "deadline_expired",
        }
    }
}

/// One shed decision, recorded at the virtual instant it was made.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedRecord {
    pub id: u64,
    pub tenant: u64,
    pub priority: u8,
    pub arrival_us: u64,
    /// Virtual instant of the shed decision (>= `arrival_us`).
    pub shed_us: u64,
    pub reason: ShedReason,
}

/// The admission queue: scheduling metadata and request payloads held as
/// parallel arrays in arrival (offer) order, plus the shed log.
pub struct Admission {
    capacity: usize,
    shed_mode: ShedMode,
    slo_default_us: u64,
    meta: Vec<QueuedRequest>,
    reqs: Vec<Request>,
    sheds: Vec<ShedRecord>,
}

impl Admission {
    pub fn new(spec: &ServeSpec) -> Admission {
        Admission {
            capacity: spec.queue_capacity,
            shed_mode: spec.shed,
            slo_default_us: spec.slo_default_us,
            meta: Vec::new(),
            reqs: Vec::new(),
            sheds: Vec::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    /// The policy's view of the queue (offer order).
    pub fn meta(&self) -> &[QueuedRequest] {
        &self.meta
    }

    pub fn shed_count(&self) -> usize {
        self.sheds.len()
    }

    pub fn into_sheds(self) -> Vec<ShedRecord> {
        self.sheds
    }

    /// Offer one arrived request at virtual instant `v_now`. Expired
    /// entries are evicted first (both modes), then the capacity check
    /// runs: a full queue sheds either the incoming request
    /// ([`ShedMode::Reject`], reason `queue_full`) or the least-preferred
    /// request under the active policy ([`ShedMode::Evict`] — a queued
    /// victim sheds as `evicted`, the incoming one as `queue_full`).
    pub fn offer(
        &mut self,
        req: Request,
        policy: &dyn SchedulerPolicy,
        v_now: u64,
        tokens: usize,
    ) {
        self.evict_expired(policy, v_now);
        let deadline_us = if req.deadline_us > 0 {
            req.deadline_us
        } else if self.slo_default_us > 0 {
            req.arrival_us + self.slo_default_us
        } else {
            u64::MAX
        };
        let meta = QueuedRequest {
            id: req.id,
            arrival_us: req.arrival_us,
            tenant: req.tenant,
            priority: req.priority,
            deadline_us,
            tokens,
        };
        if self.capacity > 0 && self.meta.len() >= self.capacity {
            match self.shed_mode {
                ShedMode::Reject => {
                    self.shed(meta, v_now, ShedReason::QueueFull);
                    return;
                }
                ShedMode::Evict => {
                    // Least-preferred over pending + incoming (appended at
                    // index len): the policy's order, read from the back.
                    let mut view = self.meta.clone();
                    view.push(meta);
                    let order = policy.order(&view, v_now);
                    let victim = *order.last().expect("queue is non-empty");
                    if victim == self.meta.len() {
                        self.shed(meta, v_now, ShedReason::QueueFull);
                        return;
                    }
                    let victim_meta = self.meta.remove(victim);
                    self.reqs.remove(victim);
                    self.shed(victim_meta, v_now, ShedReason::Evicted);
                }
            }
        }
        self.meta.push(meta);
        self.reqs.push(req);
    }

    /// Apply the policy's eviction verdicts (lapsed deadlines) at `v_now`.
    pub fn evict_expired(&mut self, policy: &dyn SchedulerPolicy, v_now: u64) {
        let mut victims = policy.evict(&self.meta, v_now);
        if victims.is_empty() {
            return;
        }
        // Remove back-to-front so earlier indices stay valid.
        victims.sort_by_key(|&(i, _)| std::cmp::Reverse(i));
        for (i, reason) in victims {
            let meta = self.meta.remove(i);
            self.reqs.remove(i);
            self.shed(meta, v_now, reason);
        }
    }

    /// Remove `picked` queue indices as one micro-batch, returned in the
    /// given (policy-preference) order.
    pub fn take(&mut self, picked: &[usize]) -> (Vec<Request>, Vec<QueuedRequest>) {
        let mut old_reqs: Vec<Option<Request>> = self.reqs.drain(..).map(Some).collect();
        let mut reqs = Vec::with_capacity(picked.len());
        let mut metas = Vec::with_capacity(picked.len());
        for &i in picked {
            reqs.push(old_reqs[i].take().expect("picked indices are unique"));
            metas.push(self.meta[i]);
        }
        let mut keep_meta = Vec::with_capacity(self.meta.len() - picked.len());
        for (i, m) in self.meta.drain(..).enumerate() {
            if old_reqs[i].is_some() {
                keep_meta.push(m);
            }
        }
        self.reqs = old_reqs.into_iter().flatten().collect();
        self.meta = keep_meta;
        (reqs, metas)
    }

    fn shed(&mut self, meta: QueuedRequest, v_now: u64, reason: ShedReason) {
        self.sheds.push(ShedRecord {
            id: meta.id,
            tenant: meta.tenant,
            priority: meta.priority,
            arrival_us: meta.arrival_us,
            shed_us: v_now,
            reason,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::{Fifo, SloDeadline};
    use super::super::spec::PolicyKind;
    use super::*;

    fn request(id: u64, arrival: u64) -> Request {
        Request::new(id, arrival, Vec::new())
    }

    fn bounded(capacity: usize, shed: ShedMode) -> Admission {
        Admission::new(&ServeSpec { queue_capacity: capacity, shed, ..ServeSpec::default() })
    }

    #[test]
    fn unbounded_queue_never_sheds() {
        let mut adm = Admission::new(&ServeSpec::default());
        for i in 0..100 {
            adm.offer(request(i, 0), &Fifo, 0, 10);
        }
        assert_eq!(adm.meta().len(), 100);
        assert_eq!(adm.shed_count(), 0);
    }

    #[test]
    fn reject_mode_tail_drops_with_queue_full() {
        let mut adm = bounded(2, ShedMode::Reject);
        for i in 0..4 {
            adm.offer(request(i, 0), &Fifo, 5, 10);
        }
        assert_eq!(adm.meta().len(), 2);
        let kept: Vec<u64> = adm.meta().iter().map(|m| m.id).collect();
        assert_eq!(kept, vec![0, 1], "FIFO keeps the earliest arrivals");
        let sheds = adm.into_sheds();
        assert_eq!(sheds.len(), 2);
        assert!(sheds.iter().all(|s| s.reason == ShedReason::QueueFull && s.shed_us == 5));
        assert_eq!(sheds.iter().map(|s| s.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn evict_mode_displaces_the_least_preferred_under_the_policy() {
        // EDF: a tighter-deadline arrival displaces the loosest queued one.
        let spec = ServeSpec {
            policy: PolicyKind::SloDeadline,
            queue_capacity: 2,
            shed: ShedMode::Evict,
            slo_default_us: 0,
            ..ServeSpec::default()
        };
        let mut adm = Admission::new(&spec);
        let with_deadline = |id: u64, deadline: u64| {
            let mut r = request(id, 0);
            r.deadline_us = deadline;
            r
        };
        adm.offer(with_deadline(0, 500), &SloDeadline, 0, 10);
        adm.offer(with_deadline(1, 900), &SloDeadline, 0, 10);
        adm.offer(with_deadline(2, 100), &SloDeadline, 0, 10);
        let kept: Vec<u64> = adm.meta().iter().map(|m| m.id).collect();
        assert_eq!(kept, vec![0, 2], "the loosest deadline (id 1) was displaced");
        // An incoming request that is itself least-preferred sheds as
        // queue_full, not evicted.
        adm.offer(with_deadline(3, 2000), &SloDeadline, 0, 10);
        let sheds = adm.into_sheds();
        assert_eq!(sheds[0].id, 1);
        assert_eq!(sheds[0].reason, ShedReason::Evicted);
        assert_eq!(sheds[1].id, 3);
        assert_eq!(sheds[1].reason, ShedReason::QueueFull);
    }

    #[test]
    fn take_removes_picked_in_preference_order() {
        let mut adm = Admission::new(&ServeSpec::default());
        for i in 0..5 {
            adm.offer(request(i, i), &Fifo, 10, 10);
        }
        let (reqs, metas) = adm.take(&[3, 1]);
        assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 1]);
        assert_eq!(metas.iter().map(|m| m.id).collect::<Vec<_>>(), vec![3, 1]);
        let left: Vec<u64> = adm.meta().iter().map(|m| m.id).collect();
        assert_eq!(left, vec![0, 2, 4], "queue order preserved for the rest");
        assert_eq!(adm.reqs.len(), 3);
    }

    #[test]
    fn slo_default_resolves_missing_deadlines_at_admission() {
        let spec = ServeSpec {
            policy: PolicyKind::SloDeadline,
            slo_default_us: 250,
            ..ServeSpec::default()
        };
        let mut adm = Admission::new(&spec);
        adm.offer(request(0, 100), &SloDeadline, 100, 10);
        assert_eq!(adm.meta()[0].deadline_us, 350);
        // A lapsed deadline is evicted with the named reason.
        adm.evict_expired(&SloDeadline, 400);
        assert!(adm.is_empty());
        let sheds = adm.into_sheds();
        assert_eq!(sheds.len(), 1);
        assert_eq!(sheds[0].reason, ShedReason::DeadlineExpired);
        assert_eq!(sheds[0].shed_us, 400);
    }
}
