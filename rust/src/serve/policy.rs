//! The scheduler-policy seam: a [`SchedulerPolicy`] ranks the admitted
//! queue each time the engine composes a micro-batch, and may evict
//! entries whose deadline has lapsed. Policies see only [`QueuedRequest`]
//! metadata (never tensors) and only virtual time, so every decision is a
//! pure function of `(trace, ServeSpec)` — the determinism contract of
//! `docs/SERVING.md` holds for all of them, not just FIFO.
//!
//! The engine's batch composition is shared across policies: it walks the
//! policy's preference order, admitting requests until the token budget or
//! request cap is hit (the first pick always fits, so an oversized request
//! runs alone instead of starving). [`Fifo`]'s preference order is the
//! queue order itself, which makes the defaulted engine bitwise-identical
//! to the pre-policy FIFO loop — asserted against a golden
//! reimplementation in `rust/tests/serve_props.rs`.

use super::admission::ShedReason;
use super::spec::{PolicyKind, ServeSpec};

/// What a policy sees of one queued request: scheduling metadata only.
#[derive(Debug, Clone, Copy)]
pub struct QueuedRequest {
    pub id: u64,
    pub arrival_us: u64,
    pub tenant: u64,
    /// Larger = more urgent (only [`PolicyKind::Priority`] reads it).
    pub priority: u8,
    /// Absolute virtual deadline, resolved at admission (`u64::MAX` =
    /// none); only [`PolicyKind::SloDeadline`] reads it.
    pub deadline_us: u64,
    /// Token cost against the micro-batch budget.
    pub tokens: usize,
}

/// One scheduling policy. `order` must return a permutation of
/// `0..pending.len()` (most-preferred first); the engine serves a prefix.
pub trait SchedulerPolicy: Send {
    fn name(&self) -> &'static str;

    /// Full preference order over the admitted queue at virtual instant
    /// `v_now`, most-preferred first.
    fn order(&self, pending: &[QueuedRequest], v_now: u64) -> Vec<usize>;

    /// Requests to shed *now* (e.g. lapsed deadlines), as
    /// `(queue index, reason)` pairs. Called before every batch
    /// composition and before every admission offer.
    fn evict(&self, pending: &[QueuedRequest], v_now: u64) -> Vec<(usize, ShedReason)> {
        let _ = (pending, v_now);
        Vec::new()
    }

    /// Notification that `served` just left the queue as one micro-batch
    /// (in service order) — the hook stateful policies account with.
    fn on_served(&mut self, served: &[QueuedRequest]) {
        let _ = served;
    }
}

/// Construct the policy a [`ServeSpec`] names. Fresh per trace run, so
/// stateful policies (FairShare) never leak accounting across traces.
pub fn policy_for(spec: &ServeSpec) -> Box<dyn SchedulerPolicy> {
    match spec.policy {
        PolicyKind::Fifo => Box::new(Fifo),
        PolicyKind::Priority => Box::new(Priority { floor_us: spec.priority_floor_us }),
        PolicyKind::FairShare => Box::new(FairShare { served_tokens: Vec::new() }),
        PolicyKind::SloDeadline => Box::new(SloDeadline),
    }
}

/// Arrival order — the default, bitwise-identical to the pre-policy engine.
pub struct Fifo;

impl SchedulerPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn order(&self, pending: &[QueuedRequest], _v_now: u64) -> Vec<usize> {
        (0..pending.len()).collect()
    }
}

/// Strict priority classes with an optional anti-starvation aging floor:
/// any request that has waited at least `floor_us` is promoted ahead of
/// all fresher traffic (overdue requests among themselves go FIFO), so a
/// sustained high-priority flood cannot starve the low classes.
pub struct Priority {
    pub floor_us: u64,
}

impl Priority {
    fn overdue(&self, r: &QueuedRequest, v_now: u64) -> bool {
        self.floor_us > 0 && v_now.saturating_sub(r.arrival_us) >= self.floor_us
    }
}

impl SchedulerPolicy for Priority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn order(&self, pending: &[QueuedRequest], v_now: u64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..pending.len()).collect();
        // Sort key: overdue first and FIFO among themselves — their class
        // is neutralized so a backlog that is entirely past the floor
        // drains by (arrival, id) instead of collapsing back to pure
        // priority. Fresh traffic follows, class descending, with the same
        // (arrival, id) tie-break so the order is total and trace-determined.
        idx.sort_by_key(|&i| {
            let r = &pending[i];
            let overdue = self.overdue(r, v_now);
            let class = if overdue { u8::MAX } else { r.priority };
            (!overdue, std::cmp::Reverse(class), r.arrival_us, r.id)
        });
        idx
    }
}

/// Deficit round-robin over tenants, accounted in served tokens: each pick
/// goes to the pending tenant with the fewest tokens served so far (ties
/// by tenant id, then arrival, then id). Within one micro-batch the
/// accounting is tentative, so a single batch already rotates across
/// tenants instead of draining one.
pub struct FairShare {
    /// `(tenant, tokens served)` — persistent across batches of one trace.
    served_tokens: Vec<(u64, u64)>,
}

impl FairShare {
    fn served(counts: &[(u64, u64)], tenant: u64) -> u64 {
        counts.iter().find(|(t, _)| *t == tenant).map(|(_, n)| *n).unwrap_or(0)
    }

    fn charge(counts: &mut Vec<(u64, u64)>, tenant: u64, tokens: u64) {
        match counts.iter_mut().find(|(t, _)| *t == tenant) {
            Some(slot) => slot.1 += tokens,
            None => counts.push((tenant, tokens)),
        }
    }
}

impl SchedulerPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn order(&self, pending: &[QueuedRequest], _v_now: u64) -> Vec<usize> {
        let mut tentative = self.served_tokens.clone();
        let mut remaining: Vec<usize> = (0..pending.len()).collect();
        let mut out = Vec::with_capacity(pending.len());
        while !remaining.is_empty() {
            let (pos, &best) = remaining
                .iter()
                .enumerate()
                .min_by_key(|(_, &i)| {
                    let r = &pending[i];
                    (Self::served(&tentative, r.tenant), r.tenant, r.arrival_us, r.id)
                })
                .expect("remaining is non-empty");
            let r = &pending[best];
            Self::charge(&mut tentative, r.tenant, r.tokens as u64);
            out.push(best);
            remaining.remove(pos);
        }
        out
    }

    fn on_served(&mut self, served: &[QueuedRequest]) {
        for r in served {
            Self::charge(&mut self.served_tokens, r.tenant, r.tokens as u64);
        }
    }
}

/// Earliest-deadline-first with deadline-based eviction: batches fill in
/// ascending deadline order, and any request whose absolute deadline has
/// already passed is shed with reason [`ShedReason::DeadlineExpired`]
/// (never silently dropped). The SLO contract is deadline-by-service-
/// start: eviction runs before each batch is composed, so every served
/// request *starts* at or before its deadline, but one picked just inside
/// it may still finish after (`finish = start + service`). Deadline-less
/// requests (`u64::MAX`) sort last and never expire.
pub struct SloDeadline;

impl SchedulerPolicy for SloDeadline {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn order(&self, pending: &[QueuedRequest], _v_now: u64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..pending.len()).collect();
        idx.sort_by_key(|&i| {
            let r = &pending[i];
            (r.deadline_us, r.arrival_us, r.id)
        });
        idx
    }

    fn evict(&self, pending: &[QueuedRequest], v_now: u64) -> Vec<(usize, ShedReason)> {
        pending
            .iter()
            .enumerate()
            .filter(|(_, r)| r.deadline_us < v_now)
            .map(|(i, _)| (i, ShedReason::DeadlineExpired))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: u64, tenant: u64, priority: u8, deadline: u64) -> QueuedRequest {
        QueuedRequest {
            id,
            arrival_us: arrival,
            tenant,
            priority,
            deadline_us: deadline,
            tokens: 10,
        }
    }

    #[test]
    fn fifo_order_is_identity() {
        let q = vec![req(0, 0, 0, 0, u64::MAX), req(1, 5, 0, 9, u64::MAX)];
        assert_eq!(Fifo.order(&q, 100), vec![0, 1]);
    }

    #[test]
    fn priority_sorts_by_class_until_the_floor_kicks_in() {
        let q = vec![req(0, 0, 0, 0, u64::MAX), req(1, 50, 0, 3, u64::MAX)];
        // Pure priority (floor disabled): the class-3 request wins.
        let pure = Priority { floor_us: 0 };
        assert_eq!(pure.order(&q, 60), vec![1, 0]);
        // With a 100 µs floor, request 0 is overdue at t=120 and is
        // promoted ahead of the fresher high-priority one.
        let aged = Priority { floor_us: 100 };
        assert_eq!(aged.order(&q, 60), vec![1, 0], "not overdue yet");
        assert_eq!(aged.order(&q, 120), vec![0, 1], "overdue wins");
    }

    #[test]
    fn fair_share_rotates_tenants_and_remembers_served_tokens() {
        let q =
            vec![req(0, 0, 7, 0, u64::MAX), req(1, 0, 7, 0, u64::MAX), req(2, 0, 9, 0, u64::MAX)];
        let mut fair = FairShare { served_tokens: Vec::new() };
        // Fresh counters: tenant 7 leads on (tenant id) tie-break, then the
        // tentative charge hands the next pick to tenant 9.
        assert_eq!(fair.order(&q, 0), vec![0, 2, 1]);
        // After tenant 7 is charged two requests, tenant 9 goes first.
        fair.on_served(&[q[0], q[1]]);
        assert_eq!(fair.order(&q, 0), vec![2, 0, 1]);
    }

    #[test]
    fn slo_orders_by_deadline_and_evicts_lapsed_ones() {
        let q = vec![req(0, 0, 0, 0, 500), req(1, 0, 0, 0, 100), req(2, 0, 0, 0, u64::MAX)];
        let slo = SloDeadline;
        assert_eq!(slo.order(&q, 0), vec![1, 0, 2]);
        assert_eq!(slo.evict(&q, 0), vec![]);
        let shed = slo.evict(&q, 200);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].0, 1);
        assert_eq!(shed[0].1, ShedReason::DeadlineExpired);
    }
}
