//! Expert/data/model-parallel placement: validation, the placement
//! simulator (paper §A.4), the prescriptive expert↔rank mapping behind
//! real expert-parallel execution, and the collectives ([`collectives`]).
//!
//! The paper trains with three composed parallelism axes: data (batch
//! shards), expert (experts partitioned across devices) and model (weight
//! matrices sharded). The first two are real in this repo: the trainer's
//! data-parallel mode (`coordinator::trainer::dp_train_step`) steps batch
//! shards on worker replicas, and its DP×EP mesh mode
//! (`coordinator::trainer::mesh_train_step`) additionally shards the
//! expert MLP weights across expert-parallel ranks that exchange token
//! buffers through real all-to-all collectives. [`ExpertPlacement`] is the
//! single source of truth for which rank owns which expert — the placement
//! *simulator* ([`place`]) and the *executor* (`runtime::ep`) both read it,
//! so the accounting and the execution can never disagree. The remaining
//! axis (model parallel) and the interconnect cost accounting stay
//! simulated: per-device token load (balance), all-to-all dispatch volume,
//! and per-device parameter memory. The `routing_sim` bench sweeps these
//! against E / C / device count.
//!
//! [`MeshSpec`] is the single parallel plan: parsed from one `--topology
//! dp=D,ep=E[,tp=T]` string ([`MeshSpec::parse`]) and checked by one
//! mode-aware validator ([`MeshSpec::validate`]) against the model entry
//! and the host *at configuration time* — so a bad topology fails with an
//! actionable message when the run is set up instead of deep inside the
//! trainer's step loop. The trainer, the elastic mesh trainer and mesh
//! serving all consume the same validated plan.

pub mod collectives;

use anyhow::{bail, Context, Result};

use crate::manifest::{ModelEntry, MoeSpec};
use crate::util::rng::Rng;

/// All divisors of `n`, ascending (the valid replica counts for a batch).
fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// How a [`MeshSpec`] will be consumed — picks which constraints
/// [`MeshSpec::validate`] enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshMode {
    /// Placement / routing / comms *simulation* (`upcycle mesh`,
    /// `upcycle comms`): geometric satisfiability only. Zero axes are
    /// legal (they normalize to 1) and a dense entry ignores the expert
    /// axis.
    Sim,
    /// Real DP×EP execution (the mesh trainer and mesh serving): the batch
    /// must shard evenly into `dp·ep` token shards and a sparse model
    /// needs at least one expert per EP rank. The rank count is
    /// deliberately *not* bounded by the host's parallelism: EP ranks
    /// spend much of a step blocked on collectives, so moderate thread
    /// oversubscription is normal (a 2×2 mesh runs fine on a 2-core
    /// host). `tp` is validated against `d_model` but executes serially.
    Exec,
    /// Plain data parallelism over worker threads (`dp` only).
    /// `max_workers` bounds the threads the host can usefully run (`None`
    /// = `std::thread::available_parallelism`); pass an explicit value to
    /// allow deliberate oversubscription.
    DataParallel { max_workers: Option<usize> },
}

/// The prescriptive expert↔rank mapping of a sharded MoE block: expert `x`
/// lives on rank `x % ranks` (round-robin, the same static placement
/// [`place`] accounts). Both the expert-parallel executor (`runtime::ep`,
/// which slices weight shards and routes dispatch payloads by owner) and
/// the placement simulator read this type, so changing the mapping in one
/// place changes it everywhere.
#[derive(Debug, Clone, Copy)]
pub struct ExpertPlacement {
    pub num_experts: usize,
    pub ranks: usize,
}

impl ExpertPlacement {
    /// `ranks` is clamped to >= 1 (a zero expert axis means "no sharding").
    pub fn new(num_experts: usize, ranks: usize) -> ExpertPlacement {
        ExpertPlacement { num_experts, ranks: ranks.max(1) }
    }

    /// Topology-aware constructor: the placement an executing mesh implies
    /// (experts shard over the `ep` axis only; `dp` rows replicate it).
    pub fn for_mesh(num_experts: usize, mesh: &MeshSpec) -> ExpertPlacement {
        ExpertPlacement::new(num_experts, mesh.expert_parallel)
    }

    /// The rank that owns expert `x`.
    pub fn owner(&self, expert: usize) -> usize {
        expert % self.ranks
    }

    /// Experts owned by `rank`, ascending.
    pub fn owned(&self, rank: usize) -> Vec<usize> {
        (0..self.num_experts).filter(|x| x % self.ranks == rank).collect()
    }
}

/// One parallel plan: the `dp × ep × tp` device mesh every parallel
/// consumer (the trainer, the elastic mesh trainer, mesh serving, the
/// placement and comms simulators) is configured with. Parsed from a
/// single `--topology` string ([`MeshSpec::parse`]) and checked by
/// [`MeshSpec::validate`].
#[derive(Debug, Clone, Copy)]
pub struct MeshSpec {
    pub data_parallel: usize,
    pub expert_parallel: usize,
    /// Tensor/model-parallel axis: validated (weight shards must be
    /// non-empty) but executed serially in this repo.
    pub model_parallel: usize,
}

impl MeshSpec {
    /// A `dp × ep` plan with no tensor-parallel axis.
    pub fn new(dp: usize, ep: usize) -> MeshSpec {
        MeshSpec { data_parallel: dp, expert_parallel: ep, model_parallel: 1 }
    }

    /// The plan plain data parallelism desugars to (`--replicas N`).
    pub fn data_parallel_only(replicas: usize) -> MeshSpec {
        MeshSpec::new(replicas, 1)
    }

    /// Parse a `--topology` string: comma-separated `axis=N` pairs with
    /// axes `dp` (data parallel), `ep` (expert parallel) and optionally
    /// `tp` (tensor parallel, validated but serial; defaults to 1). `dp`
    /// and `ep` are required; order is free; an axis may appear once.
    pub fn parse(s: &str) -> Result<MeshSpec> {
        let (mut dp, mut ep, mut tp) = (None, None, None);
        for part in s.split(',') {
            let part = part.trim();
            let (axis, val) = part
                .split_once('=')
                .with_context(|| format!("topology `{s}`: expected `axis=N`, got `{part}`"))?;
            let axis = axis.trim();
            let n: usize = val.trim().parse().with_context(|| {
                format!("topology `{s}`: axis `{axis}` wants a number, got `{}`", val.trim())
            })?;
            let slot = match axis {
                "dp" => &mut dp,
                "ep" => &mut ep,
                "tp" => &mut tp,
                other => bail!("topology `{s}`: unknown axis `{other}` (use dp, ep, tp)"),
            };
            if slot.replace(n).is_some() {
                bail!("topology `{s}`: axis `{axis}` given twice");
            }
        }
        Ok(MeshSpec {
            data_parallel: dp.with_context(|| format!("topology `{s}`: missing `dp=N`"))?,
            expert_parallel: ep.with_context(|| format!("topology `{s}`: missing `ep=N`"))?,
            model_parallel: tp.unwrap_or(1),
        })
    }

    /// Total devices. A zero-sized axis (e.g. `expert_parallel = 0` for a
    /// dense entry with no expert sharding) counts as one device on that
    /// axis — a mesh can never have zero devices.
    pub fn devices(&self) -> usize {
        self.data_parallel.max(1) * self.expert_parallel.max(1) * self.model_parallel.max(1)
    }

    /// Executing worker ranks: `dp·ep` (the `tp` axis runs serially).
    pub fn ranks(&self) -> usize {
        self.data_parallel.max(1) * self.expert_parallel.max(1)
    }

    /// The one mesh validator: checks this plan against `entry` (and, for
    /// [`MeshMode::DataParallel`], the host) under the constraints of how
    /// it will be consumed. Replaces the former
    /// `validate_replicas` / `validate_mesh` / `validate_mesh_exec` trio.
    ///
    /// Errors are actionable: they name the model, the offending axis and
    /// the valid choices, instead of letting the trainer fail mid-run on a
    /// malformed batch shard.
    pub fn validate(&self, entry: &ModelEntry, mode: MeshMode) -> Result<()> {
        match mode {
            MeshMode::Sim => self.validate_sim(entry),
            MeshMode::Exec => self.validate_exec(entry),
            MeshMode::DataParallel { max_workers } => self.validate_dp(entry, max_workers),
        }
    }

    fn validate_sim(&self, entry: &ModelEntry) -> Result<()> {
        let num_experts = entry
            .config
            .enc_moe
            .as_ref()
            .or(entry.config.dec_moe.as_ref())
            .map(|m| m.num_experts)
            .unwrap_or(0);
        let ep = self.expert_parallel.max(1);
        // A dense entry simply has no expert placement (see `place`); an
        // expert axis on it is a no-op, not an error. Only a sparse model
        // with more expert-parallel devices than experts is unsatisfiable.
        if num_experts > 0 && ep > num_experts {
            bail!(
                "model `{}`: {} expert-parallel devices but only {} experts; \
                 use expert_parallel <= {}",
                entry.name,
                ep,
                num_experts,
                num_experts
            );
        }
        let dp = self.data_parallel.max(1);
        let b = entry.config.batch_size;
        if b > 0 && (dp > b || b % dp != 0) {
            bail!(
                "model `{}`: batch_size {} does not shard evenly over {} data-parallel devices; \
                 valid data_parallel values: {:?}",
                entry.name,
                b,
                dp,
                divisors(b)
            );
        }
        self.validate_tp(entry)
    }

    fn validate_exec(&self, entry: &ModelEntry) -> Result<()> {
        let (dp, ep) = (self.data_parallel, self.expert_parallel);
        if dp == 0 || ep == 0 {
            bail!("model `{}`: mesh axes must be >= 1 (got {dp}x{ep})", entry.name);
        }
        // Every sharded tower must satisfy the expert axis — bound by the
        // *smallest* MoE block, not just the encoder's (an artifact
        // manifest may give the towers different expert counts).
        let num_experts = [entry.config.enc_moe.as_ref(), entry.config.dec_moe.as_ref()]
            .into_iter()
            .flatten()
            .map(|m| m.num_experts)
            .min()
            .unwrap_or(0);
        if ep > 1 && num_experts == 0 {
            bail!(
                "model `{}` is dense: no experts to shard across {ep} expert-parallel ranks; \
                 use a dp-only topology (ep=1) for plain data parallelism",
                entry.name
            );
        }
        if num_experts > 0 && ep > num_experts {
            bail!(
                "model `{}`: {ep} expert-parallel ranks but only {num_experts} experts in its \
                 smallest MoE block; use an expert axis <= {num_experts}",
                entry.name
            );
        }
        let ranks = dp * ep;
        let b = entry.config.batch_size;
        if b == 0 {
            bail!("model `{}`: batch_size is 0; nothing to shard over the mesh", entry.name);
        }
        if b % ranks != 0 {
            bail!(
                "model `{}`: batch_size {b} does not shard into {dp}x{ep} = {ranks} mesh token \
                 shards; valid rank counts: {:?}",
                entry.name,
                divisors(b)
            );
        }
        self.validate_tp(entry)
    }

    fn validate_dp(&self, entry: &ModelEntry, max_workers: Option<usize>) -> Result<()> {
        if self.expert_parallel.max(1) != 1 || self.model_parallel.max(1) != 1 {
            bail!(
                "model `{}`: plain data parallelism takes a dp-only topology \
                 (got dp={} ep={} tp={})",
                entry.name,
                self.data_parallel,
                self.expert_parallel,
                self.model_parallel
            );
        }
        let replicas = self.data_parallel;
        let b = entry.config.batch_size;
        if replicas == 0 {
            bail!("model `{}`: data-parallel replica count must be >= 1 (got 0)", entry.name);
        }
        if b == 0 {
            bail!("model `{}`: batch_size is 0; nothing to shard across replicas", entry.name);
        }
        if b % replicas != 0 {
            bail!(
                "model `{}`: batch_size {} does not split into {} equal replica shards; \
                 valid replica counts for this model: {:?}",
                entry.name,
                b,
                replicas,
                divisors(b)
            );
        }
        let avail = max_workers
            .unwrap_or_else(|| std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1));
        if replicas > avail {
            bail!(
                "model `{}`: {} replicas exceed the available parallelism of {} worker \
                 thread(s); use <= {} replicas, or run single-replica gradient accumulation \
                 over {} microbatches (DpConfig::accumulated) for the same arithmetic",
                entry.name,
                replicas,
                avail,
                avail,
                replicas
            );
        }
        Ok(())
    }

    fn validate_tp(&self, entry: &ModelEntry) -> Result<()> {
        let tp = self.model_parallel.max(1);
        if tp > entry.config.d_model.max(1) {
            bail!(
                "model `{}`: model_parallel {} exceeds d_model {}; weight shards would be empty",
                entry.name,
                tp,
                entry.config.d_model
            );
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct PlacementReport {
    pub devices: usize,
    pub experts_per_device: Vec<usize>,
    /// Bytes of expert parameters held per expert-parallel device.
    pub expert_param_bytes_per_device: usize,
    /// Bytes of non-expert (replicated) parameters per device.
    pub dense_param_bytes: usize,
}

/// Static placement: experts round-robined over the expert-parallel axis,
/// dense weights replicated (data parallel) and split over model-parallel.
///
/// `expert_parallel == 0` (a mesh with no expert axis, i.e. a dense entry's
/// placement) is normalized to one expert-parallel device rather than
/// dividing by zero; a dense entry reports an empty expert placement.
pub fn place(entry: &ModelEntry, mesh: &MeshSpec) -> PlacementReport {
    let num_experts = entry
        .config
        .enc_moe
        .as_ref()
        .or(entry.config.dec_moe.as_ref())
        .map(|m| m.num_experts)
        .unwrap_or(0);
    let ep = mesh.expert_parallel.max(1);
    // Same mapping the expert-parallel executor uses (`ExpertPlacement`):
    // the report is an account of the real placement, not a separate model.
    let placement = ExpertPlacement::new(num_experts, ep);
    let experts_per_device = if num_experts == 0 {
        Vec::new()
    } else {
        (0..ep).map(|r| placement.owned(r).len()).collect()
    };
    let expert_bytes = entry.expert_param_count() * 4;
    let dense_bytes = (entry.param_count - entry.expert_param_count()) * 4;
    PlacementReport {
        devices: mesh.devices(),
        experts_per_device,
        expert_param_bytes_per_device: if num_experts == 0 { 0 } else { expert_bytes / ep },
        dense_param_bytes: dense_bytes / mesh.model_parallel.max(1),
    }
}

#[derive(Debug, Clone)]
pub struct RoutingTraffic {
    /// Tokens each expert received (length E).
    pub tokens_per_expert: Vec<usize>,
    /// Tokens that crossed a device boundary (all-to-all payload).
    pub offdevice_tokens: usize,
    /// Total dispatched tokens (== n·C for Expert Choice).
    pub dispatched_tokens: usize,
    /// max/mean load over experts (1.0 = perfectly balanced).
    pub imbalance: f64,
    /// Fraction of tokens dropped (token-choice overflows only).
    pub drop_fraction: f64,
}

impl RoutingTraffic {
    pub fn all_to_all_bytes(&self, d_model: usize) -> usize {
        // dispatch + combine both move the token vector.
        2 * self.offdevice_tokens * d_model * 4
    }
}

/// Simulate one routing round for `n_tokens` under the given MoE spec, with
/// router logits drawn from a skewed popularity prior (experts are not
/// equally attractive to a trained token-choice router — that is exactly
/// what produces imbalance and drops).
pub fn simulate_routing(
    spec: &MoeSpec,
    n_tokens: usize,
    mesh: &MeshSpec,
    rng: &mut Rng,
) -> RoutingTraffic {
    let e = spec.num_experts;
    let ep = mesh.expert_parallel.max(1);
    // Skewed expert popularity (Zipf over experts).
    let popularity: Vec<f32> = (0..e).map(|i| 1.0 / (1.0 + i as f32).powf(0.7)).collect();

    let mut tokens_per_expert = vec![0usize; e];
    let mut offdevice = 0usize;
    let mut dropped = 0usize;
    let mut dispatched = 0usize;

    // Device of token t (data-parallel shard) and of expert x.
    let token_device = |t: usize| (t * ep) / n_tokens.max(1);
    let expert_device = |x: usize| x % ep;

    if spec.router_type == "ec" {
        // Expert Choice: each expert takes exactly c = n·C/E tokens.
        let c = ((n_tokens as f64 * spec.capacity_factor) / e as f64).max(1.0) as usize;
        for x in 0..e {
            for slot in 0..c {
                let t = rng.below(n_tokens);
                tokens_per_expert[x] += 1;
                dispatched += 1;
                if token_device(t) != expert_device(x) {
                    offdevice += 1;
                }
                let _ = slot;
            }
        }
    } else {
        let k = if spec.router_type == "top1" { 1 } else { 2 };
        let cap =
            (((n_tokens as f64) * spec.capacity_factor * k as f64) / e as f64).max(1.0) as usize;
        for t in 0..n_tokens {
            for _ in 0..k {
                let x = rng.categorical(&popularity);
                if tokens_per_expert[x] < cap {
                    tokens_per_expert[x] += 1;
                    dispatched += 1;
                    if token_device(t) != expert_device(x) {
                        offdevice += 1;
                    }
                } else {
                    dropped += 1;
                }
            }
        }
    }

    let max = *tokens_per_expert.iter().max().unwrap_or(&0) as f64;
    let mean = tokens_per_expert.iter().sum::<usize>() as f64 / e as f64;
    RoutingTraffic {
        tokens_per_expert,
        offdevice_tokens: offdevice,
        dispatched_tokens: dispatched,
        imbalance: if mean > 0.0 { max / mean } else { 1.0 },
        drop_fraction: dropped as f64
            / (n_tokens * (dispatched + dropped).max(1) / n_tokens.max(1)).max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ec_spec(e: usize, c: f64) -> MoeSpec {
        MoeSpec {
            num_experts: e,
            capacity_factor: c,
            router_type: "ec".into(),
            moe_layers: vec![1],
            group_size: 0,
            renormalize: false,
            bpr: false,
        }
    }

    #[test]
    fn expert_choice_is_perfectly_balanced() {
        let mesh = MeshSpec { data_parallel: 1, expert_parallel: 4, model_parallel: 1 };
        let t = simulate_routing(&ec_spec(8, 2.0), 256, &mesh, &mut Rng::new(0));
        assert!((t.imbalance - 1.0).abs() < 1e-9, "EC must be balanced by construction");
        assert_eq!(t.dispatched_tokens, 8 * (256 * 2 / 8));
        assert_eq!(t.drop_fraction, 0.0);
    }

    #[test]
    fn token_choice_skews_and_drops() {
        let mut spec = ec_spec(8, 1.0);
        spec.router_type = "top2".into();
        let mesh = MeshSpec { data_parallel: 1, expert_parallel: 4, model_parallel: 1 };
        let t = simulate_routing(&spec, 512, &mesh, &mut Rng::new(1));
        assert!(t.imbalance > 1.0, "skewed router must imbalance token choice");
        // Conservation: dispatched ≤ capacity bound.
        let cap = (512.0 * 1.0 * 2.0 / 8.0) as usize;
        assert!(t.tokens_per_expert.iter().all(|&n| n <= cap));
    }

    #[test]
    fn all_to_all_volume_scales_with_d_model() {
        let mesh = MeshSpec { data_parallel: 1, expert_parallel: 2, model_parallel: 1 };
        let t = simulate_routing(&ec_spec(4, 1.0), 128, &mesh, &mut Rng::new(2));
        assert_eq!(t.all_to_all_bytes(64) * 2, t.all_to_all_bytes(128));
    }

    #[test]
    fn mesh_accounting() {
        let mesh = MeshSpec { data_parallel: 2, expert_parallel: 4, model_parallel: 2 };
        assert_eq!(mesh.devices(), 16);
    }

    #[test]
    fn zero_expert_parallel_axis_is_normalized() {
        // A mesh with no expert axis must not divide by zero or report an
        // empty device set.
        let mesh = MeshSpec { data_parallel: 2, expert_parallel: 0, model_parallel: 1 };
        assert_eq!(mesh.devices(), 2);
        let m = crate::manifest::Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let rep = place(sparse, &mesh);
        assert_eq!(rep.devices, 2);
        // All experts land on the single (implicit) expert-parallel device.
        assert_eq!(rep.experts_per_device, vec![8]);
        assert!(rep.expert_param_bytes_per_device > 0);
    }

    #[test]
    fn topology_parse_accepts_axes_in_any_order() {
        let t = MeshSpec::parse("dp=2,ep=4").unwrap();
        assert_eq!((t.data_parallel, t.expert_parallel, t.model_parallel), (2, 4, 1));
        let t = MeshSpec::parse("tp=2, ep=1, dp=8").unwrap();
        assert_eq!((t.data_parallel, t.expert_parallel, t.model_parallel), (8, 1, 2));
        assert_eq!(t.ranks(), 8, "tp does not add executing ranks");
        // Malformed strings fail with the axis named.
        for bad in ["", "dp=2", "ep=2", "dp=2,ep=x", "dp=2,ep=2,zz=1", "dp=2,dp=2,ep=1", "2x2"] {
            let err = MeshSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains("topology"), "`{bad}`: {err}");
        }
    }

    #[test]
    fn replica_validation_is_actionable_at_config_time() {
        let m = crate::manifest::Manifest::native();
        let entry = m.model("lm_tiny_moe_e8_c2").unwrap();
        let dp_mode = MeshMode::DataParallel { max_workers: Some(64) };
        // batch_size 8: divisors are valid (given enough workers), 3 is not.
        for r in [1usize, 2, 4, 8] {
            MeshSpec::data_parallel_only(r).validate(entry, dp_mode).unwrap();
        }
        let err =
            MeshSpec::data_parallel_only(3).validate(entry, dp_mode).unwrap_err().to_string();
        assert!(err.contains("lm_tiny_moe_e8_c2") && err.contains("[1, 2, 4, 8]"), "{err}");
        assert!(MeshSpec::data_parallel_only(0).validate(entry, dp_mode).is_err());
        assert!(
            MeshSpec::data_parallel_only(16).validate(entry, dp_mode).is_err(),
            "16 > batch 8 must fail"
        );
        // Exceeding the host's worker budget is rejected with a hint.
        let err = MeshSpec::data_parallel_only(8)
            .validate(entry, MeshMode::DataParallel { max_workers: Some(2) })
            .unwrap_err()
            .to_string();
        assert!(err.contains("available parallelism") && err.contains("accumulated"), "{err}");
        // Data-parallel mode refuses a plan with real ep/tp axes.
        let err = MeshSpec::new(2, 2).validate(entry, dp_mode).unwrap_err().to_string();
        assert!(err.contains("dp-only"), "{err}");
    }

    #[test]
    fn mesh_validation_catches_impossible_axes() {
        let m = crate::manifest::Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let dense = m.model("lm_tiny_dense").unwrap();
        let ok = MeshSpec { data_parallel: 2, expert_parallel: 4, model_parallel: 1 };
        ok.validate(sparse, MeshMode::Sim).unwrap();
        // More expert-parallel devices than experts.
        let bad = MeshSpec { data_parallel: 1, expert_parallel: 16, model_parallel: 1 };
        let err = bad.validate(sparse, MeshMode::Sim).unwrap_err().to_string();
        assert!(err.contains("8 experts"), "{err}");
        // A dense model ignores the expert axis (the CLI default mesh has
        // ep=4; `upcycle mesh` on a dense entry must keep working).
        ok.validate(dense, MeshMode::Sim).unwrap();
        // Batch that does not shard over the data axis.
        let bad = MeshSpec { data_parallel: 3, expert_parallel: 1, model_parallel: 1 };
        assert!(bad.validate(dense, MeshMode::Sim).is_err());
        // Zero axes normalize instead of erroring.
        let zeroes = MeshSpec { data_parallel: 0, expert_parallel: 0, model_parallel: 0 };
        zeroes.validate(sparse, MeshMode::Sim).unwrap();
        // The tp axis is bounded by d_model in every mode.
        let fat_tp = MeshSpec { data_parallel: 1, expert_parallel: 1, model_parallel: 1 << 20 };
        let err = fat_tp.validate(sparse, MeshMode::Sim).unwrap_err().to_string();
        assert!(err.contains("d_model"), "{err}");
        assert!(fat_tp.validate(sparse, MeshMode::Exec).is_err());
    }

    #[test]
    fn expert_placement_partitions_experts() {
        let p = ExpertPlacement::new(8, 4);
        // Ownership is a partition: every expert owned exactly once.
        let mut seen = vec![0usize; 8];
        for r in 0..4 {
            for x in p.owned(r) {
                assert_eq!(p.owner(x), r);
                seen[x] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "each expert owned exactly once: {seen:?}");
        // Uneven counts round-robin (7 experts on 4 ranks: 2/2/2/1).
        let p = ExpertPlacement::new(7, 4);
        let sizes: Vec<usize> = (0..4).map(|r| p.owned(r).len()).collect();
        assert_eq!(sizes, vec![2, 2, 2, 1]);
        // A zero rank axis normalizes to one owner.
        assert_eq!(ExpertPlacement::new(3, 0).owned(0), vec![0, 1, 2]);
    }

    #[test]
    fn place_report_matches_expert_placement() {
        let m = crate::manifest::Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let mesh = MeshSpec { data_parallel: 1, expert_parallel: 4, model_parallel: 1 };
        let rep = place(sparse, &mesh);
        let placement = ExpertPlacement::new(8, 4);
        let expect: Vec<usize> = (0..4).map(|r| placement.owned(r).len()).collect();
        assert_eq!(rep.experts_per_device, expect);
    }

    #[test]
    fn mesh_exec_validation_is_actionable() {
        let m = crate::manifest::Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let dense = m.model("lm_tiny_dense").unwrap();
        // batch 8, E=8: 2x2 / 1x2 / 2x4 / 1x8 all shard cleanly.
        for (dp, ep) in [(1usize, 1usize), (1, 2), (2, 2), (2, 4), (1, 8)] {
            MeshSpec::new(dp, ep).validate(sparse, MeshMode::Exec).unwrap();
        }
        // Zero axes and indivisible rank counts fail with named errors.
        assert!(MeshSpec::new(0, 2).validate(sparse, MeshMode::Exec).is_err());
        let err = MeshSpec::new(3, 1).validate(sparse, MeshMode::Exec).unwrap_err().to_string();
        assert!(err.contains("batch_size 8") && err.contains("3x1"), "{err}");
        // More EP ranks than experts.
        let err = MeshSpec::new(1, 16).validate(sparse, MeshMode::Exec).unwrap_err().to_string();
        assert!(err.contains("8 experts"), "{err}");
        // A dense model has nothing to shard on the expert axis.
        let err = MeshSpec::new(1, 2).validate(dense, MeshMode::Exec).unwrap_err().to_string();
        assert!(err.contains("dense"), "{err}");
        MeshSpec::new(2, 1).validate(dense, MeshMode::Exec).unwrap();
    }

    #[test]
    fn dense_entry_places_without_experts() {
        let m = crate::manifest::Manifest::native();
        let dense = m.model("lm_tiny_dense").unwrap();
        let mesh = MeshSpec { data_parallel: 2, expert_parallel: 4, model_parallel: 1 };
        let rep = place(dense, &mesh);
        assert!(rep.experts_per_device.is_empty(), "dense entry has no expert placement");
        assert_eq!(rep.expert_param_bytes_per_device, 0);
        assert_eq!(rep.dense_param_bytes, dense.param_count * 4);
        // Degenerate all-zero mesh still reports one device.
        let zero = MeshSpec { data_parallel: 0, expert_parallel: 0, model_parallel: 0 };
        assert_eq!(place(dense, &zero).devices, 1);
    }
}
