//! Collectives: the in-process reductions and exchanges used by data- and
//! expert-parallel training, plus the communication cost model (paper §A.4).
//!
//! **Functional collectives.** [`reduce_sum_ordered`] / [`allreduce_mean`]
//! are the real reductions behind `coordinator::trainer::dp_train_step`:
//! replica gradient buffers are combined **in ascending rank order** —
//! `((g₀ + g₁) + g₂) + …` exactly — which is the same floating-point
//! reduction a single replica performs when it accumulates the same
//! microbatches sequentially. That ordering invariant is what makes
//! N-replica training bitwise-identical to single-replica gradient
//! accumulation on the same effective batch (asserted by the trainer's
//! tests); do not replace it with a tree or pairwise order without
//! re-deriving that guarantee. [`all_to_all`] is the functional form of the
//! MoE dispatch/combine exchange: rank `dst` receives what every `src` sent
//! it, **in ascending source order** — same discipline, applied to payload
//! placement instead of addition.
//!
//! **Thread rendezvous.** [`EpGroup`] is the split-phase counterpart of
//! [`all_to_all`] for expert-parallel rank *threads*
//! (`coordinator::trainer::mesh_train_step`): [`EpGroup::start_exchange`]
//! posts a rank's send row without blocking (per-channel FIFO queues, one
//! per `(src, dst)` pair), and [`EpGroup::finish_exchange`] later blocks
//! until every source's payload for that round has arrived, collecting the
//! receive column in ascending source order. The split is what lets the
//! overlapped runtime (`runtime::ep`) post microbatch `k+1`'s all-to-all
//! before computing microbatch `k` — the exposed wait shrinks to pipeline
//! fill/drain. [`EpGroup::exchange`] composes the two legs back into the
//! fused blocking call. Payload placement is a pure function of rank
//! indices and FIFO round order, so thread scheduling can never reorder
//! data; per-payload tags catch protocol divergence at collection time. A
//! rank that fails mid-protocol aborts the group instead of leaving its
//! peers blocked in a completion wait forever.
//!
//! **Cost model.** The paper composes data / expert / model parallelism;
//! the communication patterns behind them are all-to-all (MoE dispatch +
//! combine), all-reduce (data-parallel gradients) and all-gather
//! (model-parallel activations). [`Interconnect`] prices them on an
//! abstract link (per-link bandwidth + latency), so the placement simulator
//! can answer the §A.4 question the paper settles by construction on TPU
//! pods: which parallelism axis saturates first as E, C and the mesh grow.
//! Exercised by `cargo bench --bench routing_sim` and unit tests; the
//! `runtime_step` bench compares [`Interconnect::shared_memory`]'s
//! all-to-all prediction against the measured [`EpGroup`] exchange time.

use std::sync::{Condvar, Mutex};

use anyhow::{bail, Result};

/// Sum equal-length replica buffers in ascending rank order:
/// `((bufs[0] + bufs[1]) + bufs[2]) + …`, consuming the inputs.
///
/// The rank-ordered reduction is deliberate — see the module docs for the
/// determinism contract it upholds.
///
/// ```
/// use sparse_upcycle::parallel::collectives::reduce_sum_ordered;
/// let total = reduce_sum_ordered(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
/// assert_eq!(total, vec![4.0, 6.0]);
/// ```
pub fn reduce_sum_ordered(bufs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    let mut it = bufs.into_iter();
    let Some(mut acc) = it.next() else {
        bail!("reduce_sum_ordered: no buffers to reduce");
    };
    for (rank, buf) in it.enumerate() {
        if buf.len() != acc.len() {
            bail!(
                "reduce_sum_ordered: rank {} buffer has {} elements, rank 0 has {}",
                rank + 1,
                buf.len(),
                acc.len()
            );
        }
        for (a, b) in acc.iter_mut().zip(&buf) {
            *a += *b;
        }
    }
    Ok(acc)
}

/// Rank-ordered all-reduce-mean: [`reduce_sum_ordered`] scaled by `1/R`.
/// Every replica would observe this same buffer; in-process we return one.
pub fn allreduce_mean(bufs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    let r = bufs.len();
    let mut acc = reduce_sum_ordered(bufs)?;
    let inv = 1.0 / r as f32;
    for v in acc.iter_mut() {
        *v *= inv;
    }
    Ok(acc)
}

/// Functional all-to-all: `sends[src][dst]` is what rank `src` sends to
/// rank `dst`; the result's `recv[dst][src]` is what `dst` received from
/// `src`. Deterministic and rank-ordered by construction — the output is a
/// pure transpose of the input matrix, so no execution order can reorder
/// payloads. The send matrix must be square (`R` rows of `R` payloads).
///
/// ```
/// use sparse_upcycle::parallel::collectives::all_to_all;
/// let recv = all_to_all(vec![vec!["a0", "a1"], vec!["b0", "b1"]]).unwrap();
/// assert_eq!(recv, vec![vec!["a0", "b0"], vec!["a1", "b1"]]);
/// ```
pub fn all_to_all<T>(sends: Vec<Vec<T>>) -> Result<Vec<Vec<T>>> {
    let r = sends.len();
    for (src, row) in sends.iter().enumerate() {
        if row.len() != r {
            bail!("all_to_all: rank {src} sends {} payloads for {r} ranks", row.len());
        }
    }
    let mut recv: Vec<Vec<T>> = (0..r).map(|_| Vec::with_capacity(r)).collect();
    // Ascending source order: each receive row is filled src = 0, 1, …
    for row in sends.into_iter() {
        for (dst, payload) in row.into_iter().enumerate() {
            recv[dst].push(payload);
        }
    }
    Ok(recv)
}

/// The message every rank blocked in an aborted [`EpGroup`] collective
/// errors with. The mesh trainer matches on it to distinguish peer-abort
/// echoes from a failing rank's root-cause error — keep them in sync
/// through this constant.
pub const EP_ABORTED_MSG: &str = "expert-parallel collective aborted by a failed rank";

/// Split-phase all-to-all rendezvous for `R` expert-parallel rank threads —
/// the threaded counterpart of [`all_to_all`].
///
/// A round has two legs. [`EpGroup::start_exchange`] posts the rank's send
/// row (`send[dst]` = payload for rank `dst`) onto per-channel FIFO queues
/// and returns immediately — nothing blocks on peers. A later
/// [`EpGroup::finish_exchange`] with the same `tag` blocks until every
/// source's payload for this rank's head-of-queue round has arrived and
/// returns the receive column (`recv[src]` = payload from rank `src`,
/// ascending source order). Multiple rounds may be in flight per rank —
/// that is the point: the overlapped expert-parallel runtime posts
/// microbatch `k+1`'s dispatch before computing microbatch `k`, so by the
/// time it calls the matching `finish_exchange`, peers (which post before
/// they compute too) have usually already delivered. [`EpGroup::exchange`]
/// is the fused form (`start` + `finish` back to back) for callers that
/// want the old blocking semantics.
///
/// Determinism: payload placement depends only on `(src, dst)` indices and
/// FIFO round order — thread scheduling affects *when* a payload moves,
/// never *where*. Every payload carries its round's tag, and
/// `finish_exchange` verifies the tag of each payload it pops: two ranks
/// disagreeing on the protocol position (a routing divergence bug, or
/// mismatched microbatch counts across the group) fail loudly instead of
/// silently swapping tensors. Any rank erroring mid-step should call
/// [`EpGroup::abort`] so blocked peers return an error instead of hanging.
pub struct EpGroup<T> {
    ranks: usize,
    state: Mutex<EpGroupState<T>>,
    cv: Condvar,
}

struct EpGroupState<T> {
    /// `queues[src * ranks + dst]`: tagged payloads in flight from `src` to
    /// `dst`, FIFO per channel (front = oldest posted round).
    queues: Vec<std::collections::VecDeque<(String, T)>>,
    aborted: bool,
    /// Root cause recorded by the first abort (later aborts keep it).
    abort_reason: Option<String>,
}

impl<T> EpGroupState<T> {
    fn abort_err(&self) -> anyhow::Error {
        match &self.abort_reason {
            Some(r) => anyhow::anyhow!("{EP_ABORTED_MSG}: {r}"),
            None => anyhow::anyhow!("{EP_ABORTED_MSG}"),
        }
    }
}

impl<T: Send> EpGroup<T> {
    pub fn new(ranks: usize) -> EpGroup<T> {
        let ranks = ranks.max(1);
        EpGroup {
            ranks,
            state: Mutex::new(EpGroupState {
                queues: (0..ranks * ranks).map(|_| std::collections::VecDeque::new()).collect(),
                aborted: false,
                abort_reason: None,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Release every rank blocked in a completion wait with an error, and
    /// fail all subsequent starts/finishes on this group.
    pub fn abort(&self) {
        self.abort_inner(None);
    }

    /// [`EpGroup::abort`], recording the failing rank's root cause: every
    /// peer's error reads `"<EP_ABORTED_MSG>: <reason>"` instead of the
    /// bare abort message. The first recorded reason wins — a cascade of
    /// secondary aborts can never overwrite the original cause.
    pub fn abort_with(&self, reason: &str) {
        self.abort_inner(Some(reason));
    }

    fn abort_inner(&self, reason: Option<&str>) {
        let mut st = self.state.lock().expect("ep group state");
        st.aborted = true;
        if st.abort_reason.is_none() {
            st.abort_reason = reason.map(|r| r.to_string());
        }
        self.cv.notify_all();
    }

    /// Non-blocking send leg: post this rank's send row for round `tag`.
    /// Returns as soon as the payloads are queued; peers observe them from
    /// their matching [`EpGroup::finish_exchange`]. Malformed sends abort
    /// the group (a misaddressed rank must not leave peers blocked in a
    /// completion wait forever), carrying their cause so survivors report
    /// it verbatim.
    pub fn start_exchange(&self, rank: usize, tag: &str, send: Vec<T>) -> Result<()> {
        if rank >= self.ranks {
            let msg =
                format!("exchange `{tag}`: rank {rank} out of range for {} ranks", self.ranks);
            self.abort_with(&msg);
            bail!("{msg}");
        }
        if send.len() != self.ranks {
            let msg = format!(
                "exchange `{tag}`: rank {rank} sends {} payloads for {} ranks",
                send.len(),
                self.ranks
            );
            self.abort_with(&msg);
            bail!("{msg}");
        }
        let mut st = self.state.lock().expect("ep group state");
        if st.aborted {
            return Err(st.abort_err());
        }
        for (dst, payload) in send.into_iter().enumerate() {
            st.queues[rank * self.ranks + dst].push_back((tag.to_string(), payload));
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking completion leg: collect the receive column of the oldest
    /// outstanding round, verifying it is the round `tag` names. Blocks per
    /// source channel until that source's payload arrives; a popped payload
    /// whose tag differs from `tag` is protocol divergence and aborts the
    /// group. Rounds complete in the FIFO order they were started.
    pub fn finish_exchange(&self, rank: usize, tag: &str) -> Result<Vec<T>> {
        if rank >= self.ranks {
            let msg =
                format!("exchange `{tag}`: rank {rank} out of range for {} ranks", self.ranks);
            self.abort_with(&msg);
            bail!("{msg}");
        }
        let mut recv = Vec::with_capacity(self.ranks);
        let mut st = self.state.lock().expect("ep group state");
        for src in 0..self.ranks {
            loop {
                if st.aborted {
                    return Err(st.abort_err());
                }
                if let Some((got, payload)) = st.queues[src * self.ranks + rank].pop_front() {
                    if got != tag {
                        drop(st);
                        let msg = format!(
                            "exchange `{tag}`: rank {rank} popped round `{got}` from {src} \
                             (protocol divergence)"
                        );
                        self.abort_with(&msg);
                        bail!("{msg}");
                    }
                    recv.push(payload);
                    break;
                }
                st = self.cv.wait(st).expect("ep group wait");
            }
        }
        Ok(recv)
    }

    /// One fused tagged all-to-all round: [`EpGroup::start_exchange`]
    /// immediately followed by [`EpGroup::finish_exchange`].
    pub fn exchange(&self, rank: usize, tag: &str, send: Vec<T>) -> Result<Vec<T>> {
        self.start_exchange(rank, tag, send)?;
        self.finish_exchange(rank, tag)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-link bandwidth, bytes/second.
    pub link_bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Number of devices participating.
    pub devices: usize,
}

impl Interconnect {
    /// TPUv3-ish ICI defaults: ~70 GB/s links, ~1 µs latency.
    pub fn tpu_like(devices: usize) -> Interconnect {
        Interconnect { link_bandwidth: 70e9, latency: 1e-6, devices }
    }

    /// In-process shared-memory "interconnect": rank threads exchanging
    /// buffers through [`EpGroup`] on one host. ~8 GB/s effective memcpy
    /// bandwidth per link and ~3 µs per rendezvous (mutex + condvar barrier
    /// handoff). The `runtime_step` bench compares this model's all-to-all
    /// prediction against the measured exchange time and records the error.
    pub fn shared_memory(devices: usize) -> Interconnect {
        Interconnect { link_bandwidth: 8e9, latency: 3e-6, devices }
    }

    /// Ring all-reduce of `bytes` per device: 2(n-1)/n · bytes over the
    /// slowest link + 2(n-1) latency hops (bandwidth-optimal ring).
    pub fn allreduce_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        2.0 * (n - 1.0) / n * bytes as f64 / self.link_bandwidth
            + 2.0 * (n - 1.0) * self.latency
    }

    /// All-gather of `bytes` per device (each device ends with n·bytes).
    pub fn allgather_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) / n * (n * bytes as f64) / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Balanced all-to-all where every device sends `bytes_per_peer` to each
    /// of the other n-1 devices (the MoE dispatch/combine pattern with
    /// Expert Choice routing — balanced by construction, paper §2.1).
    pub fn alltoall_time(&self, bytes_per_peer: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) * bytes_per_peer as f64 / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Skewed all-to-all: the hot device receives `max_bytes` while others
    /// receive `mean_bytes` — token-choice routing's imbalance stretches the
    /// collective to the hottest receiver.
    pub fn alltoall_time_skewed(&self, mean_bytes: usize, max_bytes: usize) -> f64 {
        self.alltoall_time(mean_bytes.max(1))
            * (max_bytes as f64 / mean_bytes.max(1) as f64)
    }
}

/// One training step's communication bill for a sparse model under the
/// three-axis mesh (paper §A.4): expert all-to-all (dispatch+combine, per
/// MoE layer, fwd+bwd), data-parallel gradient all-reduce, model-parallel
/// activation all-gathers.
#[derive(Debug, Clone)]
pub struct StepCommsReport {
    pub expert_alltoall_s: f64,
    pub grad_allreduce_s: f64,
    pub mp_allgather_s: f64,
}

impl StepCommsReport {
    pub fn total(&self) -> f64 {
        self.expert_alltoall_s + self.grad_allreduce_s + self.mp_allgather_s
    }
}

pub fn step_comms(
    entry: &crate::manifest::ModelEntry,
    mesh: &crate::parallel::MeshSpec,
    net: &Interconnect,
    tokens_per_device: usize,
    imbalance: f64,
) -> StepCommsReport {
    let d = entry.config.d_model;
    let n_moe_layers = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.moe_layers.len())
        .unwrap_or(0)
        + entry
            .config
            .dec_moe
            .as_ref()
            .map(|m| m.moe_layers.len())
            .unwrap_or(0);
    let cap = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.capacity_factor)
        .unwrap_or(1.0);

    let ep_net = Interconnect { devices: mesh.expert_parallel, ..*net };
    // dispatch + combine, forward + backward = 4 all-to-alls per MoE layer.
    let bytes_per_peer =
        (tokens_per_device as f64 * cap * d as f64 * 4.0 / mesh.expert_parallel.max(1) as f64)
            as usize;
    let mean = bytes_per_peer.max(1);
    let max = (mean as f64 * imbalance) as usize;
    let expert_alltoall_s =
        4.0 * n_moe_layers as f64 * ep_net.alltoall_time_skewed(mean, max);

    let dp_net = Interconnect { devices: mesh.data_parallel, ..*net };
    let grad_allreduce_s = dp_net.allreduce_time(entry.param_count * 4);

    let mp_net = Interconnect { devices: mesh.model_parallel, ..*net };
    // One activation all-gather per block, fwd+bwd.
    let blocks = entry.config.num_layers + entry.config.num_decoder_layers;
    let mp_allgather_s =
        2.0 * blocks as f64 * mp_net.allgather_time(tokens_per_device * d * 4);

    StepCommsReport { expert_alltoall_s, grad_allreduce_s, mp_allgather_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_rank_ordered_and_checked() {
        // Rank order matters in f32: pick values where order changes bits.
        let a = vec![1.0e8f32, 1.0];
        let b = vec![1.0f32, -1.0e8];
        let c = vec![-1.0e8f32, 1.0e8];
        let seq = {
            let mut acc = a.clone();
            for buf in [&b, &c] {
                for (x, y) in acc.iter_mut().zip(buf.iter()) {
                    *x += *y;
                }
            }
            acc
        };
        let red = reduce_sum_ordered(vec![a, b, c]).unwrap();
        assert_eq!(seq, red, "collective must match sequential accumulation bitwise");
        assert!(reduce_sum_ordered(vec![]).is_err());
        assert!(reduce_sum_ordered(vec![vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn allreduce_mean_scales() {
        let m = allreduce_mean(vec![vec![2.0, 4.0], vec![6.0, 8.0]]).unwrap();
        assert_eq!(m, vec![4.0, 6.0]);
    }

    #[test]
    fn single_device_is_free() {
        let net = Interconnect::tpu_like(1);
        assert_eq!(net.allreduce_time(1 << 20), 0.0);
        assert_eq!(net.allgather_time(1 << 20), 0.0);
        assert_eq!(net.alltoall_time(1 << 20), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_latency_with_devices() {
        let a = Interconnect::tpu_like(8);
        assert!(a.allreduce_time(2 << 20) > a.allreduce_time(1 << 20));
        let b = Interconnect::tpu_like(64);
        // For tiny payloads, latency term dominates and grows with n.
        assert!(b.allreduce_time(64) > a.allreduce_time(64));
    }

    #[test]
    fn skew_stretches_alltoall() {
        let net = Interconnect::tpu_like(8);
        let balanced = net.alltoall_time_skewed(1 << 20, 1 << 20);
        let skewed = net.alltoall_time_skewed(1 << 20, 3 << 20);
        assert!((skewed / balanced - 3.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_optimal_ring_bound() {
        // 2(n-1)/n·bytes / BW is the textbook lower bound; check we match it
        // (plus latency) rather than the naive n·bytes.
        let net = Interconnect { link_bandwidth: 1e9, latency: 0.0, devices: 4 };
        let t = net.allreduce_time(1_000_000_000);
        assert!((t - 1.5).abs() < 1e-9, "got {t}");
    }

    #[test]
    fn all_to_all_is_a_transpose() {
        let sends: Vec<Vec<(usize, usize)>> =
            (0..3).map(|src| (0..3).map(|dst| (src, dst)).collect()).collect();
        let recv = all_to_all(sends).unwrap();
        for (dst, row) in recv.iter().enumerate() {
            for (src, &(s, d)) in row.iter().enumerate() {
                assert_eq!((s, d), (src, dst), "recv[{dst}][{src}] must come from src {src}");
            }
        }
        // Non-square matrices are rejected.
        assert!(all_to_all(vec![vec![1], vec![2, 3]]).is_err());
        // Degenerate cases.
        assert_eq!(all_to_all(Vec::<Vec<u8>>::new()).unwrap(), Vec::<Vec<u8>>::new());
        assert_eq!(all_to_all(vec![vec![7u8]]).unwrap(), vec![vec![7u8]]);
    }

    #[test]
    fn ep_group_exchanges_across_threads() {
        let ranks = 3;
        let group = EpGroup::<(usize, usize, u64)>::new(ranks);
        let out: Vec<Vec<(usize, usize, u64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..ranks)
                .map(|r| {
                    let group = &group;
                    s.spawn(move || {
                        // Two rounds, to exercise barrier/slot reuse.
                        let mut last = Vec::new();
                        for round in 0..2u64 {
                            let send: Vec<(usize, usize, u64)> =
                                (0..ranks).map(|dst| (r, dst, round)).collect();
                            last = group.exchange(r, &format!("round{round}"), send).unwrap();
                        }
                        last
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (dst, recv) in out.iter().enumerate() {
            for (src, &(s_, d_, round)) in recv.iter().enumerate() {
                assert_eq!((s_, d_, round), (src, dst, 1), "payload routed wrong");
            }
        }
    }

    #[test]
    fn ep_group_single_rank_is_self_exchange() {
        let group = EpGroup::<Vec<f32>>::new(1);
        let recv = group.exchange(0, "solo", vec![vec![1.0, 2.0]]).unwrap();
        assert_eq!(recv, vec![vec![1.0, 2.0]]);
    }

    #[test]
    fn ep_group_abort_releases_waiters() {
        let group = EpGroup::<u8>::new(2);
        let res: Vec<Result<Vec<u8>>> = std::thread::scope(|s| {
            let h0 = {
                let group = &group;
                s.spawn(move || group.exchange(0, "t", vec![0, 0]))
            };
            let h1 = {
                let group = &group;
                s.spawn(move || {
                    // Rank 1 dies before exchanging; peers must not hang.
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    group.abort();
                    Err(anyhow::anyhow!("rank 1 failed"))
                })
            };
            vec![h0.join().unwrap(), h1.join().unwrap()]
        });
        assert!(res.iter().all(|r| r.is_err()), "abort must release blocked ranks with Err");
    }

    /// An abort that names its cause surfaces that cause in every blocked
    /// peer's error — and the first recorded reason wins over later ones.
    #[test]
    fn abort_reason_reaches_blocked_peers() {
        let group = EpGroup::<u8>::new(2);
        let res: Vec<Result<Vec<u8>>> = std::thread::scope(|s| {
            let h0 = {
                let group = &group;
                s.spawn(move || group.exchange(0, "t", vec![0, 0]))
            };
            let h1 = {
                let group = &group;
                s.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    group.abort_with("rank 1 lost its lease");
                    group.abort_with("a later echo that must not win");
                    Err(anyhow::anyhow!("rank 1 lost its lease"))
                })
            };
            vec![h0.join().unwrap(), h1.join().unwrap()]
        });
        let peer_err = format!("{:#}", res[0].as_ref().unwrap_err());
        assert!(peer_err.contains(EP_ABORTED_MSG), "{peer_err}");
        assert!(peer_err.contains("rank 1 lost its lease"), "{peer_err}");
        assert!(!peer_err.contains("later echo"), "first reason must win: {peer_err}");
    }

    #[test]
    fn ep_group_rejects_malformed_sends() {
        let group = EpGroup::<u8>::new(2);
        // Wrong payload count fails immediately (and aborts the group).
        assert!(group.exchange(0, "bad", vec![1]).is_err());
        assert!(group.exchange(5, "bad", vec![1, 2]).is_err());
    }

    /// The split-phase contract: a rank may post several rounds before
    /// completing any of them, and completions drain in FIFO round order
    /// with every payload routed by `(src, dst)` — the shape of the
    /// double-buffered microbatch pipeline.
    #[test]
    fn split_phase_rounds_pipeline_in_fifo_order() {
        let ranks = 2;
        let group = EpGroup::<(usize, usize, u64)>::new(ranks);
        let out: Vec<Vec<Vec<(usize, usize, u64)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..ranks)
                .map(|r| {
                    let group = &group;
                    s.spawn(move || {
                        // Post all three rounds up front, then drain.
                        for round in 0..3u64 {
                            let send: Vec<_> = (0..ranks).map(|dst| (r, dst, round)).collect();
                            group.start_exchange(r, &format!("mb{round}"), send).unwrap();
                        }
                        (0..3u64)
                            .map(|round| group.finish_exchange(r, &format!("mb{round}")).unwrap())
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (dst, rounds) in out.iter().enumerate() {
            for (round, recv) in rounds.iter().enumerate() {
                for (src, &(s_, d_, m_)) in recv.iter().enumerate() {
                    assert_eq!((s_, d_, m_), (src, dst, round as u64), "payload routed wrong");
                }
            }
        }
    }

    /// Ranks that disagree on the round tag (protocol divergence, e.g.
    /// mismatched microbatch counts across the group) fail loudly at the
    /// completion leg instead of silently swapping tensors.
    #[test]
    fn split_phase_detects_tag_divergence() {
        let group = EpGroup::<u8>::new(2);
        let res: Vec<Result<Vec<u8>>> = std::thread::scope(|s| {
            let h0 = {
                let group = &group;
                s.spawn(move || {
                    group.start_exchange(0, "mb0", vec![0, 0])?;
                    group.finish_exchange(0, "mb0")
                })
            };
            let h1 = {
                let group = &group;
                s.spawn(move || {
                    group.start_exchange(1, "mb-other", vec![1, 1])?;
                    group.finish_exchange(1, "mb-other")
                })
            };
            vec![h0.join().unwrap(), h1.join().unwrap()]
        });
        assert!(res.iter().all(|r| r.is_err()), "divergent tags must fail both ranks");
        let msgs: Vec<String> =
            res.iter().map(|r| format!("{:#}", r.as_ref().unwrap_err())).collect();
        assert!(
            msgs.iter().any(|m| m.contains("protocol divergence")),
            "one rank must name the divergence: {msgs:?}"
        );
    }

    /// An abort landing between a rank's `start_exchange` and
    /// `finish_exchange` — the window the overlapped pipeline keeps open —
    /// must release the blocked completion leg with the root cause, and
    /// fail any later start on the torn group.
    #[test]
    fn abort_lands_between_start_and_finish() {
        let group = EpGroup::<u8>::new(2);
        let res: Result<Vec<u8>> = std::thread::scope(|s| {
            let h0 = {
                let group = &group;
                s.spawn(move || {
                    group.start_exchange(0, "mb0", vec![0, 0])?;
                    // Rank 1 dies while our round is in flight.
                    group.finish_exchange(0, "mb0")
                })
            };
            let group = &group;
            s.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                group.abort_with("rank 1 killed mid-exchange");
            });
            h0.join().unwrap()
        });
        let err = format!("{:#}", res.unwrap_err());
        assert!(err.contains(EP_ABORTED_MSG), "{err}");
        assert!(err.contains("killed mid-exchange"), "{err}");
        assert!(group.start_exchange(0, "mb1", vec![0, 0]).is_err(), "torn group must not post");
    }
}
