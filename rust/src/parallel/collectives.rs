//! Collectives: the in-process gradient reductions used by data-parallel
//! training, plus the communication cost model (paper §A.4).
//!
//! **Functional collectives.** [`reduce_sum_ordered`] / [`allreduce_mean`]
//! are the real reductions behind `coordinator::trainer::dp_train_step`:
//! replica gradient buffers are combined **in ascending rank order** —
//! `((g₀ + g₁) + g₂) + …` exactly — which is the same floating-point
//! reduction a single replica performs when it accumulates the same
//! microbatches sequentially. That ordering invariant is what makes
//! N-replica training bitwise-identical to single-replica gradient
//! accumulation on the same effective batch (asserted by the trainer's
//! tests); do not replace it with a tree or pairwise order without
//! re-deriving that guarantee.
//!
//! **Cost model.** The paper composes data / expert / model parallelism;
//! the communication patterns behind them are all-to-all (MoE dispatch +
//! combine), all-reduce (data-parallel gradients) and all-gather
//! (model-parallel activations). [`Interconnect`] prices them on an
//! abstract link (per-link bandwidth + latency), so the placement simulator
//! can answer the §A.4 question the paper settles by construction on TPU
//! pods: which parallelism axis saturates first as E, C and the mesh grow.
//! Exercised by `cargo bench --bench routing_sim` and unit tests.

use anyhow::{bail, Result};

/// Sum equal-length replica buffers in ascending rank order:
/// `((bufs[0] + bufs[1]) + bufs[2]) + …`, consuming the inputs.
///
/// The rank-ordered reduction is deliberate — see the module docs for the
/// determinism contract it upholds.
///
/// ```
/// use sparse_upcycle::parallel::collectives::reduce_sum_ordered;
/// let total = reduce_sum_ordered(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
/// assert_eq!(total, vec![4.0, 6.0]);
/// ```
pub fn reduce_sum_ordered(bufs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    let mut it = bufs.into_iter();
    let Some(mut acc) = it.next() else {
        bail!("reduce_sum_ordered: no buffers to reduce");
    };
    for (rank, buf) in it.enumerate() {
        if buf.len() != acc.len() {
            bail!(
                "reduce_sum_ordered: rank {} buffer has {} elements, rank 0 has {}",
                rank + 1,
                buf.len(),
                acc.len()
            );
        }
        for (a, b) in acc.iter_mut().zip(&buf) {
            *a += *b;
        }
    }
    Ok(acc)
}

/// Rank-ordered all-reduce-mean: [`reduce_sum_ordered`] scaled by `1/R`.
/// Every replica would observe this same buffer; in-process we return one.
pub fn allreduce_mean(bufs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
    let r = bufs.len();
    let mut acc = reduce_sum_ordered(bufs)?;
    let inv = 1.0 / r as f32;
    for v in acc.iter_mut() {
        *v *= inv;
    }
    Ok(acc)
}

#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-link bandwidth, bytes/second.
    pub link_bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Number of devices participating.
    pub devices: usize,
}

impl Interconnect {
    /// TPUv3-ish ICI defaults: ~70 GB/s links, ~1 µs latency.
    pub fn tpu_like(devices: usize) -> Interconnect {
        Interconnect { link_bandwidth: 70e9, latency: 1e-6, devices }
    }

    /// Ring all-reduce of `bytes` per device: 2(n-1)/n · bytes over the
    /// slowest link + 2(n-1) latency hops (bandwidth-optimal ring).
    pub fn allreduce_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        2.0 * (n - 1.0) / n * bytes as f64 / self.link_bandwidth
            + 2.0 * (n - 1.0) * self.latency
    }

    /// All-gather of `bytes` per device (each device ends with n·bytes).
    pub fn allgather_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) / n * (n * bytes as f64) / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Balanced all-to-all where every device sends `bytes_per_peer` to each
    /// of the other n-1 devices (the MoE dispatch/combine pattern with
    /// Expert Choice routing — balanced by construction, paper §2.1).
    pub fn alltoall_time(&self, bytes_per_peer: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) * bytes_per_peer as f64 / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Skewed all-to-all: the hot device receives `max_bytes` while others
    /// receive `mean_bytes` — token-choice routing's imbalance stretches the
    /// collective to the hottest receiver.
    pub fn alltoall_time_skewed(&self, mean_bytes: usize, max_bytes: usize) -> f64 {
        self.alltoall_time(mean_bytes.max(1))
            * (max_bytes as f64 / mean_bytes.max(1) as f64)
    }
}

/// One training step's communication bill for a sparse model under the
/// three-axis mesh (paper §A.4): expert all-to-all (dispatch+combine, per
/// MoE layer, fwd+bwd), data-parallel gradient all-reduce, model-parallel
/// activation all-gathers.
#[derive(Debug, Clone)]
pub struct StepCommsReport {
    pub expert_alltoall_s: f64,
    pub grad_allreduce_s: f64,
    pub mp_allgather_s: f64,
}

impl StepCommsReport {
    pub fn total(&self) -> f64 {
        self.expert_alltoall_s + self.grad_allreduce_s + self.mp_allgather_s
    }
}

pub fn step_comms(
    entry: &crate::manifest::ModelEntry,
    mesh: &crate::parallel::MeshSpec,
    net: &Interconnect,
    tokens_per_device: usize,
    imbalance: f64,
) -> StepCommsReport {
    let d = entry.config.d_model;
    let n_moe_layers = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.moe_layers.len())
        .unwrap_or(0)
        + entry
            .config
            .dec_moe
            .as_ref()
            .map(|m| m.moe_layers.len())
            .unwrap_or(0);
    let cap = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.capacity_factor)
        .unwrap_or(1.0);

    let ep_net = Interconnect { devices: mesh.expert_parallel, ..*net };
    // dispatch + combine, forward + backward = 4 all-to-alls per MoE layer.
    let bytes_per_peer =
        (tokens_per_device as f64 * cap * d as f64 * 4.0 / mesh.expert_parallel.max(1) as f64)
            as usize;
    let mean = bytes_per_peer.max(1);
    let max = (mean as f64 * imbalance) as usize;
    let expert_alltoall_s =
        4.0 * n_moe_layers as f64 * ep_net.alltoall_time_skewed(mean, max);

    let dp_net = Interconnect { devices: mesh.data_parallel, ..*net };
    let grad_allreduce_s = dp_net.allreduce_time(entry.param_count * 4);

    let mp_net = Interconnect { devices: mesh.model_parallel, ..*net };
    // One activation all-gather per block, fwd+bwd.
    let blocks = entry.config.num_layers + entry.config.num_decoder_layers;
    let mp_allgather_s =
        2.0 * blocks as f64 * mp_net.allgather_time(tokens_per_device * d * 4);

    StepCommsReport { expert_alltoall_s, grad_allreduce_s, mp_allgather_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_is_rank_ordered_and_checked() {
        // Rank order matters in f32: pick values where order changes bits.
        let a = vec![1.0e8f32, 1.0];
        let b = vec![1.0f32, -1.0e8];
        let c = vec![-1.0e8f32, 1.0e8];
        let seq = {
            let mut acc = a.clone();
            for buf in [&b, &c] {
                for (x, y) in acc.iter_mut().zip(buf.iter()) {
                    *x += *y;
                }
            }
            acc
        };
        let red = reduce_sum_ordered(vec![a, b, c]).unwrap();
        assert_eq!(seq, red, "collective must match sequential accumulation bitwise");
        assert!(reduce_sum_ordered(vec![]).is_err());
        assert!(reduce_sum_ordered(vec![vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn allreduce_mean_scales() {
        let m = allreduce_mean(vec![vec![2.0, 4.0], vec![6.0, 8.0]]).unwrap();
        assert_eq!(m, vec![4.0, 6.0]);
    }

    #[test]
    fn single_device_is_free() {
        let net = Interconnect::tpu_like(1);
        assert_eq!(net.allreduce_time(1 << 20), 0.0);
        assert_eq!(net.allgather_time(1 << 20), 0.0);
        assert_eq!(net.alltoall_time(1 << 20), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_latency_with_devices() {
        let a = Interconnect::tpu_like(8);
        assert!(a.allreduce_time(2 << 20) > a.allreduce_time(1 << 20));
        let b = Interconnect::tpu_like(64);
        // For tiny payloads, latency term dominates and grows with n.
        assert!(b.allreduce_time(64) > a.allreduce_time(64));
    }

    #[test]
    fn skew_stretches_alltoall() {
        let net = Interconnect::tpu_like(8);
        let balanced = net.alltoall_time_skewed(1 << 20, 1 << 20);
        let skewed = net.alltoall_time_skewed(1 << 20, 3 << 20);
        assert!((skewed / balanced - 3.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_optimal_ring_bound() {
        // 2(n-1)/n·bytes / BW is the textbook lower bound; check we match it
        // (plus latency) rather than the naive n·bytes.
        let net = Interconnect { link_bandwidth: 1e9, latency: 0.0, devices: 4 };
        let t = net.allreduce_time(1_000_000_000);
        assert!((t - 1.5).abs() < 1e-9, "got {t}");
    }
}
