//! Collective-communication cost model (paper §A.4).
//!
//! The paper composes data / expert / model parallelism; the communication
//! patterns behind them are all-to-all (MoE dispatch + combine),
//! all-reduce (data-parallel gradients) and all-gather (model-parallel
//! activations). This module prices them on an abstract interconnect
//! (per-link bandwidth + latency, ring or full-mesh topology), so the
//! placement simulator can answer the §A.4 question the paper settles by
//! construction on TPU pods: which parallelism axis saturates first as E,
//! C and the mesh grow. Exercised by `cargo bench --bench routing_sim`
//! extensions and unit tests.

#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-link bandwidth, bytes/second.
    pub link_bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Number of devices participating.
    pub devices: usize,
}

impl Interconnect {
    /// TPUv3-ish ICI defaults: ~70 GB/s links, ~1 µs latency.
    pub fn tpu_like(devices: usize) -> Interconnect {
        Interconnect { link_bandwidth: 70e9, latency: 1e-6, devices }
    }

    /// Ring all-reduce of `bytes` per device: 2(n-1)/n · bytes over the
    /// slowest link + 2(n-1) latency hops (bandwidth-optimal ring).
    pub fn allreduce_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        2.0 * (n - 1.0) / n * bytes as f64 / self.link_bandwidth
            + 2.0 * (n - 1.0) * self.latency
    }

    /// All-gather of `bytes` per device (each device ends with n·bytes).
    pub fn allgather_time(&self, bytes: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) / n * (n * bytes as f64) / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Balanced all-to-all where every device sends `bytes_per_peer` to each
    /// of the other n-1 devices (the MoE dispatch/combine pattern with
    /// Expert Choice routing — balanced by construction, paper §2.1).
    pub fn alltoall_time(&self, bytes_per_peer: usize) -> f64 {
        let n = self.devices.max(1) as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        (n - 1.0) * bytes_per_peer as f64 / self.link_bandwidth
            + (n - 1.0) * self.latency
    }

    /// Skewed all-to-all: the hot device receives `max_bytes` while others
    /// receive `mean_bytes` — token-choice routing's imbalance stretches the
    /// collective to the hottest receiver.
    pub fn alltoall_time_skewed(&self, mean_bytes: usize, max_bytes: usize) -> f64 {
        self.alltoall_time(mean_bytes.max(1))
            * (max_bytes as f64 / mean_bytes.max(1) as f64)
    }
}

/// One training step's communication bill for a sparse model under the
/// three-axis mesh (paper §A.4): expert all-to-all (dispatch+combine, per
/// MoE layer, fwd+bwd), data-parallel gradient all-reduce, model-parallel
/// activation all-gathers.
#[derive(Debug, Clone)]
pub struct StepCommsReport {
    pub expert_alltoall_s: f64,
    pub grad_allreduce_s: f64,
    pub mp_allgather_s: f64,
}

impl StepCommsReport {
    pub fn total(&self) -> f64 {
        self.expert_alltoall_s + self.grad_allreduce_s + self.mp_allgather_s
    }
}

pub fn step_comms(
    entry: &crate::manifest::ModelEntry,
    mesh: &crate::parallel::MeshSpec,
    net: &Interconnect,
    tokens_per_device: usize,
    imbalance: f64,
) -> StepCommsReport {
    let d = entry.config.d_model;
    let n_moe_layers = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.moe_layers.len())
        .unwrap_or(0)
        + entry
            .config
            .dec_moe
            .as_ref()
            .map(|m| m.moe_layers.len())
            .unwrap_or(0);
    let cap = entry
        .config
        .enc_moe
        .as_ref()
        .map(|m| m.capacity_factor)
        .unwrap_or(1.0);

    let ep_net = Interconnect { devices: mesh.expert_parallel, ..*net };
    // dispatch + combine, forward + backward = 4 all-to-alls per MoE layer.
    let bytes_per_peer =
        (tokens_per_device as f64 * cap * d as f64 * 4.0 / mesh.expert_parallel.max(1) as f64)
            as usize;
    let mean = bytes_per_peer.max(1);
    let max = (mean as f64 * imbalance) as usize;
    let expert_alltoall_s =
        4.0 * n_moe_layers as f64 * ep_net.alltoall_time_skewed(mean, max);

    let dp_net = Interconnect { devices: mesh.data_parallel, ..*net };
    let grad_allreduce_s = dp_net.allreduce_time(entry.param_count * 4);

    let mp_net = Interconnect { devices: mesh.model_parallel, ..*net };
    // One activation all-gather per block, fwd+bwd.
    let blocks = entry.config.num_layers + entry.config.num_decoder_layers;
    let mp_allgather_s =
        2.0 * blocks as f64 * mp_net.allgather_time(tokens_per_device * d * 4);

    StepCommsReport { expert_alltoall_s, grad_allreduce_s, mp_allgather_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_is_free() {
        let net = Interconnect::tpu_like(1);
        assert_eq!(net.allreduce_time(1 << 20), 0.0);
        assert_eq!(net.allgather_time(1 << 20), 0.0);
        assert_eq!(net.alltoall_time(1 << 20), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_latency_with_devices() {
        let a = Interconnect::tpu_like(8);
        assert!(a.allreduce_time(2 << 20) > a.allreduce_time(1 << 20));
        let b = Interconnect::tpu_like(64);
        // For tiny payloads, latency term dominates and grows with n.
        assert!(b.allreduce_time(64) > a.allreduce_time(64));
    }

    #[test]
    fn skew_stretches_alltoall() {
        let net = Interconnect::tpu_like(8);
        let balanced = net.alltoall_time_skewed(1 << 20, 1 << 20);
        let skewed = net.alltoall_time_skewed(1 << 20, 3 << 20);
        assert!((skewed / balanced - 3.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_optimal_ring_bound() {
        // 2(n-1)/n·bytes / BW is the textbook lower bound; check we match it
        // (plus latency) rather than the naive n·bytes.
        let net = Interconnect { link_bandwidth: 1e9, latency: 0.0, devices: 4 };
        let t = net.allreduce_time(1_000_000_000);
        assert!((t - 1.5).abs() < 1e-9, "got {t}");
    }
}
