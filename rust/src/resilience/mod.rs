//! Fault-tolerant elastic training: deterministic rank-failure injection,
//! abort-and-recover step execution, and snapshot-based auto-resume.
//!
//! A production training run must survive worker preemption and crashes —
//! the paper's whole premise is reusing sunk training cost, and losing a
//! long upcycled-MoE run to one dead rank throws that cost away. This
//! module supplies the three pieces the elastic trainer
//! (`coordinator::trainer::train_mesh_elastic`) composes:
//!
//! * **Deterministic fault injection.** A [`FaultPlan`] ("kill rank `r` at
//!   step `s` during phase `p`") arms a thread-local trigger on the doomed
//!   rank's thread when the elastic driver spawns it. The trigger fires
//!   from the phase-profiler seam (`util::bench::phase` reports every
//!   phase entry through [`on_phase`]) — the exact instrumentation points
//!   the bench breakdown already uses, so a fault can land *inside* the
//!   router, dispatch, expert-MLP, combine, backward or optimizer leg of a
//!   live step. The rank dies by panicking with [`INJECTED_FAULT_MARKER`],
//!   indistinguishable from a real mid-step crash to everything above it.
//! * **Failure detection.** A dead rank's panic is caught at the spawn
//!   site, which aborts the expert-parallel group
//!   (`parallel::collectives::EpGroup::abort_with`) so every surviving
//!   peer blocked in a collective returns an error naming the root cause
//!   instead of hanging.
//! * **Recovery bookkeeping.** [`ElasticConfig`] fixes the snapshot
//!   cadence and retention (the rotation itself lives in
//!   `checkpoint::save_snapshot`); [`ElasticReport`] records every
//!   [`RecoveryEvent`] so tests and the CLI can assert on what happened.
//!
//! **The bitwise-recovery contract.** The elastic trainer's invariant —
//! asserted for every fault point in the `tests/chaos.rs` sweep — is that
//! a run with *any* injected fault schedule produces a final state (and
//! final SUPC snapshot bundle) bitwise-identical to the uninterrupted run
//! at the same step. The contract holds because every ingredient of a step
//! is replayable: the step executor is deterministic in `(params,
//! opt_state, batch, lr, step)`, snapshots restore state bitwise
//! (`checkpoint::load_train_state`), and the driver replays the exact
//! batches of the rolled-back steps from its in-memory cache. See
//! `docs/RESILIENCE.md` for the full fault model.

use std::cell::Cell;
use std::fmt;

use anyhow::{bail, Context, Result};

/// Panic payload prefix of an injected fault. The elastic driver (and the
/// chaos suite) match on it to distinguish injected kills from genuine
/// bugs; everything else treats the panic like any real rank death.
pub const INJECTED_FAULT_MARKER: &str = "injected fault";

/// A phase of one training step at which a fault can be injected. The
/// names mirror the phase-profiler buckets (`util::bench`), which is where
/// the trigger fires from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPhase {
    /// Router logits + softmax + routing decisions (rank-local).
    Router,
    /// Token → expert gather + gate computation (rank-local).
    Dispatch,
    /// The grouped expert MLP — under expert parallelism this is the
    /// sharded leg *between* the two all-to-alls (`ep_expert_mlp`).
    ExpertMlp,
    /// The completion leg of a split-phase expert all-to-all
    /// (`ep_alltoall`): the fault lands *between* `start_exchange` and
    /// `finish_exchange`, with the rank's sends already posted to its
    /// peers' queues. Expert-parallel meshes only — no local phase maps
    /// here.
    Exchange,
    /// Gate-weighted scatter back to token order (rank-local).
    Combine,
    /// The backward tower sweep.
    Backward,
    /// The shared Adam update (runs on the coordinator after reduction).
    Optimizer,
}

impl FaultPhase {
    pub const ALL: [FaultPhase; 7] = [
        FaultPhase::Router,
        FaultPhase::Dispatch,
        FaultPhase::ExpertMlp,
        FaultPhase::Exchange,
        FaultPhase::Combine,
        FaultPhase::Backward,
        FaultPhase::Optimizer,
    ];

    pub fn parse(s: &str) -> Result<FaultPhase> {
        Ok(match s {
            "router" => FaultPhase::Router,
            "dispatch" => FaultPhase::Dispatch,
            "expert_mlp" => FaultPhase::ExpertMlp,
            "exchange" => FaultPhase::Exchange,
            "combine" => FaultPhase::Combine,
            "backward" => FaultPhase::Backward,
            "optimizer" => FaultPhase::Optimizer,
            other => bail!(
                "unknown fault phase `{other}`; one of \
                 router|dispatch|expert_mlp|exchange|combine|backward|optimizer"
            ),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FaultPhase::Router => "router",
            FaultPhase::Dispatch => "dispatch",
            FaultPhase::ExpertMlp => "expert_mlp",
            FaultPhase::Exchange => "exchange",
            FaultPhase::Combine => "combine",
            FaultPhase::Backward => "backward",
            FaultPhase::Optimizer => "optimizer",
        }
    }

    /// Does a profiler phase entry named `phase_name` belong to this fault
    /// phase? The expert-MLP leg reports as `expert_mlp` locally and
    /// `ep_expert_mlp` under expert parallelism — one fault phase covers
    /// both, so a plan is valid for any mesh shape. The exchange phase maps
    /// to `ep_alltoall`, the profiler bucket wrapping every
    /// `finish_exchange` completion wait — entered with the rank's own
    /// sends already posted, i.e. mid split-phase window.
    fn matches(&self, phase_name: &str) -> bool {
        match self {
            FaultPhase::ExpertMlp => {
                phase_name == "expert_mlp" || phase_name == "ep_expert_mlp"
            }
            FaultPhase::Exchange => phase_name == "ep_alltoall",
            _ => phase_name == self.as_str(),
        }
    }

    /// Whether this phase executes on the coordinator thread (after the
    /// rank fan-in) rather than on a rank thread.
    pub fn on_coordinator(&self) -> bool {
        matches!(self, FaultPhase::Optimizer)
    }
}

impl fmt::Display for FaultPhase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One deterministic fault: kill global mesh rank `rank` the first time
/// step `step` enters `phase`. Parsed from the CLI as `r:s:p`
/// (`--inject-fault 1:3:expert_mlp`). For the coordinator-side
/// [`FaultPhase::Optimizer`] the rank is recorded but ignored — there is
/// exactly one optimizer update per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Global mesh rank `dp_group · ep + ep_rank`.
    pub rank: usize,
    /// 1-based step index *within the run* (the first stepped batch is 1).
    pub step: u64,
    pub phase: FaultPhase,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let parts: Vec<&str> = spec.split(':').collect();
        let &[r, s, p] = parts.as_slice() else {
            bail!("fault spec `{spec}` must be rank:step:phase (e.g. 1:3:expert_mlp)");
        };
        Ok(FaultPlan {
            rank: r.parse().with_context(|| format!("bad rank in fault spec `{spec}`"))?,
            step: s.parse().with_context(|| format!("bad step in fault spec `{spec}`"))?,
            phase: FaultPhase::parse(p)?,
        })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}", self.rank, self.step, self.phase)
    }
}

/// A set of one-shot faults for one run. Each plan fires at most once —
/// after the kill, the elastic driver rolls back and replays the step,
/// which must then succeed (otherwise recovery could never converge).
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    plans: Vec<(FaultPlan, bool)>, // (plan, fired)
}

impl FaultSchedule {
    pub fn new(plans: Vec<FaultPlan>) -> FaultSchedule {
        FaultSchedule { plans: plans.into_iter().map(|p| (p, false)).collect() }
    }

    pub fn single(plan: FaultPlan) -> FaultSchedule {
        FaultSchedule::new(vec![plan])
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// The not-yet-fired fault armed for run-step `step`, if any, marking
    /// it fired. Called once per step *attempt*; a fault consumed here
    /// never re-arms on the post-rollback replay.
    pub fn take_for_step(&mut self, step: u64) -> Option<FaultPlan> {
        for (plan, fired) in self.plans.iter_mut() {
            if !*fired && plan.step == step {
                *fired = true;
                return Some(*plan);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Thread-local fault trigger (the rank-thread seam)
// ---------------------------------------------------------------------------

thread_local! {
    /// The fault armed on this thread, if any: fires on the next matching
    /// phase entry. One slot — a thread dies at its first fault.
    static ARMED: Cell<Option<FaultPhase>> = const { Cell::new(None) };
}

/// Arm `phase` on the current thread: the next [`on_phase`] entry matching
/// it panics with [`INJECTED_FAULT_MARKER`]. The elastic driver calls this
/// in the rank-thread spawn path (and around the optimizer update for
/// coordinator-side faults); returns a guard that disarms on drop so a
/// fault armed on a long-lived thread can never leak into later steps.
pub fn arm_fault(phase: FaultPhase) -> FaultArmGuard {
    ARMED.with(|a| a.set(Some(phase)));
    FaultArmGuard { _priv: () }
}

/// Disarms the current thread's fault trigger on drop (see [`arm_fault`]).
pub struct FaultArmGuard {
    _priv: (),
}

impl Drop for FaultArmGuard {
    fn drop(&mut self) {
        ARMED.with(|a| a.set(None));
    }
}

/// Phase-entry hook, called by `util::bench::phase` for every profiled
/// phase. Costs one thread-local read when nothing is armed (the universal
/// case); when the armed fault matches, the thread dies by panic — the
/// deterministic stand-in for a preempted or crashed worker.
#[inline]
pub fn on_phase(phase_name: &'static str) {
    ARMED.with(|a| {
        if let Some(armed) = a.get() {
            if armed.matches(phase_name) {
                a.set(None);
                panic!("{INJECTED_FAULT_MARKER}: killed during phase `{phase_name}`");
            }
        }
    });
}

/// Does a panic payload (downcast to text by the catch site) or an error
/// chain describe an injected fault rather than a genuine bug?
pub fn is_injected_fault(msg: &str) -> bool {
    msg.contains(INJECTED_FAULT_MARKER)
}

// ---------------------------------------------------------------------------
// Elastic-run configuration and reporting
// ---------------------------------------------------------------------------

/// Shape of one elastic training run: snapshot cadence + retention, the
/// snapshot directory, and the (possibly empty) injected fault schedule.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Write a SUPC snapshot every `snapshot_every` successful steps
    /// (must be >= 1; the run start is always snapshot 0).
    pub snapshot_every: u64,
    /// Retention: how many rotated snapshots to keep on disk (>= 1).
    pub snapshot_keep: usize,
    /// Directory the rotation writes `snap_<step>.supc` files into.
    pub dir: std::path::PathBuf,
    /// Deterministic faults to inject (empty = plain resilient run).
    pub faults: FaultSchedule,
    /// Give up after this many recoveries (a real cluster pages a human
    /// at some point; the default of 8 is far above any injected plan).
    pub max_recoveries: usize,
}

impl ElasticConfig {
    pub fn new(dir: impl Into<std::path::PathBuf>) -> ElasticConfig {
        ElasticConfig {
            snapshot_every: 10,
            snapshot_keep: 3,
            dir: dir.into(),
            faults: FaultSchedule::default(),
            max_recoveries: 8,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.snapshot_every == 0 {
            bail!("elastic training needs snapshot_every >= 1 (0 would never snapshot)");
        }
        if self.snapshot_keep == 0 {
            bail!("snapshot retention must keep >= 1 file (0 would delete the rollback target)");
        }
        Ok(())
    }
}

/// One detected failure and the rollback that recovered from it.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// The run step whose attempt failed.
    pub failed_step: u64,
    /// The snapshot step the run rolled back to.
    pub rolled_back_to: u64,
    /// Root-cause description (the injected fault's marker, or the real
    /// error chain).
    pub cause: String,
    /// Whether the cause carried the injected-fault marker.
    pub injected: bool,
}

/// What one elastic run did besides training: snapshots written and
/// recoveries performed, in order.
#[derive(Debug, Clone, Default)]
pub struct ElasticReport {
    pub snapshots_written: usize,
    pub recoveries: Vec<RecoveryEvent>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_and_displays() {
        let p = FaultPlan::parse("1:3:expert_mlp").unwrap();
        assert_eq!(p, FaultPlan { rank: 1, step: 3, phase: FaultPhase::ExpertMlp });
        assert_eq!(p.to_string(), "1:3:expert_mlp");
        for ph in FaultPhase::ALL {
            let spec = format!("0:1:{ph}");
            assert_eq!(FaultPlan::parse(&spec).unwrap().phase, ph);
        }
        assert!(FaultPlan::parse("1:2").is_err());
        assert!(FaultPlan::parse("a:2:router").is_err());
        assert!(FaultPlan::parse("1:b:router").is_err());
        assert!(FaultPlan::parse("1:2:warp_drive").is_err());
        assert!(FaultPlan::parse("1:2:router:extra").is_err());
    }

    #[test]
    fn expert_mlp_phase_covers_local_and_ep_names() {
        assert!(FaultPhase::ExpertMlp.matches("expert_mlp"));
        assert!(FaultPhase::ExpertMlp.matches("ep_expert_mlp"));
        assert!(!FaultPhase::ExpertMlp.matches("ep_alltoall"));
        assert!(FaultPhase::Exchange.matches("ep_alltoall"));
        assert!(!FaultPhase::Exchange.matches("exchange"), "no local phase maps to exchange");
        assert!(!FaultPhase::Exchange.on_coordinator());
        assert!(FaultPhase::Router.matches("router"));
        assert!(!FaultPhase::Router.matches("backward"));
        assert!(FaultPhase::Optimizer.on_coordinator());
        assert!(!FaultPhase::Backward.on_coordinator());
    }

    #[test]
    fn schedule_fires_each_plan_once() {
        let mut s = FaultSchedule::new(vec![
            FaultPlan { rank: 0, step: 2, phase: FaultPhase::Router },
            FaultPlan { rank: 1, step: 2, phase: FaultPhase::Combine },
        ]);
        assert!(s.take_for_step(1).is_none());
        let first = s.take_for_step(2).unwrap();
        assert_eq!(first.phase, FaultPhase::Router);
        let second = s.take_for_step(2).unwrap();
        assert_eq!(second.phase, FaultPhase::Combine);
        assert!(s.take_for_step(2).is_none(), "each plan fires at most once");
        assert!(FaultSchedule::default().is_empty());
    }

    #[test]
    fn armed_fault_trips_on_matching_phase_only() {
        // Not armed: phases are free.
        on_phase("router");
        {
            let _guard = arm_fault(FaultPhase::Combine);
            on_phase("router"); // wrong phase — survives
            let hit = std::panic::catch_unwind(|| on_phase("combine"));
            let msg = *hit.unwrap_err().downcast::<String>().unwrap();
            assert!(is_injected_fault(&msg), "{msg}");
            // The trigger is one-shot: the same phase no longer trips.
            on_phase("combine");
        }
        // Guard dropped: nothing armed.
        on_phase("combine");
    }

    #[test]
    fn arm_guard_disarms_on_drop() {
        {
            let _guard = arm_fault(FaultPhase::Router);
        }
        on_phase("router"); // must not panic
    }

    #[test]
    fn elastic_config_validates() {
        let mut c = ElasticConfig::new(std::env::temp_dir());
        c.validate().unwrap();
        c.snapshot_every = 0;
        assert!(c.validate().is_err());
        c.snapshot_every = 5;
        c.snapshot_keep = 0;
        assert!(c.validate().is_err());
    }
}
