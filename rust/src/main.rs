//! `upcycle` — CLI for the sparse-upcycling training coordinator.
//!
//! Subcommands (the full flag reference lives in `docs/CLI.md`):
//!   quickstart                    — native end-to-end demo (pretrain →
//!                                   surgery → continued MoE training)
//!   list                          — experiments and models available
//!   train      --model M          — (pre)train a model from scratch
//!                                   (--topology dp=D,ep=E[,tp=T] picks the
//!                                   parallel plan, --microbatches M overlaps
//!                                   the all-to-all, --save CK bundle;
//!                                   --snapshot-every/--snapshot-keep/
//!                                   --inject-fault run the elastic
//!                                   fault-tolerant loop, docs/RESILIENCE.md)
//!   serve      --load CK          — continuous-batching inference engine
//!                                   over a trained checkpoint (--precision
//!                                   bf16|int8 serves quantized weights on
//!                                   the SIMD kernel tier)
//!   infer      --load CK          — one forward-only inference pass
//!                                   (--topology dp=1,ep=E shards experts
//!                                   over rank threads, --precision as serve)
//!   bench-gate --baseline B --current C — CI bench regression gate
//!   check-docs                    — markdown relative-link check (CI docs job)
//!   upcycle    --dense CK --model M — run checkpoint surgery, save sparse CK
//!   eval       --model M --params CK — evaluate a checkpoint
//!   fewshot    --model M --params CK — 10-shot linear probe (vision)
//!   experiment <id>|all           — regenerate a paper figure/table
//!   sweep      [--sweep SPEC]     — scaling-law sweep lab: price, pack onto
//!                                   --cores worker threads, record every leg
//!                                   to SWEEP_results.json (docs/SWEEPS.md)
//!   sweep fit                     — power-law fit of final loss vs (sunk
//!                                   cost, E, continuation budget)
//!   mesh       --model M          — expert-parallel placement report (§A.4)
//!
//! Everything runs on the native CPU backend out of the box; `make
//! artifacts` + the `pjrt` cargo feature switch to the AOT/XLA path.

use anyhow::{bail, Context, Result};

use sparse_upcycle::checkpoint::quant::{quantize_params, Precision};
use sparse_upcycle::checkpoint::Checkpoint;
use sparse_upcycle::coordinator::fewshot::{fewshot_accuracy, FewShotConfig};
use sparse_upcycle::coordinator::{train, DpConfig, MeshConfig, TrainState};
use sparse_upcycle::experiments::{registry, run_by_id, Ctx, ExpParams};
use sparse_upcycle::manifest::Manifest;
use sparse_upcycle::parallel::{place, MeshSpec};
use sparse_upcycle::runtime::Runtime;
use sparse_upcycle::serve;
use sparse_upcycle::sweep;
use sparse_upcycle::upcycle::{
    router_init_from_args, strategy_from_args, upcycle_opt_state, upcycle_params, UpcycleOptions,
};
use sparse_upcycle::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run_one_experiment(ctx: &Ctx, id: &str) -> Result<()> {
    let t0 = std::time::Instant::now();
    println!("\n################ experiment {id} ################");
    let rep = run_by_id(ctx, id)?;
    rep.print();
    let csv = rep.write_csv(&ctx.out_dir)?;
    rep.write_json(&ctx.out_dir)?;
    println!("[{id}] wrote {} ({:.1}s)", csv.display(), t0.elapsed().as_secs_f64());
    Ok(())
}

fn params_from_args(a: &Args) -> Result<ExpParams> {
    let mut p = ExpParams::tiny();
    p.pretrain_steps = a.u64("pretrain-steps", p.pretrain_steps)?;
    p.extra_steps = a.u64("extra-steps", p.extra_steps)?;
    p.finetune_steps = a.u64("finetune-steps", p.finetune_steps)?;
    p.eval_every = a.u64("eval-every", p.eval_every)?;
    p.eval_batches = a.usize("eval-batches", p.eval_batches)?;
    p.seed = a.u64("seed", p.seed)?;
    Ok(p)
}

/// Serving-side parameter loading: accept either a train-state bundle
/// (`upcycle train --save`) or a params-only checkpoint, returning the
/// bound parameters and the step they were trained to. Binds from the
/// checkpoint the caller already read (no second disk pass).
fn load_serving_params(
    ck: &Checkpoint,
    entry: &sparse_upcycle::manifest::ModelEntry,
) -> Result<(Vec<sparse_upcycle::tensor::Tensor>, u64)> {
    match sparse_upcycle::checkpoint::bind_train_state(ck, entry) {
        Ok((params, _opt, step)) => Ok((params, step)),
        Err(bundle_err) => {
            // Not a train-state bundle — a params-only checkpoint also
            // serves (inference never touches optimizer state). If neither
            // binds, surface both failures: the params-only mismatch is
            // usually the actionable one.
            match sparse_upcycle::runtime::tensors_from_checkpoint(ck, &entry.params) {
                Ok(p) => Ok((p, ck.step)),
                Err(params_err) => Err(bundle_err.context(format!(
                    "not loadable as a params-only checkpoint either ({params_err:#})"
                ))),
            }
        }
    }
}

/// Resolve the training parallel plan: the canonical
/// `--topology dp=D,ep=E[,tp=T]` flag, with the deprecated `--replicas N`
/// and `--mesh DxE` aliases desugaring onto the same [`MeshSpec`] (and
/// printing a pointer to the replacement). Returns `None` when no
/// topology flag was given at all (single-worker training).
fn topology_from_args(a: &Args) -> Result<Option<MeshSpec>> {
    let has_replicas = a.flags.contains_key("replicas");
    let has_mesh = a.flags.contains_key("mesh");
    if let Some(spec) = a.flags.get("topology") {
        if has_replicas || has_mesh {
            bail!("--topology replaces --replicas/--mesh; give only --topology");
        }
        return Ok(Some(MeshSpec::parse(spec)?));
    }
    if has_replicas && has_mesh {
        bail!(
            "--mesh and --replicas conflict: the mesh's data axis IS the replica count; \
             use --topology dp=D,ep=E"
        );
    }
    if has_replicas {
        let replicas = a.usize("replicas", 1)?;
        eprintln!("warning: --replicas is deprecated; use --topology dp={replicas},ep=1");
        return Ok(Some(MeshSpec::data_parallel_only(replicas)));
    }
    if has_mesh {
        let (dp, ep) = MeshConfig::parse(a.flags.get("mesh").unwrap())?;
        eprintln!("warning: --mesh is deprecated; use --topology dp={dp},ep={ep}");
        return Ok(Some(MeshSpec::new(dp, ep)));
    }
    Ok(None)
}

/// Topology for the placement/comms simulators (`upcycle mesh`, `upcycle
/// comms`): the canonical `--topology` flag, with the old per-axis
/// `--dp/--ep/--mp` flags as deprecated aliases (defaults dp=2, ep=4).
fn sim_topology_from_args(a: &Args) -> Result<MeshSpec> {
    let has_axis_flags = ["dp", "ep", "mp"].iter().any(|k| a.flags.contains_key(*k));
    if let Some(spec) = a.flags.get("topology") {
        if has_axis_flags {
            bail!("--topology replaces --dp/--ep/--mp; give only --topology");
        }
        return MeshSpec::parse(spec);
    }
    if has_axis_flags {
        eprintln!("warning: --dp/--ep/--mp are deprecated; use --topology dp=D,ep=E[,tp=T]");
    }
    Ok(MeshSpec {
        data_parallel: a.usize("dp", 2)?,
        expert_parallel: a.usize("ep", 4)?,
        model_parallel: a.usize("mp", 1)?,
    })
}

/// Resolve the serving plan: the canonical consolidated
/// `--serve policy=…,budget=…,max-batch=…,queue=…[,shed=…,gap=…,floor=…,slo=…]`
/// flag ([`serve::ServeSpec::parse`]), with the deprecated
/// `--batch-tokens`/`--max-batch`/`--unbatched`/`--gap-us` aliases
/// desugaring onto the same spec (and printing a pointer to the
/// replacement).
fn serve_spec_from_args(a: &Args) -> Result<serve::ServeSpec> {
    let aliases = ["batch-tokens", "max-batch", "unbatched", "gap-us"];
    let has_alias = aliases.iter().any(|k| a.flags.contains_key(*k));
    if let Some(spec) = a.flags.get("serve") {
        if has_alias {
            bail!(
                "--serve replaces --batch-tokens/--max-batch/--unbatched/--gap-us; \
                 give only --serve"
            );
        }
        return serve::ServeSpec::parse(spec);
    }
    let mut spec = serve::ServeSpec::default();
    if a.flags.contains_key("batch-tokens") {
        spec.max_batch_tokens = a.usize("batch-tokens", 0)?;
        eprintln!(
            "warning: --batch-tokens is deprecated; use --serve budget={}",
            spec.max_batch_tokens
        );
    }
    if a.flags.contains_key("max-batch") {
        spec.max_batch_requests = a.usize("max-batch", 0)?;
        eprintln!(
            "warning: --max-batch is deprecated; use --serve max-batch={}",
            spec.max_batch_requests
        );
    }
    if a.bool("unbatched") {
        // The old precedence: --unbatched wins over --max-batch.
        spec.max_batch_requests = 1;
        eprintln!("warning: --unbatched is deprecated; use --serve max-batch=1");
    }
    if a.flags.contains_key("gap-us") {
        spec.gap_us = a.u64("gap-us", spec.gap_us)?;
        eprintln!("warning: --gap-us is deprecated; use --serve gap={}", spec.gap_us);
    }
    Ok(spec)
}

/// Resolve `--precision f32|bf16|int8` for the forward-only commands.
/// Quantization is inference-only by contract (docs/SERVING.md): `train`
/// rejects the flag by name instead of silently ignoring it, and unknown
/// values fail with the expected spellings.
fn precision_from_args(a: &Args, cmd: &str) -> Result<Precision> {
    match a.flags.get("precision") {
        None => Ok(Precision::F32),
        Some(_) if cmd == "train" => bail!(
            "--precision is inference-only (quantized weights would break the training \
             bitwise contracts); drop it from `upcycle train` and pass it to \
             `upcycle infer` / `upcycle serve` instead"
        ),
        Some(s) => Precision::parse(s),
    }
}

/// Runtime for the forward-only commands: full precision keeps the
/// manifest-selected backend; a quantized precision opts into the SIMD
/// kernel tier (the low-precision path is native-only and benefits most
/// from the vectorized GEMMs).
fn serving_runtime(manifest: &Manifest, precision: Precision) -> Result<Runtime> {
    if precision == Precision::F32 {
        Runtime::for_manifest(manifest)
    } else {
        Runtime::native_simd()
    }
}

fn run() -> Result<()> {
    let a = Args::from_env()?;
    let cmd = a.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let artifacts = a.str("artifacts", sparse_upcycle::ARTIFACTS_DIR);
    let out_dir = a.str("out", sparse_upcycle::RESULTS_DIR);

    match cmd {
        "help" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => {
            println!("experiments:");
            for (id, title, _) in registry() {
                println!("  {id:<6} {title}");
            }
            let m = Manifest::load_or_native(&artifacts)?;
            println!("\nmodels ({}, source {}):", m.models.len(), m.source_hash);
            for (name, e) in &m.models {
                println!(
                    "  {name:<32} {:<4} {:>9.2}M params{}",
                    e.family,
                    e.param_count as f64 / 1e6,
                    if e.is_sparse() { "  (sparse)" } else { "" }
                );
            }
            Ok(())
        }
        "quickstart" => {
            let mut p = params_from_args(&a)?;
            if !a.flags.contains_key("pretrain-steps") {
                p.pretrain_steps = 60;
            }
            if !a.flags.contains_key("extra-steps") {
                p.extra_steps = 20;
            }
            if !a.flags.contains_key("eval-every") {
                p.eval_every = 10;
            }
            let ctx = Ctx::new(&artifacts, &out_dir, p, true)?;
            println!("backend: {}", ctx.runtime.platform());
            println!("\n== 1. dense pretraining ({} steps) ==", ctx.p.pretrain_steps);
            let parent = ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;

            println!("\n== 2. upcycling surgery: lm_tiny_dense -> lm_tiny_moe_e8_c2 ==");
            let (moe, mut moe_state) = ctx.branch_upcycle(
                &parent, "lm_tiny_moe_e8_c2", &UpcycleOptions::default(), false)?;
            println!(
                "  {:.2}M dense params -> {:.2}M sparse params ({:.2}M in experts)",
                ctx.entry("lm_tiny_dense")?.param_count as f64 / 1e6,
                moe.entry.param_count as f64 / 1e6,
                moe.entry.expert_param_count() as f64 / 1e6
            );

            println!("\n== 3. continued MoE training (+{} steps) ==", ctx.p.extra_steps);
            let series = ctx.run_branch(&moe, &mut moe_state, 2, ctx.p.extra_steps, "upcycled")?;
            let loss = |pt: Option<&sparse_upcycle::metrics::Point>| {
                pt.and_then(|q| q.values.get("loss").copied()).unwrap_or(f64::NAN)
            };
            let at_branch = loss(series.points.first());
            let at_end = loss(series.points.last());
            println!("\n== result ==");
            println!("  held-out loss at the branch point: {at_branch:.4}");
            println!("  held-out loss after +{} steps:     {at_end:.4}", ctx.p.extra_steps);
            println!("  improvement: {:+.4}", at_branch - at_end);
            Ok(())
        }
        "experiment" => {
            let id = a
                .positional
                .get(1)
                .context("usage: upcycle experiment <id>|all")?;
            let p = params_from_args(&a)?;
            let ids: Vec<String> = if id == "all" {
                registry().iter().map(|(i, _, _)| i.to_string()).collect()
            } else {
                id.split(',').map(|s| s.to_string()).collect()
            };
            // Single PJRT CPU device on this box; >1 worker only helps on
            // multi-core hosts (each worker owns a client + exe cache).
            let jobs = a.usize("jobs", 1)?.max(1);
            if jobs == 1 || ids.len() == 1 {
                let ctx = Ctx::new(&artifacts, &out_dir, p, a.bool("verbose"))?;
                for id in ids {
                    run_one_experiment(&ctx, &id)?;
                }
                return Ok(());
            }
            // Parallel fan-out. PjRtClient is not Send, so every worker owns
            // its own Ctx (client + executable cache). Dense parents are
            // pre-warmed once so workers share them via the disk cache
            // instead of racing to pretrain the same checkpoint.
            {
                let ctx = Ctx::new(&artifacts, &out_dir, p.clone(), a.bool("verbose"))?;
                println!("pre-warming dense parents...");
                ctx.dense_parent("lm_tiny_dense", ctx.p.pretrain_steps)?;
                ctx.dense_parent("vit_tiny_dense", ctx.p.pretrain_steps)?;
            }
            let queue = std::sync::Arc::new(std::sync::Mutex::new(
                ids.into_iter().collect::<std::collections::VecDeque<_>>(),
            ));
            let failures = std::sync::Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
            let mut handles = Vec::new();
            for w in 0..jobs {
                let queue = queue.clone();
                let failures = failures.clone();
                let artifacts = artifacts.clone();
                let out_dir = out_dir.clone();
                let p = p.clone();
                let verbose = a.bool("verbose");
                handles.push(std::thread::spawn(move || {
                    let ctx = match Ctx::new(&artifacts, &out_dir, p, verbose) {
                        Ok(c) => c,
                        Err(e) => {
                            failures.lock().unwrap().push(format!("worker {w}: {e:#}"));
                            return;
                        }
                    };
                    loop {
                        let id = match queue.lock().unwrap().pop_front() {
                            Some(id) => id,
                            None => return,
                        };
                        if let Err(e) = run_one_experiment(&ctx, &id) {
                            failures.lock().unwrap().push(format!("{id}: {e:#}"));
                        }
                    }
                }));
            }
            for h in handles {
                let _ = h.join();
            }
            let failures = failures.lock().unwrap();
            if !failures.is_empty() {
                bail!("{} experiment(s) failed:\n  {}", failures.len(), failures.join("\n  "));
            }
            Ok(())
        }
        "train" => {
            // Fails fast if --precision was given: inference-only flag.
            precision_from_args(&a, cmd)?;
            let model_name = a.req("model")?;
            let steps = a.u64("steps", 400)?;
            // One parallel plan for every engine: `--topology` (or a
            // deprecated alias) resolves to a MeshSpec up front; the
            // elastic, mesh and data-parallel paths below all consume it.
            let topo = topology_from_args(&a)?;
            let microbatches = a.usize("microbatches", 1)?.max(1);
            let ctx = Ctx::new(&artifacts, &out_dir, params_from_args(&a)?, a.bool("verbose"))?;
            let (model, mut state) = if let Some(load) = a.flags.get("load").cloned() {
                // Continue training from a checkpoint: a one-file
                // train-state bundle resumes bitwise; a params-only
                // checkpoint (e.g. `upcycle upcycle --out-ck`) starts with
                // fresh optimizer state — the upcycling recipe's language
                // setting.
                let entry = ctx.entry(model_name)?.clone();
                let model = ctx.load(model_name, &["train", "eval"])?;
                let ck = Checkpoint::load(&load)?;
                let state = match sparse_upcycle::checkpoint::bind_train_state(&ck, &entry) {
                    Ok((params, opt_state, step)) => TrainState { params, opt_state, step },
                    Err(bundle_err) => {
                        let params = sparse_upcycle::runtime::tensors_from_checkpoint(
                            &ck,
                            &entry.params,
                        )
                        .map_err(|params_err| {
                            bundle_err.context(format!(
                                "not loadable as a params-only checkpoint either ({params_err:#})"
                            ))
                        })?;
                        let opt_state = sparse_upcycle::runtime::tensors_from_checkpoint(
                            &sparse_upcycle::init::init_opt_state(&entry)?,
                            &entry.opt_state,
                        )?;
                        TrainState { params, opt_state, step: ck.step }
                    }
                };
                println!("loaded {model_name} @ step {} from {load}", state.step);
                (model, state)
            } else {
                ctx.branch_scratch(model_name, ctx.p.seed)?
            };
            let snapshot_every = a.u64("snapshot-every", 0)?;
            let fault_spec = a.flags.get("inject-fault").cloned();
            let elastic = snapshot_every > 0 || fault_spec.is_some();
            // Shared by the elastic and plain mesh paths: one validated
            // plan (`MeshConfig::from_topology` runs `MeshSpec::validate`
            // in exec mode) + `--serial-mesh` engine selection.
            let build_mesh = |topo: &MeshSpec| -> Result<MeshConfig> {
                Ok(MeshConfig::from_topology(&model.entry, topo, !a.bool("serial-mesh"))?
                    .with_microbatches(microbatches))
            };
            let series = if elastic {
                // Elastic mesh training: periodic SUPC snapshots with
                // rotation, failure detection and rollback + replay
                // recovery (docs/RESILIENCE.md). `--inject-fault r:s:p`
                // deterministically kills rank r at step s in phase p.
                let topo = topo.unwrap_or_else(|| MeshSpec::new(1, 1)); // single-worker run
                let mesh = build_mesh(&topo)?;
                let mut ecfg = sparse_upcycle::resilience::ElasticConfig::new(
                    ctx.ck_dir.join(format!("{model_name}_snapshots")),
                );
                ecfg.snapshot_every = snapshot_every.max(1);
                ecfg.snapshot_keep = a.usize("snapshot-keep", 3)?;
                if let Some(spec) = &fault_spec {
                    let plan = sparse_upcycle::resilience::FaultPlan::parse(spec)?;
                    // Fail fast on an unreachable fault: an out-of-range
                    // rank would silently never fire (coordinator-side
                    // phases ignore the rank — one optimizer per step).
                    if !plan.phase.on_coordinator() && plan.rank >= mesh.ranks() {
                        bail!(
                            "--inject-fault names rank {} but the {}x{} mesh \
                             has ranks 0..{}",
                            plan.rank,
                            mesh.dp,
                            mesh.ep,
                            mesh.ranks()
                        );
                    }
                    if plan.step > steps {
                        bail!(
                            "--inject-fault names step {} but the run is only {steps} step(s)",
                            plan.step
                        );
                    }
                    ecfg.faults = sparse_upcycle::resilience::FaultSchedule::single(plan);
                }
                ecfg.validate()?;
                println!(
                    "elastic mesh {}x{}: snapshot every {} step(s) (keep {}) \
                     under {}{}",
                    mesh.dp,
                    mesh.ep,
                    ecfg.snapshot_every,
                    ecfg.snapshot_keep,
                    ecfg.dir.display(),
                    fault_spec
                        .as_deref()
                        .map(|f| format!(", injecting fault {f}"))
                        .unwrap_or_default()
                );
                let (series, report) = ctx.run_branch_elastic(
                    &model, &mut state, 0, steps, &mesh, &ecfg, model_name,
                )?;
                println!("  {} snapshot(s) written", report.snapshots_written);
                for ev in &report.recoveries {
                    println!(
                        "  recovered: step {} died ({}), rolled back to step {} and replayed",
                        ev.failed_step,
                        if ev.injected { "injected fault" } else { "rank failure" },
                        ev.rolled_back_to
                    );
                }
                if fault_spec.is_some() && report.recoveries.is_empty() {
                    bail!(
                        "--inject-fault was given but no recovery happened (is the fault's \
                         step within --steps and its phase reachable for this model?)"
                    );
                }
                series
            } else if let Some(topo) =
                topo.filter(|t| t.expert_parallel > 1 || t.model_parallel > 1)
            {
                // DP×EP mesh: token shards per rank, expert weights sharded
                // over each group's EP ranks, real split-phase all-to-all
                // dispatch overlapping `--microbatches` pipeline slots.
                let mesh = build_mesh(&topo)?;
                println!(
                    "mesh {}x{}: {} rank(s), experts round-robin over {} \
                     expert-parallel rank(s), {} microbatch(es){}",
                    mesh.dp,
                    mesh.ep,
                    mesh.ranks(),
                    mesh.ep,
                    mesh.microbatches,
                    if mesh.parallel { "" } else { " (serial 1-worker reference)" }
                );
                ctx.run_branch_mesh(&model, &mut state, 0, steps, &mesh, model_name)?
            } else if let Some(topo) = topo.filter(|t| t.data_parallel > 1) {
                // A dp-only plan runs plain data parallelism over worker
                // threads (validated at setup: bad replica counts fail
                // here, not mid-run — `MeshSpec::validate`).
                let dp = DpConfig::replicated(&model.entry, topo.data_parallel)?;
                ctx.run_branch_dp(&model, &mut state, 0, steps, &dp, model_name)?
            } else {
                ctx.run_branch(&model, &mut state, 0, steps, model_name)?
            };
            if let Some(p) = series.last() {
                println!("final: {:?}", p.values);
                if let Some(&loss) = p.values.get("loss") {
                    if !loss.is_finite() {
                        bail!("training diverged: final loss is {loss}");
                    }
                }
            }
            let (p, o) = state.to_checkpoints(&model.entry, "cli train")?;
            let pp = ctx.ck_dir.join(format!("{model_name}_cli.params.supc"));
            let op = ctx.ck_dir.join(format!("{model_name}_cli.opt.supc"));
            p.save(&pp)?;
            o.save(&op)?;
            println!("saved {} and {}", pp.display(), op.display());
            if let Some(save) = a.flags.get("save") {
                // One-file trained-checkpoint bundle: params + optimizer
                // state + step. `upcycle serve`/`upcycle infer --load`
                // consume it; loading it back resumes bitwise.
                state.save(&model.entry, save, "cli train --save")?;
                println!("saved train-state bundle {save} (step {})", state.step);
            }
            Ok(())
        }
        "infer" => {
            let load = a.req("load")?.to_string();
            let precision = precision_from_args(&a, cmd)?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let header = Checkpoint::load(&load)?;
            let model_name = a.str("model", &header.model);
            let entry = manifest.model(&model_name)?.clone();
            let runtime = serving_runtime(&manifest, precision)?;
            let model = runtime.load_model(&manifest, &model_name, &["eval"])?;
            let (params, step) = load_serving_params(&header, &entry)?;
            let n = a.usize("requests", 4)?.max(1);
            let topo = match a.flags.get("topology") {
                Some(spec) => {
                    if a.flags.contains_key("ep") {
                        bail!("--topology replaces --ep; give only --topology");
                    }
                    MeshSpec::parse(spec)?
                }
                None => {
                    let ep = a.usize("ep", 1)?.max(1);
                    if a.flags.contains_key("ep") {
                        eprintln!("warning: --ep is deprecated; use --topology dp=1,ep={ep}");
                    }
                    MeshSpec::new(1, ep)
                }
            };
            let ep = topo.expert_parallel.max(1);
            let microbatches = a.usize("microbatches", 1)?.max(1);
            // One batch, one arrival gap default: infer draws the same
            // ServeSpec default as serve instead of a hardcoded burst
            // (arrival times do not affect a single stacked batch).
            let gap_us = serve::ServeSpec::default().gap_us;
            let trace = serve::synthetic_trace(&entry, n, a.u64("seed", 17)?, gap_us);
            let inputs = serve::stack_inputs(&trace)?;
            let out = serve::mesh_infer(&model, &params, &inputs, &topo, microbatches, precision)?;
            println!(
                "{model_name} @ step {step}: {n} example(s){}{}",
                if ep > 1 {
                    format!(", experts sharded over {ep} expert-parallel rank(s)")
                } else {
                    String::new()
                },
                if precision != Precision::F32 {
                    format!(", {} weights (f32 accumulate)", precision.as_str())
                } else {
                    String::new()
                }
            );
            let preds = out.predictions.i32s()?;
            let per = preds.len() / n;
            for (i, (row, score)) in preds.chunks(per).zip(&out.scores).enumerate() {
                println!("  request {i}: predictions {row:?}  score {score:.4}");
            }
            Ok(())
        }
        "serve" => {
            let load = a.req("load")?.to_string();
            let precision = precision_from_args(&a, cmd)?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let header = Checkpoint::load(&load)?;
            let model_name = a.str("model", &header.model);
            let entry = manifest.model(&model_name)?.clone();
            let runtime = serving_runtime(&manifest, precision)?;
            let model = runtime.load_model(&manifest, &model_name, &["eval"])?;
            let (params, step) = load_serving_params(&header, &entry)?;
            // Quantize once at load: every engine batch binds the same
            // quantized snapshot (the Engine itself stays precision-blind).
            let params = quantize_params(&entry, &params, precision)?;
            let n = a.usize("requests", 32)?;
            let seed = a.u64("seed", 17)?;
            let tpr = serve::tokens_per_request(&entry);
            let spec = serve_spec_from_args(&a)?;
            spec.validate(&entry)?;
            println!(
                "serving {model_name} @ step {step}: {n} request(s), policy {}, \
                 token budget {} ({tpr} tokens/request){}{}",
                spec.policy.name(),
                spec.resolved_batch_tokens(&entry),
                if spec.max_batch_requests == 1 { " [unbatched]" } else { "" },
                if precision != Precision::F32 {
                    format!(", {} weights (f32 accumulate)", precision.as_str())
                } else {
                    String::new()
                }
            );
            let trace = match a.flags.get("traffic") {
                Some(shape) => {
                    let process = serve::ArrivalProcess::from_name(shape, spec.gap_us)?;
                    let tenants = a.usize("tenants", 4)?.max(1);
                    println!("  traffic: {shape} arrivals over {tenants} tenant(s)");
                    serve::generate(
                        &entry,
                        &serve::TrafficSpec::standard(process, tenants, n, seed),
                    )?
                }
                None => serve::synthetic_trace(&entry, n, seed, spec.gap_us),
            };
            let engine = serve::Engine::new(&model, &params, spec)?;
            let report = engine.run_trace(trace)?;
            if a.bool("verbose") {
                for b in &report.batches {
                    println!(
                        "  batch {:>3}: {:>3} request(s) {:>5} tokens  v[{}..{}]µs  exec {}",
                        b.index,
                        b.requests,
                        b.tokens,
                        b.start_us,
                        b.finish_us,
                        sparse_upcycle::util::bench::fmt_ns(b.wall_ns)
                    );
                }
            }
            let nb = report.batches.len().max(1);
            println!("  {} micro-batch(es), mean {:.2} request(s)/batch", nb, n as f64 / nb as f64);
            println!(
                "  {} completed, {} shed ({:.1}% shed rate)",
                report.completions.len(),
                report.sheds.len(),
                100.0 * report.shed_rate()
            );
            for (reason, count) in report.sheds_by_reason() {
                println!("    shed[{reason}]: {count}");
            }
            println!(
                "  virtual latency: p50 {:.0} µs  p99 {:.0} µs  p999 {:.0} µs",
                report.p50_latency_us(),
                report.p99_latency_us(),
                report.p999_latency_us()
            );
            let tenants = report.tenant_counts();
            if tenants.len() > 1 {
                for (tenant, done, shed) in tenants {
                    println!("  tenant {tenant}: {done} completed, {shed} shed");
                }
            }
            println!("  measured execution throughput: {:.1} tokens/s", report.tokens_per_s());
            // Belt and braces on top of the engine's own accounting check:
            // the smoke gate relies on a nonzero exit if anything was lost.
            if report.completions.len() + report.sheds.len() != n {
                bail!(
                    "serve lost requests: {} completed + {} shed != {n}",
                    report.completions.len(),
                    report.sheds.len()
                );
            }
            Ok(())
        }
        "check-docs" => {
            let root = a.str("root", ".");
            let files = sparse_upcycle::util::doclinks::doc_files(&root)?;
            let dead = sparse_upcycle::util::doclinks::check_files(&files)?;
            for d in &dead {
                eprintln!(
                    "dead link in {}: ({}) resolves to missing {}",
                    d.file.display(),
                    d.target,
                    d.resolved.display()
                );
            }
            let stale = sparse_upcycle::util::doclinks::check_deprecated_flags(&files)?;
            for sf in &stale {
                eprintln!(
                    "deprecated flag `{}` in a fenced example, {}:{}: {}",
                    sf.flag,
                    sf.file.display(),
                    sf.line,
                    sf.text
                );
            }
            if !dead.is_empty() || !stale.is_empty() {
                bail!(
                    "{} dead relative link(s), {} deprecated flag(s) in fenced examples \
                     across {} doc file(s) (use --topology dp=D,ep=E[,tp=T] and \
                     --serve policy=…,budget=…)",
                    dead.len(),
                    stale.len(),
                    files.len()
                );
            }
            println!(
                "docs ok: {} file(s) checked, 0 dead relative links, \
                 0 deprecated flags in examples",
                files.len()
            );
            Ok(())
        }
        "upcycle" => {
            let dense_path = a.req("dense")?;
            let sparse_name = a.req("model")?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let entry = manifest.model(sparse_name)?;
            let dense = Checkpoint::load(dense_path)?;
            let seed = a.u64("seed", 0)?;
            let opts = UpcycleOptions {
                strategy: strategy_from_args(&a, seed)?,
                router_init: router_init_from_args(&a)?,
                load_experts: !a.bool("random-experts"),
                expert_noise: a.f64("expert-noise", 0.0)? as f32,
                router_stddev: a.f64("router-stddev", 0.02)? as f32,
                seed,
            };
            let cost = sparse_upcycle::costmodel::surgery_cost(entry, &opts.strategy);
            println!(
                "surgery `{}`: {:.2} MB copied, {} value(s) re-initialized, \
                 {} source bundle(s), {} reduce FLOPs",
                opts.strategy.name(),
                cost.bytes_copied as f64 / 1e6,
                cost.values_reinitialized,
                cost.sources_loaded,
                cost.reduce_flops
            );
            let sparse = upcycle_params(&dense, entry, &opts)?;
            if a.bool("diversity") {
                sparse_upcycle::upcycle::diversity::expert_diversity(&sparse, entry)?.print();
            }
            let default_out =
                format!("{}/checkpoints/{sparse_name}_upcycled.params.supc", out_dir);
            let out = a.str("out-ck", &default_out);
            sparse.save(&out)?;
            println!(
                "upcycled {} ({} tensors) -> {} ({} tensors) at {}",
                dense.model,
                dense.tensors.len(),
                sparse_name,
                sparse.tensors.len(),
                out
            );
            if let Some(opt_path) = a.flags.get("dense-opt") {
                let dense_opt = Checkpoint::load(opt_path)?;
                let sp_opt =
                    upcycle_opt_state(&dense_opt, entry, a.bool("load-optimizer"), &opts.strategy)?;
                let out_o = out.replace(".params.", ".opt.");
                sp_opt.save(&out_o)?;
                println!("optimizer state -> {out_o}");
            }
            Ok(())
        }
        "eval" => {
            let model_name = a.req("model")?;
            let params_path = a.req("params")?;
            let ctx = Ctx::new(&artifacts, &out_dir, params_from_args(&a)?, false)?;
            let entry = ctx.entry(model_name)?.clone();
            let model = ctx.load(model_name, &["eval"])?;
            let params = Checkpoint::load(params_path)?;
            let opt = sparse_upcycle::init::init_opt_state(&entry)?;
            let state = TrainState::from_checkpoints(&entry, &params, &opt)?;
            let m = ctx.evaluator(&entry).eval(&model, &state)?;
            println!("{model_name} @ step {}: {m:?}", params.step);
            Ok(())
        }
        "fewshot" => {
            let model_name = a.req("model")?;
            let params_path = a.req("params")?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let runtime = Runtime::for_manifest(&manifest)?;
            let model = runtime.load_model(&manifest, model_name, &["features"])?;
            let params = Checkpoint::load(params_path)?;
            let tensors = sparse_upcycle::runtime::tensors_from_checkpoint(
                &params, &model.entry.params)?;
            let cfg = FewShotConfig {
                shots: a.usize("shots", 10)?,
                seeds: a.usize("probe-seeds", 5)?,
                ..Default::default()
            };
            let acc = fewshot_accuracy(&model, &tensors, &cfg, a.u64("seed", 17)?)?;
            println!("{model_name}: {}-shot accuracy = {acc:.4}", cfg.shots);
            Ok(())
        }
        "bench-gate" => {
            let baseline_path = a.req("baseline")?.to_string();
            let current_path = a.req("current")?.to_string();
            let tol = a.f64("tolerance-pct", 25.0)?;
            let read = |p: &str| -> Result<sparse_upcycle::util::json::Json> {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading bench report {p}"))?;
                sparse_upcycle::util::json::Json::parse(&text)
                    .with_context(|| format!("parsing bench report {p}"))
            };
            // The current report must always parse; it becomes the new
            // baseline under --update-baseline.
            let current = read(&current_path)?;
            if a.bool("update-baseline") {
                // Refresh must work across schema bumps and from a missing
                // or corrupt baseline — compare only best-effort here.
                match read(&baseline_path).and_then(|baseline| {
                    sparse_upcycle::metrics::bench_gate::compare(&baseline, &current, tol)
                }) {
                    Ok(rep) => rep.print(),
                    Err(e) => println!("old baseline not comparable ({e:#}); replacing it"),
                }
                std::fs::copy(&current_path, &baseline_path)
                    .with_context(|| format!("writing {baseline_path}"))?;
                println!("baseline refreshed from {current_path}");
                return Ok(());
            }
            let baseline = read(&baseline_path)?;
            let rep =
                sparse_upcycle::metrics::bench_gate::compare(&baseline, &current, tol)?;
            rep.print();
            if rep.gating_failures() > 0 {
                bail!(
                    "{} bench metric(s) regressed beyond {tol}% tolerance (see report above); \
                     if intentional, refresh with `make bench-baseline`",
                    rep.gating_failures()
                );
            }
            Ok(())
        }
        "report" => {
            let summaries =
                sparse_upcycle::metrics::report_summary::load_summaries(&out_dir)?;
            let md = sparse_upcycle::metrics::report_summary::render_markdown(&summaries);
            let path = std::path::Path::new(&out_dir).join("SUMMARY.md");
            std::fs::write(&path, &md)?;
            println!("{md}");
            println!("(wrote {})", path.display());
            Ok(())
        }
        "inspect" => {
            let path = a.req("ck")?;
            let ck = Checkpoint::load(path)?;
            println!("model: {}  step: {}  provenance: {}", ck.model, ck.step, ck.provenance);
            println!("{} tensors, {:.2} MB", ck.tensors.len(), ck.total_bytes() as f64 / 1e6);
            if a.bool("tensors") {
                for (name, t) in &ck.tensors {
                    println!(
                        "  {name:<44} {:>14} mean {:>10.4} l2 {:>10.3}",
                        format!("{:?}", t.shape),
                        t.mean(),
                        t.l2()
                    );
                }
            }
            Ok(())
        }
        "sweep" => {
            let results_default = std::path::Path::new(&out_dir)
                .join("SWEEP_results.json")
                .to_string_lossy()
                .into_owned();
            let results_path = a.str("results", &results_default);
            match a.positional.get(1).map(|s| s.as_str()) {
                None => {
                    let spec = sweep::SweepSpec::parse(&a.str("sweep", ""))?;
                    let mut cfg = sweep::SweepConfig::new(&artifacts, &out_dir);
                    cfg.cores = a.usize("cores", 1)?;
                    cfg.seed = a.u64("seed", cfg.seed)?;
                    cfg.eval_batches = a.usize("eval-batches", cfg.eval_batches)?;
                    cfg.results_path = std::path::PathBuf::from(&results_path);
                    cfg.verbose = a.bool("verbose");
                    let run = sweep::run_sweep(&spec, &cfg)?;
                    if run.grid >= 2 {
                        println!("  next: `upcycle sweep fit --results {results_path}`");
                    }
                    Ok(())
                }
                Some("fit") => {
                    let store = sweep::store::ResultsStore::load(&results_path)?;
                    let run = match a.flags.get("run") {
                        Some(_) => {
                            let i = a.usize("run", 0)?;
                            store.runs.get(i).with_context(|| {
                                format!(
                                    "--run {i} out of range: store has {} run(s)",
                                    store.runs.len()
                                )
                            })?
                        }
                        None => store.latest()?,
                    };
                    // Gate before fitting: a missing leg or a NaN loss is a
                    // named failure (the CI sweep-smoke relies on the
                    // nonzero exit), never a silently thinner fit.
                    run.check_complete()?;
                    println!(
                        "fitting run over `{}` (seed {}, {} leg(s)):",
                        run.spec,
                        run.seed,
                        run.legs.len()
                    );
                    let fit = sweep::fit::power_law_fit(&run.fit_points())?;
                    fit.print();
                    Ok(())
                }
                Some(other) => {
                    bail!("unknown sweep subcommand `{other}` (expected `sweep` or `sweep fit`)")
                }
            }
        }
        "comms" => {
            let model_name = a.req("model")?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let entry = manifest.model(model_name)?;
            let mesh = sim_topology_from_args(&a)?;
            mesh.validate(entry, sparse_upcycle::parallel::MeshMode::Sim)?;
            let net = sparse_upcycle::parallel::collectives::Interconnect::tpu_like(
                mesh.devices());
            let tokens = a.usize("tokens-per-device", 4096)?;
            let imb = a.f64("imbalance", 1.0)?;
            let rep = sparse_upcycle::parallel::collectives::step_comms(
                entry, &mesh, &net, tokens, imb);
            println!("{model_name} on dp={} ep={} mp={} ({} tokens/dev, imbalance {imb}):",
                     mesh.data_parallel, mesh.expert_parallel, mesh.model_parallel, tokens);
            println!("  expert all-to-all : {:>10.1} µs/step", rep.expert_alltoall_s * 1e6);
            println!("  grad all-reduce   : {:>10.1} µs/step", rep.grad_allreduce_s * 1e6);
            println!("  mp all-gather     : {:>10.1} µs/step", rep.mp_allgather_s * 1e6);
            println!("  total             : {:>10.1} µs/step", rep.total() * 1e6);
            Ok(())
        }
        "mesh" => {
            let model_name = a.req("model")?;
            let manifest = Manifest::load_or_native(&artifacts)?;
            let entry = manifest.model(model_name)?;
            let mesh = sim_topology_from_args(&a)?;
            mesh.validate(entry, sparse_upcycle::parallel::MeshMode::Sim)?;
            let rep = place(entry, &mesh);
            println!("{model_name} on {} devices (dp={} ep={} mp={}):",
                     rep.devices, mesh.data_parallel, mesh.expert_parallel, mesh.model_parallel);
            println!("  experts/device: {:?}", rep.experts_per_device);
            println!("  expert params/device: {:.2} MB",
                     rep.expert_param_bytes_per_device as f64 / 1e6);
            println!("  dense params/device:  {:.2} MB",
                     rep.dense_param_bytes as f64 / 1e6);
            Ok(())
        }
        other => bail!("unknown command `{other}`; try `upcycle help`"),
    }
}

const HELP: &str = "\
upcycle — Sparse Upcycling (ICLR 2023) training + serving coordinator

USAGE:
  upcycle quickstart [--pretrain-steps N] [--extra-steps N]   # native demo
  upcycle list
  upcycle experiment <id>|all [--pretrain-steps N] [--extra-steps N] [--seed S]
  upcycle train   --model <name> [--steps N]
                  [--load <ck.supc>]  # continue from a bundle or upcycled params
                  [--topology dp=D,ep=E[,tp=T]]  # one validated parallel plan
                  [--microbatches M]  # overlap all-to-all with expert compute
                  [--serial-mesh]     # serial 1-worker mesh reference
                  [--save <ck.supc>]  # one-file train-state bundle
                  [--snapshot-every N] [--snapshot-keep K]  # elastic training
                  [--inject-fault r:s:p]  # kill rank r at step s in phase p
  upcycle serve   --load <ck.supc> [--model <name>] [--requests N]
                  [--serve policy=fifo|priority|fair|slo,budget=T,max-batch=N,
                           queue=Q,shed=reject|evict,gap=G,floor=F,slo=D]
                  [--precision f32|bf16|int8]  # quantized weights, SIMD kernels
                  [--traffic uniform|bursty|diurnal|adversarial] [--tenants N]
                  [--seed S] [--verbose]  # policy-driven continuous batching
  upcycle infer   --load <ck.supc> [--model <name>] [--requests N]
                  [--topology dp=1,ep=E] [--microbatches M]
                  [--precision f32|bf16|int8]  # quantized weights, SIMD kernels
  upcycle upcycle --dense <ck.supc> --model <sparse-name> [--random-experts]
                  [--strategy replicate|drop-upcycle|split|multi-checkpoint]
                  [--reinit-fraction F] [--strategy-seed S]  # drop-upcycle
                  [--granularity G] [--expansion X]          # split
                  [--checkpoints a.supc,b.supc] [--shared primary|average]
                  [--router-init normal|virtual-groups] [--router-groups N]
                  [--diversity]       # print per-layer inter-expert diversity
                  [--expert-noise σ] [--dense-opt <ck>] [--load-optimizer]
  upcycle sweep   [--sweep sunk=30+60,experts=2+8,capacity=2,router=ec,
                           strategy=replicate+drop,reinit=0.25,budget=20+40,
                           eval=10,parent=lm_tiny_dense]
                  [--cores N]         # worker-thread budget (default 1)
                  [--results <json>] [--seed S]  # scaling-law sweep lab
  upcycle sweep fit [--results <json>] [--run I]  # power-law fit + residuals
  upcycle eval    --model <name> --params <ck.supc>
  upcycle fewshot --model <vit-name> --params <ck.supc> [--shots K]
  upcycle mesh    --model <name> [--topology dp=D,ep=E[,tp=T]]
  upcycle comms   --model <name> [--topology dp=D,ep=E[,tp=T]] [--imbalance X]
  upcycle bench-gate --baseline <json> --current <json> [--tolerance-pct N]
                  [--update-baseline]  # fail on perf regression vs baseline
  upcycle check-docs [--root DIR]     # markdown relative-link check
  upcycle report                      # aggregate results/*.json -> SUMMARY.md
  upcycle inspect --ck <file.supc> [--tensors]

Full flag reference: docs/CLI.md. Common flags: --artifacts DIR (default
artifacts/), --out DIR (default results/)";

// The train()/Evaluator imports are exercised through Ctx methods; keep the
// explicit names for doc discoverability.
#[allow(unused_imports)]
use sparse_upcycle::coordinator::Evaluator as _EvaluatorDoc;
#[allow(unused)]
fn _doc_anchor() {
    let _ = train;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    /// `--precision` parse matrix: accepted spellings on the forward-only
    /// commands, rejected by name on `train` and on unknown values.
    #[test]
    fn precision_flag_parse_matrix() {
        for cmd in ["infer", "serve"] {
            let a = parse(&format!("{cmd} --load ck.supc"));
            assert_eq!(precision_from_args(&a, cmd).unwrap(), Precision::F32);
            for (spelling, want) in [
                ("f32", Precision::F32),
                ("bf16", Precision::Bf16),
                ("int8", Precision::Int8PerChannel),
            ] {
                let a = parse(&format!("{cmd} --load ck.supc --precision {spelling}"));
                assert_eq!(precision_from_args(&a, cmd).unwrap(), want, "{cmd} {spelling}");
            }
            for bad in ["fp16", "int4", "F32"] {
                let a = parse(&format!("{cmd} --load ck.supc --precision {bad}"));
                let err = precision_from_args(&a, cmd).unwrap_err();
                assert!(
                    format!("{err:#}").contains("unknown precision"),
                    "{cmd} {bad}: {err:#}"
                );
            }
        }
    }

    #[test]
    fn train_rejects_precision_by_name() {
        // Without the flag, train resolves to implicit f32 like everyone.
        let a = parse("train --model lm_tiny_dense");
        assert_eq!(precision_from_args(&a, "train").unwrap(), Precision::F32);
        // With it — even spelled validly — train fails loudly.
        for spelling in ["f32", "bf16", "int8"] {
            let a = parse(&format!("train --model lm_tiny_dense --precision {spelling}"));
            let err = precision_from_args(&a, "train").unwrap_err();
            assert!(format!("{err:#}").contains("inference-only"), "{spelling}: {err:#}");
        }
    }
}
