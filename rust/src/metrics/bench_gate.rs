//! Bench regression gate: compare a fresh `BENCH_runtime.json` against the
//! committed `BENCH_baseline.json` and fail on throughput or latency
//! regressions beyond a tolerance.
//!
//! Driven by `upcycle bench-gate --baseline ... --current ...
//! [--tolerance-pct N] [--update-baseline]`; CI runs it right after the
//! `--quick` bench so a perf regression fails the pipeline instead of
//! silently landing (see `docs/BENCHMARKS.md` for the refresh procedure).
//!
//! Gated metrics, per model present in the baseline:
//! * `tokens_per_s` (`models[].train.items_per_s`) — must not drop more
//!   than `tolerance_pct` below the baseline;
//! * `train_p50_ns` (`models[].train.p50_ns`) — must not rise more than
//!   `tolerance_pct` above it.
//!
//! A baseline with `"bootstrap": true` was not measured on the gating
//! machine (e.g. committed from an offline authoring environment):
//! comparisons are reported but regressions only warn, so the gate arms
//! itself the first time a measured baseline is committed
//! (`make bench-baseline` / `--update-baseline` writes one).

use anyhow::{bail, Result};

use crate::util::json::Json;

/// One gated comparison.
pub struct GateCheck {
    pub model: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// `current / baseline` (tokens/s: higher is better; latency: lower).
    pub ratio: f64,
    pub ok: bool,
}

pub struct GateReport {
    pub checks: Vec<GateCheck>,
    pub tolerance_pct: f64,
    /// Baseline is a bootstrap placeholder; regressions warn instead of
    /// failing (see the module docs).
    pub bootstrap: bool,
}

impl GateReport {
    /// Checks outside tolerance, regardless of bootstrap status.
    pub fn regressions(&self) -> usize {
        self.checks.iter().filter(|c| !c.ok).count()
    }

    /// Regressions that should fail the pipeline (none while the baseline
    /// is a bootstrap placeholder).
    pub fn gating_failures(&self) -> usize {
        if self.bootstrap {
            0
        } else {
            self.regressions()
        }
    }

    pub fn print(&self) {
        println!(
            "bench gate: {} checks, tolerance {:.0}%{}",
            self.checks.len(),
            self.tolerance_pct,
            if self.bootstrap { " (bootstrap baseline: regressions warn only)" } else { "" }
        );
        for c in &self.checks {
            println!(
                "  {} {:<28} {:<14} base {:>14.1}  now {:>14.1}  ratio {:>6.3}",
                if c.ok { "ok  " } else { "FAIL" },
                c.model,
                c.metric,
                c.baseline,
                c.current,
                c.ratio
            );
        }
    }
}

/// Compare two `BENCH_runtime.json` documents; see the module docs for the
/// gated metrics. Fails (as an error, not a check) on schema mismatch.
pub fn compare(baseline: &Json, current: &Json, tolerance_pct: f64) -> Result<GateReport> {
    let bv = baseline.get("schema_version")?.as_f64()?;
    let cv = current.get("schema_version")?.as_f64()?;
    if bv != cv {
        bail!(
            "bench schema mismatch: baseline v{bv} vs current v{cv}; refresh the baseline \
             (docs/BENCHMARKS.md)"
        );
    }
    if !(0.0..1000.0).contains(&tolerance_pct) {
        bail!("tolerance {tolerance_pct}% out of range");
    }
    let bootstrap =
        baseline.opt("bootstrap").map(|b| b.as_bool().unwrap_or(false)).unwrap_or(false);
    let tol = tolerance_pct / 100.0;
    let mut checks = Vec::new();
    let current_models = current.get("models")?.as_arr()?;
    for bm in baseline.get("models")?.as_arr()? {
        let name = bm.get("model")?.as_str()?.to_string();
        let found = current_models
            .iter()
            .find(|m| m.opt("model").and_then(|v| v.as_str().ok()) == Some(name.as_str()));
        let Some(cm) = found else {
            // A benched model vanishing from the report is itself a
            // regression (the bench was narrowed or the model dropped).
            checks.push(GateCheck {
                model: name,
                metric: "present".to_string(),
                baseline: 1.0,
                current: 0.0,
                ratio: 0.0,
                ok: false,
            });
            continue;
        };
        let b_tok = bm.get("train")?.get("items_per_s")?.as_f64()?;
        let c_tok = cm.get("train")?.get("items_per_s")?.as_f64()?;
        checks.push(GateCheck {
            model: name.clone(),
            metric: "tokens_per_s".to_string(),
            baseline: b_tok,
            current: c_tok,
            ratio: c_tok / b_tok.max(1e-12),
            ok: c_tok >= b_tok * (1.0 - tol),
        });
        let b_p50 = bm.get("train")?.get("p50_ns")?.as_f64()?;
        let c_p50 = cm.get("train")?.get("p50_ns")?.as_f64()?;
        checks.push(GateCheck {
            model: name,
            metric: "train_p50_ns".to_string(),
            baseline: b_p50,
            current: c_p50,
            ratio: c_p50 / b_p50.max(1e-12),
            ok: c_p50 <= b_p50 * (1.0 + tol),
        });
    }
    Ok(GateReport { checks, tolerance_pct, bootstrap })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(models: &[(&str, f64, f64)], extra: &str) -> Json {
        let entries: Vec<String> = models
            .iter()
            .map(|(name, tok, p50)| {
                format!(
                    r#"{{"model": "{name}", "train": {{"items_per_s": {tok}, "p50_ns": {p50}}}}}"#
                )
            })
            .collect();
        let text = format!(
            r#"{{"schema_version": 1, {extra}"models": [{}]}}"#,
            entries.join(", ")
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn passes_within_tolerance_and_fails_beyond() {
        let base = report(&[("m1", 1000.0, 5e6), ("m2", 2000.0, 2e6)], "");
        // m1 slightly slower (within 25%), m2 well within.
        let ok = report(&[("m1", 900.0, 5.5e6), ("m2", 2100.0, 1.9e6)], "");
        let rep = compare(&base, &ok, 25.0).unwrap();
        assert_eq!(rep.regressions(), 0);
        assert_eq!(rep.gating_failures(), 0);
        assert_eq!(rep.checks.len(), 4);
        // m1 thoughput collapses and its p50 balloons: two failures.
        let bad = report(&[("m1", 500.0, 9e6), ("m2", 2000.0, 2e6)], "");
        let rep = compare(&base, &bad, 25.0).unwrap();
        assert_eq!(rep.regressions(), 2);
        assert_eq!(rep.gating_failures(), 2);
        assert!(rep.checks.iter().any(|c| c.metric == "tokens_per_s" && !c.ok));
        assert!(rep.checks.iter().any(|c| c.metric == "train_p50_ns" && !c.ok));
    }

    #[test]
    fn missing_model_is_a_regression() {
        let base = report(&[("m1", 1000.0, 5e6), ("m2", 2000.0, 2e6)], "");
        let cur = report(&[("m1", 1000.0, 5e6)], "");
        let rep = compare(&base, &cur, 25.0).unwrap();
        assert_eq!(rep.regressions(), 1);
        assert!(rep.checks.iter().any(|c| c.metric == "present" && !c.ok));
    }

    #[test]
    fn bootstrap_baseline_warns_but_does_not_gate() {
        let base = report(&[("m1", 1000.0, 5e6)], r#""bootstrap": true, "#);
        let bad = report(&[("m1", 10.0, 9e9)], "");
        let rep = compare(&base, &bad, 25.0).unwrap();
        assert!(rep.bootstrap);
        assert_eq!(rep.regressions(), 2, "regressions still reported");
        assert_eq!(rep.gating_failures(), 0, "but nothing gates");
    }

    #[test]
    fn schema_mismatch_is_an_error() {
        let base = report(&[("m1", 1.0, 1.0)], "");
        let cur = Json::parse(r#"{"schema_version": 2, "models": []}"#).unwrap();
        assert!(compare(&base, &cur, 25.0).is_err());
        assert!(compare(&base, &base, -1.0).is_err());
    }
}
