//! Metrics logging: in-memory series + CSV/JSON writers for the experiment
//! harness (every figure in DESIGN.md §4 is regenerated from these files),
//! plus the CI bench-regression gate ([`bench_gate`]).

pub mod bench_gate;
pub mod report_summary;

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// One evaluation point on a training curve.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub step: u64,
    /// Cumulative extra cost at this point (FLOPs since the branch point).
    pub extra_flops: f64,
    pub values: BTreeMap<String, f64>,
}

/// A named training/eval curve (one line in one figure panel).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Series {
    pub name: String,
    pub points: Vec<Point>,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, step: u64, extra_flops: f64, values: BTreeMap<String, f64>) {
        self.points.push(Point { step, extra_flops, values });
    }

    pub fn last(&self) -> Option<&Point> {
        self.points.last()
    }
}

/// A figure/table result: several series + metadata, serializable to CSV
/// (for plotting) and JSON (for EXPERIMENTS.md extraction).
#[derive(Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub series: Vec<Series>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report { id: id.into(), title: title.into(), series: Vec::new(), notes: Vec::new() }
    }

    pub fn add(&mut self, series: Series) {
        self.series.push(series);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    fn metric_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for srs in &self.series {
            for p in &srs.points {
                for k in p.values.keys() {
                    if !names.contains(k) {
                        names.push(k.clone());
                    }
                }
            }
        }
        names
    }

    pub fn write_csv(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&path).with_context(|| format!("creating {path:?}"))?,
        );
        let metrics = self.metric_names();
        write!(f, "series,step,extra_flops,extra_core_days")?;
        for m in &metrics {
            write!(f, ",{m}")?;
        }
        writeln!(f)?;
        for srs in &self.series {
            for p in &srs.points {
                let cd = crate::costmodel::Cost { flops: p.extra_flops }.core_days();
                write!(f, "{},{},{:.6e},{:.6}", srs.name, p.step, p.extra_flops, cd)?;
                for m in &metrics {
                    match p.values.get(m) {
                        Some(v) => write!(f, ",{v:.6}")?,
                        None => write!(f, ",")?,
                    }
                }
                writeln!(f)?;
            }
        }
        Ok(path)
    }

    pub fn write_json(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.id));
        let series = self.series.iter().map(series_json).collect();
        let root = obj(vec![
            ("id", s(&self.id)),
            ("title", s(&self.title)),
            ("notes", arr(self.notes.iter().map(|n| s(n)).collect())),
            ("series", arr(series)),
        ]);
        std::fs::write(&path, root.to_string())?;
        Ok(path)
    }

    /// Pretty console rendering (the "same rows the paper reports").
    pub fn print(&self) {
        println!("\n== {} — {} ==", self.id, self.title);
        for n in &self.notes {
            println!("   {n}");
        }
        let metrics = self.metric_names();
        for srs in &self.series {
            println!("  [{}]", srs.name);
            for p in &srs.points {
                let cd = crate::costmodel::Cost { flops: p.extra_flops }.core_days();
                let vals: Vec<String> = metrics
                    .iter()
                    .filter_map(|m| p.values.get(m).map(|v| format!("{m}={v:.4}")))
                    .collect();
                println!(
                    "    step {:>6}  +{:>8.4} core-days  {}",
                    p.step,
                    cd,
                    vals.join("  ")
                );
            }
        }
    }
}

pub fn map(kv: &[(&str, f64)]) -> BTreeMap<String, f64> {
    kv.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// One [`Point`] as a JSON object — shared by [`Report::write_json`] and
/// the sweep results store (`sweep::store`) so a trajectory serializes
/// identically wherever it lands.
pub fn point_json(p: &Point) -> Json {
    let mut fields = vec![
        ("step".to_string(), num(p.step as f64)),
        ("extra_flops".to_string(), num(p.extra_flops)),
    ];
    for (k, v) in &p.values {
        fields.push((k.clone(), num(*v)));
    }
    Json::Obj(fields.into_iter().collect())
}

/// One [`Series`] as a JSON object (`{"name": …, "points": […]}`).
pub fn series_json(srs: &Series) -> Json {
    obj(vec![("name", s(&srs.name)), ("points", arr(srs.points.iter().map(point_json).collect()))])
}

/// Inverse of [`series_json`]: every non-`step`/`extra_flops` numeric key
/// of a point becomes a metric value.
pub fn series_from_json(v: &Json) -> Result<Series> {
    let mut srs = Series::new(v.get("name")?.as_str()?);
    for p in v.get("points")?.as_arr()? {
        let step = p.get("step")?.as_f64()? as u64;
        let extra_flops = p.get("extra_flops")?.as_f64()?;
        let mut values = BTreeMap::new();
        if let Json::Obj(m) = p {
            for (k, val) in m {
                if k != "step" && k != "extra_flops" {
                    values.insert(k.clone(), val.as_f64()?);
                }
            }
        }
        srs.points.push(Point { step, extra_flops, values });
    }
    Ok(srs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_json_roundtrip() {
        let mut r = Report::new("test_fig", "unit test");
        let mut srs = Series::new("dense");
        srs.push(10, 1e12, map(&[("loss", 2.5), ("accuracy", 0.1)]));
        srs.push(20, 2e12, map(&[("loss", 2.0), ("accuracy", 0.2)]));
        r.add(srs);
        r.note("a note");
        let dir = std::env::temp_dir().join("supc_metrics_test");
        let csv = r.write_csv(&dir).unwrap();
        let json = r.write_json(&dir).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.contains("dense,10"));
        assert!(csv_text.lines().count() == 3);
        let v = Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "test_fig");
        assert_eq!(v.get("series").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn series_json_round_trips() {
        let mut srs = Series::new("upcycled");
        srs.push(5, 1.5e11, map(&[("loss", 3.25), ("accuracy", 0.125)]));
        srs.push(10, 3e11, map(&[("loss", 2.75)]));
        let back = series_from_json(&series_json(&srs)).unwrap();
        assert_eq!(back.name, srs.name);
        assert_eq!(back.points, srs.points);
        // Byte-stable: the same series always serializes identically
        // (the sweep store's bitwise-determinism contract leans on this).
        assert_eq!(series_json(&srs).to_string(), series_json(&back).to_string());
    }
}
