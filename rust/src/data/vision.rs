//! Procedural vision substrate: JFT/ImageNet stand-in (DESIGN.md §2).
//!
//! Renders small RGB images containing one dominant geometric shape
//! (circle / square / triangle / cross) in one of four hues over a noisy
//! background, plus distractor clutter. The label is `shape * 4 + hue`
//! (16 classes). Classification is capacity-bound at tiny model sizes —
//! the regime where the paper's dense-vs-upcycled comparisons live — and
//! the same generator drives pretraining, full finetuning (fewer classes,
//! different seed family) and the 10-shot linear probe (§A.2.2).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const NUM_SHAPES: usize = 4;
pub const NUM_HUES: usize = 4;
pub const NUM_CLASSES: usize = NUM_SHAPES * NUM_HUES;

const HUES: [[f32; 3]; NUM_HUES] = [
    [0.9, 0.2, 0.15], // red
    [0.2, 0.75, 0.25], // green
    [0.2, 0.35, 0.9], // blue
    [0.9, 0.8, 0.2],  // yellow
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    Circle,
    Square,
    Triangle,
    Cross,
}

impl Shape {
    fn from_id(id: usize) -> Shape {
        match id % NUM_SHAPES {
            0 => Shape::Circle,
            1 => Shape::Square,
            2 => Shape::Triangle,
            _ => Shape::Cross,
        }
    }

    /// Signed membership test for pixel (x, y) against a shape centred at
    /// (cx, cy) with radius r.
    fn contains(&self, x: f32, y: f32, cx: f32, cy: f32, r: f32) -> bool {
        let (dx, dy) = (x - cx, y - cy);
        match self {
            Shape::Circle => dx * dx + dy * dy <= r * r,
            Shape::Square => dx.abs() <= r && dy.abs() <= r,
            Shape::Triangle => {
                // Upward triangle: inside if below the two slanted edges.
                dy >= -r && dy <= r && dx.abs() <= (r - dy) * 0.5 + 0.2
            }
            Shape::Cross => {
                (dx.abs() <= r * 0.35 && dy.abs() <= r)
                    || (dy.abs() <= r * 0.35 && dx.abs() <= r)
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct VisionSpec {
    pub image_size: usize,
    pub noise: f32,
    pub distractors: usize,
}

impl Default for VisionSpec {
    fn default() -> Self {
        VisionSpec { image_size: 32, noise: 0.08, distractors: 2 }
    }
}

pub struct VisionPipeline {
    pub spec: VisionSpec,
    batch_size: usize,
    rng: Rng,
}

impl VisionPipeline {
    pub fn new(spec: VisionSpec, batch_size: usize, seed: u64, shard: u64) -> VisionPipeline {
        VisionPipeline { spec, batch_size, rng: Rng::with_stream(seed, 2 * shard + 101) }
    }

    /// Render one image for `label`; writes into `out` ([H, W, 3] row-major).
    pub fn render(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        let sz = self.spec.image_size;
        debug_assert_eq!(out.len(), sz * sz * 3);
        let shape = Shape::from_id(label / NUM_HUES);
        let hue = HUES[label % NUM_HUES];

        // Background: soft gray with per-pixel noise.
        for px in out.iter_mut() {
            *px = 0.45 + rng.normal() * self.spec.noise;
        }
        // Distractor clutter: small shapes in random dim colors (never the
        // target hue at full saturation, so the task stays well-posed).
        for _ in 0..self.spec.distractors {
            let ds = Shape::from_id(rng.below(NUM_SHAPES));
            let cx = rng.f32() * sz as f32;
            let cy = rng.f32() * sz as f32;
            let r = 1.5 + rng.f32() * 2.5;
            let col = [rng.f32() * 0.4 + 0.3; 3];
            draw(out, sz, ds, cx, cy, r, &col);
        }
        // Dominant shape: large, centered-ish, fully saturated hue.
        let margin = sz as f32 * 0.3;
        let cx = margin + rng.f32() * (sz as f32 - 2.0 * margin);
        let cy = margin + rng.f32() * (sz as f32 - 2.0 * margin);
        let r = sz as f32 * (0.18 + rng.f32() * 0.10);
        draw(out, sz, shape, cx, cy, r, &hue);
    }

    /// (images `[B,H,W,3]`, labels `[B]`) in manifest batch order.
    pub fn next_batch(&mut self) -> (Vec<Tensor>, Vec<usize>) {
        let sz = self.spec.image_size;
        let b = self.batch_size;
        let mut images = vec![0f32; b * sz * sz * 3];
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let label = self.rng.below(NUM_CLASSES);
            labels.push(label);
            let mut sub = self.rng.fork(i as u64);
            self.render(label, &mut sub, &mut images[i * sz * sz * 3..(i + 1) * sz * sz * 3]);
        }
        let lab_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        (
            vec![
                Tensor::from_f32(&[b, sz, sz, 3], images),
                Tensor::from_i32(&[b], lab_i32),
            ],
            labels,
        )
    }

    /// N examples per class in class order (few-shot probe support set).
    pub fn class_balanced(&mut self, per_class: usize) -> (Vec<Tensor>, Vec<usize>) {
        let sz = self.spec.image_size;
        let total = per_class * NUM_CLASSES;
        let mut images = vec![0f32; total * sz * sz * 3];
        let mut labels = Vec::with_capacity(total);
        for c in 0..NUM_CLASSES {
            for j in 0..per_class {
                let i = c * per_class + j;
                labels.push(c);
                let mut sub = self.rng.fork((c * 10_007 + j) as u64);
                self.render(c, &mut sub, &mut images[i * sz * sz * 3..(i + 1) * sz * sz * 3]);
            }
        }
        let lab_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        (
            vec![
                Tensor::from_f32(&[total, sz, sz, 3], images),
                Tensor::from_i32(&[total], lab_i32),
            ],
            labels,
        )
    }
}

fn draw(out: &mut [f32], sz: usize, shape: Shape, cx: f32, cy: f32, r: f32, color: &[f32; 3]) {
    for y in 0..sz {
        for x in 0..sz {
            if shape.contains(x as f32 + 0.5, y as f32 + 0.5, cx, cy, r) {
                let base = (y * sz + x) * 3;
                out[base..base + 3].copy_from_slice(color);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_wellformed() {
        let mut p = VisionPipeline::new(VisionSpec::default(), 8, 3, 0);
        let (tensors, labels) = p.next_batch();
        assert_eq!(tensors[0].shape, vec![8, 32, 32, 3]);
        assert_eq!(tensors[1].shape, vec![8]);
        assert!(labels.iter().all(|&l| l < NUM_CLASSES));
        let px = tensors[0].f32s().unwrap();
        assert!(px.iter().all(|v| v.is_finite()));
        // Images are not constant.
        let (mn, mx) = px.iter().fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        assert!(mx - mn > 0.3, "image has no contrast: {mn}..{mx}");
    }

    #[test]
    fn deterministic_per_seed_and_shard() {
        let run = |seed, shard| {
            let mut p = VisionPipeline::new(VisionSpec::default(), 4, seed, shard);
            p.next_batch().0[0].f32s().unwrap().to_vec()
        };
        assert_eq!(run(1, 0), run(1, 0));
        assert_ne!(run(1, 0), run(2, 0));
        assert_ne!(run(1, 0), run(1, 1));
    }

    #[test]
    fn class_balanced_is_balanced() {
        let mut p = VisionPipeline::new(VisionSpec::default(), 4, 5, 0);
        let (tensors, labels) = p.class_balanced(3);
        assert_eq!(labels.len(), 3 * NUM_CLASSES);
        assert_eq!(tensors[0].shape[0], 3 * NUM_CLASSES);
        for c in 0..NUM_CLASSES {
            assert_eq!(labels.iter().filter(|&&l| l == c).count(), 3);
        }
    }

    #[test]
    fn hue_dominates_target_pixels() {
        // A red circle image must contain strongly red pixels.
        let spec = VisionSpec { noise: 0.0, distractors: 0, ..Default::default() };
        let p = VisionPipeline::new(spec, 1, 0, 0);
        let mut img = vec![0f32; 32 * 32 * 3];
        p.render(0, &mut Rng::new(1), &mut img); // shape 0 (circle), hue 0 (red)
        let red_px = img
            .chunks_exact(3)
            .filter(|c| c[0] > 0.8 && c[1] < 0.3 && c[2] < 0.3)
            .count();
        assert!(red_px > 20, "expected a red blob, found {red_px} px");
    }
}
