//! Synthetic data substrates (DESIGN.md §2 substitution table): a learnable
//! HMM/Zipf text corpus with T5 span corruption standing in for C4, and a
//! procedural shapes dataset standing in for JFT-300M / ImageNet.

pub mod text;
pub mod vision;
