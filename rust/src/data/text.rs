//! Synthetic text substrate: C4 stand-in + T5 span corruption.
//!
//! The paper pretrains on the span-corruption task over C4 (§4.1). We cannot
//! ship C4, so we generate a corpus with genuinely learnable structure: a
//! hidden-Markov chain over topic states, each emitting tokens from its own
//! Zipf-skewed distribution over a state-specific vocabulary slice. Models
//! reduce span-corruption loss by learning both the unigram skew and the
//! topic transition structure — exactly the capacity-bound regime where the
//! paper's dense-vs-MoE comparisons live (DESIGN.md §2 substitutions table).
//!
//! The downstream "SuperGLUE" analogue is topic classification: sequences
//! drawn from one of `num_classes` distinct HMMs; the decoder must emit the
//! label token. Pretraining never sees the downstream HMMs.

use crate::tensor::Tensor;
use crate::util::rng::{Rng, ZipfTable};

/// Reserved token ids (mirrors the Python-side convention).
pub const PAD: i32 = 0;
pub const EOS: i32 = 1;
/// First id usable by the corpus generator (2..first_sentinel).
pub const FIRST_CONTENT: i32 = 2;
/// Number of sentinel ids reserved at the top of the vocabulary.
pub const NUM_SENTINELS: usize = 16;

/// T5 span-corruption hyperparameters (Raffel et al. 2020 defaults).
pub const NOISE_DENSITY: f64 = 0.15;
pub const MEAN_SPAN_LEN: f64 = 3.0;

#[derive(Debug, Clone)]
pub struct HmmSpec {
    pub num_states: usize,
    pub vocab_size: usize,
    /// Probability of staying in the current state.
    pub self_loop: f64,
    /// Zipf exponent of each state's emission distribution.
    pub zipf_s: f64,
}

impl Default for HmmSpec {
    fn default() -> Self {
        HmmSpec { num_states: 12, vocab_size: 256, self_loop: 0.85, zipf_s: 1.05 }
    }
}

/// Hidden-Markov corpus generator. Each state emits from a contiguous slice
/// of the content vocabulary with Zipf skew, so both local (unigram) and
/// longer-range (topic persistence) statistics are learnable.
pub struct HmmCorpus {
    spec: HmmSpec,
    /// Per-state random permutation of its vocab slice (so states do not
    /// trivially share ranks).
    state_vocab: Vec<Vec<i32>>,
    /// Per-state next-state transition weights.
    transitions: Vec<Vec<f32>>,
    /// Shared Zipf CDF over a state's vocab slice (all slices are the same
    /// size) — precomputed: per-sample `Rng::zipf` was the data-path hot
    /// spot at large vocabularies (EXPERIMENTS.md §Perf).
    zipf: ZipfTable,
}

impl HmmCorpus {
    pub fn new(spec: HmmSpec, seed: u64) -> HmmCorpus {
        let mut rng = Rng::with_stream(seed, 0x7a31);
        let content = spec.vocab_size - NUM_SENTINELS - FIRST_CONTENT as usize;
        let per_state = (content / spec.num_states).max(4);
        let mut state_vocab = Vec::new();
        for s in 0..spec.num_states {
            let lo = FIRST_CONTENT as usize + (s * per_state) % content;
            let base = lo - FIRST_CONTENT as usize;
            let mut ids: Vec<i32> = (0..per_state)
                .map(|k| (FIRST_CONTENT as usize + (base + k) % content) as i32)
                .collect();
            rng.shuffle(&mut ids);
            state_vocab.push(ids);
        }
        let mut transitions = Vec::new();
        for s in 0..spec.num_states {
            let mut w = vec![0f32; spec.num_states];
            for (t, wt) in w.iter_mut().enumerate() {
                *wt = if t == s {
                    spec.self_loop as f32
                } else {
                    (1.0 - spec.self_loop as f32) * (0.2 + rng.f32())
                };
            }
            transitions.push(w);
        }
        let zipf = ZipfTable::new(per_state, spec.zipf_s);
        HmmCorpus { spec, state_vocab, transitions, zipf }
    }

    pub fn vocab_size(&self) -> usize {
        self.spec.vocab_size
    }

    /// Sample a raw token sequence of length `len`.
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut state = rng.below(self.spec.num_states);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            let vocab = &self.state_vocab[state];
            let rank = self.zipf.sample(rng);
            out.push(vocab[rank]);
            state = rng.categorical(&self.transitions[state]);
        }
        out
    }
}

/// One span-corruption example with fixed encoder/decoder lengths.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanExample {
    pub enc_tokens: Vec<i32>,
    pub dec_tokens: Vec<i32>, // decoder input (shifted right, starts with PAD)
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
}

/// Sentinel id for span `k` (highest ids first, T5 convention).
pub fn sentinel(vocab_size: usize, k: usize) -> i32 {
    (vocab_size - 1 - k) as i32
}

/// T5 span corruption: mask ~15% of tokens in spans of mean length 3;
/// encoder sees text with each span replaced by one sentinel; targets are
/// `sentinel span sentinel span ... EOS`.
pub fn span_corrupt(
    raw: &[i32],
    vocab_size: usize,
    enc_len: usize,
    dec_len: usize,
    rng: &mut Rng,
) -> SpanExample {
    let n = raw.len();
    let noise_tokens = ((n as f64 * NOISE_DENSITY).round() as usize).clamp(1, n / 2);
    let num_spans = ((noise_tokens as f64 / MEAN_SPAN_LEN).round() as usize).max(1);

    // Choose span start positions; greedy non-overlapping placement.
    let base_len = (noise_tokens / num_spans).max(1);
    let mut starts: Vec<usize> = Vec::new();
    let mut occupied = vec![false; n];
    let mut attempts = 0;
    while starts.len() < num_spans && attempts < 20 * num_spans {
        attempts += 1;
        let s = rng.below(n.saturating_sub(base_len).max(1));
        if occupied[s..(s + base_len).min(n)].iter().any(|&o| o) {
            continue;
        }
        for o in occupied.iter_mut().skip(s).take(base_len) {
            *o = true;
        }
        starts.push(s);
    }
    starts.sort_unstable();

    let mut enc = Vec::with_capacity(enc_len);
    let mut tgt = Vec::with_capacity(dec_len);
    let mut i = 0;
    let mut span_id = 0;
    while i < n {
        if span_id < starts.len() && i == starts[span_id] {
            let sid = sentinel(vocab_size, span_id);
            enc.push(sid);
            tgt.push(sid);
            for j in 0..base_len.min(n - i) {
                tgt.push(raw[i + j]);
            }
            i += base_len;
            span_id += 1;
        } else {
            enc.push(raw[i]);
            i += 1;
        }
    }
    tgt.push(EOS);

    enc.truncate(enc_len);
    while enc.len() < enc_len {
        enc.push(PAD);
    }
    tgt.truncate(dec_len);
    // Decoder input: shift right, PAD as BOS (T5 convention).
    let mut dec = Vec::with_capacity(dec_len);
    dec.push(PAD);
    dec.extend_from_slice(&tgt[..tgt.len().saturating_sub(0).min(dec_len - 1)]);
    dec.truncate(dec_len);
    while dec.len() < dec_len {
        dec.push(PAD);
    }
    let mut mask: Vec<f32> = tgt.iter().map(|_| 1.0).collect();
    mask.resize(dec_len, 0.0);
    let mut tgt_padded = tgt;
    tgt_padded.resize(dec_len, PAD);

    SpanExample { enc_tokens: enc, dec_tokens: dec, targets: tgt_padded, loss_mask: mask }
}

/// Batched pretraining stream with disjoint deterministic shards.
pub struct TextPipeline {
    corpus: HmmCorpus,
    enc_len: usize,
    dec_len: usize,
    batch_size: usize,
    rng: Rng,
}

impl TextPipeline {
    pub fn new(
        corpus: HmmCorpus,
        batch_size: usize,
        enc_len: usize,
        dec_len: usize,
        seed: u64,
        shard: u64,
    ) -> TextPipeline {
        TextPipeline {
            corpus,
            enc_len,
            dec_len,
            batch_size,
            rng: Rng::with_stream(seed, 2 * shard + 1),
        }
    }

    /// Raw sequence length so that masking leaves ≈enc_len encoder tokens.
    fn raw_len(&self) -> usize {
        (self.enc_len as f64 / (1.0 - NOISE_DENSITY * (1.0 - 1.0 / MEAN_SPAN_LEN))) as usize
    }

    pub fn next_examples(&mut self) -> Vec<SpanExample> {
        let raw_len = self.raw_len();
        let vocab = self.corpus.vocab_size();
        (0..self.batch_size)
            .map(|_| {
                let raw = self.corpus.sample(raw_len, &mut self.rng);
                span_corrupt(&raw, vocab, self.enc_len, self.dec_len, &mut self.rng)
            })
            .collect()
    }

    /// Batch tensors in manifest order: enc_tokens, dec_tokens, targets, loss_mask.
    pub fn next_batch(&mut self) -> Vec<Tensor> {
        let ex = self.next_examples();
        batch_tensors(&ex, self.batch_size, self.enc_len, self.dec_len)
    }
}

pub fn batch_tensors(
    ex: &[SpanExample],
    batch: usize,
    enc_len: usize,
    dec_len: usize,
) -> Vec<Tensor> {
    let mut enc = Vec::with_capacity(batch * enc_len);
    let mut dec = Vec::with_capacity(batch * dec_len);
    let mut tgt = Vec::with_capacity(batch * dec_len);
    let mut mask = Vec::with_capacity(batch * dec_len);
    for e in ex {
        enc.extend_from_slice(&e.enc_tokens);
        dec.extend_from_slice(&e.dec_tokens);
        tgt.extend_from_slice(&e.targets);
        mask.extend_from_slice(&e.loss_mask);
    }
    vec![
        Tensor::from_i32(&[batch, enc_len], enc),
        Tensor::from_i32(&[batch, dec_len], dec),
        Tensor::from_i32(&[batch, dec_len], tgt),
        Tensor::from_f32(&[batch, dec_len], mask),
    ]
}

// ---------------------------------------------------------------------------
// Downstream task: topic classification (SuperGLUE analogue, Fig. 3 / Tab. 5)
// ---------------------------------------------------------------------------

pub struct ClassificationPipeline {
    corpora: Vec<HmmCorpus>,
    enc_len: usize,
    dec_len: usize,
    batch_size: usize,
    rng: Rng,
}

impl ClassificationPipeline {
    /// `num_classes` distinct HMMs (disjoint seeds from pretraining).
    pub fn new(
        num_classes: usize,
        vocab_size: usize,
        batch_size: usize,
        enc_len: usize,
        dec_len: usize,
        seed: u64,
    ) -> ClassificationPipeline {
        let corpora = (0..num_classes)
            .map(|c| {
                HmmCorpus::new(
                    HmmSpec { vocab_size, num_states: 6, ..Default::default() },
                    0xdead_0000 + c as u64,
                )
            })
            .collect();
        ClassificationPipeline {
            corpora,
            enc_len,
            dec_len,
            batch_size,
            rng: Rng::with_stream(seed, 0x51),
        }
    }

    pub fn label_token(label: usize) -> i32 {
        FIRST_CONTENT + label as i32
    }

    pub fn next_batch(&mut self) -> (Vec<Tensor>, Vec<usize>) {
        let b = self.batch_size;
        let mut enc = Vec::with_capacity(b * self.enc_len);
        let mut dec = Vec::with_capacity(b * self.dec_len);
        let mut tgt = Vec::with_capacity(b * self.dec_len);
        let mut mask = Vec::with_capacity(b * self.dec_len);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let label = self.rng.below(self.corpora.len());
            labels.push(label);
            let mut seq = self.corpora[label].sample(self.enc_len, &mut self.rng);
            seq.truncate(self.enc_len);
            enc.extend_from_slice(&seq);
            // Decoder: PAD → [label_token, EOS, PAD...]; loss on both tokens.
            let mut d = vec![PAD; self.dec_len];
            d[1] = Self::label_token(label);
            let mut t = vec![PAD; self.dec_len];
            t[0] = Self::label_token(label);
            t[1] = EOS;
            let mut m = vec![0.0; self.dec_len];
            m[0] = 1.0;
            m[1] = 1.0;
            dec.extend_from_slice(&d);
            tgt.extend_from_slice(&t);
            mask.extend_from_slice(&m);
        }
        (
            vec![
                Tensor::from_i32(&[b, self.enc_len], enc),
                Tensor::from_i32(&[b, self.dec_len], dec),
                Tensor::from_i32(&[b, self.dec_len], tgt),
                Tensor::from_f32(&[b, self.dec_len], mask),
            ],
            labels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_content_range() {
        let c = HmmCorpus::new(HmmSpec::default(), 1);
        let mut rng = Rng::new(2);
        let seq = c.sample(500, &mut rng);
        let hi = sentinel(c.vocab_size(), NUM_SENTINELS - 1);
        assert!(seq.iter().all(|&t| t >= FIRST_CONTENT && t < hi));
    }

    #[test]
    fn corpus_is_deterministic() {
        let c = HmmCorpus::new(HmmSpec::default(), 7);
        let a = c.sample(64, &mut Rng::new(3));
        let b = c.sample(64, &mut Rng::new(3));
        assert_eq!(a, b);
    }

    #[test]
    fn span_corruption_invariants() {
        let c = HmmCorpus::new(HmmSpec::default(), 1);
        let mut rng = Rng::new(4);
        for trial in 0..50 {
            let raw = c.sample(40, &mut rng);
            let ex = span_corrupt(&raw, 256, 32, 16, &mut rng);
            assert_eq!(ex.enc_tokens.len(), 32, "trial {trial}");
            assert_eq!(ex.dec_tokens.len(), 16);
            assert_eq!(ex.targets.len(), 16);
            assert_eq!(ex.loss_mask.len(), 16);
            // Decoder input is targets shifted right with PAD BOS.
            assert_eq!(ex.dec_tokens[0], PAD);
            for i in 1..16 {
                assert_eq!(ex.dec_tokens[i], ex.targets[i - 1]);
            }
            // Targets start with the first sentinel.
            assert_eq!(ex.targets[0], sentinel(256, 0));
            // Mask covers exactly the non-pad prefix.
            let n_mask = ex.loss_mask.iter().filter(|&&m| m > 0.0).count();
            assert!(n_mask >= 2);
            for (i, &m) in ex.loss_mask.iter().enumerate() {
                if m == 0.0 {
                    assert_eq!(ex.targets[i], PAD);
                }
            }
            // Sentinels in encoder appear in increasing span order.
            let sents: Vec<i32> = ex
                .enc_tokens
                .iter()
                .copied()
                .filter(|&t| t >= sentinel(256, NUM_SENTINELS - 1))
                .collect();
            for (k, &s) in sents.iter().enumerate() {
                assert_eq!(s, sentinel(256, k));
            }
        }
    }

    #[test]
    fn shards_are_disjoint() {
        let mk = |shard| {
            let c = HmmCorpus::new(HmmSpec::default(), 1);
            let mut p = TextPipeline::new(c, 4, 32, 16, 9, shard);
            p.next_batch()[0].i32s().unwrap().to_vec()
        };
        assert_ne!(mk(0), mk(1), "different shards must see different data");
        assert_eq!(mk(2), mk(2), "same shard must be deterministic");
    }

    #[test]
    fn classification_batches_are_wellformed() {
        let mut p = ClassificationPipeline::new(8, 256, 4, 32, 16, 1);
        let (tensors, labels) = p.next_batch();
        assert_eq!(tensors.len(), 4);
        assert_eq!(labels.len(), 4);
        let tgt = tensors[2].i32s().unwrap();
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(tgt[i * 16], ClassificationPipeline::label_token(l));
            assert_eq!(tgt[i * 16 + 1], EOS);
        }
    }
}
