//! Host tensor type: the common currency between checkpoints, the upcycling
//! surgery, the data pipelines and the execution backends (the native CPU
//! backend computes on it directly; the PJRT backend converts to device
//! literals at its boundary).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype `{s}`"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Dense host tensor. Data lives in one of two typed vecs; the two-variant
/// enum keeps conversions explicit (no bit-punning surprises in checkpoints).
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; numel(shape)]) }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![1.0; numel(shape)]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Consume the tensor, returning its f32 buffer without copying.
    pub fn into_f32s(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mean of f32 elements (metrics convenience).
    pub fn mean(&self) -> f32 {
        match &self.data {
            Data::F32(v) if !v.is_empty() => v.iter().sum::<f32>() / v.len() as f32,
            _ => 0.0,
        }
    }

    /// L2 norm of f32 elements.
    pub fn l2(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v.iter().map(|x| x * x).sum::<f32>().sqrt(),
            _ => 0.0,
        }
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert!((t.mean() - 3.5).abs() < 1e-6);
        assert!(t.i32s().is_err());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn scalar_and_norms() {
        let t = Tensor::scalar_f32(0.25);
        assert_eq!(t.numel(), 1);
        assert!((t.l2() - 0.25).abs() < 1e-7);
        let z = Tensor::zeros(&[3, 3]);
        assert_eq!(z.l2(), 0.0);
        assert_eq!(z.mean(), 0.0);
    }
}
