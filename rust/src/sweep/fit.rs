//! Log-log least-squares power-law fits of final loss against the sweep's
//! cost axes — the "Scaling Laws for Upcycling MoE" curve shape
//! (PAPERS.md): `loss ≈ a · sunk^α · E^β · budget^γ`.
//!
//! Taking logs turns the model into ordinary multilinear least squares,
//! `ln loss = ln a + α ln sunk + β ln E + γ ln budget`, solved exactly via
//! the normal equations (≤ 4 unknowns — Gaussian elimination with partial
//! pivoting, no iterative solver). Axes that do not vary across the sweep
//! are excluded from the design matrix and reported as *not fitted* rather
//! than producing a singular system; every degenerate input (too few legs,
//! non-positive losses, collinear axes) is a named error, never a NaN fit.

use anyhow::{bail, Result};

/// Names of the fit's regressors, in [`FitPoint::regressors`] order.
pub const REGRESSOR_NAMES: [&str; 3] = ["sunk_cost", "experts", "continuation_budget"];

/// One leg's contribution to the fit.
#[derive(Debug, Clone)]
pub struct FitPoint {
    /// Leg label carried into the per-point residual report.
    pub label: String,
    /// Final held-out loss (must be finite and > 0 — it is logged).
    pub loss: f64,
    /// `[sunk_flops, experts, budget_flops]` (each finite and > 0).
    pub regressors: [f64; 3],
}

/// A fitted power law with per-point residuals.
#[derive(Debug, Clone)]
pub struct PowerLawFit {
    /// Multiplicative coefficient `a` (e^intercept).
    pub coefficient: f64,
    /// Fitted exponent per regressor, [`REGRESSOR_NAMES`] order; `None`
    /// when that axis was constant across the sweep (not fittable).
    pub exponents: [Option<f64>; 3],
    /// Per-leg log-space residual `ln(loss) − ln(prediction)`.
    pub residuals: Vec<(String, f64)>,
    /// Root-mean-square of the log-space residuals.
    pub rmse: f64,
    /// Number of legs the fit used.
    pub points: usize,
}

impl PowerLawFit {
    /// Model prediction at a grid point (unfitted axes contribute 1).
    pub fn predict(&self, regressors: &[f64; 3]) -> f64 {
        let mut y = self.coefficient;
        for (x, e) in regressors.iter().zip(&self.exponents) {
            if let Some(e) = e {
                y *= x.powf(*e);
            }
        }
        y
    }

    pub fn print(&self) {
        let mut terms = format!("{:.6}", self.coefficient);
        for (name, e) in REGRESSOR_NAMES.iter().zip(&self.exponents) {
            match e {
                Some(e) => terms.push_str(&format!(" · {name}^{e:+.4}")),
                None => terms.push_str(&format!(" [{name}: constant, not fitted]")),
            }
        }
        println!("  loss ≈ {terms}");
        println!("  {} leg(s), log-space RMSE {:.6}", self.points, self.rmse);
        for (label, r) in &self.residuals {
            println!("    residual {label:<32} {r:+.6}");
        }
    }
}

/// Solve `A x = b` (A square, small) by Gaussian elimination with partial
/// pivoting. A pivot collapsing to ~0 means the design matrix is rank
/// deficient — collinear sweep axes — and is a named error.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>> {
    let n = b.len();
    // Rank deficiency shows up as a pivot collapsing to rounding noise.
    // Noise is relative to the matrix's own magnitude (XᵀX entries grow
    // with n·ln²x ≈ 10³ here), so the threshold must be scale-free: an
    // absolute cutoff would sit right at the cancellation residue.
    let scale = a.iter().flatten().fold(1.0f64, |m, v| m.max(v.abs()));
    for col in 0..n {
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .expect("non-empty system");
        if a[pivot][col].abs() < 1e-9 * scale {
            bail!("singular normal equations: the sweep's cost axes are collinear");
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for k in col + 1..n {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Ok(x)
}

/// Fit `loss = a · Π regressor^exponent` over `points` by exact log-log
/// least squares. Named errors (never NaN): fewer than 2 legs, fewer legs
/// than unknowns, non-finite/non-positive inputs, collinear axes.
pub fn power_law_fit(points: &[FitPoint]) -> Result<PowerLawFit> {
    if points.len() < 2 {
        bail!(
            "power-law fit needs at least 2 legs, got {} — run a sweep with a grid \
             (e.g. two budgets) first",
            points.len()
        );
    }
    for p in points {
        if !(p.loss.is_finite() && p.loss > 0.0) {
            bail!("leg `{}` has unloggable final loss {} (need finite > 0)", p.label, p.loss);
        }
        for (name, x) in REGRESSOR_NAMES.iter().zip(&p.regressors) {
            if !(x.is_finite() && *x > 0.0) {
                bail!("leg `{}` has unloggable {name} {x} (need finite > 0)", p.label);
            }
        }
    }
    // Only axes that actually vary enter the design matrix; a constant
    // column would make the normal equations singular against the
    // intercept, and its exponent is unidentifiable anyway.
    let active: Vec<usize> = (0..3)
        .filter(|&j| {
            let x0 = points[0].regressors[j].ln();
            points.iter().any(|p| (p.regressors[j].ln() - x0).abs() > 1e-12)
        })
        .collect();
    let unknowns = 1 + active.len();
    if points.len() < unknowns {
        bail!(
            "power-law fit over {} varying axis(es) needs at least {unknowns} legs, got {}",
            active.len(),
            points.len()
        );
    }
    // Design rows [1, ln x_j ...] and targets ln loss.
    let rows: Vec<Vec<f64>> = points
        .iter()
        .map(|p| {
            let mut row = vec![1.0];
            row.extend(active.iter().map(|&j| p.regressors[j].ln()));
            row
        })
        .collect();
    let y: Vec<f64> = points.iter().map(|p| p.loss.ln()).collect();
    // Normal equations XᵀX θ = Xᵀy.
    let mut xtx = vec![vec![0.0; unknowns]; unknowns];
    let mut xty = vec![0.0; unknowns];
    for (row, yi) in rows.iter().zip(&y) {
        for i in 0..unknowns {
            for j in 0..unknowns {
                xtx[i][j] += row[i] * row[j];
            }
            xty[i] += row[i] * yi;
        }
    }
    let theta = solve(xtx, xty)?;
    if theta.iter().any(|t| !t.is_finite()) {
        bail!("power-law fit produced non-finite coefficients {theta:?}");
    }
    let mut exponents = [None; 3];
    for (slot, &j) in active.iter().enumerate() {
        exponents[j] = Some(theta[slot + 1]);
    }
    let mut residuals = Vec::with_capacity(points.len());
    let mut sq = 0.0;
    for (row, (p, yi)) in rows.iter().zip(points.iter().zip(&y)) {
        let pred: f64 = row.iter().zip(&theta).map(|(x, t)| x * t).sum();
        let r = yi - pred;
        sq += r * r;
        residuals.push((p.label.clone(), r));
    }
    Ok(PowerLawFit {
        coefficient: theta[0].exp(),
        exponents,
        residuals,
        rmse: (sq / points.len() as f64).sqrt(),
        points: points.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(a: f64, alpha: f64, beta: f64, gamma: f64) -> Vec<FitPoint> {
        let mut pts = Vec::new();
        for (i, &sunk) in [1e15, 2e15, 4e15].iter().enumerate() {
            for (j, &e) in [2.0, 8.0].iter().enumerate() {
                for (k, &budget) in [5e14, 1e15].iter().enumerate() {
                    pts.push(FitPoint {
                        label: format!("p{i}{j}{k}"),
                        loss: a * sunk.powf(alpha) * e.powf(beta) * budget.powf(gamma),
                        regressors: [sunk, e, budget],
                    });
                }
            }
        }
        pts
    }

    #[test]
    fn recovers_synthetic_power_law_exactly() {
        let fit = power_law_fit(&synth(3.0, -0.12, -0.05, -0.3)).unwrap();
        // Exact data; tolerances cover the normal equations' conditioning
        // (ln-regressors ≈ 35 correlate with the intercept).
        assert!((fit.coefficient - 3.0).abs() < 1e-6, "a = {}", fit.coefficient);
        assert!((fit.exponents[0].unwrap() + 0.12).abs() < 1e-6);
        assert!((fit.exponents[1].unwrap() + 0.05).abs() < 1e-6);
        assert!((fit.exponents[2].unwrap() + 0.3).abs() < 1e-6);
        assert!(fit.rmse < 1e-8, "rmse {}", fit.rmse);
        assert!(fit.residuals.iter().all(|(_, r)| r.abs() < 1e-7));
    }

    #[test]
    fn constant_axes_are_reported_not_fitted() {
        // Only the budget axis varies: E and sunk must come back None,
        // and the fit stays exact.
        let pts: Vec<FitPoint> = [5e14, 1e15, 2e15]
            .iter()
            .map(|&b| FitPoint {
                label: format!("b{b}"),
                loss: 2.0 * b.powf(-0.25),
                regressors: [1e15, 8.0, b],
            })
            .collect();
        let fit = power_law_fit(&pts).unwrap();
        assert!(fit.exponents[0].is_none());
        assert!(fit.exponents[1].is_none());
        assert!((fit.exponents[2].unwrap() + 0.25).abs() < 1e-6);
        assert!((fit.predict(&[9e99, 9e99, 1e15]) - 2.0 * 1e15f64.powf(-0.25)).abs() < 1e-6);
    }

    #[test]
    fn degenerate_inputs_are_named_errors_never_nan() {
        // < 2 points.
        let one = vec![FitPoint { label: "x".into(), loss: 1.0, regressors: [1.0, 2.0, 3.0] }];
        let err = power_law_fit(&one).unwrap_err();
        assert!(format!("{err:#}").contains("at least 2 legs"), "{err:#}");
        assert!(power_law_fit(&[]).is_err());
        // Non-positive loss.
        let mut bad = synth(3.0, -0.1, -0.1, -0.1);
        bad[0].loss = 0.0;
        assert!(format!("{:#}", power_law_fit(&bad).unwrap_err()).contains("unloggable"));
        let mut nan = synth(3.0, -0.1, -0.1, -0.1);
        nan[0].loss = f64::NAN;
        assert!(format!("{:#}", power_law_fit(&nan).unwrap_err()).contains("unloggable"));
        // Fewer legs than unknowns: 2 points but 3 varying axes + intercept.
        let thin = vec![
            FitPoint { label: "a".into(), loss: 1.0, regressors: [1.0, 2.0, 3.0] },
            FitPoint { label: "b".into(), loss: 2.0, regressors: [2.0, 4.0, 6.0] },
        ];
        let err = power_law_fit(&thin).unwrap_err();
        assert!(format!("{err:#}").contains("needs at least"), "{err:#}");
    }

    #[test]
    fn collinear_axes_are_a_named_error() {
        // sunk and budget move in lockstep over 4+ points: rank deficient.
        let pts: Vec<FitPoint> = [1e15, 2e15, 4e15, 8e15]
            .iter()
            .map(|&x| FitPoint {
                label: format!("x{x}"),
                loss: 2.0 * x.powf(-0.2),
                regressors: [x, 8.0, x],
            })
            .collect();
        let err = power_law_fit(&pts).unwrap_err();
        assert!(format!("{err:#}").contains("collinear"), "{err:#}");
    }
}
