//! The sweep lab's machine-readable results store: `SWEEP_results.json`.
//!
//! Append-only and schema-versioned — every `sweep` invocation appends one
//! [`SweepRun`] (its canonical spec, seed, and one [`LegRecord`] per grid
//! point) and never rewrites earlier runs. Serialization rides on
//! `metrics` ([`crate::metrics::series_json`]) and `util::json`, whose
//! `BTreeMap`-backed objects and stable number formatting make the bytes a
//! pure function of the recorded values: the determinism contract
//! (docs/SWEEPS.md) is checked against this file's literal bytes.
//!
//! Each leg record carries the **priced** cost (what `costmodel` predicted
//! up front from the spec alone) next to the **accounted** cost (what the
//! training loop actually metered), so scheduler pricing can be audited
//! after the fact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::costmodel::SurgeryCost;
use crate::metrics::{series_from_json, series_json, Series};
use crate::sweep::fit::FitPoint;
use crate::util::json::{arr, num, obj, s, Json};

/// Bump on any breaking change to the record layout.
pub const SCHEMA_VERSION: u64 = 1;

/// Up-front `costmodel` pricing for one leg — computed from the spec
/// before any training runs, and recorded verbatim.
#[derive(Debug, Clone, PartialEq)]
pub struct PricedCost {
    /// Dense parent pretraining FLOPs (the paper's sunk cost).
    pub sunk_flops: f64,
    /// Continuation FLOPs for this leg's budget on its MoE target.
    pub extra_flops: f64,
    /// `extra / sunk` in percent (the paper's "Relative Extra" column).
    pub relative_extra_pct: f64,
    /// One-shot checkpoint-surgery cost.
    pub surgery: SurgeryCost,
}

/// One grid point's results: identity, priced + accounted cost, quality.
#[derive(Debug, Clone, PartialEq)]
pub struct LegRecord {
    pub index: usize,
    pub label: String,
    pub model: String,
    pub parent: String,
    pub sunk_steps: u64,
    pub budget_steps: u64,
    pub experts: usize,
    pub capacity: usize,
    pub router: String,
    pub strategy: String,
    pub priced: PricedCost,
    /// Extra FLOPs the training loop actually metered (final point of the
    /// trajectory) — recorded next to `priced.extra_flops` by contract.
    pub accounted_extra_flops: f64,
    /// Held-out loss right after surgery, before any continued training.
    pub init_loss: f64,
    /// Held-out loss at the end of the continuation budget.
    pub final_loss: f64,
    /// Mean pairwise cosine distance between experts at init.
    pub mean_cosine_diversity: f64,
    /// The leg's loss trajectory (eval cadence = the spec's `eval`).
    pub trajectory: Series,
}

impl LegRecord {
    fn to_json(&self) -> Json {
        obj(vec![
            ("index", num(self.index as f64)),
            ("label", s(&self.label)),
            ("model", s(&self.model)),
            ("parent", s(&self.parent)),
            ("sunk_steps", num(self.sunk_steps as f64)),
            ("budget_steps", num(self.budget_steps as f64)),
            ("experts", num(self.experts as f64)),
            ("capacity", num(self.capacity as f64)),
            ("router", s(&self.router)),
            ("strategy", s(&self.strategy)),
            (
                "priced",
                obj(vec![
                    ("sunk_flops", num(self.priced.sunk_flops)),
                    ("extra_flops", num(self.priced.extra_flops)),
                    ("relative_extra_pct", num(self.priced.relative_extra_pct)),
                    (
                        "surgery",
                        obj(vec![
                            ("bytes_copied", num(self.priced.surgery.bytes_copied as f64)),
                            (
                                "values_reinitialized",
                                num(self.priced.surgery.values_reinitialized as f64),
                            ),
                            ("sources_loaded", num(self.priced.surgery.sources_loaded as f64)),
                            ("reduce_flops", num(self.priced.surgery.reduce_flops as f64)),
                        ]),
                    ),
                ]),
            ),
            ("accounted_extra_flops", num(self.accounted_extra_flops)),
            ("init_loss", num(self.init_loss)),
            ("final_loss", num(self.final_loss)),
            ("mean_cosine_diversity", num(self.mean_cosine_diversity)),
            ("trajectory", series_json(&self.trajectory)),
        ])
    }

    fn from_json(v: &Json) -> Result<LegRecord> {
        let priced = v.get("priced")?;
        let surgery = priced.get("surgery")?;
        Ok(LegRecord {
            index: v.get("index")?.as_usize()?,
            label: v.get("label")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            parent: v.get("parent")?.as_str()?.to_string(),
            sunk_steps: v.get("sunk_steps")?.as_f64()? as u64,
            budget_steps: v.get("budget_steps")?.as_f64()? as u64,
            experts: v.get("experts")?.as_usize()?,
            capacity: v.get("capacity")?.as_usize()?,
            router: v.get("router")?.as_str()?.to_string(),
            strategy: v.get("strategy")?.as_str()?.to_string(),
            priced: PricedCost {
                sunk_flops: priced.get("sunk_flops")?.as_f64()?,
                extra_flops: priced.get("extra_flops")?.as_f64()?,
                relative_extra_pct: priced.get("relative_extra_pct")?.as_f64()?,
                surgery: SurgeryCost {
                    bytes_copied: surgery.get("bytes_copied")?.as_f64()? as u64,
                    values_reinitialized: surgery.get("values_reinitialized")?.as_f64()? as u64,
                    sources_loaded: surgery.get("sources_loaded")?.as_f64()? as u64,
                    reduce_flops: surgery.get("reduce_flops")?.as_f64()? as u64,
                },
            },
            accounted_extra_flops: v.get("accounted_extra_flops")?.as_f64()?,
            init_loss: v.get("init_loss")?.as_f64()?,
            final_loss: v.get("final_loss")?.as_f64()?,
            mean_cosine_diversity: v.get("mean_cosine_diversity")?.as_f64()?,
            trajectory: series_from_json(v.get("trajectory")?)?,
        })
    }
}

/// One completed sweep invocation.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRun {
    /// The spec's canonical spelling ([`crate::sweep::SweepSpec::canonical`]).
    pub spec: String,
    pub seed: u64,
    /// Grid size the spec enumerated — `legs.len()` must match or the run
    /// is incomplete ([`SweepRun::check_complete`]).
    pub grid: usize,
    pub legs: Vec<LegRecord>,
}

impl SweepRun {
    fn to_json(&self) -> Json {
        obj(vec![
            ("spec", s(&self.spec)),
            ("seed", num(self.seed as f64)),
            ("grid", num(self.grid as f64)),
            ("legs", arr(self.legs.iter().map(|l| l.to_json()).collect())),
        ])
    }

    fn from_json(v: &Json) -> Result<SweepRun> {
        Ok(SweepRun {
            spec: v.get("spec")?.as_str()?.to_string(),
            seed: v.get("seed")?.as_f64()? as u64,
            grid: v.get("grid")?.as_usize()?,
            legs: v
                .get("legs")?
                .as_arr()?
                .iter()
                .map(LegRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Every grid point present exactly once, in order, with finite losses
    /// — the gate `sweep fit` applies before fitting anything.
    pub fn check_complete(&self) -> Result<()> {
        if self.legs.len() != self.grid {
            bail!(
                "sweep run `{}` is missing legs: grid has {} point(s) but only {} recorded",
                self.spec,
                self.grid,
                self.legs.len()
            );
        }
        for (i, leg) in self.legs.iter().enumerate() {
            if leg.index != i {
                bail!(
                    "sweep run `{}` has leg index {} at position {i} — store out of order",
                    self.spec,
                    leg.index
                );
            }
            if !leg.init_loss.is_finite() || !leg.final_loss.is_finite() {
                bail!(
                    "sweep run `{}` leg `{}` has non-finite losses (init {}, final {})",
                    self.spec,
                    leg.label,
                    leg.init_loss,
                    leg.final_loss
                );
            }
        }
        Ok(())
    }

    /// The run's legs as fit inputs: final loss vs (sunk cost, E,
    /// continuation budget), all on the priced-FLOPs axes.
    pub fn fit_points(&self) -> Vec<FitPoint> {
        self.legs
            .iter()
            .map(|l| FitPoint {
                label: l.label.clone(),
                loss: l.final_loss,
                regressors: [l.priced.sunk_flops, l.experts as f64, l.priced.extra_flops],
            })
            .collect()
    }
}

/// The whole `SWEEP_results.json` file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResultsStore {
    pub runs: Vec<SweepRun>,
}

impl ResultsStore {
    pub fn load(path: impl AsRef<Path>) -> Result<ResultsStore> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep results store {path:?}"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let version = v.get("schema_version")?.as_f64()? as u64;
        if version != SCHEMA_VERSION {
            bail!(
                "sweep results store {path:?} has schema_version {version}, \
                 this binary expects {SCHEMA_VERSION}"
            );
        }
        Ok(ResultsStore {
            runs: v
                .get("runs")?
                .as_arr()?
                .iter()
                .map(SweepRun::from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("decoding {path:?}"))?,
        })
    }

    /// Load, or start an empty store when the file doesn't exist yet.
    pub fn load_or_empty(path: impl AsRef<Path>) -> Result<ResultsStore> {
        if path.as_ref().exists() {
            ResultsStore::load(path)
        } else {
            Ok(ResultsStore::default())
        }
    }

    /// Append-only: earlier runs are never touched.
    pub fn append_run(&mut self, run: SweepRun) {
        self.runs.push(run);
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", num(SCHEMA_VERSION as f64)),
            ("runs", arr(self.runs.iter().map(|r| r.to_json()).collect())),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing sweep results store {path:?}"))
    }

    /// The most recent run (what `sweep fit` fits by default).
    pub fn latest(&self) -> Result<&SweepRun> {
        self.runs.last().ok_or_else(|| {
            anyhow::anyhow!("sweep results store has no runs yet — run `sweep` first")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::map;

    fn record(index: usize) -> LegRecord {
        let mut trajectory = Series::new(format!("leg{index}").as_str());
        trajectory.push(2, 1e10, map(&[("loss", 3.5 - index as f64 * 0.25)]));
        trajectory.push(4, 2e10, map(&[("loss", 3.0 - index as f64 * 0.25)]));
        LegRecord {
            index,
            label: format!("leg{index}_s10_e8_c2_ec_replicate_b4"),
            model: "lm_tiny_moe_e8_c2".into(),
            parent: "lm_tiny_dense".into(),
            sunk_steps: 10,
            budget_steps: 4,
            experts: 8,
            capacity: 2,
            router: "ec".into(),
            strategy: "replicate".into(),
            priced: PricedCost {
                sunk_flops: 5e10,
                extra_flops: 2e10,
                relative_extra_pct: 40.0,
                surgery: SurgeryCost {
                    bytes_copied: 1024,
                    values_reinitialized: 64,
                    sources_loaded: 1,
                    reduce_flops: 0,
                },
            },
            accounted_extra_flops: 2e10,
            init_loss: 3.5,
            final_loss: 3.0 - index as f64 * 0.25,
            mean_cosine_diversity: 0.0,
            trajectory,
        }
    }

    fn run(legs: usize) -> SweepRun {
        SweepRun {
            spec: "budget=4,eval=2".into(),
            seed: 17,
            grid: legs,
            legs: (0..legs).map(record).collect(),
        }
    }

    #[test]
    fn store_round_trips_bitwise_and_appends() {
        let dir = std::env::temp_dir().join("supc_sweep_store_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("SWEEP_results.json");
        let mut store = ResultsStore::load_or_empty(&path).unwrap();
        assert!(store.runs.is_empty());
        store.append_run(run(2));
        store.save(&path).unwrap();
        let bytes1 = std::fs::read(&path).unwrap();
        // Load → save is byte-identical (the determinism contract's
        // serialization half).
        let loaded = ResultsStore::load(&path).unwrap();
        assert_eq!(loaded, store);
        loaded.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes1);
        // Appending a second run preserves the first verbatim.
        let mut store2 = ResultsStore::load(&path).unwrap();
        store2.append_run(run(2));
        store2.save(&path).unwrap();
        let reread = ResultsStore::load(&path).unwrap();
        assert_eq!(reread.runs.len(), 2);
        assert_eq!(reread.runs[0], store.runs[0]);
        assert_eq!(reread.latest().unwrap(), &reread.runs[1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn check_complete_names_missing_and_broken_legs() {
        run(2).check_complete().unwrap();
        // Missing leg.
        let mut missing = run(2);
        missing.legs.pop();
        let err = missing.check_complete().unwrap_err();
        assert!(format!("{err:#}").contains("missing legs"), "{err:#}");
        // Out-of-order indices.
        let mut disorder = run(2);
        disorder.legs.swap(0, 1);
        assert!(format!("{:#}", disorder.check_complete().unwrap_err()).contains("out of order"));
        // Non-finite loss.
        let mut nan = run(2);
        nan.legs[1].final_loss = f64::NAN;
        assert!(format!("{:#}", nan.check_complete().unwrap_err()).contains("non-finite"));
        // Empty store has no latest.
        assert!(ResultsStore::default().latest().is_err());
    }

    #[test]
    fn fit_points_carry_the_priced_axes() {
        let pts = run(3).fit_points();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].regressors, [5e10, 8.0, 2e10]);
        assert_eq!(pts[1].loss, 2.75);
        assert!(pts.iter().all(|p| p.label.starts_with("leg")));
    }

    #[test]
    fn version_skew_is_a_named_error() {
        let dir = std::env::temp_dir().join("supc_sweep_store_ver_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SWEEP_results.json");
        std::fs::write(&path, r#"{"schema_version":999,"runs":[]}"#).unwrap();
        let err = ResultsStore::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("schema_version 999"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
