//! The validated sweep plan: [`SweepSpec`], the one front door to the
//! scaling-law lab's grid knobs — mirroring how `parallel::MeshSpec` is the
//! one front door to the mesh and `serve::ServeSpec` to the scheduler. The
//! CLI's consolidated `--sweep experts=…,budget=…` flag parses into a
//! `SweepSpec` ([`SweepSpec::parse`]), every construction path funnels
//! through [`SweepSpec::legs`] (which validates each leg against the model
//! zoo), and the scheduler takes the spec whole.
//!
//! Grid axes (`+`-separated value lists, every key optional):
//!
//! ```text
//! --sweep sunk=30+60,experts=2+8,capacity=2,router=ec,\
//!         strategy=replicate+drop,reinit=0.25,budget=20+40,eval=10
//! ```
//!
//! Leg order — and therefore the results store — is a pure function of the
//! spec: the cartesian product is enumerated sunk → experts → capacity →
//! router → strategy → budget, each axis in the user's spelling order.
//! The full grammar lives in `docs/SWEEPS.md`.

use anyhow::{bail, Context, Result};

use crate::manifest::Manifest;
use crate::upcycle::UpcycleStrategy;

/// Which routing family a leg's MoE target uses. Families map onto zoo
/// model-name suffixes (`lm_tiny_moe_e8_c2_top1`, …); the suffix-less
/// default family is Expert Choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterFamily {
    /// Expert Choice routing (the zoo's suffix-less default).
    ExpertChoice,
    Top1,
    Top2,
    /// Top-2 with batch-priority routing.
    Top2Bpr,
}

impl RouterFamily {
    pub fn parse(s: &str) -> Result<RouterFamily> {
        match s {
            "ec" => Ok(RouterFamily::ExpertChoice),
            "top1" => Ok(RouterFamily::Top1),
            "top2" => Ok(RouterFamily::Top2),
            "top2bpr" => Ok(RouterFamily::Top2Bpr),
            other => bail!("unknown router family `{other}` (expected ec|top1|top2|top2bpr)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterFamily::ExpertChoice => "ec",
            RouterFamily::Top1 => "top1",
            RouterFamily::Top2 => "top2",
            RouterFamily::Top2Bpr => "top2bpr",
        }
    }

    /// The zoo model-name suffix this family selects ("" for the default).
    fn model_suffix(&self) -> &'static str {
        match self {
            RouterFamily::ExpertChoice => "",
            RouterFamily::Top1 => "_top1",
            RouterFamily::Top2 => "_top2",
            RouterFamily::Top2Bpr => "_top2bpr",
        }
    }
}

/// Which [`UpcycleStrategy`] family a leg's surgery uses. The sweep grid
/// carries the *kind*; [`SweepSpec::legs`] resolves it to a concrete
/// strategy (Drop-Upcycling picks up the spec's `reinit` fraction and the
/// sweep seed). Split / multi-checkpoint surgeries need per-leg target
/// models and extra source bundles, so they stay one-off CLI runs
/// (`upcycle upcycle --strategy …`) rather than sweep axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Replicate,
    DropUpcycle,
}

impl StrategyKind {
    pub fn parse(s: &str) -> Result<StrategyKind> {
        match s {
            "replicate" => Ok(StrategyKind::Replicate),
            "drop" => Ok(StrategyKind::DropUpcycle),
            other => bail!("unknown sweep strategy `{other}` (expected replicate|drop)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Replicate => "replicate",
            StrategyKind::DropUpcycle => "drop",
        }
    }
}

/// The complete, validated sweep plan. Every field participates in the
/// determinism contract: a sweep's leg list and results store are a pure
/// function of `(SweepSpec, seed)` — worker count never changes them.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Dense-parent pretraining budgets in steps — the paper's *sunk cost*
    /// axis. Parents are cached on disk per (parent, steps, seed), so every
    /// leg sharing a sunk point shares the same checkpoint bitwise.
    pub sunk: Vec<u64>,
    /// Expert counts `E`.
    pub experts: Vec<usize>,
    /// Capacity factors `C` (integer, matching the zoo's `_c{C}` targets).
    pub capacity: Vec<usize>,
    /// Router families.
    pub routers: Vec<RouterFamily>,
    /// Upcycle strategy kinds.
    pub strategies: Vec<StrategyKind>,
    /// Drop-Upcycling re-init fraction (only meaningful when `strategies`
    /// contains [`StrategyKind::DropUpcycle`]).
    pub reinit_fraction: f32,
    /// Continuation budgets in steps — how long each upcycled branch trains.
    pub budgets: Vec<u64>,
    /// Eval cadence inside each leg (0 = only the final point). Controls the
    /// loss-trajectory density in the results store.
    pub eval_every: u64,
    /// Dense parent model (must end in `_dense`; the MoE target names are
    /// derived from its prefix).
    pub parent: String,
}

impl Default for SweepSpec {
    fn default() -> SweepSpec {
        SweepSpec {
            sunk: vec![60],
            experts: vec![8],
            capacity: vec![2],
            routers: vec![RouterFamily::ExpertChoice],
            strategies: vec![StrategyKind::Replicate],
            reinit_fraction: 0.25,
            budgets: vec![40],
            eval_every: 20,
            parent: "lm_tiny_dense".to_string(),
        }
    }
}

/// One fully-resolved grid point: the sweep's unit of work. `index` is the
/// leg's position in the spec's cartesian enumeration and keys the results
/// store, the per-leg data shard and the scheduler's tie-breaking — all
/// independent of how legs are packed onto cores.
#[derive(Debug, Clone, PartialEq)]
pub struct Leg {
    pub index: usize,
    pub sunk_steps: u64,
    pub experts: usize,
    pub capacity: usize,
    pub router: RouterFamily,
    pub strategy: UpcycleStrategy,
    pub budget_steps: u64,
    /// Resolved MoE target (validated against the manifest).
    pub model: String,
    pub parent: String,
}

impl Leg {
    /// Short human/series label, stable across runs.
    pub fn label(&self) -> String {
        format!(
            "leg{}_s{}_e{}_c{}_{}_{}_b{}",
            self.index,
            self.sunk_steps,
            self.experts,
            self.capacity,
            self.router.name(),
            self.strategy_kind_name(),
            self.budget_steps
        )
    }

    /// The grid-axis strategy spelling (`replicate` / `drop`), as opposed
    /// to [`UpcycleStrategy::name`]'s canonical surgery name.
    pub fn strategy_kind_name(&self) -> &'static str {
        match self.strategy {
            UpcycleStrategy::Replicate => "replicate",
            UpcycleStrategy::DropUpcycle { .. } => "drop",
            _ => "other",
        }
    }
}

fn parse_list<T>(
    spec: &str,
    key: &str,
    value: &str,
    mut one: impl FnMut(&str) -> Result<T>,
) -> Result<Vec<T>>
where
    T: PartialEq,
{
    let mut out = Vec::new();
    for part in value.split('+') {
        if part.is_empty() {
            bail!("sweep spec `{spec}`: `{key}={value}` has an empty list entry");
        }
        let v = one(part).with_context(|| format!("sweep spec `{spec}`: key `{key}`"))?;
        if out.contains(&v) {
            bail!("sweep spec `{spec}`: `{key}={value}` lists `{part}` twice");
        }
        out.push(v);
    }
    Ok(out)
}

impl SweepSpec {
    /// Parse the consolidated CLI spelling: comma-separated `key=value`
    /// pairs, `+`-separated value lists, every key optional, each at most
    /// once. Syntax plus policy-foreign-knob rejection only — per-leg model
    /// resolution lives in [`SweepSpec::legs`].
    pub fn parse(s: &str) -> Result<SweepSpec> {
        let mut spec = SweepSpec::default();
        let mut seen: Vec<&str> = Vec::new();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("sweep spec `{s}`: expected `key=value`, got `{part}`"))?;
            if seen.contains(&key) {
                bail!("sweep spec `{s}`: key `{key}` given twice");
            }
            seen.push(key);
            let usize_one = |v: &str| -> Result<usize> {
                let n = v
                    .parse::<usize>()
                    .with_context(|| format!("`{key}={v}` is not a number"))?;
                if n == 0 {
                    bail!("`{key}` values must be >= 1");
                }
                Ok(n)
            };
            let u64_one = |v: &str| -> Result<u64> { usize_one(v).map(|n| n as u64) };
            match key {
                "sunk" => spec.sunk = parse_list(s, key, value, u64_one)?,
                "experts" => spec.experts = parse_list(s, key, value, usize_one)?,
                "capacity" => spec.capacity = parse_list(s, key, value, usize_one)?,
                "router" => {
                    spec.routers = parse_list(s, key, value, |v| RouterFamily::parse(v))?
                }
                "strategy" => {
                    spec.strategies = parse_list(s, key, value, |v| StrategyKind::parse(v))?
                }
                "reinit" => {
                    spec.reinit_fraction = value
                        .parse::<f32>()
                        .with_context(|| format!("sweep spec `{s}`: `reinit={value}`"))?;
                    if !(spec.reinit_fraction > 0.0 && spec.reinit_fraction <= 1.0) {
                        bail!(
                            "sweep spec `{s}`: `reinit={value}` must be in (0, 1] \
                             (reinit=0 is spelled strategy=replicate)"
                        );
                    }
                }
                "budget" => spec.budgets = parse_list(s, key, value, u64_one)?,
                "eval" => {
                    spec.eval_every = value
                        .parse::<u64>()
                        .with_context(|| format!("sweep spec `{s}`: `eval={value}`"))?
                }
                "parent" => spec.parent = value.to_string(),
                other => bail!(
                    "sweep spec `{s}`: unknown key `{other}` (expected \
                     sunk|experts|capacity|router|strategy|reinit|budget|eval|parent)"
                ),
            }
        }
        // Strategy-foreign knobs are rejected at parse time so a typo'd
        // plan fails loudly instead of being silently ignored (the same
        // contract as ServeSpec's `floor`/`slo`).
        if seen.contains(&"reinit") && !spec.strategies.contains(&StrategyKind::DropUpcycle) {
            bail!("sweep spec `{s}`: `reinit` only applies when strategy includes drop");
        }
        if !spec.parent.ends_with("_dense") {
            bail!(
                "sweep spec `{s}`: parent `{}` must be a dense model (name ending `_dense`) \
                 so MoE targets can be derived from its prefix",
                spec.parent
            );
        }
        Ok(spec)
    }

    /// The canonical normalized spelling — what the results store records
    /// as the run's identity. `parse(canonical()) == self`.
    pub fn canonical(&self) -> String {
        let join_u64 = |v: &[u64]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("+")
        };
        let join_usize = |v: &[usize]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("+")
        };
        let mut out = format!(
            "sunk={},experts={},capacity={},router={},strategy={}",
            join_u64(&self.sunk),
            join_usize(&self.experts),
            join_usize(&self.capacity),
            self.routers.iter().map(|r| r.name()).collect::<Vec<_>>().join("+"),
            self.strategies.iter().map(|k| k.name()).collect::<Vec<_>>().join("+"),
        );
        if self.strategies.contains(&StrategyKind::DropUpcycle) {
            out.push_str(&format!(",reinit={}", self.reinit_fraction));
        }
        out.push_str(&format!(
            ",budget={},eval={},parent={}",
            join_u64(&self.budgets),
            self.eval_every,
            self.parent
        ));
        out
    }

    /// Number of legs in the grid.
    pub fn grid_size(&self) -> usize {
        self.sunk.len()
            * self.experts.len()
            * self.capacity.len()
            * self.routers.len()
            * self.strategies.len()
            * self.budgets.len()
    }

    /// The MoE target a grid point resolves to, derived from the parent's
    /// prefix: `lm_tiny_dense` → `lm_tiny_moe_e{E}_c{C}[{router suffix}]`.
    pub fn model_name(&self, experts: usize, capacity: usize, router: RouterFamily) -> String {
        let prefix = self.parent.trim_end_matches("_dense");
        format!("{prefix}_moe_e{experts}_c{capacity}{}", router.model_suffix())
    }

    /// Enumerate and validate every leg of the grid, in the canonical order
    /// (sunk → experts → capacity → router → strategy → budget). A grid
    /// point whose model is absent from the zoo is a named error — legs are
    /// never silently dropped. Drop-Upcycling legs carry `(reinit, seed)`
    /// so their surgery is a pure function of `(spec, seed)` too.
    pub fn legs(&self, manifest: &Manifest, seed: u64) -> Result<Vec<Leg>> {
        manifest
            .model(&self.parent)
            .with_context(|| format!("sweep parent `{}`", self.parent))?;
        let mut legs = Vec::with_capacity(self.grid_size());
        for &sunk_steps in &self.sunk {
            for &experts in &self.experts {
                for &capacity in &self.capacity {
                    for &router in &self.routers {
                        for &kind in &self.strategies {
                            for &budget_steps in &self.budgets {
                                let model = self.model_name(experts, capacity, router);
                                manifest.model(&model).with_context(|| {
                                    format!(
                                        "sweep leg #{} (E={experts}, C={capacity}, \
                                         router={}): no zoo model `{model}`",
                                        legs.len(),
                                        router.name()
                                    )
                                })?;
                                let strategy = match kind {
                                    StrategyKind::Replicate => UpcycleStrategy::Replicate,
                                    StrategyKind::DropUpcycle => UpcycleStrategy::DropUpcycle {
                                        reinit_fraction: self.reinit_fraction,
                                        seed,
                                    },
                                };
                                legs.push(Leg {
                                    index: legs.len(),
                                    sunk_steps,
                                    experts,
                                    capacity,
                                    router,
                                    strategy,
                                    budget_steps,
                                    model,
                                    parent: self.parent.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(legs)
    }

    /// Validate the whole grid against the zoo without keeping the legs.
    pub fn validate(&self, manifest: &Manifest) -> Result<()> {
        self.legs(manifest, 0).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_key() {
        let text = "sunk=30+60,experts=2+8,capacity=2,router=ec+top1,\
                    strategy=replicate+drop,reinit=0.5,budget=20+40,eval=10,\
                    parent=lm_tiny_dense";
        let spec = SweepSpec::parse(text).unwrap();
        assert_eq!(spec.sunk, vec![30, 60]);
        assert_eq!(spec.experts, vec![2, 8]);
        assert_eq!(spec.capacity, vec![2]);
        assert_eq!(spec.routers, vec![RouterFamily::ExpertChoice, RouterFamily::Top1]);
        assert_eq!(spec.strategies, vec![StrategyKind::Replicate, StrategyKind::DropUpcycle]);
        assert_eq!(spec.reinit_fraction, 0.5);
        assert_eq!(spec.budgets, vec![20, 40]);
        assert_eq!(spec.eval_every, 10);
        assert_eq!(spec.grid_size(), 2 * 2 * 1 * 2 * 2 * 2);
        // The canonical spelling parses back to the same spec.
        assert_eq!(SweepSpec::parse(&spec.canonical()).unwrap(), spec);
        // An empty spec is the default plan.
        let dflt = SweepSpec::parse("").unwrap();
        assert_eq!(dflt, SweepSpec::default());
        assert_eq!(dflt.grid_size(), 1);
    }

    #[test]
    fn parse_rejects_malformed_specs_loudly() {
        for (spec, needle) in [
            ("experts", "expected `key=value`"),
            ("experts=two", "is not a number"),
            ("experts=0", "must be >= 1"),
            ("experts=2+2", "lists `2` twice"),
            ("experts=2,experts=4", "given twice"),
            ("experts=2+", "empty list entry"),
            ("capacity=banana", "is not a number"),
            ("router=topk", "unknown router family"),
            ("strategy=split", "unknown sweep strategy"),
            ("reinit=0.25", "only applies when strategy includes drop"),
            ("strategy=drop,reinit=0", "must be in (0, 1]"),
            ("strategy=drop,reinit=1.5", "must be in (0, 1]"),
            ("parent=lm_tiny_moe_e8_c2", "must be a dense model"),
            ("tenant=3", "unknown key"),
        ] {
            let err = SweepSpec::parse(spec).unwrap_err();
            assert!(format!("{err:#}").contains(needle), "{spec}: {err:#}");
        }
    }

    #[test]
    fn legs_enumerate_the_grid_in_canonical_order() {
        let m = Manifest::native();
        let spec = SweepSpec::parse("sunk=10,experts=2+8,capacity=2,strategy=replicate+drop,\
                                     reinit=0.25,budget=4+8")
            .unwrap();
        let legs = spec.legs(&m, 17).unwrap();
        assert_eq!(legs.len(), spec.grid_size());
        assert_eq!(legs.len(), 8);
        // budget varies fastest, then strategy, then experts.
        assert_eq!(legs[0].model, "lm_tiny_moe_e2_c2");
        assert_eq!(legs[0].budget_steps, 4);
        assert_eq!(legs[1].budget_steps, 8);
        assert_eq!(legs[1].strategy, UpcycleStrategy::Replicate);
        assert!(matches!(legs[2].strategy, UpcycleStrategy::DropUpcycle { seed: 17, .. }));
        assert_eq!(legs[4].model, "lm_tiny_moe_e8_c2");
        for (i, leg) in legs.iter().enumerate() {
            assert_eq!(leg.index, i);
        }
        // Same (spec, seed) → identical legs (purity).
        assert_eq!(spec.legs(&m, 17).unwrap(), legs);
    }

    #[test]
    fn legs_name_unresolvable_grid_points() {
        let m = Manifest::native();
        // top1 targets only exist at E=8, C=2: E=4 must fail by name.
        let spec = SweepSpec::parse("experts=4,router=top1").unwrap();
        let err = spec.legs(&m, 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("lm_tiny_moe_e4_c2_top1"), "{msg}");
        assert!(msg.contains("sweep leg #0"), "{msg}");
        // And the same through validate().
        assert!(spec.validate(&m).is_err());
        // A resolvable router-family grid point validates.
        SweepSpec::parse("experts=8,router=top1+top2bpr").unwrap().validate(&m).unwrap();
    }
}
