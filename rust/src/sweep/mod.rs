//! Scaling-law sweep lab (docs/SWEEPS.md): grid specs over the upcycling
//! knobs, a cost-budgeted concurrent scheduler, an append-only results
//! store, and power-law curve fitting.
//!
//! "Scaling Laws for Upcycling MoE" (PAPERS.md) fits upcycling outcomes as
//! a function of dense sunk cost, expert count and continuation budget.
//! This module turns the repo's one-off paper-figure runners into that
//! lab: one validated [`SweepSpec`] enumerates the grid, every leg is
//! **priced up front** via `costmodel` ([`price_legs`]), legs are packed
//! onto `--cores` worker threads by deterministic LPT ([`pack`]), each
//! worker runs its legs through the standard experiment harness
//! ([`experiments::Ctx`](crate::experiments::Ctx), one context per
//! worker — the execution [`Backend`](crate::runtime::Backend) is not
//! `Send`), and results land in `SWEEP_results.json` ([`store`]).
//!
//! **Determinism contract:** the results store is a pure function of
//! `(SweepSpec, seed)`. Worker count changes wall-clock only — legs are
//! keyed and written in grid order, every leg trains under
//! [`util::serial_compute`](crate::util::serial_compute) (so nested kernel
//! parallelism can neither oversubscribe the `--cores` budget nor vary
//! with it), and dense parents are pre-warmed serially before workers
//! start so no two legs ever race to pretrain the same checkpoint.

pub mod fit;
pub mod spec;
pub mod store;

pub use spec::{Leg, RouterFamily, StrategyKind, SweepSpec};

use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::costmodel::{surgery_cost, Cost, SurgeryCost};
use crate::experiments::{Ctx, ExpParams};
use crate::manifest::Manifest;
use crate::metrics::{map, Report, Series};
use crate::upcycle::diversity::expert_diversity;
use crate::upcycle::{upcycle_opt_state, upcycle_params, UpcycleOptions};
use store::{LegRecord, PricedCost, ResultsStore, SweepRun};

/// Data shards 0..~1000 belong to the figure runners (1000 is the held-out
/// eval shard); sweep legs draw from `SWEEP_SHARD_BASE + leg.index` so no
/// leg ever shares a training stream with another leg or experiment.
const SWEEP_SHARD_BASE: u64 = 2000;

/// One leg with its up-front `costmodel` price attached.
#[derive(Debug, Clone, PartialEq)]
pub struct PricedLeg {
    pub index: usize,
    /// Dense-parent pretraining cost (shared across legs at the same sunk
    /// point — the parent checkpoint is cached, not retrained per leg).
    pub sunk: Cost,
    /// Continuation cost: `budget_steps` on the MoE target.
    pub extra: Cost,
    pub surgery: SurgeryCost,
}

impl PricedLeg {
    fn to_priced_cost(&self) -> PricedCost {
        PricedCost {
            sunk_flops: self.sunk.flops,
            extra_flops: self.extra.flops,
            relative_extra_pct: self.extra.relative_pct(&self.sunk),
            surgery: self.surgery,
        }
    }
}

/// Price every leg of the grid from the manifest alone — no training, no
/// tensors. This is what the scheduler packs against.
pub fn price_legs(manifest: &Manifest, legs: &[Leg]) -> Result<Vec<PricedLeg>> {
    legs.iter()
        .map(|leg| {
            let parent = manifest.model(&leg.parent)?;
            let target = manifest.model(&leg.model)?;
            Ok(PricedLeg {
                index: leg.index,
                sunk: Cost::of_steps(parent, leg.sunk_steps),
                extra: Cost::of_steps(target, leg.budget_steps),
                surgery: surgery_cost(target, &leg.strategy),
            })
        })
        .collect()
}

/// A deterministic assignment of legs onto worker bins.
#[derive(Debug, Clone, PartialEq)]
pub struct Packing {
    /// `bins[w]` = leg indices worker `w` runs, in grid order.
    pub bins: Vec<Vec<usize>>,
    /// Priced FLOPs of the heaviest bin (the predicted critical path).
    pub makespan_flops: f64,
    /// Priced continuation FLOPs over all legs.
    pub total_flops: f64,
}

/// Longest-processing-time bin packing of legs onto `cores` bins, weighted
/// by priced continuation FLOPs. Fully deterministic: legs are considered
/// heaviest-first (ties broken by grid index), each goes to the currently
/// lightest bin (ties broken by lowest bin index), and each bin's legs are
/// then sorted back into grid order. The packing only decides *where* a
/// leg runs — never what it computes — so results are independent of it
/// by construction; determinism here just keeps schedules reproducible.
pub fn pack(priced: &[PricedLeg], cores: usize) -> Packing {
    let bins_n = cores.min(priced.len()).max(1);
    let mut order: Vec<usize> = (0..priced.len()).collect();
    order.sort_by(|&a, &b| {
        priced[b].extra.flops.total_cmp(&priced[a].extra.flops).then(a.cmp(&b))
    });
    let mut bins = vec![Vec::new(); bins_n];
    let mut loads = vec![0.0f64; bins_n];
    for i in order {
        let lightest = (0..bins_n)
            .min_by(|&a, &b| loads[a].total_cmp(&loads[b]).then(a.cmp(&b)))
            .expect("at least one bin");
        bins[lightest].push(i);
        loads[lightest] += priced[i].extra.flops;
    }
    for bin in &mut bins {
        bin.sort_unstable();
    }
    Packing {
        bins,
        makespan_flops: loads.iter().cloned().fold(0.0, f64::max),
        total_flops: priced.iter().map(|p| p.extra.flops).sum(),
    }
}

/// Everything about a sweep invocation that is *not* part of the results'
/// identity: worker budget, file locations, eval sampling.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker-thread budget (`--cores`). The scheduler spawns at most this
    /// many workers and each computes strictly serially.
    pub cores: usize,
    /// The sweep seed — with the spec, the results store's full identity.
    pub seed: u64,
    /// Eval batches per evaluation point.
    pub eval_batches: usize,
    pub artifacts: String,
    pub out_dir: String,
    /// The append-only results store (`SWEEP_results.json`).
    pub results_path: PathBuf,
    pub verbose: bool,
}

impl SweepConfig {
    pub fn new(artifacts: &str, out_dir: &str) -> SweepConfig {
        SweepConfig {
            cores: 1,
            seed: ExpParams::tiny().seed,
            eval_batches: ExpParams::tiny().eval_batches,
            artifacts: artifacts.to_string(),
            out_dir: out_dir.to_string(),
            results_path: PathBuf::from(out_dir).join("SWEEP_results.json"),
            verbose: false,
        }
    }

    fn exp_params(&self, spec: &SweepSpec) -> ExpParams {
        ExpParams {
            eval_every: spec.eval_every,
            eval_batches: self.eval_batches,
            seed: self.seed,
            ..ExpParams::tiny()
        }
    }
}

/// Run one leg end to end inside `ctx`: load the cached dense parent,
/// perform the surgery, measure init quality + expert diversity, continue
/// training for the leg's budget, and fold everything into a [`LegRecord`]
/// with the up-front price recorded next to the accounted cost. The body
/// mirrors the strategy-zoo runner so sweep legs and figure runs measure
/// the same quantities the same way.
fn run_leg(ctx: &Ctx, leg: &Leg, priced: &PricedLeg) -> Result<LegRecord> {
    let parent = ctx.dense_parent(&leg.parent, leg.sunk_steps)?;
    let entry = ctx.entry(&leg.model)?.clone();
    let opts = UpcycleOptions { strategy: leg.strategy.clone(), seed: ctx.p.seed, ..Default::default() };
    let params = upcycle_params(&parent.0, &entry, &opts)
        .with_context(|| format!("sweep leg `{}`: surgery", leg.label()))?;
    let diversity = expert_diversity(&params, &entry)?;
    let opt = upcycle_opt_state(&parent.1, &entry, false, &leg.strategy)?;
    let model = ctx.load(&leg.model, &["train", "eval"])?;
    let mut state = crate::coordinator::TrainState::from_checkpoints(&entry, &params, &opt)?;
    let init = ctx.evaluator(&entry).eval(&model, &state)?;
    let label = leg.label();
    let trajectory = ctx.run_branch(
        &model,
        &mut state,
        SWEEP_SHARD_BASE + leg.index as u64,
        leg.budget_steps,
        &label,
    )?;
    let last = trajectory
        .last()
        .ok_or_else(|| anyhow!("sweep leg `{label}` produced an empty trajectory"))?;
    Ok(LegRecord {
        index: leg.index,
        label,
        model: leg.model.clone(),
        parent: leg.parent.clone(),
        sunk_steps: leg.sunk_steps,
        budget_steps: leg.budget_steps,
        experts: leg.experts,
        capacity: leg.capacity,
        router: leg.router.name().to_string(),
        strategy: leg.strategy_kind_name().to_string(),
        priced: priced.to_priced_cost(),
        accounted_extra_flops: last.extra_flops,
        init_loss: init.get("loss").copied().unwrap_or(f64::NAN),
        final_loss: last.values.get("loss").copied().unwrap_or(f64::NAN),
        mean_cosine_diversity: diversity.mean_cosine_distance(),
        trajectory,
    })
}

/// Execute the whole sweep: price → pack → pre-warm parents → run legs on
/// worker threads → append the run to the results store and mirror it as
/// a `metrics::Report` (CSV + JSON) under `out_dir`. Returns the recorded
/// run. Any leg failure fails the sweep (lowest leg index first) — legs
/// are never silently dropped.
pub fn run_sweep(spec: &SweepSpec, cfg: &SweepConfig) -> Result<SweepRun> {
    if cfg.cores == 0 {
        bail!("--cores must be >= 1");
    }
    let manifest = Manifest::load_or_native(&cfg.artifacts)?;
    let legs = spec.legs(&manifest, cfg.seed)?;
    let priced = price_legs(&manifest, &legs)?;
    let packing = pack(&priced, cfg.cores);
    println!(
        "sweep: {} leg(s) over `{}`, seed {}",
        legs.len(),
        spec.canonical(),
        cfg.seed
    );
    println!(
        "  priced: {:.4} core-days continuation total, critical path {:.4} \
         core-days on {} worker(s)",
        Cost { flops: packing.total_flops }.core_days(),
        Cost { flops: packing.makespan_flops }.core_days(),
        packing.bins.len()
    );

    // Pre-warm every distinct dense parent serially: legs sharing a sunk
    // point must share one checkpoint bitwise, so the pretrain never races.
    let mut parents: Vec<(String, u64)> = Vec::new();
    for leg in &legs {
        let key = (leg.parent.clone(), leg.sunk_steps);
        if !parents.contains(&key) {
            parents.push(key);
        }
    }
    {
        let ctx = Ctx::new(&cfg.artifacts, &cfg.out_dir, cfg.exp_params(spec), cfg.verbose)?;
        for (parent, sunk) in &parents {
            crate::util::serial_compute(|| ctx.dense_parent(parent, *sunk))
                .with_context(|| format!("pre-warming dense parent `{parent}` at {sunk} steps"))?;
        }
    }

    // One worker thread per non-empty bin, one `Ctx` per worker (the
    // backend is not Send). Each worker computes strictly serially, so at
    // most `cores` threads ever compute at once.
    let results: Mutex<Vec<(usize, Result<LegRecord>)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for bin in packing.bins.iter().filter(|b| !b.is_empty()) {
            let (results, legs, priced) = (&results, &legs, &priced);
            scope.spawn(move || {
                let ctx = match Ctx::new(
                    &cfg.artifacts,
                    &cfg.out_dir,
                    cfg.exp_params(spec),
                    cfg.verbose,
                ) {
                    Ok(ctx) => ctx,
                    Err(e) => {
                        let mut out = results.lock().unwrap();
                        for &i in bin {
                            out.push((i, Err(anyhow!("sweep worker context: {e:#}"))));
                        }
                        return;
                    }
                };
                for &i in bin {
                    let r = crate::util::serial_compute(|| run_leg(&ctx, &legs[i], &priced[i]));
                    results.lock().unwrap().push((i, r));
                }
            });
        }
    });

    // Reassemble in grid order — the store must be independent of which
    // worker finished when.
    let mut results = results.into_inner().unwrap();
    results.sort_by_key(|(i, _)| *i);
    let mut records = Vec::with_capacity(legs.len());
    for (i, r) in results {
        let rec = r.with_context(|| format!("sweep leg `{}`", legs[i].label()))?;
        println!(
            "  [{}] init loss {:.4} → final loss {:.4} (+{:.4} priced core-days)",
            rec.label,
            rec.init_loss,
            rec.final_loss,
            Cost { flops: rec.priced.extra_flops }.core_days()
        );
        records.push(rec);
    }
    let run = SweepRun {
        spec: spec.canonical(),
        seed: cfg.seed,
        grid: spec.grid_size(),
        legs: records,
    };
    run.check_complete()?;

    let mut store = ResultsStore::load_or_empty(&cfg.results_path)?;
    store.append_run(run.clone());
    store.save(&cfg.results_path)?;
    println!("  results store: {} ({} run(s))", cfg.results_path.display(), store.runs.len());

    // Mirror the run as a standard experiment report so the sweep plots
    // with the same tooling as the paper figures.
    let mut report = Report::new("sweep", "scaling-law sweep");
    report.note(format!("spec: {}", run.spec));
    report.note(format!("seed: {}", run.seed));
    let mut summary = Series::new("sweep_summary");
    for rec in &run.legs {
        summary.push(
            rec.index as u64,
            rec.priced.extra_flops,
            map(&[
                ("init_loss", rec.init_loss),
                ("final_loss", rec.final_loss),
                ("mean_cosine_diversity", rec.mean_cosine_diversity),
                ("priced_sunk_flops", rec.priced.sunk_flops),
                ("accounted_extra_flops", rec.accounted_extra_flops),
            ]),
        );
        report.add(rec.trajectory.clone());
    }
    report.add(summary);
    report.write_csv(&cfg.out_dir)?;
    report.write_json(&cfg.out_dir)?;
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn priced_with_flops(flops: &[f64]) -> Vec<PricedLeg> {
        flops
            .iter()
            .enumerate()
            .map(|(i, &f)| PricedLeg {
                index: i,
                sunk: Cost { flops: 1e12 },
                extra: Cost { flops: f },
                surgery: SurgeryCost::default(),
            })
            .collect()
    }

    #[test]
    fn pricing_scales_with_budget_and_capacity() {
        let m = Manifest::native();
        let spec = SweepSpec::parse("capacity=1+2,budget=10+20").unwrap();
        let legs = spec.legs(&m, 7).unwrap();
        let priced = price_legs(&m, &legs).unwrap();
        assert_eq!(priced.len(), 4);
        // budget varies fastest: doubling it doubles the priced extra.
        assert!((priced[1].extra.flops - 2.0 * priced[0].extra.flops).abs() < 1e-3);
        // capacity=2 costs more per step than capacity=1.
        assert!(priced[2].extra.flops > priced[0].extra.flops);
        // Sunk cost is the parent's, identical across legs.
        assert_eq!(priced[0].sunk, priced[3].sunk);
        // Every leg's surgery is priced.
        assert!(priced.iter().all(|p| p.surgery.bytes_copied > 0));
    }

    #[test]
    fn pack_is_deterministic_and_respects_cores() {
        let priced = priced_with_flops(&[5.0, 3.0, 8.0, 1.0, 4.0]);
        for cores in [1, 2, 4, 8] {
            let p = pack(&priced, cores);
            assert_eq!(p, pack(&priced, cores), "cores={cores} not deterministic");
            assert!(p.bins.len() <= cores, "cores={cores} exceeded");
            // Every leg appears exactly once, each bin in grid order.
            let mut seen: Vec<usize> = p.bins.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3, 4]);
            for bin in &p.bins {
                assert!(bin.windows(2).all(|w| w[0] < w[1]));
            }
            assert!((p.total_flops - 21.0).abs() < 1e-12);
            assert!(p.makespan_flops <= p.total_flops + 1e-12);
        }
        // LPT on 2 bins: 8+3 vs 5+4+1 → makespan 11 (better than naive 13).
        let two = pack(&priced, 2);
        assert!((two.makespan_flops - 11.0).abs() < 1e-12);
        // One bin degenerates to the serial schedule.
        assert_eq!(pack(&priced, 1).bins, vec![vec![0, 1, 2, 3, 4]]);
    }

    #[test]
    fn pack_ties_break_by_index() {
        // All-equal weights: round-robin by grid index, lowest bin first.
        let priced = priced_with_flops(&[2.0, 2.0, 2.0, 2.0]);
        let p = pack(&priced, 2);
        assert_eq!(p.bins, vec![vec![0, 2], vec![1, 3]]);
    }
}
