//! Model manifest: the signature contract between model definitions and the
//! execution backends.
//!
//! Two sources produce a [`Manifest`]:
//!
//! * **Native zoo** ([`Manifest::native`], the default): model entries built
//!   in pure Rust by [`zoo`], no artifacts required.
//! * **AOT artifacts** ([`Manifest::load`]): `python/compile/aot.py` writes
//!   `artifacts/manifest.json` describing, for every model configuration,
//!   the ordered flat parameter / optimizer-state / batch tensor signatures
//!   (names, shapes, dtypes, init specs), the model hyperparameters, an
//!   analytic FLOPs estimate, and the HLO artifact file names (the `pjrt`
//!   backend's input).
//!
//! Everything the coordinator does — initialization, checkpointing, surgery,
//! cost accounting, step execution — is keyed off this structure.
//! [`Manifest::load_or_native`] picks the artifact manifest when one exists
//! on disk and falls back to the zoo otherwise.

pub mod zoo;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct InitSpec {
    pub kind: String, // "normal" | "fan_in" | "zeros" | "ones"
    pub stddev: f32,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub init: Option<InitSpec>,
}

#[derive(Debug, Clone)]
pub struct MoeSpec {
    pub num_experts: usize,
    pub capacity_factor: f64,
    pub router_type: String,
    pub moe_layers: Vec<usize>,
    pub group_size: usize,
    pub renormalize: bool,
    pub bpr: bool,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub family: String,
    pub d_model: usize,
    pub d_ff: usize,
    pub num_heads: usize,
    pub num_layers: usize,
    pub num_decoder_layers: usize,
    pub vocab_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub image_size: usize,
    pub patch_size: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub enc_moe: Option<MoeSpec>,
    pub dec_moe: Option<MoeSpec>,
}

#[derive(Debug, Clone)]
pub struct FlopsInfo {
    pub train_step: f64,
    pub eval_step: f64,
    pub fwd_per_example: f64,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub family: String,
    pub config: ModelConfig,
    pub params: Vec<TensorSpec>,
    pub opt_state: Vec<TensorSpec>,
    pub batch: Vec<TensorSpec>,
    pub scalars: Vec<String>,
    pub metrics: Vec<String>,
    pub param_count: usize,
    pub flops: FlopsInfo,
    /// artifact kind ("train" | "eval" | "features") → file name
    pub artifacts: BTreeMap<String, String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub source_hash: String,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    /// The built-in native model zoo (no artifacts needed).
    pub fn native() -> Manifest {
        Manifest {
            dir: PathBuf::from("<native>"),
            source_hash: zoo::NATIVE_SOURCE.to_string(),
            models: zoo::native_models(),
        }
    }

    /// The manifest matching the compiled execution backend. This is what
    /// the CLI, experiments and benches use: a clean checkout works
    /// immediately on the native zoo. AOT signatures (`dir/manifest.json`,
    /// written by `make artifacts`) describe Adafactor state layouts and
    /// attention parameters the native backend does not implement, so they
    /// are only picked up when the `pjrt` backend that executes them is
    /// compiled in; default builds always use the zoo.
    pub fn load_or_native(dir: impl AsRef<Path>) -> Result<Manifest> {
        let m = if cfg!(feature = "pjrt") && dir.as_ref().join("manifest.json").exists() {
            Manifest::load(dir)?
        } else {
            Manifest::native()
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for m in v.get("models")?.as_arr()? {
            let e = parse_entry(m)?;
            models.insert(e.name.clone(), e);
        }
        let m = Manifest {
            dir,
            source_hash: v.get("source_hash")?.as_str()?.to_string(),
            models,
        };
        m.validate().context("validating manifest.json")?;
        Ok(m)
    }

    /// Structural validation of every entry, run at manifest load time so a
    /// malformed model geometry fails here with a named error rather than
    /// deep inside the trainer or the parallel placement.
    pub fn validate(&self) -> Result<()> {
        for e in self.models.values() {
            e.validate()?;
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model `{name}` not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, entry: &ModelEntry, which: &str) -> Result<PathBuf> {
        let f = entry
            .artifacts
            .get(which)
            .ok_or_else(|| anyhow!("model `{}` has no `{which}` artifact", entry.name))?;
        Ok(self.dir.join(f))
    }
}

impl ModelEntry {
    /// Number of flat inputs to the train step.
    pub fn train_arity(&self) -> usize {
        self.params.len() + self.opt_state.len() + self.batch.len() + self.scalars.len()
    }

    /// Names of train-step outputs in order.
    pub fn train_output_names(&self) -> Vec<String> {
        self.params
            .iter()
            .chain(self.opt_state.iter())
            .map(|s| s.name.clone())
            .chain(self.metrics.iter().cloned())
            .collect()
    }

    pub fn is_sparse(&self) -> bool {
        self.config.enc_moe.is_some() || self.config.dec_moe.is_some()
    }

    /// MoE block tags in tower/layer order: `("enc/block_01", &MoeSpec)`
    /// for every sparsified layer of both towers. The tag is the
    /// parameter-name prefix (`<tag>/moe/{router,wi,wo}`) shared with the
    /// native backend's block construction — the expert-parallel weight
    /// scatter (`runtime::ep`) and the executor resolve the same blocks
    /// through it.
    pub fn moe_block_tags(&self) -> Vec<(String, &MoeSpec)> {
        let towers = [
            ("enc", self.config.enc_moe.as_ref(), self.config.num_layers),
            ("dec", self.config.dec_moe.as_ref(), self.config.num_decoder_layers),
        ];
        let mut out = Vec::new();
        for (tower, moe, layers) in towers {
            let Some(m) = moe else { continue };
            for i in 0..layers {
                if m.moe_layers.contains(&i) {
                    out.push((format!("{tower}/block_{i:02}"), m));
                }
            }
        }
        out
    }

    /// The leading batch tensors that are pure model *inputs* — the
    /// signature of the forward-only inference entry point
    /// (`runtime::Executable::infer`). LM entries take
    /// `[enc_tokens, dec_tokens]`; vision entries take `[images]`. The
    /// remaining batch tensors (targets, labels, loss masks) exist only for
    /// training/eval and are never required to serve.
    pub fn infer_batch(&self) -> &[TensorSpec] {
        let n = if self.family == "lm" { 2 } else { 1 };
        &self.batch[..n.min(self.batch.len())]
    }

    /// Total parameters held by MoE experts (sparse capacity).
    pub fn expert_param_count(&self) -> usize {
        self.params
            .iter()
            .filter(|s| s.name.contains("/moe/w"))
            .map(|s| s.shape.iter().product::<usize>())
            .sum()
    }

    /// Structural sanity of one entry: geometry that later layers assume
    /// without re-checking. Called by [`Manifest::validate`] at load time.
    pub fn validate(&self) -> Result<()> {
        if self.config.batch_size == 0 {
            bail!("model `{}`: batch_size must be >= 1", self.name);
        }
        let towers = [
            ("enc_moe", self.config.enc_moe.as_ref(), self.config.num_layers),
            ("dec_moe", self.config.dec_moe.as_ref(), self.config.num_decoder_layers),
        ];
        for (which, moe, layers) in towers {
            let Some(m) = moe else { continue };
            if m.num_experts == 0 {
                bail!("model `{}`: {which} has 0 experts", self.name);
            }
            if !m.capacity_factor.is_finite() || m.capacity_factor <= 0.0 {
                bail!(
                    "model `{}`: {which} capacity_factor {} must be > 0",
                    self.name,
                    m.capacity_factor
                );
            }
            if let Some(&bad) = m.moe_layers.iter().find(|&&l| l >= layers) {
                bail!(
                    "model `{}`: {which} sparsifies layer {bad} but the tower has {layers} \
                     layer(s) (valid: 0..{layers})",
                    self.name,
                );
            }
        }
        Ok(())
    }
}

fn parse_tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let init = match v.opt("init") {
        Some(i) if !i.is_null() => Some(InitSpec {
            kind: i.get("kind")?.as_str()?.to_string(),
            stddev: i.get("stddev")?.as_f64()? as f32,
        }),
        _ => None,
    };
    Ok(TensorSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape,
        dtype: DType::from_str(v.get("dtype")?.as_str()?)?,
        init,
    })
}

fn parse_moe(v: &Json) -> Result<Option<MoeSpec>> {
    if v.is_null() {
        return Ok(None);
    }
    Ok(Some(MoeSpec {
        num_experts: v.get("num_experts")?.as_usize()?,
        capacity_factor: v.get("capacity_factor")?.as_f64()?,
        router_type: v.get("router_type")?.as_str()?.to_string(),
        moe_layers: v
            .get("moe_layers")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?,
        group_size: v.get("group_size")?.as_usize()?,
        renormalize: v.get("renormalize")?.as_bool()?,
        bpr: v.get("bpr")?.as_bool()?,
    }))
}

fn parse_config(v: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        family: v.get("family")?.as_str()?.to_string(),
        d_model: v.get("d_model")?.as_usize()?,
        d_ff: v.get("d_ff")?.as_usize()?,
        num_heads: v.get("num_heads")?.as_usize()?,
        num_layers: v.get("num_layers")?.as_usize()?,
        num_decoder_layers: v.get("num_decoder_layers")?.as_usize()?,
        vocab_size: v.get("vocab_size")?.as_usize()?,
        enc_len: v.get("enc_len")?.as_usize()?,
        dec_len: v.get("dec_len")?.as_usize()?,
        image_size: v.get("image_size")?.as_usize()?,
        patch_size: v.get("patch_size")?.as_usize()?,
        channels: v.get("channels")?.as_usize()?,
        num_classes: v.get("num_classes")?.as_usize()?,
        batch_size: v.get("batch_size")?.as_usize()?,
        enc_moe: parse_moe(v.get("enc_moe")?)?,
        dec_moe: parse_moe(v.get("dec_moe")?)?,
    })
}

fn parse_entry(v: &Json) -> Result<ModelEntry> {
    let specs = |key: &str| -> Result<Vec<TensorSpec>> {
        v.get(key)?.as_arr()?.iter().map(parse_tensor_spec).collect()
    };
    let strs = |key: &str| -> Result<Vec<String>> {
        v.get(key)?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect()
    };
    let flops = v.get("flops")?;
    let mut artifacts = BTreeMap::new();
    match v.get("artifacts")? {
        Json::Obj(m) => {
            for (k, f) in m {
                artifacts.insert(k.clone(), f.as_str()?.to_string());
            }
        }
        _ => bail!("artifacts must be an object"),
    }
    Ok(ModelEntry {
        name: v.get("name")?.as_str()?.to_string(),
        family: v.get("family")?.as_str()?.to_string(),
        config: parse_config(v.get("config")?)?,
        params: specs("params")?,
        opt_state: specs("opt_state")?,
        batch: specs("batch")?,
        scalars: strs("scalars")?,
        metrics: strs("metrics")?,
        param_count: v.get("param_count")?.as_usize()?,
        flops: FlopsInfo {
            train_step: flops.get("train_step")?.as_f64()?,
            eval_step: flops.get("eval_step")?.as_f64()?,
            fwd_per_example: flops.get("fwd_per_example")?.as_f64()?,
        },
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_manifest_loads() {
        let m = Manifest::native();
        assert!(m.models.len() >= 20, "expected the full zoo");
        assert_eq!(m.source_hash, zoo::NATIVE_SOURCE);
        let e = m.model("lm_tiny_moe_e8_c2").unwrap();
        assert!(e.is_sparse());
        assert_eq!(e.scalars, vec!["lr", "wd", "step"]);
        assert!(e.param_count > 50_000);
        assert!(e.flops.train_step > e.flops.eval_step);
        // Signature bookkeeping: sorted and unique names.
        let names: Vec<&str> = e.params.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(names, sorted, "param specs must be sorted and unique");
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn moe_block_tags_name_real_parameters() {
        let m = Manifest::native();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        let tags = sparse.moe_block_tags();
        // Standard recipe: enc layers 1 and 3, dec layer 1.
        let names: Vec<&str> = tags.iter().map(|(t, _)| t.as_str()).collect();
        assert_eq!(names, vec!["enc/block_01", "enc/block_03", "dec/block_01"]);
        for (tag, spec) in &tags {
            assert_eq!(spec.num_experts, 8);
            for suffix in ["router", "wi", "wo"] {
                let pname = format!("{tag}/moe/{suffix}");
                assert!(
                    sparse.params.iter().any(|s| s.name == pname),
                    "tag must resolve to parameter `{pname}`"
                );
            }
        }
        assert!(m.model("lm_tiny_dense").unwrap().moe_block_tags().is_empty());
    }

    /// The inference signature is the input prefix of the batch signature:
    /// token streams for LM, images for vision — never targets or masks.
    #[test]
    fn infer_batch_selects_model_inputs() {
        let m = Manifest::native();
        let lm = m.model("lm_tiny_moe_e8_c2").unwrap();
        let names: Vec<&str> = lm.infer_batch().iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["enc_tokens", "dec_tokens"]);
        let vit = m.model("vit_tiny_dense").unwrap();
        let names: Vec<&str> = vit.infer_batch().iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["images"]);
    }

    #[test]
    fn dense_vs_sparse_bookkeeping() {
        let m = Manifest::native();
        let dense = m.model("lm_tiny_dense").unwrap();
        let sparse = m.model("lm_tiny_moe_e8_c2").unwrap();
        assert!(!dense.is_sparse());
        assert_eq!(dense.expert_param_count(), 0);
        assert!(sparse.expert_param_count() > 0);
        assert!(sparse.param_count > dense.param_count);
    }

    #[test]
    fn validation_rejects_malformed_entries() {
        let m = Manifest::native();
        m.validate().expect("the shipped zoo must validate");
        let mut e = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
        e.config.enc_moe.as_mut().unwrap().moe_layers.push(99);
        let err = e.validate().unwrap_err().to_string();
        assert!(err.contains("layer 99"), "{err}");
        let mut e = m.model("lm_tiny_moe_e8_c2").unwrap().clone();
        e.config.enc_moe.as_mut().unwrap().num_experts = 0;
        assert!(e.validate().is_err());
        let mut e = m.model("lm_tiny_dense").unwrap().clone();
        e.config.batch_size = 0;
        assert!(e.validate().is_err());
    }

    #[test]
    fn load_or_native_falls_back() {
        // A directory without manifest.json yields the native zoo.
        let dir = std::env::temp_dir().join("supc_no_artifacts_here");
        std::fs::create_dir_all(&dir).ok();
        let m = Manifest::load_or_native(&dir).unwrap();
        assert_eq!(m.source_hash, zoo::NATIVE_SOURCE);
        assert!(m.model("vit_tiny_dense").is_ok());
    }
}
