//! Built-in model zoo for the native backend.
//!
//! The AOT path derives model signatures from `python/compile/aot.py`; the
//! native backend derives them here, in pure Rust, so a clean checkout can
//! run the full pipeline (init → train → surgery → continued MoE training)
//! with zero artifacts. Names and tensor naming conventions match the AOT
//! manifest (`enc/block_XX/mlp/wi`, `.../moe/wi [E,d,f]`,
//! `.../moe/router [d,E]`, `opt/<param>/<slot>`), so the upcycling surgery
//! and the checkpoint format are identical across backends.
//!
//! The geometry is deliberately tiny (the paper's protocol at toy scale):
//! every entry here trains in seconds on a laptop CPU.

use std::collections::BTreeMap;

use super::{FlopsInfo, InitSpec, ModelConfig, ModelEntry, MoeSpec, TensorSpec};
use crate::tensor::DType;

/// Source-hash marker for the built-in zoo.
pub const NATIVE_SOURCE: &str = "native-zoo-v1";

#[derive(Clone, Copy)]
struct LmGeom {
    vocab: usize,
    d: usize,
    ff: usize,
    n_enc: usize,
    n_dec: usize,
    enc_len: usize,
    dec_len: usize,
    batch: usize,
}

const LM_TINY: LmGeom =
    LmGeom { vocab: 256, d: 32, ff: 64, n_enc: 4, n_dec: 2, enc_len: 32, dec_len: 16, batch: 8 };

const LM_TINY_TILED: LmGeom =
    LmGeom { vocab: 256, d: 32, ff: 64, n_enc: 6, n_dec: 3, enc_len: 32, dec_len: 16, batch: 8 };

const LM_SMALL: LmGeom = LmGeom {
    vocab: 8192,
    d: 64,
    ff: 128,
    n_enc: 4,
    n_dec: 2,
    enc_len: 128,
    dec_len: 32,
    batch: 8,
};

#[derive(Clone, Copy)]
struct VitGeom {
    image: usize,
    patch: usize,
    channels: usize,
    classes: usize,
    d: usize,
    ff: usize,
    n_layers: usize,
    batch: usize,
}

const VIT_TINY: VitGeom = VitGeom {
    image: 32,
    patch: 8,
    channels: 3,
    classes: 16,
    d: 32,
    ff: 64,
    n_layers: 4,
    batch: 8,
};

/// MoE knobs for one sparse variant.
#[derive(Clone)]
struct MoeVariant {
    num_experts: usize,
    capacity: f64,
    router: &'static str,
    renormalize: bool,
    bpr: bool,
    group_size: usize,
    enc_layers: Vec<usize>,
    dec_layers: Vec<usize>,
}

impl MoeVariant {
    /// The standard recipe: every other layer sparsified, Expert Choice.
    fn standard(e: usize, c: f64) -> MoeVariant {
        MoeVariant {
            num_experts: e,
            capacity: c,
            router: "ec",
            renormalize: false,
            bpr: false,
            group_size: 0,
            enc_layers: vec![1, 3],
            dec_layers: vec![1],
        }
    }

    fn spec(&self, layers: &[usize]) -> Option<MoeSpec> {
        if layers.is_empty() {
            return None;
        }
        Some(MoeSpec {
            num_experts: self.num_experts,
            capacity_factor: self.capacity,
            router_type: self.router.to_string(),
            moe_layers: layers.to_vec(),
            group_size: self.group_size,
            renormalize: self.renormalize,
            bpr: self.bpr,
        })
    }
}

fn spec(name: &str, shape: &[usize], kind: &str, stddev: f32) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: DType::F32,
        init: Some(InitSpec { kind: kind.to_string(), stddev }),
    }
}

fn batch_spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype, init: None }
}

/// Residual-block params for one tower; MoE layers get expert weights + a
/// router, others a dense MLP.
fn block_params(
    params: &mut Vec<TensorSpec>,
    tower: &str,
    n: usize,
    d: usize,
    ff: usize,
    moe: Option<&MoeSpec>,
) {
    let wi_std = 1.0 / (d as f32).sqrt();
    let wo_std = 1.0 / (ff as f32).sqrt();
    for i in 0..n {
        let prefix = format!("{tower}/block_{i:02}");
        let is_moe = moe.map(|m| m.moe_layers.contains(&i)).unwrap_or(false);
        if is_moe {
            let e = moe.expect("moe spec present").num_experts;
            params.push(spec(&format!("{prefix}/moe/wi"), &[e, d, ff], "fan_in", wi_std));
            params.push(spec(&format!("{prefix}/moe/wo"), &[e, ff, d], "fan_in", wo_std));
            params.push(spec(&format!("{prefix}/moe/router"), &[d, e], "normal", 0.02));
        } else {
            params.push(spec(&format!("{prefix}/mlp/wi"), &[d, ff], "fan_in", wi_std));
            params.push(spec(&format!("{prefix}/mlp/wo"), &[ff, d], "fan_in", wo_std));
        }
    }
}

/// Optimizer slots: Adam (m, v) per parameter, in param order.
fn opt_specs(params: &[TensorSpec]) -> Vec<TensorSpec> {
    let mut out = Vec::with_capacity(2 * params.len());
    for p in params {
        out.push(batch_spec(&format!("opt/{}/m", p.name), &p.shape, DType::F32));
        out.push(batch_spec(&format!("opt/{}/v", p.name), &p.shape, DType::F32));
    }
    out
}

/// Per-token forward FLOPs of one residual block.
fn block_flops(d: usize, ff: usize, moe: Option<&MoeSpec>, layer: usize) -> f64 {
    let dense = 4.0 * d as f64 * ff as f64;
    match moe {
        Some(m) if m.moe_layers.contains(&layer) => {
            dense * m.capacity_factor + 2.0 * d as f64 * m.num_experts as f64
        }
        _ => dense,
    }
}

fn metrics_for(sparse: bool) -> Vec<String> {
    if sparse {
        vec!["accuracy".into(), "aux_loss".into(), "coverage".into(), "loss".into()]
    } else {
        vec!["accuracy".into(), "loss".into()]
    }
}

fn scalars() -> Vec<String> {
    vec!["lr".into(), "wd".into(), "step".into()]
}

fn native_artifacts(features: bool) -> BTreeMap<String, String> {
    let mut a = BTreeMap::new();
    a.insert("train".to_string(), "native".to_string());
    a.insert("eval".to_string(), "native".to_string());
    if features {
        a.insert("features".to_string(), "native".to_string());
    }
    a
}

fn lm_entry(name: &str, g: LmGeom, variant: Option<&MoeVariant>) -> ModelEntry {
    let enc_moe = variant.and_then(|v| v.spec(&v.enc_layers));
    let dec_moe = variant.and_then(|v| v.spec(&v.dec_layers));
    let mut params = vec![
        spec("token_embed", &[g.vocab, g.d], "normal", 0.1),
        spec("dec/cross_w", &[g.d, g.d], "fan_in", 1.0 / (g.d as f32).sqrt()),
    ];
    block_params(&mut params, "enc", g.n_enc, g.d, g.ff, enc_moe.as_ref());
    block_params(&mut params, "dec", g.n_dec, g.d, g.ff, dec_moe.as_ref());
    params.sort_by(|a, b| a.name.cmp(&b.name));
    let opt_state = opt_specs(&params);
    let param_count: usize = params.iter().map(|s| s.shape.iter().product::<usize>()).sum();

    let batch = vec![
        batch_spec("enc_tokens", &[g.batch, g.enc_len], DType::I32),
        batch_spec("dec_tokens", &[g.batch, g.dec_len], DType::I32),
        batch_spec("targets", &[g.batch, g.dec_len], DType::I32),
        batch_spec("loss_mask", &[g.batch, g.dec_len], DType::F32),
    ];

    let enc_tok_flops: f64 =
        (0..g.n_enc).map(|i| block_flops(g.d, g.ff, enc_moe.as_ref(), i)).sum();
    let dec_tok_flops: f64 =
        (0..g.n_dec).map(|i| block_flops(g.d, g.ff, dec_moe.as_ref(), i)).sum();
    let fwd = g.enc_len as f64 * enc_tok_flops
        + g.dec_len as f64 * (dec_tok_flops + 2.0 * g.d as f64 * g.vocab as f64)
        + 2.0 * (g.d * g.d) as f64;
    let flops = FlopsInfo {
        train_step: 3.0 * fwd * g.batch as f64,
        eval_step: fwd * g.batch as f64,
        fwd_per_example: fwd,
    };

    let sparse = enc_moe.is_some() || dec_moe.is_some();
    ModelEntry {
        name: name.to_string(),
        family: "lm".to_string(),
        config: ModelConfig {
            family: "lm".to_string(),
            d_model: g.d,
            d_ff: g.ff,
            num_heads: 1,
            num_layers: g.n_enc,
            num_decoder_layers: g.n_dec,
            vocab_size: g.vocab,
            enc_len: g.enc_len,
            dec_len: g.dec_len,
            image_size: 0,
            patch_size: 0,
            channels: 0,
            num_classes: 0,
            batch_size: g.batch,
            enc_moe,
            dec_moe,
        },
        params,
        opt_state,
        batch,
        scalars: scalars(),
        metrics: metrics_for(sparse),
        param_count,
        flops,
        artifacts: native_artifacts(false),
    }
}

fn vit_entry(name: &str, g: VitGeom, variant: Option<&MoeVariant>) -> ModelEntry {
    let enc_moe = variant.and_then(|v| v.spec(&v.enc_layers));
    let plen = g.patch * g.patch * g.channels;
    let mut params = vec![
        spec("patch_embed/w", &[plen, g.d], "fan_in", 1.0 / (plen as f32).sqrt()),
        spec("head/w", &[g.d, g.classes], "normal", 1.0 / (g.d as f32).sqrt()),
    ];
    block_params(&mut params, "enc", g.n_layers, g.d, g.ff, enc_moe.as_ref());
    params.sort_by(|a, b| a.name.cmp(&b.name));
    let opt_state = opt_specs(&params);
    let param_count: usize = params.iter().map(|s| s.shape.iter().product::<usize>()).sum();

    let batch = vec![
        batch_spec("images", &[g.batch, g.image, g.image, g.channels], DType::F32),
        batch_spec("labels", &[g.batch], DType::I32),
    ];

    let np = (g.image / g.patch) * (g.image / g.patch);
    let tok_flops: f64 =
        (0..g.n_layers).map(|i| block_flops(g.d, g.ff, enc_moe.as_ref(), i)).sum();
    let fwd = np as f64 * (2.0 * (plen * g.d) as f64 + tok_flops)
        + 2.0 * (g.d * g.classes) as f64;
    let flops = FlopsInfo {
        train_step: 3.0 * fwd * g.batch as f64,
        eval_step: fwd * g.batch as f64,
        fwd_per_example: fwd,
    };

    let sparse = enc_moe.is_some();
    ModelEntry {
        name: name.to_string(),
        family: "vit".to_string(),
        config: ModelConfig {
            family: "vit".to_string(),
            d_model: g.d,
            d_ff: g.ff,
            num_heads: 1,
            num_layers: g.n_layers,
            num_decoder_layers: 0,
            vocab_size: 0,
            enc_len: 0,
            dec_len: 0,
            image_size: g.image,
            patch_size: g.patch,
            channels: g.channels,
            num_classes: g.classes,
            batch_size: g.batch,
            enc_moe,
            dec_moe: None,
        },
        params,
        opt_state,
        batch,
        scalars: scalars(),
        metrics: metrics_for(sparse),
        param_count,
        flops,
        artifacts: native_artifacts(true),
    }
}

/// All models the native backend ships with.
pub fn native_models() -> BTreeMap<String, ModelEntry> {
    let mut models = BTreeMap::new();
    let mut add = |e: ModelEntry| {
        models.insert(e.name.clone(), e);
    };

    // -- language, tiny -----------------------------------------------------
    add(lm_entry("lm_tiny_dense", LM_TINY, None));
    add(lm_entry("lm_tiny_dense_tiled", LM_TINY_TILED, None));

    for (e, name) in [
        (2usize, "lm_tiny_moe_e2_c2"),
        (4, "lm_tiny_moe_e4_c2"),
        (8, "lm_tiny_moe_e8_c2"),
        (16, "lm_tiny_moe_e16_c2"),
    ] {
        add(lm_entry(name, LM_TINY, Some(&MoeVariant::standard(e, 2.0))));
    }
    add(lm_entry("lm_tiny_moe_e8_c1", LM_TINY, Some(&MoeVariant::standard(8, 1.0))));
    add(lm_entry("lm_tiny_moe_e8_c3", LM_TINY, Some(&MoeVariant::standard(8, 3.0))));

    for (router, bpr, name) in [
        ("top1", false, "lm_tiny_moe_e8_c2_top1"),
        ("top2", false, "lm_tiny_moe_e8_c2_top2"),
        ("top2", true, "lm_tiny_moe_e8_c2_top2bpr"),
    ] {
        let mut v = MoeVariant::standard(8, 2.0);
        v.router = router;
        v.bpr = bpr;
        // Top-k combine weights are conventionally renormalized over k.
        v.renormalize = true;
        add(lm_entry(name, LM_TINY, Some(&v)));
    }

    {
        let mut v = MoeVariant::standard(8, 2.0);
        v.renormalize = true;
        add(lm_entry("lm_tiny_moe_e8_c2_renorm", LM_TINY, Some(&v)));
    }
    for (g, name) in [(16usize, "lm_tiny_moe_e8_c2_g16"), (64, "lm_tiny_moe_e8_c2_g64")] {
        let mut v = MoeVariant::standard(8, 2.0);
        v.group_size = g;
        add(lm_entry(name, LM_TINY, Some(&v)));
    }

    // FFN-splitting targets (docs/UPCYCLING.md): every layer is MoE and the
    // expert FFN is *narrower* than the dense parent's (d_ff 32 vs LM_TINY's
    // 64, granularity 2), so `upcycle --strategy split` can column-partition
    // one wide dense FFN into several narrow experts. All layers are
    // sparsified because the native backend derives FFN width from
    // `config.d_ff`; a leftover dense MLP layer could not copy from the
    // wide parent.
    {
        let mut narrow = LM_TINY;
        narrow.ff = 32;
        let mut v = MoeVariant::standard(8, 2.0);
        v.enc_layers = vec![0, 1, 2, 3];
        v.dec_layers = vec![0, 1];
        add(lm_entry("lm_tiny_moe_split_g2e8", narrow, Some(&v)));
        v.num_experts = 4;
        add(lm_entry("lm_tiny_moe_split_g2e4", narrow, Some(&v)));
    }

    // MoE layer placement variants (encoder only; decoder stays dense).
    for (layers, name) in [
        (vec![0usize, 1], "lm_tiny_moe_first2"),
        (vec![3], "lm_tiny_moe_last1"),
        (vec![2, 3], "lm_tiny_moe_last2"),
        (vec![1, 2, 3], "lm_tiny_moe_last3"),
    ] {
        let mut v = MoeVariant::standard(8, 2.0);
        v.enc_layers = layers;
        v.dec_layers = Vec::new();
        add(lm_entry(name, LM_TINY, Some(&v)));
    }

    // -- language, small ----------------------------------------------------
    add(lm_entry("lm_small_dense", LM_SMALL, None));
    add(lm_entry("lm_small_moe_e8_c2", LM_SMALL, Some(&MoeVariant::standard(8, 2.0))));

    // -- vision -------------------------------------------------------------
    add(vit_entry("vit_tiny_dense", VIT_TINY, None));
    for (c, name) in [(1.0f64, "vit_tiny_moe_e8_c1"), (2.0, "vit_tiny_moe_e8_c2")] {
        // Vision recipe (§3.1): Expert Choice + renormalized combine weights.
        let mut v = MoeVariant::standard(8, c);
        v.renormalize = true;
        v.dec_layers = Vec::new();
        add(vit_entry(name, VIT_TINY, Some(&v)));
    }
    for (c, name) in
        [(1.0f64, "vit_tiny_moe_e8_c1_norenorm"), (2.0, "vit_tiny_moe_e8_c2_norenorm")]
    {
        let mut v = MoeVariant::standard(8, c);
        v.dec_layers = Vec::new();
        add(vit_entry(name, VIT_TINY, Some(&v)));
    }
    {
        let mut v = MoeVariant::standard(8, 2.0);
        v.router = "top2";
        v.renormalize = true;
        v.dec_layers = Vec::new();
        add(vit_entry("vit_tiny_moe_e8_c2_top2", VIT_TINY, Some(&v)));
    }

    models
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_consistent() {
        let models = native_models();
        assert!(models.len() >= 20, "zoo has {} models", models.len());
        for (name, e) in &models {
            assert_eq!(&e.name, name);
            // Params sorted + unique.
            let names: Vec<&str> = e.params.iter().map(|s| s.name.as_str()).collect();
            let mut sorted = names.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(names, sorted, "{name}: param specs must be sorted and unique");
            // Adam slots pair with params.
            assert_eq!(e.opt_state.len(), 2 * e.params.len(), "{name}");
            for (i, p) in e.params.iter().enumerate() {
                assert_eq!(e.opt_state[2 * i].name, format!("opt/{}/m", p.name));
                assert_eq!(e.opt_state[2 * i + 1].name, format!("opt/{}/v", p.name));
                assert_eq!(e.opt_state[2 * i].shape, p.shape);
            }
            assert_eq!(e.scalars, vec!["lr", "wd", "step"], "{name}");
            assert!(e.param_count > 0 && e.flops.train_step > e.flops.eval_step);
            assert!(e.artifacts.contains_key("train") && e.artifacts.contains_key("eval"));
            if e.family == "vit" {
                assert!(e.artifacts.contains_key("features"), "{name}");
            }
            // Every param has an init spec (from-scratch baselines need it).
            assert!(e.params.iter().all(|p| p.init.is_some()), "{name}");
        }
    }

    #[test]
    fn zoo_entries_validate_and_shard_for_data_parallel() {
        // Every shipped entry must pass load-time validation, and its batch
        // must shard for the data-parallel replica counts the trainer and
        // benches use (the whole zoo ships batch_size 8).
        for (name, e) in &native_models() {
            e.validate().unwrap_or_else(|err| panic!("{name}: {err}"));
            for r in [1usize, 2, 4, 8] {
                crate::parallel::MeshSpec::data_parallel_only(r)
                    .validate(
                        e,
                        crate::parallel::MeshMode::DataParallel { max_workers: Some(64) },
                    )
                    .unwrap_or_else(|err| panic!("{name} x{r} replicas: {err}"));
            }
        }
    }

    #[test]
    fn sparse_variants_expand_params_not_flops_much() {
        let models = native_models();
        let dense = &models["lm_tiny_dense"];
        let e8 = &models["lm_tiny_moe_e8_c2"];
        let e16 = &models["lm_tiny_moe_e16_c2"];
        assert!(e8.is_sparse() && !dense.is_sparse());
        assert!(e8.param_count > dense.param_count);
        assert!(e16.param_count > e8.param_count);
        assert!(e8.expert_param_count() > 0);
        assert_eq!(dense.expert_param_count(), 0);
        // Experts are ~FLOPs-neutral; capacity is not.
        let r = e16.flops.train_step / e8.flops.train_step;
        assert!(r < 1.1, "experts should be ~FLOPs-neutral, got {r}");
    }

    #[test]
    fn split_targets_are_all_moe_and_half_width() {
        let models = native_models();
        let dense = &models["lm_tiny_dense"];
        for name in ["lm_tiny_moe_split_g2e8", "lm_tiny_moe_split_g2e4"] {
            let e = &models[name];
            // Narrow experts: granularity 2 against the LM_TINY parent.
            assert_eq!(dense.config.d_ff, 2 * e.config.d_ff, "{name}");
            // Every layer sparsified: no dense MLP left to mismatch the
            // wide parent.
            assert!(
                e.params.iter().all(|s| !s.name.contains("/mlp/")),
                "{name} must not carry dense MLP layers"
            );
            assert_eq!(
                e.moe_block_tags().len(),
                e.config.num_layers + e.config.num_decoder_layers,
                "{name}"
            );
            // Each expert tensor maps onto a wide dense source.
            for s in &e.params {
                if s.name.contains("/moe/wi") {
                    let dense_name = s.name.replace("/moe/", "/mlp/");
                    let src = dense.params.iter().find(|p| p.name == dense_name).unwrap();
                    assert_eq!(src.shape[1], 2 * s.shape[2], "{name}: {dense_name}");
                }
            }
        }
    }

    #[test]
    fn surgery_geometry_matches() {
        // Every sparse tiny-LM tensor must map onto the dense parent.
        let models = native_models();
        let dense = &models["lm_tiny_dense"];
        let dense_names: Vec<&str> = dense.params.iter().map(|s| s.name.as_str()).collect();
        let sparse = &models["lm_tiny_moe_e8_c2"];
        for s in &sparse.params {
            if s.name.contains("/moe/router") {
                continue;
            }
            let expect = s.name.replace("/moe/", "/mlp/");
            assert!(
                dense_names.contains(&expect.as_str()),
                "dense parent lacks `{expect}` for `{}`",
                s.name
            );
        }
    }
}
