//! **The paper's algorithm: sparse upcycling checkpoint surgery** (Figure 1).
//!
//! Takes a dense checkpoint and a target sparse (MoE) model entry with the
//! same block geometry, and produces the warm-started sparse checkpoint:
//!
//! * every non-MoE tensor is copied across unchanged;
//! * each MoE layer's experts `.../moe/wi [E,d,f]`, `.../moe/wo [E,f,d]` are
//!   `E` identical copies of the dense layer's `.../mlp/wi`, `.../mlp/wo`
//!   (optionally perturbed with independent Gaussian noise — Appendix B.9,
//!   or randomly re-initialized — the Appendix B.5 ablation);
//! * routers `.../moe/router [d,E]` are freshly initialized N(0, 0.02);
//! * optimizer state is either carried over (vision, Appendix B.6) with the
//!   dense accumulators broadcast across experts, or zeroed (language).
//!
//! Also implements the **dense upcycling** baseline of Fig. 5: depth-tiling
//! a shallow dense checkpoint into a deeper dense model (Rae et al. 2021).

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::manifest::ModelEntry;
use crate::tensor::{numel, Tensor};
use crate::util::rng::Rng;

/// Options for the surgery; defaults reproduce the paper's standard recipe.
#[derive(Debug, Clone)]
pub struct UpcycleOptions {
    /// Copy dense MLP weights into experts (false = Appendix B.5 ablation).
    pub load_experts: bool,
    /// Stddev of independent Gaussian noise added per expert (Appendix B.9).
    pub expert_noise: f32,
    /// Router init stddev (paper §A.1.1: 0.02).
    pub router_stddev: f32,
    /// Seed for router init / noise / random experts.
    pub seed: u64,
}

impl Default for UpcycleOptions {
    fn default() -> Self {
        UpcycleOptions { load_experts: true, expert_noise: 0.0, router_stddev: 0.02, seed: 0 }
    }
}

/// Dense params → sparse params.
pub fn upcycle_params(
    dense: &Checkpoint,
    sparse: &ModelEntry,
    opts: &UpcycleOptions,
) -> Result<Checkpoint> {
    let mut rng = Rng::new(opts.seed);
    let mut out = Checkpoint::new(
        &sparse.name,
        dense.step,
        &format!("upcycled from {} @ step {}", dense.model, dense.step),
    );
    for (i, spec) in sparse.params.iter().enumerate() {
        let name = &spec.name;
        let mut sub = rng.fork(i as u64);
        let t = if name.contains("/moe/router") {
            Tensor::from_f32(&spec.shape, sub.normal_vec(numel(&spec.shape), opts.router_stddev))
        } else if name.contains("/moe/wi") || name.contains("/moe/wo") {
            if opts.load_experts {
                let dense_name = name.replace("/moe/", "/mlp/");
                let src = dense
                    .get(&dense_name)
                    .with_context(|| format!("dense parent lacks `{dense_name}`"))?;
                if opts.expert_noise > 0.0 {
                    replicate_experts_noisy(src, spec.shape[0], opts.expert_noise, &mut sub)?
                } else {
                    replicate_experts(src, spec.shape[0])?
                }
            } else {
                // Appendix B.5: random expert init, same fan-in scaling the
                // from-scratch model would use.
                let stddev = spec.init.as_ref().map(|i| i.stddev).unwrap_or(0.02);
                Tensor::from_f32(&spec.shape, sub.normal_vec(numel(&spec.shape), stddev))
            }
        } else {
            dense
                .get(name)
                .with_context(|| format!("dense parent lacks `{name}`"))?
                .clone()
        };
        if t.shape != spec.shape {
            bail!("surgery shape mismatch for `{name}`: {:?} vs {:?}", t.shape, spec.shape);
        }
        out.insert(name, t);
    }
    Ok(out)
}

/// Dense optimizer state → sparse optimizer state (Appendix B.6).
///
/// `load_optimizer=false` (the paper's language setting) zeroes everything;
/// `true` (vision) broadcasts each dense MLP accumulator across experts and
/// zeroes router state (footnote 6: routers have nothing to resume).
pub fn upcycle_opt_state(
    dense_opt: &Checkpoint,
    sparse: &ModelEntry,
    load_optimizer: bool,
) -> Result<Checkpoint> {
    let mut out = Checkpoint::new(
        &sparse.name,
        dense_opt.step,
        &format!("opt state upcycled from {} (load={load_optimizer})", dense_opt.model),
    );
    for spec in &sparse.opt_state {
        let name = &spec.name; // e.g. "opt/enc/block_01/moe/wi/vr"
        let t = if !load_optimizer || name.contains("/moe/router/") {
            Tensor::zeros(&spec.shape)
        } else if name.contains("/moe/wi/") || name.contains("/moe/wo/") {
            let dense_name = name.replace("/moe/", "/mlp/");
            let src = dense_opt
                .get(&dense_name)
                .with_context(|| format!("dense opt state lacks `{dense_name}`"))?;
            // Accumulator broadcast is a pure tiling — deterministic and
            // noise-free *by construction*: the no-noise replicate takes no
            // RNG, so no code path can ever perturb optimizer state.
            replicate_experts(src, spec.shape[0])?
        } else {
            dense_opt
                .get(name)
                .with_context(|| format!("dense opt state lacks `{name}`"))?
                .clone()
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Tile a tensor E times along a new leading axis — exact copies, no RNG.
///
/// This is the paper's default surgery (and the *only* path optimizer
/// state ever takes): taking no randomness source makes "noise-free" a
/// property of the signature rather than of a parameter value.
fn replicate_experts(src: &Tensor, e: usize) -> Result<Tensor> {
    let data = src.f32s()?;
    let mut out = Vec::with_capacity(e * data.len());
    for _ in 0..e {
        out.extend_from_slice(data);
    }
    let mut shape = vec![e];
    shape.extend_from_slice(&src.shape);
    Ok(Tensor::from_f32(&shape, out))
}

/// [`replicate_experts`] plus independent Gaussian noise on every copy
/// (Appendix B.9's expert-diversification ablation). Only parameter
/// surgery with `expert_noise > 0` comes through here.
fn replicate_experts_noisy(src: &Tensor, e: usize, noise: f32, rng: &mut Rng) -> Result<Tensor> {
    let mut t = replicate_experts(src, e)?;
    if noise > 0.0 {
        for x in t.f32s_mut()? {
            *x += rng.normal() * noise;
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Dense upcycling baseline (Fig. 5): depth tiling.
// ---------------------------------------------------------------------------

/// Map new block index → source block index (order-preserving contiguous
/// tiling, the Gopher pattern).
pub fn tile_source_block(new_idx: usize, n_new: usize, n_old: usize) -> usize {
    new_idx * n_old / n_new
}

/// Warm-start a deeper dense model from a shallower dense checkpoint.
pub fn depth_tile_params(
    dense: &Checkpoint,
    dense_entry: &ModelEntry,
    tiled_entry: &ModelEntry,
) -> Result<Checkpoint> {
    let mut out = Checkpoint::new(
        &tiled_entry.name,
        dense.step,
        &format!("depth-tiled from {} @ step {}", dense.model, dense.step),
    );
    for spec in &tiled_entry.params {
        let name = &spec.name;
        let t = if let Some((tower, block, rest)) = split_block_name(name) {
            let (n_new, n_old) = if tower == "enc" {
                (tiled_entry.config.num_layers, dense_entry.config.num_layers)
            } else {
                (tiled_entry.config.num_decoder_layers, dense_entry.config.num_decoder_layers)
            };
            let src = tile_source_block(block, n_new, n_old);
            let src_name = format!("{tower}/block_{src:02}/{rest}");
            dense
                .get(&src_name)
                .with_context(|| format!("tiling source `{src_name}` missing"))?
                .clone()
        } else {
            dense.get(name)?.clone()
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// "enc/block_03/attn/wq" → ("enc", 3, "attn/wq")
fn split_block_name(name: &str) -> Option<(&str, usize, &str)> {
    let (tower, rest) = name.split_once("/block_")?;
    let (num, tail) = rest.split_once('/')?;
    Some((tower, num.parse().ok()?, tail))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicate_is_exact_copies() {
        let src = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = replicate_experts(&src, 4).unwrap();
        assert_eq!(t.shape, vec![4, 2, 3]);
        let d = t.f32s().unwrap();
        for e in 0..4 {
            assert_eq!(&d[e * 6..(e + 1) * 6], src.f32s().unwrap());
        }
    }

    #[test]
    fn replicate_noise_diversifies() {
        let src = Tensor::from_f32(&[8], vec![0.0; 8]);
        let t = replicate_experts_noisy(&src, 2, 0.1, &mut Rng::new(1)).unwrap();
        let d = t.f32s().unwrap();
        assert_ne!(&d[0..8], &d[8..16], "noise must differ per expert");
        assert!(d.iter().all(|x| x.abs() < 1.0));
        // noise = 0 through the noisy path degrades to exact copies.
        let z = replicate_experts_noisy(&src, 2, 0.0, &mut Rng::new(1)).unwrap();
        assert_eq!(z.f32s().unwrap(), &vec![0.0; 16][..]);
    }

    #[test]
    fn tiling_pattern_is_order_preserving() {
        // 4 → 6 blocks: [0,0,1,2,2,3] with i*4/6.
        let got: Vec<usize> = (0..6).map(|i| tile_source_block(i, 6, 4)).collect();
        assert_eq!(got, vec![0, 0, 1, 2, 2, 3]);
        // Identity when sizes match.
        for i in 0..5 {
            assert_eq!(tile_source_block(i, 5, 5), i);
        }
        // Monotone non-decreasing, covers all source blocks.
        let got: Vec<usize> = (0..12).map(|i| tile_source_block(i, 12, 4)).collect();
        assert!(got.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(got.iter().copied().max(), Some(3));
        assert_eq!(got[0], 0);
    }

    #[test]
    fn split_block_name_works() {
        assert_eq!(
            split_block_name("enc/block_03/attn/wq"),
            Some(("enc", 3, "attn/wq"))
        );
        assert_eq!(
            split_block_name("dec/block_11/moe/wi"),
            Some(("dec", 11, "moe/wi"))
        );
        assert_eq!(split_block_name("token_embed"), None);
    }
}
