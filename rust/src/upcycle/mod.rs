//! **The paper's algorithm: sparse upcycling checkpoint surgery** (Figure 1),
//! generalized into a strategy zoo.
//!
//! The paper's recipe takes a dense checkpoint and a target sparse (MoE)
//! model entry with the same block geometry, and produces the warm-started
//! sparse checkpoint:
//!
//! * every non-MoE tensor is copied across unchanged;
//! * each MoE layer's experts `.../moe/wi [E,d,f]`, `.../moe/wo [E,f,d]` are
//!   `E` identical copies of the dense layer's `.../mlp/wi`, `.../mlp/wo`
//!   (optionally perturbed with independent Gaussian noise — Appendix B.9,
//!   or randomly re-initialized — the Appendix B.5 ablation);
//! * routers `.../moe/router [d,E]` are freshly initialized N(0, 0.02);
//! * optimizer state is either carried over (vision, Appendix B.6) with the
//!   dense accumulators broadcast across experts, or zeroed (language).
//!
//! That recipe is [`UpcycleStrategy::Replicate`], and it is guaranteed
//! bitwise-identical to the pre-strategy surgery (pinned by
//! `tests/upcycle_props.rs`). The related-work strategies share the same
//! seam (see `docs/UPCYCLING.md` for the full contract):
//!
//! * [`UpcycleStrategy::DropUpcycle`] — partial re-initialization of each
//!   expert's FFN intermediate units (Drop-Upcycling, arXiv:2502.19261);
//!   inter-expert diversity is measured by [`diversity`].
//! * [`UpcycleStrategy::Split`] — one wide dense FFN column-partitioned
//!   into several narrower experts (granularity/expansion, after the
//!   levanter `upcycle_lm.py` exemplar and "Llama 3 Meets MoE").
//! * [`UpcycleStrategy::MultiCheckpoint`] — experts round-robined across
//!   several dense SUPC bundles, shared non-FFN params averaged or taken
//!   from the designated primary.
//!
//! Router init is an orthogonal axis ([`RouterInit`]): plain Gaussian, or
//! virtual-group tiling where experts in a group share a router column.
//!
//! Also implements the **dense upcycling** baseline of Fig. 5: depth-tiling
//! a shallow dense checkpoint into a deeper dense model (Rae et al. 2021).

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::manifest::{ModelEntry, TensorSpec};
use crate::tensor::{numel, Tensor};
use crate::util::cli::Args;
use crate::util::rng::Rng;

pub mod diversity;

/// How shared (non-FFN, non-router) parameters are combined under
/// [`UpcycleStrategy::MultiCheckpoint`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedInit {
    /// Take every shared tensor from the primary source (`--dense`).
    Primary,
    /// Elementwise mean over all sources (primary + `checkpoint_paths`).
    Average,
}

/// Router initialization — orthogonal to the expert strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterInit {
    /// Fresh N(0, `router_stddev`) per logit column (paper §A.1.1).
    #[default]
    Normal,
    /// Virtual-group init ("Llama 3 Meets MoE"): draw `groups` base router
    /// columns and tile them, so the `E/groups` experts of each group start
    /// with bitwise-identical routing logits.
    VirtualGroups { groups: usize },
}

/// The expert-construction strategy consumed by [`upcycle_params`] and
/// [`upcycle_opt_state`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum UpcycleStrategy {
    /// The paper's surgery: every expert an exact copy of the dense FFN.
    #[default]
    Replicate,
    /// Drop-Upcycling: replicate, then re-initialize a `reinit_fraction`
    /// of each expert's FFN intermediate units with a seeded RNG. The
    /// dropped unit set is sampled per (layer, expert) — shared between
    /// `wi` columns and `wo` rows so each re-initialized unit is reset
    /// end-to-end — and `reinit_fraction = 0` degrades to [`Self::Replicate`]
    /// bitwise.
    DropUpcycle { reinit_fraction: f32, seed: u64 },
    /// FFN splitting: the dense FFN's `F` intermediate units are cut into
    /// `granularity` contiguous column blocks of width `F/granularity`;
    /// expert `e` takes block `e % granularity`, so the `E = granularity *
    /// expansion` experts cover every block `expansion` times.
    /// `granularity = 1` degrades to [`Self::Replicate`] bitwise.
    Split { granularity: usize, expansion: usize },
    /// Upcycle several dense SUPC bundles into one MoE: expert `e` copies
    /// its FFN from source `e % S` (source 0 is the `--dense` primary,
    /// sources 1.. are `checkpoint_paths` in order, `S` sources total);
    /// shared non-FFN tensors follow `shared`.
    MultiCheckpoint { checkpoint_paths: Vec<String>, shared: SharedInit },
}

impl UpcycleStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            UpcycleStrategy::Replicate => "replicate",
            UpcycleStrategy::DropUpcycle { .. } => "drop-upcycle",
            UpcycleStrategy::Split { .. } => "split",
            UpcycleStrategy::MultiCheckpoint { .. } => "multi-checkpoint",
        }
    }

    /// Fail-fast structural validation against the target entry: every
    /// violation is a named error raised before any tensor is touched.
    pub fn validate(&self, sparse: &ModelEntry) -> Result<()> {
        match self {
            UpcycleStrategy::Replicate => {}
            UpcycleStrategy::DropUpcycle { reinit_fraction, .. } => {
                if !reinit_fraction.is_finite() || !(0.0..=1.0).contains(reinit_fraction) {
                    bail!(
                        "drop-upcycle reinit_fraction must be in [0, 1], got {reinit_fraction}"
                    );
                }
            }
            UpcycleStrategy::Split { granularity, expansion } => {
                if *granularity == 0 || *expansion == 0 {
                    bail!("split granularity and expansion must be >= 1");
                }
                for (tag, moe) in sparse.moe_block_tags() {
                    if moe.num_experts != granularity * expansion {
                        bail!(
                            "split surgery needs num_experts = granularity * expansion, \
                             but `{tag}` has {} experts != {granularity} * {expansion}",
                            moe.num_experts
                        );
                    }
                }
            }
            UpcycleStrategy::MultiCheckpoint { checkpoint_paths, shared: _ } => {
                if checkpoint_paths.is_empty() {
                    bail!(
                        "multi-checkpoint surgery needs at least one extra source in \
                         checkpoint_paths (the --dense primary is source 0)"
                    );
                }
                for (i, p) in checkpoint_paths.iter().enumerate() {
                    if p.trim().is_empty() {
                        bail!("multi-checkpoint source #{} is an empty path", i + 1);
                    }
                    if checkpoint_paths[..i].contains(p) {
                        bail!("multi-checkpoint sources list `{p}` twice");
                    }
                }
                let sources = 1 + checkpoint_paths.len();
                for (tag, moe) in sparse.moe_block_tags() {
                    if moe.num_experts % sources != 0 {
                        bail!(
                            "multi-checkpoint surgery round-robins experts over sources, \
                             but `{tag}` has {} experts which is not divisible by \
                             {sources} source(s)",
                            moe.num_experts
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

impl RouterInit {
    pub fn validate(&self, sparse: &ModelEntry) -> Result<()> {
        if let RouterInit::VirtualGroups { groups } = self {
            if *groups == 0 {
                bail!("virtual-group router init needs groups >= 1");
            }
            for (tag, moe) in sparse.moe_block_tags() {
                if moe.num_experts % groups != 0 {
                    bail!(
                        "virtual-group router init needs num_experts divisible by groups, \
                         but `{tag}` has {} experts and {groups} group(s)",
                        moe.num_experts
                    );
                }
            }
        }
        Ok(())
    }
}

/// Options for the surgery; defaults reproduce the paper's standard recipe.
#[derive(Debug, Clone)]
pub struct UpcycleOptions {
    /// Expert-construction strategy (default: the paper's replication).
    pub strategy: UpcycleStrategy,
    /// Router init (default: fresh Gaussian).
    pub router_init: RouterInit,
    /// Copy dense MLP weights into experts (false = Appendix B.5 ablation).
    pub load_experts: bool,
    /// Stddev of independent Gaussian noise added per expert (Appendix B.9).
    pub expert_noise: f32,
    /// Router init stddev (paper §A.1.1: 0.02).
    pub router_stddev: f32,
    /// Seed for router init / noise / random experts.
    pub seed: u64,
}

impl Default for UpcycleOptions {
    fn default() -> Self {
        UpcycleOptions {
            strategy: UpcycleStrategy::Replicate,
            router_init: RouterInit::Normal,
            load_experts: true,
            expert_noise: 0.0,
            router_stddev: 0.02,
            seed: 0,
        }
    }
}

/// One loaded surgery source with the label used in error messages.
struct Source<'a> {
    label: String,
    ck: &'a Checkpoint,
}

/// Dense params → sparse params.
pub fn upcycle_params(
    dense: &Checkpoint,
    sparse: &ModelEntry,
    opts: &UpcycleOptions,
) -> Result<Checkpoint> {
    opts.strategy.validate(sparse)?;
    opts.router_init.validate(sparse)?;
    // Multi-checkpoint sources are loaded up front through the hardened
    // SUPC loader: a corrupt bundle fails here, with its path named,
    // before any surgery output exists.
    let extra: Vec<(String, Checkpoint)> = match &opts.strategy {
        UpcycleStrategy::MultiCheckpoint { checkpoint_paths, .. } => checkpoint_paths
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Checkpoint::load(p)
                    .with_context(|| format!("loading multi-checkpoint source #{} `{p}`", i + 1))
                    .map(|ck| (p.clone(), ck))
            })
            .collect::<Result<_>>()?,
        _ => Vec::new(),
    };
    let mut sources = vec![Source { label: "primary (--dense)".to_string(), ck: dense }];
    for (i, (path, ck)) in extra.iter().enumerate() {
        sources.push(Source { label: format!("source #{} (`{path}`)", i + 1), ck });
    }

    let mut rng = Rng::new(opts.seed);
    let mut out = Checkpoint::new(
        &sparse.name,
        dense.step,
        &format!("upcycled from {} @ step {} ({})", dense.model, dense.step, opts.strategy.name()),
    );
    for (i, spec) in sparse.params.iter().enumerate() {
        let name = &spec.name;
        // One forked stream per spec index, consumed in the same order as
        // the pre-strategy surgery: this is what keeps `Replicate` (and the
        // degenerate Drop/Split cases) bitwise-unchanged.
        let mut sub = rng.fork(i as u64);
        let t = if name.contains("/moe/router") {
            init_router(spec, opts, &mut sub)
        } else if name.contains("/moe/wi") || name.contains("/moe/wo") {
            if opts.load_experts {
                build_experts(spec, &sources, opts, &mut sub)?
            } else {
                // Appendix B.5: random expert init, same fan-in scaling the
                // from-scratch model would use.
                let stddev = spec.init.as_ref().map(|i| i.stddev).unwrap_or(0.02);
                Tensor::from_f32(&spec.shape, sub.normal_vec(numel(&spec.shape), stddev))
            }
        } else {
            shared_param(spec, &sources, &opts.strategy)?
        };
        if t.shape != spec.shape {
            bail!("surgery shape mismatch for `{name}`: {:?} vs {:?}", t.shape, spec.shape);
        }
        out.insert(name, t);
    }
    Ok(out)
}

/// Router tensor `[d, E]` under the selected [`RouterInit`].
fn init_router(spec: &TensorSpec, opts: &UpcycleOptions, sub: &mut Rng) -> Tensor {
    match opts.router_init {
        RouterInit::Normal => {
            Tensor::from_f32(&spec.shape, sub.normal_vec(numel(&spec.shape), opts.router_stddev))
        }
        RouterInit::VirtualGroups { groups } => {
            let (d, e) = (spec.shape[0], spec.shape[1]);
            let per = e / groups; // divisibility validated up front
            let base = sub.normal_vec(d * groups, opts.router_stddev);
            let mut data = vec![0.0f32; d * e];
            for r in 0..d {
                for x in 0..e {
                    data[r * e + x] = base[r * groups + x / per];
                }
            }
            Tensor::from_f32(&spec.shape, data)
        }
    }
}

/// Expert weight tensor (`wi [E,d,f]` or `wo [E,f,d]`) under the selected
/// strategy, plus the optional Appendix B.9 diversification noise.
fn build_experts(
    spec: &TensorSpec,
    sources: &[Source<'_>],
    opts: &UpcycleOptions,
    sub: &mut Rng,
) -> Result<Tensor> {
    let dense_name = spec.name.replace("/moe/", "/mlp/");
    let e = spec.shape[0];
    let mut t = match &opts.strategy {
        UpcycleStrategy::Replicate | UpcycleStrategy::DropUpcycle { .. } => {
            let src = dense_source(&sources[0], &dense_name)?;
            replicate_experts(src, e)?
        }
        UpcycleStrategy::Split { granularity, expansion } => {
            let src = dense_source(&sources[0], &dense_name)?;
            split_experts(src, spec, *granularity, *expansion)?
        }
        UpcycleStrategy::MultiCheckpoint { .. } => {
            let expect = dense_source(&sources[0], &dense_name)?.shape.clone();
            let mut data = Vec::with_capacity(e * numel(&expect));
            for x in 0..e {
                let s = &sources[x % sources.len()];
                let src = dense_source(s, &dense_name)?;
                if src.shape != expect {
                    bail!(
                        "multi-checkpoint architecture mismatch: {} has `{dense_name}` \
                         {:?} but the primary has {:?}",
                        s.label,
                        src.shape,
                        expect
                    );
                }
                data.extend_from_slice(src.f32s()?);
            }
            let mut shape = vec![e];
            shape.extend_from_slice(&expect);
            Tensor::from_f32(&shape, data)
        }
    };
    // Appendix B.9 noise consumes the per-spec stream exactly as the
    // pre-strategy surgery did (noise == 0 never touches the RNG).
    if opts.expert_noise > 0.0 {
        for x in t.f32s_mut()? {
            *x += sub.normal() * opts.expert_noise;
        }
    }
    // Drop-Upcycling re-init comes *after* noise so `reinit_fraction = 0`
    // matches Replicate bitwise for every (noise, seed) combination.
    if let UpcycleStrategy::DropUpcycle { reinit_fraction, seed } = opts.strategy {
        apply_drop_reinit(&mut t, spec, reinit_fraction, seed)?;
    }
    Ok(t)
}

fn dense_source<'a>(s: &'a Source<'_>, dense_name: &str) -> Result<&'a Tensor> {
    s.ck
        .get(dense_name)
        .with_context(|| format!("upcycle {} lacks `{dense_name}`", s.label))
}

/// Shared (non-MoE) tensor: cloned from the primary, or averaged across all
/// sources under `MultiCheckpoint { shared: Average }`.
fn shared_param(
    spec: &TensorSpec,
    sources: &[Source<'_>],
    strategy: &UpcycleStrategy,
) -> Result<Tensor> {
    let name = &spec.name;
    let primary = sources[0]
        .ck
        .get(name)
        .with_context(|| format!("dense parent lacks `{name}`"))?;
    let average = matches!(
        strategy,
        UpcycleStrategy::MultiCheckpoint { shared: SharedInit::Average, .. }
    );
    if !average || sources.len() == 1 {
        // MultiCheckpoint{Primary} deliberately shares this exact-clone path.
        return Ok(primary.clone());
    }
    let mut acc: Vec<f64> = primary.f32s()?.iter().map(|&x| x as f64).collect();
    for s in &sources[1..] {
        let t = s
            .ck
            .get(name)
            .with_context(|| format!("multi-checkpoint {} lacks `{name}`", s.label))?;
        if t.shape != primary.shape {
            bail!(
                "multi-checkpoint architecture mismatch: {} has `{name}` {:?} but the \
                 primary has {:?}",
                s.label,
                t.shape,
                primary.shape
            );
        }
        for (a, &x) in acc.iter_mut().zip(t.f32s()?) {
            *a += x as f64;
        }
    }
    let n = sources.len() as f64;
    Ok(Tensor::from_f32(&spec.shape, acc.into_iter().map(|x| (x / n) as f32).collect()))
}

/// Dense optimizer state → sparse optimizer state (Appendix B.6), under the
/// same strategy as the parameter surgery.
///
/// `load_optimizer=false` (the paper's language setting) zeroes everything;
/// `true` (vision) carries accumulators over per strategy and zeroes router
/// state (footnote 6: routers have nothing to resume):
///
/// * `Replicate` — dense MLP accumulators broadcast across experts
///   (bitwise-unchanged vs the pre-strategy surgery);
/// * `DropUpcycle` — broadcast, then the re-initialized units' accumulators
///   are zeroed (a fresh weight has nothing to resume), using the *same*
///   seeded unit masks as the parameter surgery;
/// * `Split` — accumulators column-partitioned exactly like the weights;
/// * `MultiCheckpoint` — expert accumulators zeroed (the extra sources'
///   optimizer bundles are not part of the surgery input), shared tensors
///   taken from the primary.
pub fn upcycle_opt_state(
    dense_opt: &Checkpoint,
    sparse: &ModelEntry,
    load_optimizer: bool,
    strategy: &UpcycleStrategy,
) -> Result<Checkpoint> {
    strategy.validate(sparse)?;
    let mut out = Checkpoint::new(
        &sparse.name,
        dense_opt.step,
        &format!(
            "opt state upcycled from {} (load={load_optimizer}, {})",
            dense_opt.model,
            strategy.name()
        ),
    );
    for spec in &sparse.opt_state {
        let name = &spec.name; // e.g. "opt/enc/block_01/moe/wi/m"
        let t = if !load_optimizer || name.contains("/moe/router/") {
            Tensor::zeros(&spec.shape)
        } else if name.contains("/moe/wi/") || name.contains("/moe/wo/") {
            match strategy {
                UpcycleStrategy::MultiCheckpoint { .. } => Tensor::zeros(&spec.shape),
                UpcycleStrategy::Split { granularity, expansion } => {
                    let dense_name = name.replace("/moe/", "/mlp/");
                    let src = dense_opt
                        .get(&dense_name)
                        .with_context(|| format!("dense opt state lacks `{dense_name}`"))?;
                    split_experts(src, spec, *granularity, *expansion)?
                }
                UpcycleStrategy::Replicate | UpcycleStrategy::DropUpcycle { .. } => {
                    let dense_name = name.replace("/moe/", "/mlp/");
                    let src = dense_opt
                        .get(&dense_name)
                        .with_context(|| format!("dense opt state lacks `{dense_name}`"))?;
                    // Accumulator broadcast is a pure tiling — deterministic
                    // and noise-free *by construction*: the no-noise
                    // replicate takes no RNG, so no code path can ever
                    // perturb optimizer state. The drop masks below are a
                    // pure function of (seed, layer, expert), not a stream.
                    let mut t = replicate_experts(src, spec.shape[0])?;
                    if let UpcycleStrategy::DropUpcycle { reinit_fraction, seed } = strategy {
                        zero_dropped_units(&mut t, spec, *reinit_fraction, *seed)?;
                    }
                    t
                }
            }
        } else {
            dense_opt
                .get(name)
                .with_context(|| format!("dense opt state lacks `{name}`"))?
                .clone()
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Tile a tensor E times along a new leading axis — exact copies, no RNG.
///
/// This is the paper's default surgery (and the *only* path optimizer
/// state ever takes): taking no randomness source makes "noise-free" a
/// property of the signature rather than of a parameter value.
pub(crate) fn replicate_experts(src: &Tensor, e: usize) -> Result<Tensor> {
    let data = src.f32s()?;
    let mut out = Vec::with_capacity(e * data.len());
    for _ in 0..e {
        out.extend_from_slice(data);
    }
    let mut shape = vec![e];
    shape.extend_from_slice(&src.shape);
    Ok(Tensor::from_f32(&shape, out))
}

/// [`replicate_experts`] plus independent Gaussian noise on every copy
/// (Appendix B.9's expert-diversification ablation). Parameter surgery
/// routes through [`build_experts`]; kept as the unit-test reference.
#[allow(dead_code)]
fn replicate_experts_noisy(src: &Tensor, e: usize, noise: f32, rng: &mut Rng) -> Result<Tensor> {
    let mut t = replicate_experts(src, e)?;
    if noise > 0.0 {
        for x in t.f32s_mut()? {
            *x += rng.normal() * noise;
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// FFN splitting.
// ---------------------------------------------------------------------------

/// Column-partition one wide dense FFN tensor into `E` narrower experts.
///
/// `wi` sources are `[d, F]` sliced along columns into `[E, d, f]`;
/// `wo` sources are `[F, d]` sliced along rows into `[E, f, d]`; expert `e`
/// takes contiguous block `e % granularity`, so `granularity = 1` is a
/// bitwise replicate. Divisibility is fail-fast, mirroring the levanter
/// exemplar's `ValueError`s.
fn split_experts(
    src: &Tensor,
    spec: &TensorSpec,
    granularity: usize,
    expansion: usize,
) -> Result<Tensor> {
    let name = &spec.name;
    let e = spec.shape[0];
    if e != granularity * expansion {
        bail!(
            "split surgery for `{name}`: num_experts {e} != granularity {granularity} * \
             expansion {expansion}"
        );
    }
    let is_wi = name.contains("/moe/wi");
    // Intermediate (FFN) width of the dense source vs one expert.
    let big_f = if is_wi { src.shape[1] } else { src.shape[0] };
    let f = if is_wi { spec.shape[2] } else { spec.shape[1] };
    if f == 0 || big_f % f != 0 {
        bail!(
            "split surgery for `{name}`: dense d_ff {big_f} is not divisible by expert \
             d_ff {f}"
        );
    }
    if big_f / f != granularity {
        bail!(
            "split surgery for `{name}`: granularity {granularity} does not match dense \
             d_ff {big_f} / expert d_ff {f} = {}",
            big_f / f
        );
    }
    let data = src.f32s()?;
    let mut out = Vec::with_capacity(e * numel(&spec.shape[1..]));
    for x in 0..e {
        let p = x % granularity;
        if is_wi {
            // src [d, F]: columns p*f .. (p+1)*f of every row.
            let d = src.shape[0];
            for r in 0..d {
                out.extend_from_slice(&data[r * big_f + p * f..r * big_f + (p + 1) * f]);
            }
        } else {
            // src [F, d]: rows p*f .. (p+1)*f, contiguous.
            let d = src.shape[1];
            out.extend_from_slice(&data[p * f * d..(p + 1) * f * d]);
        }
    }
    Ok(Tensor::from_f32(&spec.shape, out))
}

// ---------------------------------------------------------------------------
// Drop-Upcycling.
// ---------------------------------------------------------------------------

const DROP_MASK_STREAM: u64 = 0x5eed_0000_0000_0001;
const DROP_VALUE_STREAM: u64 = 0x5eed_0000_0000_0002;

fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The MoE block tag of a param or opt-slot name:
/// `enc/block_01/moe/wi` and `opt/enc/block_01/moe/wi/m` both map to
/// `enc/block_01`, so weights and their accumulators share one unit mask.
fn moe_block_tag(name: &str) -> &str {
    let name = name.strip_prefix("opt/").unwrap_or(name);
    name.split("/moe/").next().unwrap_or(name)
}

/// The dropped FFN intermediate units of one (layer, expert): a pure,
/// sorted function of `(seed, layer tag, expert, f, fraction)` — stream-
/// independent so params and optimizer state always agree.
fn dropped_units(seed: u64, tag: &str, expert: usize, f: usize, fraction: f32) -> Vec<usize> {
    let k = drop_reinit_units(f, fraction);
    if k == 0 {
        return Vec::new();
    }
    let mut rng = Rng::with_stream(seed ^ DROP_MASK_STREAM, fnv1a64(tag)).fork(expert as u64);
    let mut units = rng.choose_k(f, k);
    units.sort_unstable();
    units
}

/// How many FFN intermediate units Drop-Upcycling re-initializes per
/// expert at width `f` — the single definition shared with
/// [`crate::costmodel::surgery_cost`] so priced and performed surgery
/// can never disagree.
pub fn drop_reinit_units(f: usize, fraction: f32) -> usize {
    ((fraction as f64 * f as f64).round() as usize).min(f)
}

/// FFN geometry of one expert tensor: `(experts, rows, cols, f, is_wi)`.
fn expert_geom(spec: &TensorSpec) -> (usize, usize, usize, usize, bool) {
    let (e, a, b) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let is_wi = spec.name.contains("/moe/wi");
    let f = if is_wi { b } else { a };
    (e, a, b, f, is_wi)
}

/// Re-initialize the dropped units of each expert with fresh fan-in values
/// (`wi` columns and `wo` rows of the same unit are both reset).
fn apply_drop_reinit(t: &mut Tensor, spec: &TensorSpec, fraction: f32, seed: u64) -> Result<()> {
    let (e, a, b, f, is_wi) = expert_geom(spec);
    let stddev = spec.init.as_ref().map(|i| i.stddev).unwrap_or(0.02);
    let tag = moe_block_tag(&spec.name).to_string();
    let data = t.f32s_mut()?;
    for x in 0..e {
        let units = dropped_units(seed, &tag, x, f, fraction);
        if units.is_empty() {
            continue;
        }
        let mut vrng =
            Rng::with_stream(seed ^ DROP_VALUE_STREAM, fnv1a64(&spec.name)).fork(x as u64);
        for &j in &units {
            if is_wi {
                for r in 0..a {
                    data[x * a * b + r * b + j] = vrng.normal() * stddev;
                }
            } else {
                for c in 0..b {
                    data[x * a * b + j * b + c] = vrng.normal() * stddev;
                }
            }
        }
    }
    Ok(())
}

/// Zero the optimizer accumulators of the dropped units (same masks as
/// [`apply_drop_reinit`]; a freshly re-initialized weight has no momentum
/// to resume).
fn zero_dropped_units(t: &mut Tensor, spec: &TensorSpec, fraction: f32, seed: u64) -> Result<()> {
    let (e, a, b, f, is_wi) = expert_geom(spec);
    let tag = moe_block_tag(&spec.name).to_string();
    let data = t.f32s_mut()?;
    for x in 0..e {
        for &j in &dropped_units(seed, &tag, x, f, fraction) {
            if is_wi {
                for r in 0..a {
                    data[x * a * b + r * b + j] = 0.0;
                }
            } else {
                for c in 0..b {
                    data[x * a * b + j * b + c] = 0.0;
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// CLI flag parsing (fail-fast, mirroring `--inject-fault`'s style).
// ---------------------------------------------------------------------------

/// Build the strategy from `upcycle upcycle` flags. Unknown strategy names,
/// out-of-range fractions, zero granularity/expansion, empty or duplicate
/// checkpoint lists, and flags that belong to a *different* strategy are
/// all named errors raised before any checkpoint is read.
pub fn strategy_from_args(a: &Args, default_seed: u64) -> Result<UpcycleStrategy> {
    let name = a.str("strategy", "replicate");
    let check_foreign = |strategy: &str, foreign: &[&str]| -> Result<()> {
        for fl in foreign {
            if a.flags.contains_key(*fl) {
                bail!("--{fl} only applies to --strategy {strategy}; got --strategy {name}");
            }
        }
        Ok(())
    };
    match name.as_str() {
        "replicate" => {
            check_foreign("drop", &["reinit-fraction"])?;
            check_foreign("split", &["granularity", "expansion"])?;
            check_foreign("multi", &["checkpoints", "shared"])?;
            Ok(UpcycleStrategy::Replicate)
        }
        "drop" | "drop-upcycle" => {
            check_foreign("split", &["granularity", "expansion"])?;
            check_foreign("multi", &["checkpoints", "shared"])?;
            let reinit_fraction = a.f64("reinit-fraction", 0.5)? as f32;
            let s = UpcycleStrategy::DropUpcycle {
                reinit_fraction,
                seed: a.u64("strategy-seed", default_seed)?,
            };
            if !reinit_fraction.is_finite() || !(0.0..=1.0).contains(&reinit_fraction) {
                bail!("--reinit-fraction must be in [0, 1], got {reinit_fraction}");
            }
            Ok(s)
        }
        "split" => {
            check_foreign("drop", &["reinit-fraction"])?;
            check_foreign("multi", &["checkpoints", "shared"])?;
            let granularity = a.usize("granularity", 0)?;
            let expansion = a.usize("expansion", 0)?;
            if granularity == 0 || expansion == 0 {
                bail!(
                    "--strategy split requires --granularity G and --expansion X (both >= 1); \
                     num_experts must equal G * X"
                );
            }
            Ok(UpcycleStrategy::Split { granularity, expansion })
        }
        "multi" | "multi-checkpoint" => {
            check_foreign("drop", &["reinit-fraction"])?;
            check_foreign("split", &["granularity", "expansion"])?;
            let list = a.req("checkpoints").map_err(|_| {
                anyhow::anyhow!(
                    "--strategy multi requires --checkpoints p1,p2,... (extra dense SUPC \
                     bundles; --dense stays the primary source)"
                )
            })?;
            let checkpoint_paths: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let shared = match a.str("shared", "primary").as_str() {
                "primary" => SharedInit::Primary,
                "average" => SharedInit::Average,
                other => bail!("--shared must be `primary` or `average`, got `{other}`"),
            };
            if checkpoint_paths.is_empty() {
                bail!("--checkpoints is empty; give at least one extra dense SUPC bundle");
            }
            for (i, p) in checkpoint_paths.iter().enumerate() {
                if checkpoint_paths[..i].contains(p) {
                    bail!("--checkpoints lists `{p}` twice");
                }
            }
            Ok(UpcycleStrategy::MultiCheckpoint { checkpoint_paths, shared })
        }
        other => bail!(
            "unknown --strategy `{other}`; use replicate | drop | split | multi \
             (see docs/UPCYCLING.md)"
        ),
    }
}

/// Build the router init from `upcycle upcycle` flags.
pub fn router_init_from_args(a: &Args) -> Result<RouterInit> {
    match a.str("router-init", "normal").as_str() {
        "normal" => {
            if a.flags.contains_key("router-groups") {
                bail!("--router-groups only applies to --router-init virtual-groups");
            }
            Ok(RouterInit::Normal)
        }
        "virtual-groups" | "virtual-group" => {
            let groups = a.usize("router-groups", 0)?;
            if groups == 0 {
                bail!("--router-init virtual-groups requires --router-groups N (>= 1)");
            }
            Ok(RouterInit::VirtualGroups { groups })
        }
        other => bail!("unknown --router-init `{other}`; use normal | virtual-groups"),
    }
}

// ---------------------------------------------------------------------------
// Dense upcycling baseline (Fig. 5): depth tiling.
// ---------------------------------------------------------------------------

/// Map new block index → source block index (order-preserving contiguous
/// tiling, the Gopher pattern).
pub fn tile_source_block(new_idx: usize, n_new: usize, n_old: usize) -> usize {
    new_idx * n_old / n_new
}

/// Warm-start a deeper dense model from a shallower dense checkpoint.
pub fn depth_tile_params(
    dense: &Checkpoint,
    dense_entry: &ModelEntry,
    tiled_entry: &ModelEntry,
) -> Result<Checkpoint> {
    let mut out = Checkpoint::new(
        &tiled_entry.name,
        dense.step,
        &format!("depth-tiled from {} @ step {}", dense.model, dense.step),
    );
    for spec in &tiled_entry.params {
        let name = &spec.name;
        let t = if let Some((tower, block, rest)) = split_block_name(name) {
            let (n_new, n_old) = if tower == "enc" {
                (tiled_entry.config.num_layers, dense_entry.config.num_layers)
            } else {
                (tiled_entry.config.num_decoder_layers, dense_entry.config.num_decoder_layers)
            };
            let src = tile_source_block(block, n_new, n_old);
            let src_name = format!("{tower}/block_{src:02}/{rest}");
            dense
                .get(&src_name)
                .with_context(|| format!("tiling source `{src_name}` missing"))?
                .clone()
        } else {
            dense.get(name)?.clone()
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// "enc/block_03/attn/wq" → ("enc", 3, "attn/wq")
fn split_block_name(name: &str) -> Option<(&str, usize, &str)> {
    let (tower, rest) = name.split_once("/block_")?;
    let (num, tail) = rest.split_once('/')?;
    Some((tower, num.parse().ok()?, tail))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicate_is_exact_copies() {
        let src = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = replicate_experts(&src, 4).unwrap();
        assert_eq!(t.shape, vec![4, 2, 3]);
        let d = t.f32s().unwrap();
        for e in 0..4 {
            assert_eq!(&d[e * 6..(e + 1) * 6], src.f32s().unwrap());
        }
    }

    #[test]
    fn replicate_noise_diversifies() {
        let src = Tensor::from_f32(&[8], vec![0.0; 8]);
        let t = replicate_experts_noisy(&src, 2, 0.1, &mut Rng::new(1)).unwrap();
        let d = t.f32s().unwrap();
        assert_ne!(&d[0..8], &d[8..16], "noise must differ per expert");
        assert!(d.iter().all(|x| x.abs() < 1.0));
        // noise = 0 through the noisy path degrades to exact copies.
        let z = replicate_experts_noisy(&src, 2, 0.0, &mut Rng::new(1)).unwrap();
        assert_eq!(z.f32s().unwrap(), &vec![0.0; 16][..]);
    }

    fn wi_spec(e: usize, d: usize, f: usize) -> TensorSpec {
        TensorSpec {
            name: "enc/block_01/moe/wi".to_string(),
            shape: vec![e, d, f],
            dtype: crate::tensor::DType::F32,
            init: Some(crate::manifest::InitSpec { kind: "fan_in".to_string(), stddev: 0.1 }),
        }
    }

    fn wo_spec(e: usize, f: usize, d: usize) -> TensorSpec {
        TensorSpec {
            name: "enc/block_01/moe/wo".to_string(),
            shape: vec![e, f, d],
            dtype: crate::tensor::DType::F32,
            init: Some(crate::manifest::InitSpec { kind: "fan_in".to_string(), stddev: 0.1 }),
        }
    }

    #[test]
    fn split_partitions_columns_and_rows() {
        // wi [d=2, F=4] -> granularity 2 -> experts [4, 2, 2].
        let src = Tensor::from_f32(&[2, 4], (0..8).map(|x| x as f32).collect());
        let t = split_experts(&src, &wi_spec(4, 2, 2), 2, 2).unwrap();
        let d = t.f32s().unwrap();
        // Expert 0: columns 0..2 of each row = [0,1, 4,5]; expert 1: [2,3, 6,7].
        assert_eq!(&d[0..4], &[0., 1., 4., 5.]);
        assert_eq!(&d[4..8], &[2., 3., 6., 7.]);
        // Experts 2,3 repeat the partition cycle.
        assert_eq!(&d[8..12], &d[0..4]);
        assert_eq!(&d[12..16], &d[4..8]);

        // wo [F=4, d=2] -> rows are contiguous blocks.
        let src = Tensor::from_f32(&[4, 2], (0..8).map(|x| x as f32).collect());
        let t = split_experts(&src, &wo_spec(4, 2, 2), 2, 2).unwrap();
        let d = t.f32s().unwrap();
        assert_eq!(&d[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&d[4..8], &[4., 5., 6., 7.]);
    }

    #[test]
    fn split_granularity_one_is_replicate() {
        let src = Tensor::from_f32(&[2, 4], (0..8).map(|x| x as f32).collect());
        let split = split_experts(&src, &wi_spec(3, 2, 4), 1, 3).unwrap();
        let repl = replicate_experts(&src, 3).unwrap();
        assert_eq!(split.f32s().unwrap(), repl.f32s().unwrap());
        assert_eq!(split.shape, repl.shape);
    }

    #[test]
    fn split_divisibility_is_fail_fast() {
        let src = Tensor::from_f32(&[2, 4], vec![0.0; 8]);
        // E != g * x.
        let err = split_experts(&src, &wi_spec(4, 2, 2), 2, 3).unwrap_err();
        assert!(err.to_string().contains("num_experts"), "{err:#}");
        // Dense F=4 not divisible by expert f=3.
        let err = split_experts(&src, &wi_spec(4, 2, 3), 2, 2).unwrap_err();
        assert!(err.to_string().contains("not divisible"), "{err:#}");
        // Granularity flag contradicts the actual width ratio.
        let err = split_experts(&src, &wi_spec(4, 2, 2), 4, 1).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err:#}");
    }

    #[test]
    fn drop_masks_shared_between_wi_and_wo() {
        // Same tag + expert => same units, for every fraction.
        for frac in [0.25f32, 0.5, 1.0] {
            for x in 0..4 {
                let a = dropped_units(7, "enc/block_01", x, 16, frac);
                let b = dropped_units(7, "enc/block_01", x, 16, frac);
                assert_eq!(a, b);
            }
        }
        // Different experts (almost surely) differ; zero fraction is empty.
        assert_ne!(
            dropped_units(7, "enc/block_01", 0, 64, 0.5),
            dropped_units(7, "enc/block_01", 1, 64, 0.5)
        );
        assert!(dropped_units(7, "enc/block_01", 0, 64, 0.0).is_empty());
        assert_eq!(dropped_units(7, "enc/block_01", 0, 64, 1.0).len(), 64);
    }

    #[test]
    fn opt_tag_matches_param_tag() {
        assert_eq!(moe_block_tag("enc/block_01/moe/wi"), "enc/block_01");
        assert_eq!(moe_block_tag("opt/enc/block_01/moe/wi/m"), "enc/block_01");
        assert_eq!(moe_block_tag("opt/dec/block_00/moe/wo/v"), "dec/block_00");
    }

    #[test]
    fn drop_reinit_resets_units_end_to_end() {
        let e = 2;
        let (d, f) = (3, 8);
        let mut wi = replicate_experts(&Tensor::from_f32(&[d, f], vec![1.0; d * f]), e).unwrap();
        let mut wo = replicate_experts(&Tensor::from_f32(&[f, d], vec![1.0; d * f]), e).unwrap();
        apply_drop_reinit(&mut wi, &wi_spec(e, d, f), 0.5, 9).unwrap();
        apply_drop_reinit(&mut wo, &wo_spec(e, f, d), 0.5, 9).unwrap();
        let (wi, wo) = (wi.f32s().unwrap(), wo.f32s().unwrap());
        for x in 0..e {
            let units = dropped_units(9, "enc/block_01", x, f, 0.5);
            assert_eq!(units.len(), 4);
            for j in 0..f {
                let wi_touched = (0..d).any(|r| wi[x * d * f + r * f + j] != 1.0);
                let wo_touched = (0..d).any(|c| wo[x * d * f + j * d + c] != 1.0);
                if units.contains(&j) {
                    assert!(wi_touched && wo_touched, "unit {j} of expert {x} must be reset");
                } else {
                    assert!(!wi_touched && !wo_touched, "unit {j} of expert {x} must be kept");
                }
            }
        }
    }

    #[test]
    fn strategy_validation_names_every_failure() {
        let m = crate::manifest::Manifest::native();
        let e8 = m.model("lm_tiny_moe_e8_c2").unwrap();
        let bad = [
            (UpcycleStrategy::DropUpcycle { reinit_fraction: -0.1, seed: 0 }, "[0, 1]"),
            (UpcycleStrategy::DropUpcycle { reinit_fraction: 1.5, seed: 0 }, "[0, 1]"),
            (
                UpcycleStrategy::DropUpcycle { reinit_fraction: f32::NAN, seed: 0 },
                "[0, 1]",
            ),
            (UpcycleStrategy::Split { granularity: 0, expansion: 8 }, ">= 1"),
            (UpcycleStrategy::Split { granularity: 3, expansion: 3 }, "8 experts"),
            (
                UpcycleStrategy::MultiCheckpoint {
                    checkpoint_paths: vec![],
                    shared: SharedInit::Primary,
                },
                "at least one",
            ),
            (
                UpcycleStrategy::MultiCheckpoint {
                    checkpoint_paths: vec!["a.supc".into(), "a.supc".into()],
                    shared: SharedInit::Primary,
                },
                "twice",
            ),
            (
                UpcycleStrategy::MultiCheckpoint {
                    checkpoint_paths: vec!["a".into(), "b".into()],
                    shared: SharedInit::Primary,
                },
                "not divisible",
            ),
        ];
        for (s, needle) in bad {
            let err = s.validate(e8).unwrap_err().to_string();
            assert!(err.contains(needle), "{s:?}: `{err}` should mention `{needle}`");
        }
        UpcycleStrategy::Replicate.validate(e8).unwrap();
        UpcycleStrategy::Split { granularity: 1, expansion: 8 }.validate(e8).unwrap();
        RouterInit::VirtualGroups { groups: 4 }.validate(e8).unwrap();
        let err = RouterInit::VirtualGroups { groups: 3 }.validate(e8).unwrap_err();
        assert!(err.to_string().contains("divisible"), "{err:#}");
    }

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn cli_strategy_parsing_defaults_and_happy_paths() {
        assert_eq!(strategy_from_args(&parse(""), 0).unwrap(), UpcycleStrategy::Replicate);
        assert_eq!(
            strategy_from_args(&parse("--strategy drop --reinit-fraction 0.25"), 7).unwrap(),
            UpcycleStrategy::DropUpcycle { reinit_fraction: 0.25, seed: 7 }
        );
        assert_eq!(
            strategy_from_args(
                &parse("--strategy drop --reinit-fraction 0.25 --strategy-seed 3"),
                7
            )
            .unwrap(),
            UpcycleStrategy::DropUpcycle { reinit_fraction: 0.25, seed: 3 }
        );
        assert_eq!(
            strategy_from_args(&parse("--strategy split --granularity 2 --expansion 4"), 0)
                .unwrap(),
            UpcycleStrategy::Split { granularity: 2, expansion: 4 }
        );
        assert_eq!(
            strategy_from_args(
                &parse("--strategy multi --checkpoints a.supc,b.supc --shared average"),
                0
            )
            .unwrap(),
            UpcycleStrategy::MultiCheckpoint {
                checkpoint_paths: vec!["a.supc".into(), "b.supc".into()],
                shared: SharedInit::Average,
            }
        );
        assert_eq!(router_init_from_args(&parse("")).unwrap(), RouterInit::Normal);
        assert_eq!(
            router_init_from_args(&parse("--router-init virtual-groups --router-groups 4"))
                .unwrap(),
            RouterInit::VirtualGroups { groups: 4 }
        );
    }

    #[test]
    fn cli_strategy_parsing_fails_fast() {
        // Mirrors the `--inject-fault` style: every bad flag combination is
        // a named error raised before any checkpoint is touched.
        let bad = [
            ("--strategy warp", "unknown --strategy"),
            ("--strategy drop --reinit-fraction 1.5", "[0, 1]"),
            ("--strategy drop --reinit-fraction -0.1", "[0, 1]"),
            ("--strategy split", "--granularity"),
            ("--strategy split --granularity 0 --expansion 4", ">= 1"),
            ("--strategy split --granularity 2", ">= 1"),
            ("--strategy multi", "--checkpoints"),
            ("--strategy multi --checkpoints ,", "empty"),
            ("--strategy multi --checkpoints a.supc,a.supc", "twice"),
            ("--strategy multi --shared nope --checkpoints a.supc", "--shared"),
            ("--strategy replicate --reinit-fraction 0.5", "only applies"),
            ("--strategy drop --granularity 2", "only applies"),
            ("--strategy split --expansion 4 --granularity 1 --checkpoints a", "only applies"),
            ("--strategy multi --checkpoints a --reinit-fraction 0.1", "only applies"),
        ];
        for (flags, needle) in bad {
            let err = strategy_from_args(&parse(flags), 0).unwrap_err().to_string();
            assert!(err.contains(needle), "`{flags}` -> `{err}` should mention `{needle}`");
        }
        let bad_router = [
            ("--router-init weird", "unknown --router-init"),
            ("--router-init virtual-groups", "--router-groups"),
            ("--router-init virtual-groups --router-groups 0", "--router-groups"),
            ("--router-init normal --router-groups 4", "only applies"),
        ];
        for (flags, needle) in bad_router {
            let err = router_init_from_args(&parse(flags)).unwrap_err().to_string();
            assert!(err.contains(needle), "`{flags}` -> `{err}` should mention `{needle}`");
        }
    }

    #[test]
    fn virtual_group_router_tiles_columns() {
        let spec = TensorSpec {
            name: "enc/block_01/moe/router".to_string(),
            shape: vec![3, 8],
            dtype: crate::tensor::DType::F32,
            init: None,
        };
        let opts = UpcycleOptions {
            router_init: RouterInit::VirtualGroups { groups: 4 },
            ..Default::default()
        };
        let t = init_router(&spec, &opts, &mut Rng::new(5));
        let d = t.f32s().unwrap();
        for r in 0..3 {
            for g in 0..4 {
                // Experts 2g and 2g+1 share a column; adjacent groups differ.
                assert_eq!(d[r * 8 + 2 * g], d[r * 8 + 2 * g + 1]);
            }
            assert_ne!(d[r * 8], d[r * 8 + 2]);
        }
    }

    #[test]
    fn tiling_pattern_is_order_preserving() {
        // 4 → 6 blocks: [0,0,1,2,2,3] with i*4/6.
        let got: Vec<usize> = (0..6).map(|i| tile_source_block(i, 6, 4)).collect();
        assert_eq!(got, vec![0, 0, 1, 2, 2, 3]);
        // Identity when sizes match.
        for i in 0..5 {
            assert_eq!(tile_source_block(i, 5, 5), i);
        }
        // Monotone non-decreasing, covers all source blocks.
        let got: Vec<usize> = (0..12).map(|i| tile_source_block(i, 12, 4)).collect();
        assert!(got.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(got.iter().copied().max(), Some(3));
        assert_eq!(got[0], 0);
    }

    #[test]
    fn split_block_name_works() {
        assert_eq!(
            split_block_name("enc/block_03/attn/wq"),
            Some(("enc", 3, "attn/wq"))
        );
        assert_eq!(
            split_block_name("dec/block_11/moe/wi"),
            Some(("dec", 11, "moe/wi"))
        );
        assert_eq!(split_block_name("token_embed"), None);
    }
}
