//! Inter-expert diversity metrics for upcycled checkpoints.
//!
//! Drop-Upcycling's premise is that replicated experts start with zero
//! diversity and the router has to break the symmetry the slow way; partial
//! re-initialization restores diversity at init. This module measures it:
//! for every MoE layer, each expert's FFN (`wi[e]` ++ `wo[e]` flattened) is
//! one vector, and the layer's diversity is summarized over all expert
//! pairs as cosine distance (`1 - cos`) and L2 parameter distance.
//!
//! Exactness contract (pinned by the analytic-fixture tests): bitwise-
//! identical experts score exactly `0.0` on both metrics, and orthogonal
//! experts score exactly `1.0` cosine distance — the pairwise accumulation
//! is f64 and the identical-pair case is short-circuited on the L2 sum, so
//! no `sqrt(x)*sqrt(x) != x` rounding can leak into the zero case.
//!
//! Reachable as `sparse_upcycle::surgery::diversity` (the `surgery` alias
//! re-exports the upcycle module); schema documented in `docs/UPCYCLING.md`.

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::manifest::ModelEntry;
use crate::tensor::Tensor;

/// Pairwise diversity summary of one MoE layer.
#[derive(Debug, Clone)]
pub struct LayerDiversity {
    /// Block tag, e.g. `enc/block_01`.
    pub tag: String,
    pub num_experts: usize,
    /// Mean over expert pairs of `1 - cos(a, b)`.
    pub mean_cosine_distance: f64,
    pub max_cosine_distance: f64,
    /// Mean over expert pairs of `||a - b||_2`.
    pub mean_l2_distance: f64,
    pub max_l2_distance: f64,
}

/// Per-layer diversity of one upcycled checkpoint.
#[derive(Debug, Clone)]
pub struct DiversityReport {
    pub model: String,
    pub layers: Vec<LayerDiversity>,
}

impl DiversityReport {
    /// Mean cosine distance over all MoE layers (the single scalar the
    /// experiments emit per strategy).
    pub fn mean_cosine_distance(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.mean_cosine_distance).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn mean_l2_distance(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.mean_l2_distance).sum::<f64>() / self.layers.len() as f64
    }

    /// One line per layer, for CLI output.
    pub fn print(&self) {
        for l in &self.layers {
            println!(
                "  diversity {:<16} E={:<3} cos mean {:.4} max {:.4}  l2 mean {:.4} max {:.4}",
                l.tag,
                l.num_experts,
                l.mean_cosine_distance,
                l.max_cosine_distance,
                l.mean_l2_distance,
                l.max_l2_distance
            );
        }
    }
}

/// Cosine and L2 distance of one expert pair (f64 accumulation).
///
/// Identical vectors return exactly `(0.0, 0.0)`; a zero vector against a
/// non-zero one has undefined angle and is scored as distance `1.0`.
fn pair_distances(a: &[f32], b: &[f32]) -> (f64, f64) {
    let mut dot = 0.0f64;
    let (mut na, mut nb) = (0.0f64, 0.0f64);
    let mut l2 = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let (x, y) = (x as f64, y as f64);
        dot += x * y;
        na += x * x;
        nb += y * y;
        let d = x - y;
        l2 += d * d;
    }
    if l2 == 0.0 {
        return (0.0, 0.0);
    }
    let cos_dist = if na == 0.0 || nb == 0.0 {
        1.0
    } else {
        1.0 - dot / (na.sqrt() * nb.sqrt())
    };
    (cos_dist, l2.sqrt())
}

/// Diversity summary of one MoE layer from its stacked expert tensors
/// `wi [E, d, f]`, `wo [E, f, d]`.
pub fn layer_diversity(tag: &str, wi: &Tensor, wo: &Tensor) -> Result<LayerDiversity> {
    let e = wi.shape[0];
    anyhow::ensure!(
        e == wo.shape[0],
        "layer `{tag}`: wi has {e} experts but wo has {}",
        wo.shape[0]
    );
    let wi_data = wi.f32s()?;
    let wo_data = wo.f32s()?;
    let wi_per = wi_data.len() / e.max(1);
    let wo_per = wo_data.len() / e.max(1);
    let expert_vec = |x: usize| -> Vec<f32> {
        let mut v = Vec::with_capacity(wi_per + wo_per);
        v.extend_from_slice(&wi_data[x * wi_per..(x + 1) * wi_per]);
        v.extend_from_slice(&wo_data[x * wo_per..(x + 1) * wo_per]);
        v
    };
    let vecs: Vec<Vec<f32>> = (0..e).map(expert_vec).collect();
    let (mut cos_sum, mut cos_max) = (0.0f64, 0.0f64);
    let (mut l2_sum, mut l2_max) = (0.0f64, 0.0f64);
    let mut pairs = 0usize;
    for i in 0..e {
        for j in (i + 1)..e {
            let (c, l) = pair_distances(&vecs[i], &vecs[j]);
            cos_sum += c;
            l2_sum += l;
            cos_max = cos_max.max(c);
            l2_max = l2_max.max(l);
            pairs += 1;
        }
    }
    let n = pairs.max(1) as f64;
    Ok(LayerDiversity {
        tag: tag.to_string(),
        num_experts: e,
        mean_cosine_distance: cos_sum / n,
        max_cosine_distance: cos_max,
        mean_l2_distance: l2_sum / n,
        max_l2_distance: l2_max,
    })
}

/// Per-layer inter-expert diversity of an upcycled (or trained) sparse
/// checkpoint, over every MoE block the entry declares.
pub fn expert_diversity(ck: &Checkpoint, entry: &ModelEntry) -> Result<DiversityReport> {
    let mut layers = Vec::new();
    for (tag, _) in entry.moe_block_tags() {
        let wi = ck.get(&format!("{tag}/moe/wi"))?;
        let wo = ck.get(&format!("{tag}/moe/wo"))?;
        layers.push(layer_diversity(&tag, wi, wo)?);
    }
    Ok(DiversityReport { model: ck.model.clone(), layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use crate::upcycle::{upcycle_params, UpcycleOptions, UpcycleStrategy};

    #[test]
    fn replicated_experts_score_exactly_zero() {
        // 4 identical experts: every metric must be exactly 0.0 — not
        // merely small — per the determinism contract in docs/UPCYCLING.md.
        let one = Tensor::from_f32(&[2, 3], vec![0.3, -1.7, 0.0, 2.5, 0.1, -0.9]);
        let wi = crate::upcycle::replicate_experts(&one, 4).unwrap();
        let wo = crate::upcycle::replicate_experts(
            &Tensor::from_f32(&[3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            4,
        )
        .unwrap();
        let l = layer_diversity("enc/block_01", &wi, &wo).unwrap();
        assert_eq!(l.mean_cosine_distance, 0.0);
        assert_eq!(l.max_cosine_distance, 0.0);
        assert_eq!(l.mean_l2_distance, 0.0);
        assert_eq!(l.max_l2_distance, 0.0);
    }

    #[test]
    fn orthogonal_experts_score_closed_form() {
        // Expert 0 = e_0, expert 1 = e_1 (disjoint support): dot = 0 so the
        // cosine distance is exactly 1.0 and the L2 distance is sqrt(2).
        let wi = Tensor::from_f32(&[2, 1, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let wo = Tensor::from_f32(&[2, 2, 1], vec![0.0, 0.0, 0.0, 0.0]);
        let l = layer_diversity("enc/block_01", &wi, &wo).unwrap();
        assert_eq!(l.mean_cosine_distance, 1.0);
        assert_eq!(l.max_cosine_distance, 1.0);
        assert_eq!(l.mean_l2_distance, 2.0f64.sqrt());

        // Anti-parallel experts: cos = -1 so the distance is exactly 2.
        let wi = Tensor::from_f32(&[2, 1, 2], vec![1.0, 2.0, -1.0, -2.0]);
        let l = layer_diversity("enc/block_01", &wi, &wo).unwrap();
        assert_eq!(l.mean_cosine_distance, 2.0);
    }

    #[test]
    fn zero_vs_nonzero_expert_is_max_angle() {
        let wi = Tensor::from_f32(&[2, 1, 2], vec![0.0, 0.0, 3.0, 4.0]);
        let wo = Tensor::from_f32(&[2, 2, 1], vec![0.0; 4]);
        let l = layer_diversity("t", &wi, &wo).unwrap();
        assert_eq!(l.mean_cosine_distance, 1.0);
        assert_eq!(l.mean_l2_distance, 5.0);
    }

    #[test]
    fn drop_upcycle_diversity_is_monotone_in_reinit_fraction() {
        // On a seeded dense parent, more re-initialization must mean more
        // inter-expert diversity — with exactly zero at fraction 0.
        let m = Manifest::native();
        let dense = crate::init::init_params(m.model("lm_tiny_dense").unwrap(), 11).unwrap();
        let entry = m.model("lm_tiny_moe_e8_c2").unwrap();
        let mut last = -1.0f64;
        for frac in [0.0f32, 0.25, 0.5, 1.0] {
            let opts = UpcycleOptions {
                strategy: UpcycleStrategy::DropUpcycle { reinit_fraction: frac, seed: 3 },
                ..Default::default()
            };
            let ck = upcycle_params(&dense, entry, &opts).unwrap();
            let div = expert_diversity(&ck, entry).unwrap().mean_cosine_distance();
            if frac == 0.0 {
                assert_eq!(div, 0.0, "fraction 0 must be exactly replicated");
            } else {
                assert!(
                    div > last,
                    "diversity must grow with reinit_fraction: {div} after {last} at {frac}"
                );
            }
            last = div;
        }
        assert!(last > 0.1, "full re-init should be clearly diverse, got {last}");
    }
}
