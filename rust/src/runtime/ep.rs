//! Expert-parallel execution: weight shards, dispatch packing, and the
//! overlapped rank exchange that pipelines token buffers between EP
//! workers.
//!
//! One expert-parallel rank is a thread stepping its own token shard
//! through the full model (`coordinator::trainer::mesh_train_step`); at
//! every MoE block its [`EpRankExchange`] takes over the expert-MLP leg,
//! running the [`ExpertExchange`] split-phase lifecycle over microbatch
//! row chunks:
//!
//! 1. **Dispatch (split-phase)** — each microbatch's per-expert input
//!    chunks are packed by owner ([`pack_dispatch`], round-robin
//!    `parallel::ExpertPlacement`) and *posted* through
//!    `parallel::collectives::EpGroup::start_exchange` without blocking;
//!    the matching `finish_exchange` completes when every source's chunk
//!    has arrived. The default pipeline drivers post chunk `k+1` before
//!    computing chunk `k`, so the all-to-all of one microbatch overlaps
//!    the expert compute of another.
//! 2. **Shard compute** — the owner runs
//!    `runtime::native::expert_mlp_forward` (or the row-independent
//!    backward half, `expert_mlp_backward_rows`) on **its weight shard
//!    only** (sliced out of the replicated params at step start; unowned
//!    expert weights are never touched), one call per `(expert, source
//!    rank)` chunk. The gathered inputs and pre-ReLU activations stay
//!    cached at the owner, per microbatch, for the backward pass.
//! 3. **Combine return (split-phase)** — outputs are posted back as soon
//!    as a chunk is computed and the completions drain only after every
//!    chunk, then reassemble into per-expert buffers ([`unpack_combine`],
//!    chunk concatenation in microbatch order).
//!
//! **Bitwise contract.** Overlapped N-rank execution is bitwise-identical
//! to serial execution for *every* microbatch count: forward and the
//! `dr`/`dxg` backward half are row-independent (chunking is exact), and
//! the row-*reducing* expert weight-grad GEMMs are deferred — each
//! `(owned expert, source)` pair's operand chunks are concatenated across
//! microbatches and `expert_mlp_weight_grads` runs once on the full
//! buffers, per source **in ascending source order** (the
//! `reduce_sum_ordered` discipline), exactly the GEMMs the fused serial
//! path runs. Asserted by this module's tests and the trainer's
//! microbatch × rank property test.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::linalg::gemm::GemmKernels;
use crate::manifest::{ModelEntry, MoeSpec};
use crate::parallel::collectives::EpGroup;
use crate::parallel::ExpertPlacement;
use crate::tensor::Tensor;
use crate::util::bench::phase;

use super::native::{
    accumulate, expert_mlp_backward_rows, expert_mlp_forward, expert_mlp_weight_grads,
};
use super::{ExchangeLeg, ExpertExchange};

/// One expert's token buffer crossing the EP interconnect: `rows` rows of
/// a fixed width (d_model), row-major, in assignment order.
#[derive(Debug, Clone)]
pub struct ExpertBuf {
    pub expert: usize,
    pub rows: usize,
    pub data: Vec<f32>,
}

/// What one rank sends to (or receives from) one peer in a single
/// all-to-all round: the buffers of every expert the peer owns (dispatch)
/// or every expert this rank owns (return), ascending expert order.
pub type EpPayload = Vec<ExpertBuf>;

/// Pack per-expert buffers into per-destination payloads: rank `dst`
/// receives, in ascending expert order, the buffers of the experts it owns
/// under `placement`. Every buffer is routed exactly once (the ownership
/// map is a partition), which is what makes dispatch → combine a lossless
/// permutation of the token rows — asserted by `tests/ep_props.rs`.
pub fn pack_dispatch(
    bufs: Vec<Vec<f32>>,
    placement: &ExpertPlacement,
    width: usize,
) -> Vec<EpPayload> {
    let mut send: Vec<EpPayload> = (0..placement.ranks).map(|_| Vec::new()).collect();
    for (expert, data) in bufs.into_iter().enumerate() {
        let rows = if width == 0 { 0 } else { data.len() / width };
        send[placement.owner(expert)].push(ExpertBuf { expert, rows, data });
    }
    send
}

/// Inverse of [`pack_dispatch`] on the return path: reassemble per-expert
/// buffers from the per-owner payloads. Every expert must come back
/// exactly once.
pub fn unpack_combine(payloads: Vec<EpPayload>, num_experts: usize) -> Result<Vec<Vec<f32>>> {
    let mut out: Vec<Option<Vec<f32>>> = (0..num_experts).map(|_| None).collect();
    for payload in payloads {
        for buf in payload {
            if buf.expert >= num_experts {
                bail!("combine return names expert {} of {num_experts}", buf.expert);
            }
            if out[buf.expert].replace(buf.data).is_some() {
                bail!("expert {} returned by more than one rank", buf.expert);
            }
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(x, o)| o.with_context(|| format!("expert {x} missing from combine return")))
        .collect()
}

/// This rank's weight shard of one MoE block: `(expert, wi [d·ff],
/// wo [ff·d])` for every owned expert, ascending.
struct BlockShard {
    num_experts: usize,
    experts: Vec<(usize, Vec<f32>, Vec<f32>)>,
}

/// Per-block forward cache: for each owned expert (shard order), for each
/// source rank (ascending), the per-microbatch `(gathered input chunk,
/// pre-ReLU hidden chunk)` pairs in microbatch order.
type FwdCache = Vec<Vec<Vec<(Vec<f32>, Vec<f32>)>>>;

/// Per-block deferred backward operands, same indexing as [`FwdCache`]:
/// per-microbatch `(masked hidden grad chunk, gated output grad chunk)` —
/// what `expert_mlp_weight_grads` needs beyond the forward cache.
type BwdParts = Vec<Vec<Vec<(Vec<f32>, Vec<f32>)>>>;

/// Collective round tag of one microbatch's dispatch leg.
fn round_tag(tag: &str, leg: ExchangeLeg, mb: usize) -> String {
    format!("{tag}/{}/mb{mb}", leg.wire())
}

/// Collective round tag of one microbatch's combine (return) leg.
fn return_tag(tag: &str, leg: ExchangeLeg, mb: usize) -> String {
    format!("{tag}/{}_ret/mb{mb}", leg.wire())
}

/// Concatenate microbatch chunks into the full per-`(expert, source)`
/// operand buffer. Borrows at `m == 1` (the fused schedule stays
/// copy-free); the chunk order is microbatch order, so the result is
/// bitwise the buffer the fused path would have seen.
fn concat_chunks<'a>(chunks: impl Iterator<Item = &'a [f32]>) -> Cow<'a, [f32]> {
    let parts: Vec<&[f32]> = chunks.collect();
    if parts.len() == 1 {
        Cow::Borrowed(parts[0])
    } else {
        Cow::Owned(parts.concat())
    }
}

/// Free-function shard lookup so callers can keep disjoint `&mut` borrows
/// of the exchange's other per-block maps.
fn shard_for<'a>(
    shards: &'a BTreeMap<String, BlockShard>,
    tag: &str,
    e_cnt: usize,
) -> Result<&'a BlockShard> {
    let shard = shards.get(tag).with_context(|| format!("no expert shard for `{tag}`"))?;
    if shard.num_experts != e_cnt {
        bail!("shard for `{tag}` has {} experts, spec says {e_cnt}", shard.num_experts);
    }
    Ok(shard)
}

/// The [`ExpertExchange`] of one expert-parallel rank; see the module docs
/// for the protocol and the determinism contract.
pub struct EpRankExchange {
    rank: usize,
    group: Arc<EpGroup<EpPayload>>,
    d: usize,
    ff: usize,
    microbatches: usize,
    gemm: Option<GemmKernels>,
    shards: BTreeMap<String, BlockShard>,
    cache: BTreeMap<String, FwdCache>,
    bwd: BTreeMap<String, BwdParts>,
    /// Computed payloads staged between `finish_dispatch` and
    /// `start_combine`, keyed by return round tag.
    staged: BTreeMap<String, Vec<EpPayload>>,
}

impl EpRankExchange {
    /// Scatter: slice this rank's expert weight shard out of the replicated
    /// `params` for every MoE block of `entry`
    /// (`ModelEntry::moe_block_tags` ↔ the native backend's block tags).
    /// The exchange holds *owned copies* of only the owned experts'
    /// weights; everything else it ever sees arrives over the group's
    /// collectives.
    pub fn new(
        entry: &ModelEntry,
        params: &[Tensor],
        rank: usize,
        group: Arc<EpGroup<EpPayload>>,
    ) -> Result<EpRankExchange> {
        let ranks = group.ranks();
        if rank >= ranks {
            bail!("EP rank {rank} out of range for a {ranks}-rank group");
        }
        let d = entry.config.d_model;
        let ff = entry.config.d_ff;
        let mut shards = BTreeMap::new();
        for (tag, spec) in entry.moe_block_tags() {
            let e_cnt = spec.num_experts;
            let wi_name = format!("{tag}/moe/wi");
            let wo_name = format!("{tag}/moe/wo");
            let pidx = |name: &str| {
                entry
                    .params
                    .iter()
                    .position(|s| s.name == name)
                    .with_context(|| format!("parameter `{name}` missing from manifest"))
            };
            let wi = params[pidx(&wi_name)?].f32s()?;
            let wo = params[pidx(&wo_name)?].f32s()?;
            if wi.len() != e_cnt * d * ff || wo.len() != e_cnt * ff * d {
                bail!("MoE block `{tag}` weights do not match [E={e_cnt}, d={d}, ff={ff}]");
            }
            let placement = ExpertPlacement::new(e_cnt, ranks);
            let mut experts = Vec::new();
            for x in placement.owned(rank) {
                let wi_e = wi[x * d * ff..(x + 1) * d * ff].to_vec();
                let wo_e = wo[x * ff * d..(x + 1) * ff * d].to_vec();
                experts.push((x, wi_e, wo_e));
            }
            shards.insert(tag, BlockShard { num_experts: e_cnt, experts });
        }
        Ok(EpRankExchange {
            rank,
            group,
            d,
            ff,
            microbatches: 1,
            gemm: None,
            shards,
            cache: BTreeMap::new(),
            bwd: BTreeMap::new(),
            staged: BTreeMap::new(),
        })
    }

    /// Set how many microbatch chunks the pipeline drivers split every
    /// block's buffers into (>= 1; 1 = the fused schedule). Bitwise
    /// results are identical for every value — only the overlap of
    /// all-to-all and expert compute changes.
    pub fn with_microbatches(mut self, m: usize) -> EpRankExchange {
        self.microbatches = m.max(1);
        self
    }

    fn bound_gemm(&self) -> Result<GemmKernels> {
        self.gemm.context("exchange not bound to a kernel family (bind() not called)")
    }
}

impl ExpertExchange for EpRankExchange {
    fn bind(&mut self, gemm: GemmKernels) -> Result<()> {
        self.gemm = Some(gemm);
        Ok(())
    }

    fn microbatches(&self) -> usize {
        self.microbatches.max(1)
    }

    fn d_model(&self) -> usize {
        self.d
    }

    fn plan(&mut self, tag: &str, spec: &MoeSpec, leg: ExchangeLeg, m: usize) -> Result<()> {
        self.bound_gemm()?;
        if m != self.microbatches.max(1) {
            bail!("plan `{tag}`: {m} microbatches, exchange configured for {}", self.microbatches);
        }
        let shard = shard_for(&self.shards, tag, spec.num_experts)?;
        let n_owned = shard.experts.len();
        let ranks = self.group.ranks();
        match leg {
            ExchangeLeg::Forward { want_cache } => {
                // A replayed forward drops any stale cache for the block; a
                // fresh one is staged only when a backward will consume it.
                self.cache.remove(tag);
                if want_cache {
                    let fresh: FwdCache =
                        (0..n_owned).map(|_| (0..ranks).map(|_| Vec::new()).collect()).collect();
                    self.cache.insert(tag.to_string(), fresh);
                }
            }
            ExchangeLeg::Backward => {
                let cache = self
                    .cache
                    .get(tag)
                    .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
                if cache.len() != n_owned {
                    bail!(
                        "backward `{tag}`: cache has {} experts, shard owns {n_owned}",
                        cache.len()
                    );
                }
                // Both legs must chunk identically: a backward chunk consumes
                // the forward chunk's cached activations at the owner.
                for per_src in cache {
                    for chunks in per_src {
                        if chunks.len() != m {
                            bail!(
                                "backward `{tag}`: forward cached {} microbatches, backward \
                                 plans {m}",
                                chunks.len()
                            );
                        }
                    }
                }
                let fresh: BwdParts =
                    (0..n_owned).map(|_| (0..ranks).map(|_| Vec::new()).collect()).collect();
                self.bwd.insert(tag.to_string(), fresh);
            }
        }
        Ok(())
    }

    fn start_dispatch(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
        chunk: Vec<Vec<f32>>,
    ) -> Result<()> {
        let e_cnt = spec.num_experts;
        if chunk.len() != e_cnt {
            bail!(
                "{} `{tag}`: microbatch {mb} has {} expert chunks for {e_cnt} experts",
                leg.wire(),
                chunk.len()
            );
        }
        // Dispatch all-to-all: every expert chunk's rows go to its owner.
        // Posting is non-blocking — the matching wait lives in
        // `finish_dispatch`, so the chunk is in flight while this rank
        // computes another.
        let placement = ExpertPlacement::new(e_cnt, self.group.ranks());
        let send = pack_dispatch(chunk, &placement, self.d);
        self.group.start_exchange(self.rank, &round_tag(tag, leg, mb), send)
    }

    fn finish_dispatch(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<()> {
        let gemm = self.bound_gemm()?;
        let (d, ff) = (self.d, self.ff);
        // `ep_alltoall` wraps only the *blocking* completion leg: this is
        // the exposed all-to-all window the bench's `overlap` section
        // measures, and the seam where a `FaultPhase::Exchange` injection
        // lands — after the round's sends were posted, before its receives
        // complete.
        let recv = {
            let _ph = phase("ep_alltoall");
            self.group.finish_exchange(self.rank, &round_tag(tag, leg, mb))?
        };
        let shard = shard_for(&self.shards, tag, spec.num_experts)?;
        let n_owned = shard.experts.len();
        let ranks = self.group.ranks();
        let mut ret: Vec<EpPayload> = (0..ranks).map(|_| Vec::with_capacity(n_owned)).collect();
        let _ph = phase("ep_expert_mlp");
        match leg {
            ExchangeLeg::Forward { want_cache } => {
                let mut cache = if want_cache {
                    Some(self.cache.get_mut(tag).with_context(|| {
                        format!("forward `{tag}`: microbatch {mb} dispatched before plan")
                    })?)
                } else {
                    None
                };
                for (src, payload) in recv.into_iter().enumerate() {
                    if payload.len() != n_owned {
                        bail!(
                            "forward `{tag}`: rank {src} sent {} buffers, own {n_owned} experts",
                            payload.len()
                        );
                    }
                    for (oi, buf) in payload.into_iter().enumerate() {
                        let (x, wi_e, wo_e) = &shard.experts[oi];
                        if buf.expert != *x || buf.data.len() != buf.rows * d {
                            bail!(
                                "forward `{tag}`: malformed buffer from rank {src} (expert {}, \
                                 {} values, {} rows)",
                                buf.expert,
                                buf.data.len(),
                                buf.rows
                            );
                        }
                        let (u, y) = expert_mlp_forward(gemm, wi_e, wo_e, &buf.data, d, ff);
                        ret[src].push(ExpertBuf { expert: *x, rows: buf.rows, data: y });
                        if let Some(cache) = cache.as_mut() {
                            cache[oi][src].push((buf.data, u));
                        }
                    }
                }
            }
            ExchangeLeg::Backward => {
                let cache = self
                    .cache
                    .get(tag)
                    .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
                let parts = self.bwd.get_mut(tag).with_context(|| {
                    format!("backward `{tag}`: microbatch {mb} dispatched before plan")
                })?;
                for (src, payload) in recv.into_iter().enumerate() {
                    if payload.len() != n_owned {
                        bail!(
                            "backward `{tag}`: rank {src} sent {} buffers, own {n_owned} experts",
                            payload.len()
                        );
                    }
                    for (oi, buf) in payload.into_iter().enumerate() {
                        let (x, wi_e, wo_e) = &shard.experts[oi];
                        let (xg, u) = cache[oi][src].get(mb).with_context(|| {
                            format!(
                                "backward `{tag}`: expert {x} has no cached microbatch {mb} \
                                 from rank {src}"
                            )
                        })?;
                        if buf.expert != *x
                            || buf.data.len() != buf.rows * d
                            || xg.len() != buf.data.len()
                        {
                            bail!(
                                "backward `{tag}`: malformed buffer from rank {src} (expert {}, \
                                 {} values, {} rows)",
                                buf.expert,
                                buf.data.len(),
                                buf.rows
                            );
                        }
                        // Row-independent half only; the row-reducing weight
                        // grads wait for `finish_weight_grads`.
                        let (dr, dxg) =
                            expert_mlp_backward_rows(gemm, wi_e, wo_e, u, &buf.data, d, ff);
                        ret[src].push(ExpertBuf { expert: *x, rows: buf.rows, data: dxg });
                        parts[oi][src].push((dr, buf.data));
                    }
                }
            }
        }
        self.staged.insert(return_tag(tag, leg, mb), ret);
        Ok(())
    }

    fn start_combine(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<()> {
        let _ = spec;
        // Each staged ret[src] was pushed per owned expert outer, source
        // inner, so it is already ascending in `oi` — the order the
        // sources' unpack expects.
        let key = return_tag(tag, leg, mb);
        let ret = self.staged.remove(&key).with_context(|| {
            format!("{} `{tag}`: combine of microbatch {mb} before its dispatch finished",
                leg.wire())
        })?;
        self.group.start_exchange(self.rank, &key, ret)
    }

    fn finish_combine(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        leg: ExchangeLeg,
        mb: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let back = {
            let _ph = phase("ep_alltoall");
            self.group.finish_exchange(self.rank, &return_tag(tag, leg, mb))?
        };
        unpack_combine(back, spec.num_experts)
    }

    fn finish_weight_grads(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<()> {
        let gemm = self.bound_gemm()?;
        let (d, ff) = (self.d, self.ff);
        let e_cnt = spec.num_experts;
        if dwi.len() != e_cnt * d * ff || dwo.len() != e_cnt * ff * d {
            bail!("backward `{tag}`: weight grad buffers do not match [E={e_cnt}, d={d}, ff={ff}]");
        }
        let cache = self
            .cache
            .remove(tag)
            .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
        let parts = self.bwd.remove(tag).with_context(|| {
            format!("backward `{tag}`: weight grads before any microbatch dispatched")
        })?;
        let shard = shard_for(&self.shards, tag, e_cnt)?;
        let ranks = self.group.ranks();
        let _ph = phase("ep_expert_mlp");
        for (oi, (x, _, _)) in shard.experts.iter().enumerate() {
            if cache[oi].len() != ranks || parts[oi].len() != ranks {
                bail!(
                    "backward `{tag}`: expert {x} cached {} sources, want {ranks}",
                    cache[oi].len()
                );
            }
            let dwi_slice = &mut dwi[x * d * ff..(x + 1) * d * ff];
            let dwo_slice = &mut dwo[x * ff * d..(x + 1) * ff * d];
            // Ascending source order — the reduce_sum_ordered discipline
            // that keeps the group-summed weight grads bitwise-identical to
            // the serial per-shard reduction — and ONE fused GEMM per
            // (expert, source) over the concatenated microbatch chunks, so
            // the float association never depends on the microbatch count.
            for src in 0..ranks {
                if parts[oi][src].len() != cache[oi][src].len() {
                    bail!(
                        "backward `{tag}`: expert {x} has {} backward chunks for {} cached \
                         chunks from rank {src}",
                        parts[oi][src].len(),
                        cache[oi][src].len()
                    );
                }
                let xg = concat_chunks(cache[oi][src].iter().map(|(xg, _)| xg.as_slice()));
                let u = concat_chunks(cache[oi][src].iter().map(|(_, u)| u.as_slice()));
                let dr = concat_chunks(parts[oi][src].iter().map(|(dr, _)| dr.as_slice()));
                let dye = concat_chunks(parts[oi][src].iter().map(|(_, dye)| dye.as_slice()));
                let (dwi_p, dwo_p) = expert_mlp_weight_grads(gemm, &xg, &u, &dr, &dye, d, ff);
                accumulate(dwi_slice, &dwi_p);
                accumulate(dwo_slice, &dwo_p);
            }
        }
        Ok(())
    }

    /// Recoverable teardown: an aborted step can strand forward caches,
    /// deferred backward operands, and staged combine payloads (their
    /// consuming calls never ran); the elastic trainer rebuilds exchanges
    /// per attempt, but any future in-crate reuse of a torn exchange must
    /// drop them first — asserted by this module's kill-mid-exchange test.
    fn reset(&mut self) {
        self.cache.clear();
        self.bwd.clear();
        self.staged.clear();
    }

    fn has_pending(&self) -> bool {
        !self.cache.is_empty() || !self.bwd.is_empty() || !self.staged.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use crate::runtime::{Backend, Runtime};

    /// A 1-rank EP group is a degenerate mesh: every exchange is a
    /// self-exchange and the rank owns every expert. The gradients must be
    /// bitwise-identical to the plain local path — this pins the whole
    /// dispatch → shard-compute → combine machinery to the fused reference
    /// without needing threads.
    #[test]
    fn single_rank_ep_matches_local_grads_bitwise() {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        for name in ["lm_tiny_moe_e8_c2", "lm_tiny_moe_e8_c2_top2", "vit_tiny_moe_e8_c2"] {
            let entry = manifest.model(name).unwrap().clone();
            let model = runtime.load_model(&manifest, name, &["train", "eval"]).unwrap();
            let params = crate::runtime::tensors_from_checkpoint(
                &crate::init::init_params(&entry, 11).unwrap(),
                &entry.params,
            )
            .unwrap();
            let batch: Vec<Tensor> = if entry.family == "lm" {
                crate::data::text::TextPipeline::new(
                    crate::data::text::HmmCorpus::new(
                        crate::data::text::HmmSpec {
                            vocab_size: entry.config.vocab_size,
                            ..Default::default()
                        },
                        1,
                    ),
                    entry.config.batch_size,
                    entry.config.enc_len,
                    entry.config.dec_len,
                    1,
                    0,
                )
                .next_batch()
            } else {
                crate::data::vision::VisionPipeline::new(
                    crate::data::vision::VisionSpec::default(),
                    entry.config.batch_size,
                    1,
                    0,
                )
                .next_batch()
                .0
            };
            let (m_local, g_local) = model.grads(&params, &batch).unwrap();
            let group = Arc::new(EpGroup::new(1));
            let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
            let (m_ep, g_ep) = model.grads_ep(&params, &batch, &mut exch).unwrap();
            assert_eq!(m_local, m_ep, "{name}: metrics must match bitwise");
            for ((a, b), spec) in g_local.iter().zip(&g_ep).zip(&entry.params) {
                assert_eq!(a, b, "{name}: grad `{}` must match bitwise", spec.name);
            }
        }
    }

    /// The microbatched pipeline must be bitwise the fused schedule: the
    /// forward/backward row halves are chunk-exact and the weight grads
    /// run as one deferred GEMM per (expert, source) on the concatenated
    /// chunks. A 1-rank group keeps this thread-free; odd row counts per
    /// expert exercise the uneven `microbatch_ranges` splits.
    #[test]
    fn microbatched_pipeline_matches_fused_grads_bitwise() {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let name = "lm_tiny_moe_e8_c2";
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["train", "eval"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 11).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        )
        .next_batch();
        let (m_local, g_local) = model.grads(&params, &batch).unwrap();
        for m in [1usize, 2, 3, 4] {
            let group = Arc::new(EpGroup::new(1));
            let mut exch =
                EpRankExchange::new(&entry, &params, 0, group).unwrap().with_microbatches(m);
            let (m_ep, g_ep) = model.grads_ep(&params, &batch, &mut exch).unwrap();
            assert_eq!(m_local, m_ep, "m={m}: metrics must match bitwise");
            for ((a, b), spec) in g_local.iter().zip(&g_ep).zip(&entry.params) {
                assert_eq!(a, b, "m={m}: grad `{}` must match bitwise", spec.name);
            }
            assert!(!exch.has_pending(), "m={m}: a clean step leaves no staged state");
        }
    }

    #[test]
    fn pack_dispatch_partitions_and_unpack_roundtrips() {
        let placement = ExpertPlacement::new(5, 2);
        let bufs: Vec<Vec<f32>> = (0..5).map(|x| vec![x as f32; 2 * (x + 1)]).collect();
        let send = pack_dispatch(bufs.clone(), &placement, 2);
        assert_eq!(send.len(), 2);
        // Rank 0 owns 0, 2, 4; rank 1 owns 1, 3 — ascending within payload.
        assert_eq!(send[0].iter().map(|b| b.expert).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(send[1].iter().map(|b| b.expert).collect::<Vec<_>>(), vec![1, 3]);
        for payload in &send {
            for b in payload {
                assert_eq!(b.rows * 2, b.data.len());
            }
        }
        let back = unpack_combine(send, 5).unwrap();
        assert_eq!(back, bufs, "pack → unpack must be the identity");
        // Duplicate and missing experts are rejected.
        let dup = vec![
            vec![ExpertBuf { expert: 0, rows: 1, data: vec![1.0] }],
            vec![ExpertBuf { expert: 0, rows: 1, data: vec![2.0] }],
        ];
        assert!(unpack_combine(dup, 1).is_err());
        assert!(unpack_combine(vec![Vec::new()], 1).is_err());
    }

    /// The forward-only serving path through a 1-rank exchange must also
    /// pin to the local arithmetic bitwise — inference reuses the same
    /// dispatch → shard-compute → combine machinery with `want_cache`
    /// false, so nothing may depend on the backward caches existing.
    #[test]
    fn single_rank_ep_matches_local_infer_bitwise() {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let name = "lm_tiny_moe_e8_c2";
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 11).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        )
        .next_batch();
        let local = model.infer(&params, &batch[..2]).unwrap();
        let group = Arc::new(EpGroup::new(1));
        let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
        let ep = model.infer_ep(&params, &batch[..2], &mut exch).unwrap();
        assert_eq!(local, ep, "{name}: EP inference must match local bitwise");
    }

    /// A rank killed mid-step (via the injected-fault seam) must release
    /// its peers from the group's collectives with the root cause attached
    /// — the detection half of the elastic-recovery loop — and the
    /// survivor's torn exchange must tear down recoverably (pending caches
    /// clearable via `reset`, no hangs, no panics on drop).
    #[test]
    fn killed_rank_releases_peers_with_root_cause() {
        use crate::parallel::collectives::EP_ABORTED_MSG;
        use crate::resilience::{arm_fault, FaultPhase, INJECTED_FAULT_MARKER};
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let name = "lm_tiny_moe_e8_c2";
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["train"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 3).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        )
        .next_batch();
        let group: Arc<EpGroup<EpPayload>> = Arc::new(EpGroup::new(2));
        let shards = crate::coordinator::shard_batch(&batch, 2).unwrap();
        let results: Vec<(usize, Result<()>, bool)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let group = group.clone();
                    let shard = &shards[rank];
                    let model = &model;
                    let params = &params;
                    let entry = &entry;
                    s.spawn(move || {
                        let _arm = (rank == 1).then(|| arm_fault(FaultPhase::ExpertMlp));
                        let mut exch =
                            EpRankExchange::new(entry, params, rank, group.clone()).unwrap();
                        let body = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || model.grads_ep(params, shard, &mut exch).map(|_| ()),
                        ));
                        let res = match body {
                            Ok(r) => r,
                            Err(p) => {
                                let msg = p
                                    .downcast_ref::<String>()
                                    .cloned()
                                    .unwrap_or_else(|| "rank panicked".into());
                                group.abort_with(&msg);
                                Err(anyhow::anyhow!("{msg}"))
                            }
                        };
                        // Survivor-side teardown: stale forward caches and
                        // staged payloads from the aborted step must be
                        // clearable.
                        let had_pending = exch.has_pending();
                        exch.reset();
                        assert!(!exch.has_pending());
                        (rank, res, had_pending)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, res, had_pending) in &results {
            let err = format!("{:#}", res.as_ref().unwrap_err());
            if *rank == 1 {
                assert!(err.contains(INJECTED_FAULT_MARKER), "rank 1: {err}");
            } else {
                // The survivor sees the aborted collective *with* the dead
                // rank's root cause, not a bare abort.
                assert!(err.contains(EP_ABORTED_MSG), "rank 0: {err}");
                assert!(err.contains(INJECTED_FAULT_MARKER), "rank 0: {err}");
                assert!(
                    had_pending,
                    "the survivor aborted after at least one cached forward block"
                );
            }
        }
    }

    #[test]
    fn ep_exchange_requires_bind() {
        let manifest = Manifest::native();
        let entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 1).unwrap(),
            &entry.params,
        )
        .unwrap();
        let group = Arc::new(EpGroup::new(1));
        let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
        let spec = entry.config.enc_moe.clone().unwrap();
        let xg: Vec<Vec<f32>> = (0..spec.num_experts).map(|_| Vec::new()).collect();
        assert!(exch.forward("enc/block_01", &spec, xg, true).is_err());
    }
}
