//! Expert-parallel execution: weight shards, dispatch packing, and the
//! rank exchange that ships token buffers between EP workers.
//!
//! One expert-parallel rank is a thread stepping its own token shard
//! through the full model (`coordinator::trainer::mesh_train_step`); at
//! every MoE block its [`EpRankExchange`] takes over the expert-MLP leg:
//!
//! 1. **Dispatch** — the rank's per-expert input buffers are packed by
//!    owner ([`pack_dispatch`], round-robin `parallel::ExpertPlacement`)
//!    and exchanged through `parallel::collectives::EpGroup`, so every
//!    rank receives the token rows routed to the experts *it* owns.
//! 2. **Shard compute** — the owner runs
//!    `runtime::native::expert_mlp_forward` on **its weight shard only**
//!    (sliced out of the replicated params at step start; unowned expert
//!    weights are never touched), one call per `(expert, source rank)`
//!    buffer. The gathered inputs and pre-ReLU activations stay cached at
//!    the owner for the backward pass.
//! 3. **Combine return** — outputs travel back through a second all-to-all
//!    and are reassembled into per-expert buffers ([`unpack_combine`]) for
//!    the rank's local gate-weighted combine.
//!
//! Backward mirrors the same two exchanges with gated output grads going
//! out and input grads coming back; expert *weight* grads accumulate at
//! the owner, per source rank **in ascending source order** — the
//! `reduce_sum_ordered` discipline, which keeps every number
//! bitwise-identical to the serial 1-worker execution of the same mesh
//! (each `(expert, source)` buffer sees exactly the GEMM the source shard
//! would have run locally; forward is row-independent, and the ordered
//! partial sums match the ordered per-shard reduction).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::linalg::gemm::GemmKernels;
use crate::manifest::{ModelEntry, MoeSpec};
use crate::parallel::collectives::EpGroup;
use crate::parallel::ExpertPlacement;
use crate::tensor::Tensor;
use crate::util::bench::phase;

use super::native::{accumulate, expert_mlp_backward, expert_mlp_forward};
use super::ExpertExchange;

/// One expert's token buffer crossing the EP interconnect: `rows` rows of
/// a fixed width (d_model), row-major, in assignment order.
#[derive(Debug, Clone)]
pub struct ExpertBuf {
    pub expert: usize,
    pub rows: usize,
    pub data: Vec<f32>,
}

/// What one rank sends to (or receives from) one peer in a single
/// all-to-all round: the buffers of every expert the peer owns (dispatch)
/// or every expert this rank owns (return), ascending expert order.
pub type EpPayload = Vec<ExpertBuf>;

/// Pack per-expert buffers into per-destination payloads: rank `dst`
/// receives, in ascending expert order, the buffers of the experts it owns
/// under `placement`. Every buffer is routed exactly once (the ownership
/// map is a partition), which is what makes dispatch → combine a lossless
/// permutation of the token rows — asserted by `tests/ep_props.rs`.
pub fn pack_dispatch(
    bufs: Vec<Vec<f32>>,
    placement: &ExpertPlacement,
    width: usize,
) -> Vec<EpPayload> {
    let mut send: Vec<EpPayload> = (0..placement.ranks).map(|_| Vec::new()).collect();
    for (expert, data) in bufs.into_iter().enumerate() {
        let rows = if width == 0 { 0 } else { data.len() / width };
        send[placement.owner(expert)].push(ExpertBuf { expert, rows, data });
    }
    send
}

/// Inverse of [`pack_dispatch`] on the return path: reassemble per-expert
/// buffers from the per-owner payloads. Every expert must come back
/// exactly once.
pub fn unpack_combine(payloads: Vec<EpPayload>, num_experts: usize) -> Result<Vec<Vec<f32>>> {
    let mut out: Vec<Option<Vec<f32>>> = (0..num_experts).map(|_| None).collect();
    for payload in payloads {
        for buf in payload {
            if buf.expert >= num_experts {
                bail!("combine return names expert {} of {num_experts}", buf.expert);
            }
            if out[buf.expert].replace(buf.data).is_some() {
                bail!("expert {} returned by more than one rank", buf.expert);
            }
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(x, o)| o.with_context(|| format!("expert {x} missing from combine return")))
        .collect()
}

/// This rank's weight shard of one MoE block: `(expert, wi [d·ff],
/// wo [ff·d])` for every owned expert, ascending.
struct BlockShard {
    num_experts: usize,
    experts: Vec<(usize, Vec<f32>, Vec<f32>)>,
}

/// Per-block forward cache: for each owned expert (shard order), for each
/// source rank (ascending), the gathered inputs and pre-ReLU hidden.
type FwdCache = Vec<Vec<(Vec<f32>, Vec<f32>)>>;

/// The [`ExpertExchange`] of one expert-parallel rank; see the module docs
/// for the protocol and the determinism contract.
pub struct EpRankExchange {
    rank: usize,
    group: Arc<EpGroup<EpPayload>>,
    d: usize,
    ff: usize,
    gemm: Option<GemmKernels>,
    shards: BTreeMap<String, BlockShard>,
    cache: BTreeMap<String, FwdCache>,
}

impl EpRankExchange {
    /// Scatter: slice this rank's expert weight shard out of the replicated
    /// `params` for every MoE block of `entry`
    /// (`ModelEntry::moe_block_tags` ↔ the native backend's block tags).
    /// The exchange holds *owned copies* of only the owned experts'
    /// weights; everything else it ever sees arrives over the group's
    /// collectives.
    pub fn new(
        entry: &ModelEntry,
        params: &[Tensor],
        rank: usize,
        group: Arc<EpGroup<EpPayload>>,
    ) -> Result<EpRankExchange> {
        let ranks = group.ranks();
        if rank >= ranks {
            bail!("EP rank {rank} out of range for a {ranks}-rank group");
        }
        let d = entry.config.d_model;
        let ff = entry.config.d_ff;
        let mut shards = BTreeMap::new();
        for (tag, spec) in entry.moe_block_tags() {
            let e_cnt = spec.num_experts;
            let wi_name = format!("{tag}/moe/wi");
            let wo_name = format!("{tag}/moe/wo");
            let pidx = |name: &str| {
                entry
                    .params
                    .iter()
                    .position(|s| s.name == name)
                    .with_context(|| format!("parameter `{name}` missing from manifest"))
            };
            let wi = params[pidx(&wi_name)?].f32s()?;
            let wo = params[pidx(&wo_name)?].f32s()?;
            if wi.len() != e_cnt * d * ff || wo.len() != e_cnt * ff * d {
                bail!("MoE block `{tag}` weights do not match [E={e_cnt}, d={d}, ff={ff}]");
            }
            let placement = ExpertPlacement::new(e_cnt, ranks);
            let mut experts = Vec::new();
            for x in placement.owned(rank) {
                let wi_e = wi[x * d * ff..(x + 1) * d * ff].to_vec();
                let wo_e = wo[x * ff * d..(x + 1) * ff * d].to_vec();
                experts.push((x, wi_e, wo_e));
            }
            shards.insert(tag, BlockShard { num_experts: e_cnt, experts });
        }
        Ok(EpRankExchange { rank, group, d, ff, gemm: None, shards, cache: BTreeMap::new() })
    }

    fn bound_gemm(&self) -> Result<GemmKernels> {
        self.gemm.context("exchange not bound to a kernel family (bind() not called)")
    }

    /// Recoverable teardown: drop every forward cache this exchange holds.
    ///
    /// An aborted step can leave caches behind — `forward` ran for some MoE
    /// blocks before a peer died, so their `backward` never consumed the
    /// cached activations. The elastic trainer rebuilds exchanges per step
    /// attempt, so nothing in the product reuses a torn exchange today;
    /// this stays `pub(crate)` as the teardown contract for any future
    /// in-crate path that does (a stale cache paired with a replayed
    /// forward would feed the backward pass the aborted attempt's
    /// activations), asserted by this module's kill-mid-exchange test.
    pub(crate) fn reset(&mut self) {
        self.cache.clear();
    }

    /// Whether any forward cache is pending a backward (used by teardown
    /// assertions: a cleanly-finished step leaves none).
    pub(crate) fn has_pending_cache(&self) -> bool {
        !self.cache.is_empty()
    }
}

impl ExpertExchange for EpRankExchange {
    fn bind(&mut self, gemm: GemmKernels) -> Result<()> {
        self.gemm = Some(gemm);
        Ok(())
    }

    fn forward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        xg: Vec<Vec<f32>>,
        want_cache: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let gemm = self.bound_gemm()?;
        let (d, ff) = (self.d, self.ff);
        let e_cnt = spec.num_experts;
        if xg.len() != e_cnt {
            bail!("forward `{tag}`: {} expert buffers for {e_cnt} experts", xg.len());
        }
        let ranks = self.group.ranks();
        let placement = ExpertPlacement::new(e_cnt, ranks);

        // Dispatch all-to-all: every expert's rows go to its owner.
        let send = pack_dispatch(xg, &placement, d);
        let recv = {
            let _ph = phase("ep_alltoall");
            self.group.exchange(self.rank, &format!("{tag}/fwd"), send)?
        };

        let shard =
            self.shards.get(tag).with_context(|| format!("no expert shard for `{tag}`"))?;
        if shard.num_experts != e_cnt {
            bail!("shard for `{tag}` has {} experts, spec says {e_cnt}", shard.num_experts);
        }
        let n_owned = shard.experts.len();
        let mut cache: FwdCache = (0..n_owned).map(|_| Vec::with_capacity(ranks)).collect();
        let mut ret: Vec<EpPayload> = (0..ranks).map(|_| Vec::with_capacity(n_owned)).collect();
        {
            let _ph = phase("ep_expert_mlp");
            for (src, payload) in recv.into_iter().enumerate() {
                if payload.len() != n_owned {
                    bail!(
                        "forward `{tag}`: rank {src} sent {} buffers, own {n_owned} experts",
                        payload.len()
                    );
                }
                for (oi, buf) in payload.into_iter().enumerate() {
                    let (x, wi_e, wo_e) = &shard.experts[oi];
                    if buf.expert != *x || buf.data.len() != buf.rows * d {
                        bail!(
                            "forward `{tag}`: malformed buffer from rank {src} (expert {}, {} \
                             values, {} rows)",
                            buf.expert,
                            buf.data.len(),
                            buf.rows
                        );
                    }
                    let (u, y) = expert_mlp_forward(gemm, wi_e, wo_e, &buf.data, d, ff);
                    ret[src].push(ExpertBuf { expert: *x, rows: buf.rows, data: y });
                    if want_cache {
                        cache[oi].push((buf.data, u));
                    }
                }
            }
        }
        if want_cache {
            self.cache.insert(tag.to_string(), cache);
        }

        // Combine all-to-all: outputs travel back to the token sources.
        let back = {
            let _ph = phase("ep_alltoall");
            self.group.exchange(self.rank, &format!("{tag}/fwd_ret"), ret)?
        };
        unpack_combine(back, e_cnt)
    }

    fn backward(
        &mut self,
        tag: &str,
        spec: &MoeSpec,
        dye: Vec<Vec<f32>>,
        dwi: &mut [f32],
        dwo: &mut [f32],
    ) -> Result<Vec<Vec<f32>>> {
        let gemm = self.bound_gemm()?;
        let (d, ff) = (self.d, self.ff);
        let e_cnt = spec.num_experts;
        if dye.len() != e_cnt {
            bail!("backward `{tag}`: {} expert grad buffers for {e_cnt} experts", dye.len());
        }
        if dwi.len() != e_cnt * d * ff || dwo.len() != e_cnt * ff * d {
            bail!("backward `{tag}`: weight grad buffers do not match [E={e_cnt}, d={d}, ff={ff}]");
        }
        let ranks = self.group.ranks();
        let placement = ExpertPlacement::new(e_cnt, ranks);

        // Ship the gated output grads to the expert owners.
        let send = pack_dispatch(dye, &placement, d);
        let recv = {
            let _ph = phase("ep_alltoall");
            self.group.exchange(self.rank, &format!("{tag}/bwd"), send)?
        };

        let cache = self
            .cache
            .remove(tag)
            .with_context(|| format!("no forward cache for MoE block `{tag}`"))?;
        let shard =
            self.shards.get(tag).with_context(|| format!("no expert shard for `{tag}`"))?;
        let n_owned = shard.experts.len();
        if cache.len() != n_owned {
            bail!("backward `{tag}`: cache has {} experts, shard owns {n_owned}", cache.len());
        }
        for (src, payload) in recv.iter().enumerate() {
            if payload.len() != n_owned {
                bail!(
                    "backward `{tag}`: rank {src} sent {} buffers, own {n_owned} experts",
                    payload.len()
                );
            }
        }
        let mut ret: Vec<EpPayload> = (0..ranks).map(|_| Vec::with_capacity(n_owned)).collect();
        {
            let _ph = phase("ep_expert_mlp");
            for (oi, (x, wi_e, wo_e)) in shard.experts.iter().enumerate() {
                if cache[oi].len() != ranks {
                    bail!(
                        "backward `{tag}`: expert {x} cached {} sources, want {ranks}",
                        cache[oi].len()
                    );
                }
                let dwi_slice = &mut dwi[x * d * ff..(x + 1) * d * ff];
                let dwo_slice = &mut dwo[x * ff * d..(x + 1) * ff * d];
                // Ascending source order — the reduce_sum_ordered discipline
                // that keeps the group-summed weight grads bitwise-identical
                // to the serial per-shard reduction.
                for (src, payload) in recv.iter().enumerate() {
                    let buf = &payload[oi];
                    let (xg, u) = &cache[oi][src];
                    if buf.expert != *x
                        || buf.data.len() != buf.rows * d
                        || xg.len() != buf.data.len()
                    {
                        bail!(
                            "backward `{tag}`: malformed buffer from rank {src} (expert {}, {} \
                             values, {} rows)",
                            buf.expert,
                            buf.data.len(),
                            buf.rows
                        );
                    }
                    let (dwi_p, dwo_p, dxg) =
                        expert_mlp_backward(gemm, wi_e, wo_e, xg, u, &buf.data, d, ff);
                    accumulate(dwi_slice, &dwi_p);
                    accumulate(dwo_slice, &dwo_p);
                    ret[src].push(ExpertBuf { expert: *x, rows: buf.rows, data: dxg });
                }
            }
        }
        // Rebuild per-source payloads in ascending expert order: the loop
        // above pushed per owned expert outer, source inner, so each
        // ret[src] is already ascending in `oi` — the order the sources'
        // unpack expects.
        let back = {
            let _ph = phase("ep_alltoall");
            self.group.exchange(self.rank, &format!("{tag}/bwd_ret"), ret)?
        };
        unpack_combine(back, e_cnt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use crate::runtime::{Backend, Runtime};

    /// A 1-rank EP group is a degenerate mesh: every exchange is a
    /// self-exchange and the rank owns every expert. The gradients must be
    /// bitwise-identical to the plain local path — this pins the whole
    /// dispatch → shard-compute → combine machinery to the fused reference
    /// without needing threads.
    #[test]
    fn single_rank_ep_matches_local_grads_bitwise() {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        for name in ["lm_tiny_moe_e8_c2", "lm_tiny_moe_e8_c2_top2", "vit_tiny_moe_e8_c2"] {
            let entry = manifest.model(name).unwrap().clone();
            let model = runtime.load_model(&manifest, name, &["train", "eval"]).unwrap();
            let params = crate::runtime::tensors_from_checkpoint(
                &crate::init::init_params(&entry, 11).unwrap(),
                &entry.params,
            )
            .unwrap();
            let batch: Vec<Tensor> = if entry.family == "lm" {
                crate::data::text::TextPipeline::new(
                    crate::data::text::HmmCorpus::new(
                        crate::data::text::HmmSpec {
                            vocab_size: entry.config.vocab_size,
                            ..Default::default()
                        },
                        1,
                    ),
                    entry.config.batch_size,
                    entry.config.enc_len,
                    entry.config.dec_len,
                    1,
                    0,
                )
                .next_batch()
            } else {
                crate::data::vision::VisionPipeline::new(
                    crate::data::vision::VisionSpec::default(),
                    entry.config.batch_size,
                    1,
                    0,
                )
                .next_batch()
                .0
            };
            let (m_local, g_local) = model.grads(&params, &batch).unwrap();
            let group = Arc::new(EpGroup::new(1));
            let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
            let (m_ep, g_ep) = model.grads_ep(&params, &batch, &mut exch).unwrap();
            assert_eq!(m_local, m_ep, "{name}: metrics must match bitwise");
            for ((a, b), spec) in g_local.iter().zip(&g_ep).zip(&entry.params) {
                assert_eq!(a, b, "{name}: grad `{}` must match bitwise", spec.name);
            }
        }
    }

    #[test]
    fn pack_dispatch_partitions_and_unpack_roundtrips() {
        let placement = ExpertPlacement::new(5, 2);
        let bufs: Vec<Vec<f32>> = (0..5).map(|x| vec![x as f32; 2 * (x + 1)]).collect();
        let send = pack_dispatch(bufs.clone(), &placement, 2);
        assert_eq!(send.len(), 2);
        // Rank 0 owns 0, 2, 4; rank 1 owns 1, 3 — ascending within payload.
        assert_eq!(send[0].iter().map(|b| b.expert).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(send[1].iter().map(|b| b.expert).collect::<Vec<_>>(), vec![1, 3]);
        for payload in &send {
            for b in payload {
                assert_eq!(b.rows * 2, b.data.len());
            }
        }
        let back = unpack_combine(send, 5).unwrap();
        assert_eq!(back, bufs, "pack → unpack must be the identity");
        // Duplicate and missing experts are rejected.
        let dup = vec![
            vec![ExpertBuf { expert: 0, rows: 1, data: vec![1.0] }],
            vec![ExpertBuf { expert: 0, rows: 1, data: vec![2.0] }],
        ];
        assert!(unpack_combine(dup, 1).is_err());
        assert!(unpack_combine(vec![Vec::new()], 1).is_err());
    }

    /// The forward-only serving path through a 1-rank exchange must also
    /// pin to the local arithmetic bitwise — inference reuses the same
    /// dispatch → shard-compute → combine machinery with `want_cache`
    /// false, so nothing may depend on the backward caches existing.
    #[test]
    fn single_rank_ep_matches_local_infer_bitwise() {
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let name = "lm_tiny_moe_e8_c2";
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["eval"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 11).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        )
        .next_batch();
        let local = model.infer(&params, &batch[..2]).unwrap();
        let group = Arc::new(EpGroup::new(1));
        let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
        let ep = model.infer_ep(&params, &batch[..2], &mut exch).unwrap();
        assert_eq!(local, ep, "{name}: EP inference must match local bitwise");
    }

    /// A rank killed mid-step (via the injected-fault seam) must release
    /// its peers from the group's collectives with the root cause attached
    /// — the detection half of the elastic-recovery loop — and the
    /// survivor's torn exchange must tear down recoverably (pending caches
    /// clearable via `reset`, no hangs, no panics on drop).
    #[test]
    fn killed_rank_releases_peers_with_root_cause() {
        use crate::parallel::collectives::EP_ABORTED_MSG;
        use crate::resilience::{arm_fault, FaultPhase, INJECTED_FAULT_MARKER};
        let manifest = Manifest::native();
        let runtime = Runtime::new().unwrap();
        let name = "lm_tiny_moe_e8_c2";
        let entry = manifest.model(name).unwrap().clone();
        let model = runtime.load_model(&manifest, name, &["train"]).unwrap();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 3).unwrap(),
            &entry.params,
        )
        .unwrap();
        let batch = crate::data::text::TextPipeline::new(
            crate::data::text::HmmCorpus::new(
                crate::data::text::HmmSpec {
                    vocab_size: entry.config.vocab_size,
                    ..Default::default()
                },
                1,
            ),
            entry.config.batch_size,
            entry.config.enc_len,
            entry.config.dec_len,
            1,
            0,
        )
        .next_batch();
        let group: Arc<EpGroup<EpPayload>> = Arc::new(EpGroup::new(2));
        let shards = crate::coordinator::shard_batch(&batch, 2).unwrap();
        let results: Vec<(usize, Result<()>, bool)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let group = group.clone();
                    let shard = &shards[rank];
                    let model = &model;
                    let params = &params;
                    let entry = &entry;
                    s.spawn(move || {
                        let _arm = (rank == 1).then(|| arm_fault(FaultPhase::ExpertMlp));
                        let mut exch =
                            EpRankExchange::new(entry, params, rank, group.clone()).unwrap();
                        let body = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || model.grads_ep(params, shard, &mut exch).map(|_| ()),
                        ));
                        let res = match body {
                            Ok(r) => r,
                            Err(p) => {
                                let msg = p
                                    .downcast_ref::<String>()
                                    .cloned()
                                    .unwrap_or_else(|| "rank panicked".into());
                                group.abort_with(&msg);
                                Err(anyhow::anyhow!("{msg}"))
                            }
                        };
                        // Survivor-side teardown: stale forward caches from
                        // the aborted step must be clearable.
                        let had_pending = exch.has_pending_cache();
                        exch.reset();
                        assert!(!exch.has_pending_cache());
                        (rank, res, had_pending)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, res, had_pending) in &results {
            let err = format!("{:#}", res.as_ref().unwrap_err());
            if *rank == 1 {
                assert!(err.contains(INJECTED_FAULT_MARKER), "rank 1: {err}");
            } else {
                // The survivor sees the aborted collective *with* the dead
                // rank's root cause, not a bare abort.
                assert!(err.contains(EP_ABORTED_MSG), "rank 0: {err}");
                assert!(err.contains(INJECTED_FAULT_MARKER), "rank 0: {err}");
                assert!(
                    had_pending,
                    "the survivor aborted after at least one cached forward block"
                );
            }
        }
    }

    #[test]
    fn ep_exchange_requires_bind() {
        let manifest = Manifest::native();
        let entry = manifest.model("lm_tiny_moe_e8_c2").unwrap().clone();
        let params = crate::runtime::tensors_from_checkpoint(
            &crate::init::init_params(&entry, 1).unwrap(),
            &entry.params,
        )
        .unwrap();
        let group = Arc::new(EpGroup::new(1));
        let mut exch = EpRankExchange::new(&entry, &params, 0, group).unwrap();
        let spec = entry.config.enc_moe.clone().unwrap();
        let xg: Vec<Vec<f32>> = (0..spec.num_experts).map(|_| Vec::new()).collect();
        assert!(exch.forward("enc/block_01", &spec, xg, true).is_err());
    }
}
