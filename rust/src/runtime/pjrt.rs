//! PJRT backend (cargo feature `pjrt`): load AOT-compiled HLO artifacts and
//! execute them.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` for
//! why). Python never runs on this path: artifacts are compiled once at
//! `load_model` and then executed step after step by the trainer.
//!
//! Output convention (probed at bring-up, DESIGN.md): the artifacts are
//! lowered with `return_tuple=True`, and this PJRT build returns the whole
//! result as a *single tuple buffer* regardless of arity. Each step we sync
//! the tuple to a host literal and decompose it; on the CPU client this is a
//! memcpy.
//!
//! NOTE: the workspace vendors an API-compatible **stub** of the `xla` crate
//! (see `vendor/xla`): this module type-checks and its entry points return a
//! clear "PJRT unavailable" error until the real bindings are linked in.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::tensor::{Data, Tensor};

use super::{Backend, Executable, LoadedModel, Metrics, StepOutput};

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v.as_slice()),
        Data::I32(v) => xla::Literal::vec1(v.as_slice()),
    };
    Ok(lit.reshape(&dims)?)
}

// The catch-all arm is unreachable against the vendored stub (two variants)
// but required by the real xla bindings' wider ElementType.
#[allow(unreachable_patterns)]
fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::from_f32(&dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::from_i32(&dims, lit.to_vec::<i32>()?)),
        t => bail!("unsupported literal element type {t:?}"),
    }
}

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        Ok(PjrtBackend { client: xla::PjRtClient::cpu()? })
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?)
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile the artifacts of one model. `kinds` selects which
    /// executables to build ("train", "eval", "features") — compiling only
    /// what an experiment needs keeps sweep startup fast (XLA compilation of
    /// a train-step module dominates experiment startup).
    fn load_model(&self, manifest: &Manifest, name: &str, kinds: &[&str]) -> Result<LoadedModel> {
        let entry = manifest.model(name)?.clone();
        let get = |k: &str| -> Result<Option<xla::PjRtLoadedExecutable>> {
            if !kinds.contains(&k) || !entry.artifacts.contains_key(k) {
                return Ok(None);
            }
            Ok(Some(self.compile(&manifest.artifact_path(&entry, k)?)?))
        };
        let exec = PjrtExec {
            entry: entry.clone(),
            train: get("train")?,
            eval: get("eval")?,
            features: get("features")?,
        };
        Ok(LoadedModel::new(entry, Box::new(exec)))
    }
}

pub struct PjrtExec {
    entry: ModelEntry,
    train: Option<xla::PjRtLoadedExecutable>,
    eval: Option<xla::PjRtLoadedExecutable>,
    features: Option<xla::PjRtLoadedExecutable>,
}

fn extract_metrics(names: &[String], lits: &[xla::Literal]) -> Result<Metrics> {
    let mut m = Metrics::new();
    for (name, lit) in names.iter().zip(lits) {
        let t = from_literal(lit)?;
        m.insert(name.clone(), t.f32s()?[0] as f64);
    }
    Ok(m)
}

impl Executable for PjrtExec {
    fn has(&self, kind: &str) -> bool {
        match kind {
            "train" => self.train.is_some(),
            "eval" => self.eval.is_some(),
            "features" => self.features.is_some(),
            _ => false,
        }
    }

    fn train_step(
        &self,
        params: Vec<Tensor>,
        opt_state: Vec<Tensor>,
        batch: &[Tensor],
        lr: f64,
        wd: f64,
        step: u64,
    ) -> Result<StepOutput> {
        let exe = self.train.as_ref().context("train executable not loaded")?;
        let e = &self.entry;
        let mut inputs: Vec<xla::Literal> = Vec::new();
        for t in params.iter().chain(opt_state.iter()).chain(batch.iter()) {
            inputs.push(to_literal(t)?);
        }
        inputs.push(to_literal(&Tensor::scalar_f32(lr as f32))?);
        inputs.push(to_literal(&Tensor::scalar_f32(wd as f32))?);
        inputs.push(to_literal(&Tensor::scalar_f32(step as f32))?);

        let out = exe.execute::<xla::Literal>(&inputs)?;
        let mut flat = out[0][0].to_literal_sync()?.to_tuple()?;
        let expected = e.params.len() + e.opt_state.len() + e.metrics.len();
        if flat.len() != expected {
            bail!("train step returned {} outputs, expected {expected}", flat.len());
        }
        let metrics_lits = flat.split_off(e.params.len() + e.opt_state.len());
        let opt_lits = flat.split_off(e.params.len());
        let metrics = extract_metrics(&e.metrics, &metrics_lits)?;
        Ok(StepOutput {
            params: flat.iter().map(from_literal).collect::<Result<_>>()?,
            opt_state: opt_lits.iter().map(from_literal).collect::<Result<_>>()?,
            metrics,
        })
    }

    fn eval_step(&self, params: &[Tensor], batch: &[Tensor]) -> Result<Metrics> {
        let exe = self.eval.as_ref().context("eval executable not loaded")?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + batch.len());
        for t in params.iter().chain(batch.iter()) {
            inputs.push(to_literal(t)?);
        }
        let out = exe.execute::<xla::Literal>(&inputs)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple()?;
        extract_metrics(&self.entry.metrics, &flat)
    }

    fn features(&self, params: &[Tensor], images: &Tensor) -> Result<Tensor> {
        let exe = self.features.as_ref().context("features executable not loaded")?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(params.len() + 1);
        for t in params {
            inputs.push(to_literal(t)?);
        }
        inputs.push(to_literal(images)?);
        let out = exe.execute::<xla::Literal>(&inputs)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple()?;
        from_literal(&flat[0])
    }
}
